//! Scale-out serving: partition the base set across several (simulated)
//! accelerator shards, fan out queries, merge top-k — then drive the
//! single-shard and sharded services with the Poisson open-loop load
//! generator and compare latency under load (§IV-E scalability story).
//!
//! ```bash
//! cargo run --release --example sharded_scaleout -- --scale 0.03 --shards 4
//! ```

use proxima::config::{GraphParams, PqParams, SearchParams};
use proxima::coordinator::loadgen;
use proxima::coordinator::shard::ShardedService;
use proxima::coordinator::SearchService;
use proxima::dataset::ground_truth::brute_force;
use proxima::dataset::synth::SynthSpec;
use proxima::util::cli::Args;
use std::sync::Arc;
use std::time::Duration;

fn main() -> proxima::util::error::Result<()> {
    let args = Args::from_env(false);
    let name = args.get_or("dataset", "sift-s");
    let scale = args.get_f64("scale", 0.03);
    let n_shards = args.get_usize("shards", 4);
    let k = 10;

    let spec = SynthSpec::by_name(name, scale)
        .ok_or_else(|| proxima::anyhow!("unknown dataset {name}"))?;
    let ds = spec.generate();
    let gp = GraphParams::default();
    let pq = PqParams::for_dim(ds.dim());
    let params = SearchParams::default();

    println!(
        "[shard] building 1-shard and {n_shards}-shard indexes over {} x {}d...",
        ds.n_base(),
        ds.dim()
    );
    let single = ShardedService::build(&ds, 1, &gp, &pq, params);
    let sharded = ShardedService::build(&ds, n_shards, &gp, &pq, params);
    let gt = brute_force(&ds, k);

    // Recall parity check.
    let recall = |sh: &ShardedService| {
        let mut r = 0.0;
        for qi in 0..ds.n_queries() {
            let out = sh.search(ds.queries.row(qi), k);
            r += proxima::dataset::recall_at_k(&out.ids, gt.row(qi), k);
        }
        r / ds.n_queries() as f64
    };
    let r1 = recall(&single);
    let rn = recall(&sharded);
    println!("[shard] recall@{k}: 1 shard {r1:.4}  |  {n_shards} shards {rn:.4}");

    // Load test the single-shard service through the load generator.
    let svc: Arc<SearchService> = Arc::new(
        SearchService::build(&ds, &gp, &pq, params, false),
    );
    println!("\n{:<12} {:>10} {:>10} {:>10} {:>10} {:>6}", "offered", "achieved", "p50", "p95", "p99", "late");
    for target in [200.0, 1000.0, 4000.0] {
        let rep = loadgen::run(
            svc.clone(),
            &ds.queries,
            k,
            target,
            Duration::from_millis(800),
            2,
            7,
        );
        println!(
            "{:<12} {:>10.0} {:>9.0}u {:>9.0}u {:>9.0}u {:>6}",
            format!("{target} QPS"),
            rep.achieved_qps,
            rep.p50_us,
            rep.p95_us,
            rep.p99_us,
            rep.late_starts
        );
    }
    assert!(rn >= r1 - 0.05, "sharded recall regressed: {r1} -> {rn}");
    println!("sharded_scaleout OK");
    Ok(())
}
