//! Drive the 3D NAND near-storage accelerator simulator on a real search
//! workload: collect Proxima traces from the software, replay them through
//! the DES with and without hot-node repetition, and print the latency/
//! energy/utilization story of paper §V-C/D.
//!
//! ```bash
//! cargo run --release --example accelerator_sim -- --dataset sift-s --scale 0.03
//! ```

use proxima::engine::{sim, EngineConfig};
use proxima::figures::{self, Workbench};
use proxima::nand::timing::TimingModel;
use proxima::nand::NandConfig;
use proxima::util::cli::Args;

fn main() -> proxima::util::error::Result<()> {
    let args = Args::from_env(false);
    let name = args.get_or("dataset", "sift-s");
    let scale = args.get_f64("scale", 0.03);
    let l = args.get_usize("l", 100);

    // Device summary.
    let nand = NandConfig::proxima();
    let timing = TimingModel::default();
    println!("=== Proxima accelerator configuration ===");
    println!(
        "3D NAND: {} tiles x {} cores, {:.0} Gb total, {} B granularity",
        nand.n_tiles,
        nand.cores_per_tile,
        nand.total_bits() as f64 / (1u64 << 30) as f64,
        nand.granularity_bytes()
    );
    println!(
        "core read latency {:.0} ns (commodity SSD page: {:.1} us)",
        timing.read_latency_ns(&nand),
        timing.read_latency_ns(&NandConfig::commodity_ssd()) / 1000.0
    );

    println!("\n[sim] building workload ({name} @ scale {scale})...");
    let w = Workbench::get(name, scale, 10);
    let cfg = EngineConfig::paper(w.ds.dim(), w.codebook.m);

    // Cold mapping (no hot nodes).
    let (traces, stats) = figures::collect_traces(&w, figures::Algo::Proxima, l, 10);
    let per_q = figures::per_query(&stats, w.ds.n_queries());
    println!(
        "[sim] workload: {} queries, per-query {} hops / {} pq dists / {:.1} KB traffic",
        traces.len(),
        per_q.hops,
        per_q.pq_dists,
        per_q.total_bytes() as f64 / 1024.0
    );
    let cold = sim::simulate(&cfg, &figures::default_mapping(&w, 0.0), &traces);

    // Hot mapping (3% hot nodes on the frequency-reordered index).
    let hot_traces = figures::fig13::proxima_hot_traces(&w, l, 10, 0.03);
    let hot = sim::simulate(&cfg, &figures::default_mapping(&w, 0.03), &hot_traces);

    println!("\n=== DES results ===");
    for (tag, r) in [("no hot nodes", &cold), ("3% hot nodes", &hot)] {
        println!(
            "{tag:>14}: {:.0} QPS | {:.1} us mean latency | {:.1} QPS/W | core util {:.1}% | {} same-page reads",
            r.qps,
            r.mean_latency_ns / 1000.0,
            r.qps_per_watt,
            r.core_utilization * 100.0,
            r.same_page_reads
        );
        let b = &r.breakdown;
        let total = b.total().max(1e-9);
        println!(
            "{:>14}  breakdown: nand {:.0}% bus {:.0}% compute {:.0}% sort {:.0}% adt {:.0}%",
            "",
            100.0 * b.nand_ns / total,
            100.0 * b.bus_ns / total,
            100.0 * b.compute_ns / total,
            100.0 * b.sort_ns / total,
            100.0 * b.adt_ns / total
        );
    }
    let speedup = cold.mean_latency_ns / hot.mean_latency_ns;
    println!("\nhot-node latency reduction: {speedup:.2}x (paper: ~3x at 3%)");
    println!("accelerator_sim OK");
    Ok(())
}
