//! ECC-free reliability study (paper §V-E / Fig 17): inject raw bit errors
//! into every stored representation (PQ codes, gap-encoded indices, raw
//! vectors) at SLC/MLC/TLC rates and report the recall impact.
//!
//! ```bash
//! cargo run --release --example error_resilience -- --dataset sift-s --scale 0.03
//! ```

use proxima::error_model::ber;
use proxima::figures::{fig17, Workbench};
use proxima::util::cli::Args;

fn main() -> proxima::util::error::Result<()> {
    let args = Args::from_env(false);
    let name = args.get_or("dataset", "sift-s");
    let scale = args.get_f64("scale", 0.03);

    let w = Workbench::get(name, scale, 10);
    println!(
        "dataset {}: {} vectors; SLC raw BER < 1e-5, MLC > 1e-4 (paper cites [29],[49],[54])\n",
        w.ds.name,
        w.ds.n_base()
    );

    let clean = fig17::recall_at_ber(&w, 0.0, 0);
    println!("{:<12} {:>10} {:>10}", "cell type", "BER", "recall@10");
    for (tag, rate) in [
        ("clean", 0.0),
        ("SLC", ber::SLC),
        ("MLC", ber::MLC),
        ("TLC", ber::TLC),
        ("1e-3", 1e-3),
        ("1e-2", 1e-2),
    ] {
        let r = fig17::recall_at_ber(&w, rate, 42);
        println!(
            "{tag:<12} {rate:>10.0e} {r:>10.4}   ({:+.4} vs clean)",
            r - clean
        );
    }
    let slc = fig17::recall_at_ber(&w, ber::SLC, 42);
    println!(
        "\nSLC recall loss: {:.2}% -> ECC-free SLC design is {} (paper: <3% loss at 1e-4)",
        100.0 * (clean - slc),
        if clean - slc < 0.03 { "viable" } else { "NOT viable" }
    );
    println!("error_resilience OK");
    Ok(())
}
