//! End-to-end serving driver (the EXPERIMENTS.md §End-to-end run): builds
//! the index, starts the TCP coordinator (router → dynamic batcher →
//! worker pool, ADTs through the AOT/XLA runtime when present), then
//! drives it two ways — one-query-per-round-trip v1 clients, and the v2
//! batch RPC (N queries per round-trip) — and reports recall, throughput
//! and the latency distribution for both, so the round-trip amortization
//! is visible in one run.
//!
//! ```bash
//! cargo run --release --example serve_queries -- --scale 0.05 --clients 4 --requests 400 --batch 8
//! ```
//!
//! # The serving API
//!
//! Everything below goes through the typed, versioned query API
//! (`proxima::api`): a [`proxima::api::QueryRequest`] carries N query
//! vectors, `k`, and per-request [`proxima::api::QueryOptions`]
//! (`mode` accurate/pq_adt/hybrid, `l_override`, `early_term_tau`,
//! `rerank`, `want_stats`); the answer is a
//! [`proxima::api::QueryResponse`] with one `NeighborList` per query, or
//! a structured `ApiError` (`bad_request` / `dim_mismatch` / `closed` /
//! `internal`). The SAME contract serves:
//!
//! * in-process calls — `SearchService::query(&req)`;
//! * the dynamic batcher — each queued request keeps its own options;
//! * the TCP wire — `Client::search` (v1 compat, single query) and
//!   `Client::search_batch` (v2: N queries in ONE round-trip);
//! * the binary plane — [`proxima::net::BinClient`] speaks the
//!   length-prefixed PXW3 frame format on the SAME port (the server
//!   sniffs the first byte) and pipelines: many request ids in flight
//!   on one connection, answers matched back by id.
//!
//! # The index lifecycle
//!
//! The built index is not trapped in this process: the final phase
//! below saves it as a versioned, checksummed artifact
//! (`SearchService::save`), inspects the running server with the v2
//! admin plane (`Client::status` → spec + provenance + counters), and
//! hot-swaps the served index from the artifact (`Client::reload`)
//! without dropping the connection — the epoch-cell swap lets in-flight
//! queries finish on the old index while new requests hit the reloaded
//! one. In production the phases split across processes:
//!
//! ```text
//! proxima build --dataset sift-s --index data/sift-s.pxa    # once
//! proxima serve --index data/sift-s.pxa --port 7878         # per replica
//! {"v":2,"op":"status"}  /  {"v":2,"op":"reload","path":...}  # operate
//! ```
//!
//! The served index is also MUTABLE over the same wire: the final phase
//! drives the v2 write plane (`{"v":2,"op":"insert"|"delete"|"flush"}`)
//! — insert a vector and find it immediately, tombstone it and watch it
//! vanish from results, then `flush` a compacted artifact back to disk
//! and hot-swap onto it, all while the connection keeps answering
//! queries.
//!
//! # The execution model behind the wire
//!
//! Every batch — a v2 multi-query line, a batcher flush, a shard
//! fan-out — executes on ONE persistent work-stealing pool
//! (`proxima::exec::ExecPool`, shared process-wide; no per-request
//! thread spawning) as a staged pipeline: first a batched,
//! DEDUPLICATED ADT-build pass (repeated query vectors in a batch share
//! one table — the `adt_builds` stat counts distinct builds), then one
//! work-stealing task per query (a heavy `l_override` query no longer
//! idles its batch-mates the way contiguous chunking did). With
//! `want_stats`, the response stats also report `queue_wait_us` — the
//! total time the batch's queries sat in the pool queue before a lane
//! picked them up, the serving-side congestion signal. A query whose
//! worker task panics comes back as an inline `{"error":...}` entry in
//! its own result slot; batch-mates are answered normally.
//!
//! Wire shapes are documented at the top of `coordinator::server`.

use proxima::api::QueryOptions;
use proxima::config::{GraphParams, PqParams, SearchParams};
use proxima::coordinator::batcher::{spawn, BatchPolicy};
use proxima::coordinator::server::Client;
use proxima::net::{BinClient, NetConfig, NetServer};
use proxima::coordinator::{loadgen, SearchService, ServiceCell};
use proxima::dataset::ground_truth::brute_force;
use proxima::dataset::synth::SynthSpec;
use proxima::util::cli::Args;
use proxima::util::json::Json;
use std::sync::Arc;

fn main() -> proxima::util::error::Result<()> {
    let args = Args::from_env(false);
    let name = args.get_or("dataset", "sift-s");
    let scale = args.get_f64("scale", 0.05);
    let clients = args.get_usize("clients", 4);
    let total_requests = args.get_usize("requests", 400);
    let k = args.get_usize("k", 10);
    let batch = args.get_usize("batch", 8).max(1);

    let spec = SynthSpec::by_name(name, scale)
        .ok_or_else(|| proxima::anyhow!("unknown dataset {name}"))?;
    let ds = spec.generate();
    println!(
        "[serve] building index over {} x {}d ({})...",
        ds.n_base(),
        ds.dim(),
        ds.metric.name()
    );
    let svc = Arc::new(SearchService::build(
        &ds,
        &GraphParams::default(),
        &PqParams::for_dim(ds.dim()),
        SearchParams::default(),
        true,
    ));
    println!("[serve] XLA runtime attached: {}", svc.runtime.is_some());
    let gt = brute_force(&ds, k);

    let cell = Arc::new(ServiceCell::new(svc.clone()));
    let (handle, _join) = spawn(
        cell.clone(),
        BatchPolicy {
            max_batch: 16,
            max_wait: std::time::Duration::from_millis(2),
        },
    );
    let server = NetServer::start(cell, handle, NetConfig::default())?;
    println!(
        "[serve] listening on {} (JSON + PXW3 binary planes, one port)",
        server.addr
    );

    // Closed-loop clients.
    let addr = server.addr;
    let t0 = std::time::Instant::now();
    let per_client = total_requests / clients;
    let results: Vec<(Vec<f64>, f64)> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..clients {
            let ds = &ds;
            let gt = &gt;
            handles.push(scope.spawn(move || {
                let mut client = Client::connect(addr).expect("connect");
                let mut lats = Vec::with_capacity(per_client);
                let mut recall = 0.0;
                for i in 0..per_client {
                    let qi = (c * per_client + i) % ds.n_queries();
                    let t = std::time::Instant::now();
                    let (ids, _dists, _server_lat) =
                        client.search(ds.queries.row(qi), k).expect("search");
                    lats.push(t.elapsed().as_secs_f64() * 1e6);
                    recall += proxima::dataset::recall_at_k(&ids, gt.row(qi), k);
                }
                (lats, recall / per_client as f64)
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall = t0.elapsed().as_secs_f64();

    let mut all_lats: Vec<f64> = results.iter().flat_map(|(l, _)| l.clone()).collect();
    let recall: f64 = results.iter().map(|(_, r)| r).sum::<f64>() / clients as f64;
    all_lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let served = all_lats.len();
    let pct = |p: f64| all_lats[((served - 1) as f64 * p) as usize];

    println!("\n=== end-to-end serving results ===");
    println!("requests served     : {served}");
    println!("concurrent clients  : {clients}");
    println!("throughput          : {:.0} QPS", served as f64 / wall);
    println!("recall@{k}          : {recall:.4}");
    println!(
        "latency p50/p95/p99 : {:.0} / {:.0} / {:.0} us",
        pct(0.50),
        pct(0.95),
        pct(0.99)
    );
    println!(
        "early-terminated    : {:.0}%",
        100.0 * svc.stats.early_terminated.load(std::sync::atomic::Ordering::Relaxed) as f64
            / served as f64
    );

    // --- The v2 batch RPC: the same query budget, but `batch` queries
    // per round-trip, so closed-loop QPS reflects amortized round-trips.
    let rpc_requests = (total_requests / (clients * batch)).max(1);
    let rep = loadgen::run_rpc(
        addr,
        &ds.queries,
        k,
        QueryOptions::default(),
        batch,
        clients,
        rpc_requests,
    )?;
    println!("\n=== v2 batch RPC ({batch} queries / round-trip) ===");
    println!("round-trips         : {}", rep.round_trips);
    println!("queries served      : {}", rep.queries);
    println!("throughput          : {:.0} QPS", rep.qps);
    println!(
        "round-trip p50/p99  : {:.0} / {:.0} us  ({:.0} us/query at p50)",
        rep.p50_us,
        rep.p99_us,
        rep.p50_us / batch as f64
    );

    // --- The binary plane (PXW3) on the SAME port: length-prefixed
    // frames instead of JSON lines, matched back by request id, so one
    // connection can hold many requests in flight. Serial round-trips
    // first, then the identical queries pipelined `depth` deep — same
    // answers, fewer round-trip stalls.
    let depth = batch.max(4).min(ds.n_queries());
    let mut bin = BinClient::connect(addr)?;
    let t = std::time::Instant::now();
    let mut serial = Vec::with_capacity(depth);
    for qi in 0..depth {
        let req = proxima::api::QueryRequest::single(ds.queries.row(qi), k);
        let resp = bin
            .query(&req)?
            .map_err(|e| proxima::anyhow!("binary query failed: {}", e.message))?;
        serial.push(resp);
    }
    let serial_us = t.elapsed().as_secs_f64() * 1e6;
    let t = std::time::Instant::now();
    let mut in_flight = std::collections::HashMap::new();
    for qi in 0..depth {
        let req = proxima::api::QueryRequest::single(ds.queries.row(qi), k);
        in_flight.insert(bin.send_query(&req, 0)?, qi);
    }
    let mut pipelined: Vec<Option<proxima::api::QueryResponse>> = vec![None; depth];
    while !in_flight.is_empty() {
        let (rid, outcome) = bin.recv()?;
        let qi = in_flight
            .remove(&rid)
            .ok_or_else(|| proxima::anyhow!("unexpected response id {rid}"))?;
        match outcome {
            Ok(proxima::net::frame::FrameBody::QueryOk { response }) => {
                pipelined[qi] = Some(response);
            }
            Ok(_) => proxima::bail!("pipelined query {qi}: non-query response"),
            Err(e) => proxima::bail!("pipelined query {qi} failed: {}", e.message),
        }
    }
    let pipelined_us = t.elapsed().as_secs_f64() * 1e6;
    println!("\n=== binary plane (PXW3 frames, {depth} in flight) ===");
    println!("serial round-trips  : {serial_us:.0} us total");
    println!(
        "pipelined           : {pipelined_us:.0} us total ({:.1}x)",
        serial_us / pipelined_us.max(1.0)
    );
    for (qi, resp) in pipelined.iter().enumerate() {
        let resp = resp.as_ref().expect("every in-flight id must be answered");
        assert_eq!(
            resp.results, serial[qi].results,
            "pipelined answers must match serial answers bitwise"
        );
    }
    println!("pipelining parity   : {depth} in-flight answers match serial round-trips");

    // Open-loop Poisson sweep on the binary plane: offered load is set
    // by the arrival schedule, not by round-trip completion, so the
    // knee — the highest offered rate still achieved (≥90%) without
    // shedding (≤1%) — is visible instead of hidden by closed-loop
    // self-throttling. The `wire_knee` line is the machine-readable
    // record EXPERIMENTS.md tracks; `json_qps` is the closed-loop v1
    // figure from the first phase, same queries, same k.
    let json_qps = served as f64 / wall;
    let rates = [500.0, 1000.0, 2000.0, 4000.0];
    let sweep = loadgen::sweep_open(
        addr,
        &ds.queries,
        k,
        &rates,
        std::time::Duration::from_millis(400),
        77,
    )?;
    println!("\n=== open-loop sweep (binary plane, Poisson arrivals) ===");
    for r in &sweep {
        println!(
            "offered={:>6.0} qps : achieved={:>6.0} shed={} errors={} p50/p99={:.0}/{:.0} us",
            r.offered_qps, r.achieved_qps, r.shed, r.errors, r.p50_us, r.p99_us
        );
    }
    let knee_qps = loadgen::knee(&sweep).unwrap_or(0.0);
    let binary_qps = sweep
        .iter()
        .filter(|r| r.offered_qps == knee_qps)
        .map(|r| r.achieved_qps)
        .next()
        .unwrap_or(0.0);
    println!(
        "wire_knee rates={:?} knee_qps={:.0} binary_qps={:.0} json_qps={:.0} speedup={:.2}",
        rates,
        knee_qps,
        binary_qps,
        json_qps,
        binary_qps / json_qps.max(1.0)
    );

    // --- Per-request options through the same contract: a stats-bearing
    // high-accuracy request vs the service default.
    let mut c = Client::connect(addr)?;
    let probe: Vec<&[f32]> = (0..batch.min(ds.n_queries())).map(|i| ds.queries.row(i)).collect();
    let deflt = c.search_batch(
        &probe,
        k,
        &QueryOptions {
            want_stats: true,
            ..Default::default()
        },
    )?;
    let wide = c.search_batch(
        &probe,
        k,
        &QueryOptions {
            l_override: Some(2 * SearchParams::default().l),
            early_term_tau: Some(0),
            want_stats: true,
            ..Default::default()
        },
    )?;
    let (sd, sw) = (deflt.stats.unwrap(), wide.stats.unwrap());
    println!("\n=== per-request options (same wire, same contract) ===");
    println!(
        "default options     : {} PQ dists, {} exact, {} ADT builds, {} us queued, {} us server",
        sd.pq_dists, sd.exact_dists, sd.adt_builds, sd.queue_wait_us, deflt.server_latency_us
    );
    println!(
        "2L + no early-term  : {} PQ dists, {} exact, {} ADT builds, {} us queued, {} us server",
        sw.pq_dists, sw.exact_dists, sw.adt_builds, sw.queue_wait_us, wide.server_latency_us
    );
    assert!(
        sw.pq_dists > sd.pq_dists,
        "a wider list must do more PQ work"
    );

    // --- Index lifecycle over the same wire: save the built index as an
    // artifact, inspect the server, hot-swap to the artifact.
    let art_path = std::env::temp_dir().join(format!("serve-queries-{}.pxa", std::process::id()));
    svc.save(&art_path)?;
    let bytes = std::fs::metadata(&art_path).map(|m| m.len()).unwrap_or(0);
    println!("\n=== index lifecycle (save -> status -> reload) ===");
    println!("artifact            : {} ({bytes} bytes)", art_path.display());

    let status = c.status()?;
    let source = |s: &Json| {
        s.get("provenance")
            .and_then(|p| p.get("source"))
            .and_then(Json::as_str)
            .unwrap_or("?")
            .to_string()
    };
    println!(
        "status before reload: dataset={} provenance={}",
        status
            .get("spec")
            .and_then(|s| s.get("dataset"))
            .and_then(Json::as_str)
            .unwrap_or("?"),
        source(&status)
    );
    assert_eq!(source(&status), "built");

    // Hot-swap: the server opens the artifact (checksum-verified) and
    // swaps its epoch cell; the connection stays up throughout.
    c.reload(&art_path.display().to_string())?;
    let status = c.status()?;
    println!("status after reload : provenance={}", source(&status));
    assert_eq!(source(&status), "artifact");
    let probe_q = ds.queries.row(0);
    let before = svc.search(probe_q, k);
    let after = c.search_with_options(probe_q, k, &QueryOptions::default())?;
    assert_eq!(
        after.results[0].ids, before.ids,
        "the reopened artifact must answer exactly like the built index"
    );
    println!("reload parity       : artifact answers match the built index");

    // --- Storage tiers over the same wire: reload the SAME artifact
    // with the COLD residency (raw vectors served in place from the
    // file, OS page cache as the cold tier) and watch the status
    // counters move. `resident_bytes` drops to 0 — serving DRAM no
    // longer scales with n_base — and `cold_reads`/`cold_bytes` meter
    // every rerank fetch that hits the file.
    use proxima::storage::Residency;
    c.reload_opts(&art_path.display().to_string(), Some(Residency::Cold))?;
    let storage_of = |s: &Json, key: &str| {
        s.get("storage")
            .and_then(|st| st.get(key))
            .and_then(Json::as_f64)
            .unwrap_or(-1.0)
    };
    let status = c.status()?;
    println!("\n=== tiered storage (cold reload -> residency counters) ===");
    println!(
        "after cold reload   : residency={} resident_bytes={} cold_reads={}",
        status
            .get("storage")
            .and_then(|st| st.get("residency"))
            .and_then(Json::as_str)
            .unwrap_or("?"),
        storage_of(&status, "resident_bytes"),
        storage_of(&status, "cold_reads"),
    );
    assert_eq!(storage_of(&status, "resident_bytes"), 0.0);
    assert_eq!(storage_of(&status, "cold_reads"), 0.0, "fresh epoch, no reads yet");
    let cold_resp = c.search_batch(
        &probe,
        k,
        &QueryOptions {
            want_stats: true,
            ..Default::default()
        },
    )?;
    assert_eq!(
        cold_resp.results[0].ids, before.ids,
        "cold serving must answer exactly like resident serving"
    );
    let cs = cold_resp.stats.unwrap();
    let status = c.status()?;
    println!(
        "after {} queries     : cold_reads={} cold_bytes={} (per-batch stats: {} reads)",
        probe.len(),
        storage_of(&status, "cold_reads"),
        storage_of(&status, "cold_bytes"),
        cs.cold_reads
    );
    assert!(cs.cold_reads > 0, "cold serving must meter its file reads");
    assert!(storage_of(&status, "cold_reads") >= cs.cold_reads as f64);
    println!("cold parity         : in-place file serving matches resident answers");

    // --- The adaptive hot set over the same wire: reload the SAME
    // artifact with the CACHED residency (S3-FIFO cold-row cache, 4 MiB
    // here) and repeat a fixed workload — the status storage block now
    // carries the cache counters, and the cumulative hit_rate climbs as
    // the hot rows settle into the arena. The typed decode
    // (`wire::decode_storage_status`) is forward-compatible: unknown
    // keys are ignored, absent cache keys mean "no cache attached".
    use proxima::api::wire::decode_storage_status;
    use proxima::storage::cache::{CachePolicy, DEFAULT_CACHE_BYTES};
    c.reload_with(
        &art_path.display().to_string(),
        Some(Residency::Cached {
            capacity_bytes: DEFAULT_CACHE_BYTES,
        }),
        Some(4), // --cache_mb 4 overrides the default capacity
        Some(CachePolicy::S3Fifo),
        None,
    )?;
    println!("\n=== adaptive hot set (cached reload -> hit_rate climbs) ===");
    let decode = |c: &mut Client| {
        let s = c.status().expect("status");
        decode_storage_status(s.get("storage").expect("storage block"))
    };
    let st0 = decode(&mut c);
    assert_eq!(st0.residency, "cached");
    let cache0 = st0.cache.expect("cached residency must report its cache");
    assert_eq!(cache0.policy, "s3fifo");
    assert_eq!(cache0.capacity_bytes, 4 << 20);
    assert_eq!(cache0.hit_rate, 0.0, "fresh epoch, no lookups yet");
    let mut last_rate = 0.0;
    for round in 1..=3 {
        let resp = c.search_batch(
            &probe,
            k,
            &QueryOptions {
                want_stats: true,
                ..Default::default()
            },
        )?;
        assert_eq!(
            resp.results[0].ids, before.ids,
            "cached serving must answer exactly like resident serving"
        );
        let rs = resp.stats.unwrap();
        assert!(
            rs.cache_hits + rs.cache_misses > 0,
            "cached serving must route rerank fetches through the cache"
        );
        let cache = decode(&mut c).cache.expect("cache block");
        println!(
            "round {round}             : batch hits={} misses={} cumulative hit_rate={:.3}",
            rs.cache_hits, rs.cache_misses, cache.hit_rate
        );
        assert!(
            cache.hit_rate >= last_rate,
            "a repeated workload must not cool the cache: {} < {last_rate}",
            cache.hit_rate
        );
        last_rate = cache.hit_rate;
    }
    assert!(
        last_rate > 0.5,
        "after three identical rounds most lookups must hit: {last_rate}"
    );
    println!("cached parity       : S3-FIFO serving matches resident answers, hit_rate={last_rate:.3}");

    // --- Online updates over the same wire: insert → query → delete →
    // flush. Writers serialize behind a single-writer queue and publish
    // epoch snapshots; queries pin one snapshot per walk and never block
    // on a writer. `flush` (no path) compacts back to the artifact the
    // served index was opened from and hot-swaps the successor — the
    // write is atomic (temp + rename), so the old epoch keeps serving
    // its inode until its last in-flight query completes.
    println!("\n=== online updates (insert -> query -> delete -> flush) ===");
    let (new_id, epoch) = c.insert(probe_q)?;
    println!("insert              : id={new_id} epoch={epoch}");
    let found = c.search_with_options(probe_q, 1, &QueryOptions::default())?;
    assert_eq!(
        found.results[0].ids,
        vec![new_id],
        "an insert must be findable the moment it returns"
    );
    let (deleted, epoch) = c.delete(new_id)?;
    assert!(deleted);
    println!("delete              : id={new_id} epoch={epoch} (tombstoned, still traversable)");
    let gone = c.search_with_options(probe_q, k, &QueryOptions::default())?;
    assert!(
        !gone.results[0].ids.contains(&new_id),
        "a delete must be excluded the moment it returns"
    );
    let flushed = c.flush(None)?;
    println!(
        "flush               : path={} n_live={} epoch={}",
        flushed.get("path").and_then(Json::as_str).unwrap_or("?"),
        flushed.get("n_live").and_then(Json::as_f64).unwrap_or(-1.0),
        flushed.get("epoch").and_then(Json::as_f64).unwrap_or(-1.0),
    );
    let status = c.status()?;
    let online_of = |s: &Json, key: &str| {
        s.get("online")
            .and_then(|o| o.get(key))
            .and_then(Json::as_f64)
            .unwrap_or(-1.0)
    };
    assert_eq!(
        online_of(&status, "n_tombstoned"),
        0.0,
        "flush compacts tombstones away"
    );
    assert_eq!(online_of(&status, "inserts_total"), 1.0);
    assert_eq!(online_of(&status, "deletes_total"), 1.0);
    assert_eq!(online_of(&status, "flushes_total"), 1.0);
    let after_flush = c.search_with_options(probe_q, k, &QueryOptions::default())?;
    let flush_recall = proxima::dataset::recall_at_k(&after_flush.results[0].ids, gt.row(0), k);
    println!("post-flush recall@{k}: {flush_recall:.2} (exact ground truth, all base ids survived)");
    assert!(
        flush_recall >= 0.6,
        "compaction must not crater graph quality: {flush_recall}"
    );
    std::fs::remove_file(&art_path).ok();

    // Shut down cleanly.
    c.shutdown().ok();
    server.stop();
    assert!(recall > 0.7, "serving recall sanity failed: {recall}");
    println!("serve_queries OK");
    Ok(())
}
