//! End-to-end serving driver (the EXPERIMENTS.md §End-to-end run): builds
//! the index, starts the TCP coordinator (router → dynamic batcher →
//! worker pool, ADTs through the AOT/XLA runtime when present), then
//! drives it with concurrent closed-loop clients and reports recall,
//! throughput and the latency distribution.
//!
//! ```bash
//! cargo run --release --example serve_queries -- --scale 0.05 --clients 4 --requests 400
//! ```

use proxima::config::{GraphParams, PqParams, SearchParams};
use proxima::coordinator::batcher::{spawn, BatchPolicy};
use proxima::coordinator::server::{Client, Server};
use proxima::coordinator::SearchService;
use proxima::dataset::ground_truth::brute_force;
use proxima::dataset::synth::SynthSpec;
use proxima::util::cli::Args;
use std::sync::Arc;

fn main() -> proxima::util::error::Result<()> {
    let args = Args::from_env(false);
    let name = args.get_or("dataset", "sift-s");
    let scale = args.get_f64("scale", 0.05);
    let clients = args.get_usize("clients", 4);
    let total_requests = args.get_usize("requests", 400);
    let k = args.get_usize("k", 10);

    let spec = SynthSpec::by_name(name, scale)
        .ok_or_else(|| proxima::anyhow!("unknown dataset {name}"))?;
    let ds = spec.generate();
    println!(
        "[serve] building index over {} x {}d ({})...",
        ds.n_base(),
        ds.dim(),
        ds.metric.name()
    );
    let svc = Arc::new(SearchService::build(
        &ds,
        &GraphParams::default(),
        &PqParams::for_dim(ds.dim()),
        SearchParams::default(),
        true,
    ));
    println!("[serve] XLA runtime attached: {}", svc.runtime.is_some());
    let gt = brute_force(&ds, k);

    let (handle, _join) = spawn(
        svc.clone(),
        BatchPolicy {
            max_batch: 16,
            max_wait: std::time::Duration::from_millis(2),
        },
        2,
    );
    let server = Server::start(svc.clone(), handle, 0)?;
    println!("[serve] listening on {}", server.addr);

    // Closed-loop clients.
    let addr = server.addr;
    let t0 = std::time::Instant::now();
    let per_client = total_requests / clients;
    let results: Vec<(Vec<f64>, f64)> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..clients {
            let ds = &ds;
            let gt = &gt;
            handles.push(scope.spawn(move || {
                let mut client = Client::connect(addr).expect("connect");
                let mut lats = Vec::with_capacity(per_client);
                let mut recall = 0.0;
                for i in 0..per_client {
                    let qi = (c * per_client + i) % ds.n_queries();
                    let t = std::time::Instant::now();
                    let (ids, _dists, _server_lat) =
                        client.search(ds.queries.row(qi), k).expect("search");
                    lats.push(t.elapsed().as_secs_f64() * 1e6);
                    recall += proxima::dataset::recall_at_k(&ids, gt.row(qi), k);
                }
                (lats, recall / per_client as f64)
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall = t0.elapsed().as_secs_f64();

    let mut all_lats: Vec<f64> = results.iter().flat_map(|(l, _)| l.clone()).collect();
    let recall: f64 = results.iter().map(|(_, r)| r).sum::<f64>() / clients as f64;
    all_lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let served = all_lats.len();
    let pct = |p: f64| all_lats[((served - 1) as f64 * p) as usize];

    println!("\n=== end-to-end serving results ===");
    println!("requests served     : {served}");
    println!("concurrent clients  : {clients}");
    println!("throughput          : {:.0} QPS", served as f64 / wall);
    println!("recall@{k}          : {recall:.4}");
    println!(
        "latency p50/p95/p99 : {:.0} / {:.0} / {:.0} us",
        pct(0.50),
        pct(0.95),
        pct(0.99)
    );
    println!(
        "early-terminated    : {:.0}%",
        100.0 * svc.stats.early_terminated.load(std::sync::atomic::Ordering::Relaxed) as f64
            / served as f64
    );

    // Shut down cleanly.
    let mut c = Client::connect(addr)?;
    c.shutdown().ok();
    server.stop();
    assert!(recall > 0.7, "serving recall sanity failed: {recall}");
    println!("serve_queries OK");
    Ok(())
}
