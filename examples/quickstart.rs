//! Quickstart: generate a synthetic dataset, build the full Proxima index
//! stack (Vamana graph + PQ + gap encoding), run Algorithm 1, and report
//! recall/QPS — the 60-second tour of the public API.
//!
//! ```bash
//! cargo run --release --example quickstart -- --dataset sift-s --scale 0.05
//! ```

use proxima::config::{GraphParams, PqParams, SearchParams};
use proxima::coordinator::SearchService;
use proxima::dataset::ground_truth::brute_force;
use proxima::dataset::synth::SynthSpec;
use proxima::util::cli::Args;

fn main() -> proxima::util::error::Result<()> {
    let args = Args::from_env(false);
    let name = args.get_or("dataset", "sift-s");
    let scale = args.get_f64("scale", 0.05);
    let k = args.get_usize("k", 10);

    // 1. Synthesize a Table I-style dataset.
    let spec = SynthSpec::by_name(name, scale)
        .ok_or_else(|| proxima::anyhow!("unknown dataset {name}"))?;
    let ds = spec.generate();
    println!(
        "dataset {}: {} base vectors, dim {}, metric {}",
        ds.name,
        ds.n_base(),
        ds.dim(),
        ds.metric.name()
    );

    // 2. Build the index stack (graph + PQ + gap encoding). `true` attaches
    //    the AOT/XLA runtime when artifacts/ exists.
    let t0 = std::time::Instant::now();
    let svc = SearchService::build(
        &ds,
        &GraphParams::default(),
        &PqParams::for_dim(ds.dim()),
        SearchParams::default(),
        true,
    );
    println!(
        "index built in {:.1}s ({} edges, XLA runtime: {})",
        t0.elapsed().as_secs_f64(),
        svc.graph.n_edges(),
        svc.runtime.is_some()
    );

    // 3. Exact ground truth for scoring.
    let gt = brute_force(&ds, k);

    // 4. Search all queries.
    let t0 = std::time::Instant::now();
    let mut recall = 0.0;
    for qi in 0..ds.n_queries() {
        let out = svc.search(ds.queries.row(qi), k);
        recall += proxima::dataset::recall_at_k(&out.ids, gt.row(qi), k);
    }
    let secs = t0.elapsed().as_secs_f64();
    recall /= ds.n_queries() as f64;

    println!(
        "recall@{k} = {recall:.4}  |  {:.0} QPS  |  mean latency {:.0} us  |  early-term rate {:.0}%",
        ds.n_queries() as f64 / secs,
        svc.mean_latency_us(),
        100.0 * svc.stats.early_terminated.load(std::sync::atomic::Ordering::Relaxed) as f64
            / ds.n_queries() as f64
    );
    assert!(recall > 0.7, "quickstart recall sanity failed: {recall}");

    // 5. The batch API: the same queries as per-query tasks on the
    //    persistent work-stealing exec pool, after one staged
    //    (deduplicated) ADT-build pass — the serving hot path.
    let qrefs: Vec<&[f32]> = (0..ds.n_queries()).map(|i| ds.queries.row(i)).collect();
    let t0 = std::time::Instant::now();
    let outs = svc.search_batch(&qrefs, k);
    let batch_secs = t0.elapsed().as_secs_f64();
    let batch_recall: f64 = outs
        .iter()
        .enumerate()
        .map(|(qi, o)| proxima::dataset::recall_at_k(&o.ids, gt.row(qi), k))
        .sum::<f64>()
        / outs.len() as f64;
    println!(
        "search_batch: {} queries on {} workers  |  {:.0} QPS ({:.1}x serial)  |  recall {batch_recall:.4}",
        outs.len(),
        svc.workers,
        outs.len() as f64 / batch_secs,
        secs / batch_secs,
    );
    assert_eq!(outs.len(), ds.n_queries());

    println!("quickstart OK");
    Ok(())
}
