"""L2 model shape checks and AOT lowering smoke tests."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def test_gt_fn_l2_matches_bruteforce():
    rng = np.random.default_rng(1)
    qs = jnp.asarray(rng.standard_normal((3, 8)), dtype=jnp.float32)
    xs = jnp.asarray(rng.standard_normal((20, 8)), dtype=jnp.float32)
    fn = model.make_gt_fn("l2", 8, 3, 20)
    (out,) = fn(qs, xs)
    naive = np.array(
        [[np.sum((np.array(q) - np.array(x)) ** 2) for x in xs] for q in qs]
    )
    np.testing.assert_allclose(out, naive, rtol=1e-3, atol=1e-3)


def test_gt_fn_ip():
    rng = np.random.default_rng(2)
    qs = jnp.asarray(rng.standard_normal((2, 4)), dtype=jnp.float32)
    xs = jnp.asarray(rng.standard_normal((5, 4)), dtype=jnp.float32)
    fn = model.make_gt_fn("ip", 4, 2, 5)
    (out,) = fn(qs, xs)
    np.testing.assert_allclose(out, -(np.array(qs) @ np.array(xs).T), rtol=1e-5)


def test_adt_fn_shapes():
    fn = model.make_adt_fn("l2", 4, 16, 3)
    q = jnp.zeros(12, dtype=jnp.float32)
    cb = jnp.zeros((4, 16, 3), dtype=jnp.float32)
    (adt,) = fn(q, cb)
    assert adt.shape == (4, 16)
    assert adt.dtype == jnp.float32


def test_lowering_produces_hlo_text():
    """Every artifact entry must lower to parseable HLO text."""
    seen = set()
    for name, fn, args, meta in aot.build_entries():
        assert name not in seen, f"duplicate artifact name {name}"
        seen.add(name)
        # Lower the smallest dim only to keep the test fast.
        if meta.get("dim", 96) != 96:
            continue
        lowered = jax.jit(fn).lower(*args)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), f"{name}: not HLO text"
        assert "ENTRY" in text
    assert len(seen) >= 15  # 3 shapes x 2 metrics x 3 kinds + 3 scans


def test_full_aot_cli(tmp_path):
    """The Makefile entry point end-to-end for one artifact."""
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    out = tmp_path / "artifacts"
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--only", "scan_m24"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
        capture_output=True,
        text=True,
        timeout=300,
    )
    assert r.returncode == 0, r.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["artifacts"][0]["name"] == "scan_m24"
    hlo = (out / "scan_m24.hlo.txt").read_text()
    assert hlo.startswith("HloModule")


def test_decode_roundtrip_identity_codebook():
    # Codebook where centroid j of every subspace is the constant j.
    m, c, dsub = 3, 4, 2
    cb = jnp.broadcast_to(
        jnp.arange(c, dtype=jnp.float32)[None, :, None], (m, c, dsub)
    )
    codes = jnp.asarray([[0, 1, 2], [3, 3, 3]], dtype=jnp.int32)
    dec = model.decode(cb, codes)
    expect = np.array(
        [[0, 0, 1, 1, 2, 2], [3, 3, 3, 3, 3, 3]], dtype=np.float32
    )
    np.testing.assert_allclose(dec, expect)


def test_compose_pq_distance_consistency():
    rng = np.random.default_rng(3)
    m, c, dsub, b = 4, 8, 2, 6
    q = jnp.asarray(rng.standard_normal(m * dsub), dtype=jnp.float32)
    cb = jnp.asarray(rng.standard_normal((m, c, dsub)), dtype=jnp.float32)
    codes = jnp.asarray(rng.integers(0, c, size=(b, m)), dtype=jnp.int32)
    d1 = model.compose_pq_distance(q, cb, codes, "l2")
    d2 = ref.rerank_ref(q, model.decode(cb, codes), "l2")
    np.testing.assert_allclose(d1, d2, rtol=1e-4, atol=1e-4)
