"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes (m, c, dsub, batch) and values; every kernel must
match ref.py within float32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pq, ref

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


@st.composite
def adt_case(draw):
    m = draw(st.integers(1, 8))
    c = draw(st.integers(1, 32))
    dsub = draw(st.integers(1, 6))
    seed = draw(st.integers(0, 2**31 - 1))
    return m, c, dsub, seed


@given(adt_case())
@settings(**SETTINGS)
def test_adt_l2_matches_ref(case):
    m, c, dsub, seed = case
    rng = np.random.default_rng(seed)
    q = rand(rng, m, 1, dsub)
    cb = rand(rng, m, c, dsub)
    out = pq.adt_l2(q, cb)
    expect = ref.adt_ref(q, cb, "l2")
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


@given(adt_case())
@settings(**SETTINGS)
def test_adt_ip_matches_ref(case):
    m, c, dsub, seed = case
    rng = np.random.default_rng(seed)
    q = rand(rng, m, 1, dsub)
    cb = rand(rng, m, c, dsub)
    out = pq.adt_ip(q, cb)
    expect = ref.adt_ref(q, cb, "ip")
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


@st.composite
def scan_case(draw):
    m = draw(st.integers(1, 8))
    c = draw(st.integers(1, 32))
    b = draw(st.integers(1, 64))
    seed = draw(st.integers(0, 2**31 - 1))
    return m, c, b, seed


@given(scan_case())
@settings(**SETTINGS)
def test_pq_scan_matches_ref(case):
    m, c, b, seed = case
    rng = np.random.default_rng(seed)
    adt = rand(rng, m, c)
    codes = jnp.asarray(rng.integers(0, c, size=(b, m)), dtype=jnp.int32)
    out = pq.pq_scan(adt, codes)
    expect = ref.pq_scan_ref(adt, codes)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


@st.composite
def rerank_case(draw):
    d = draw(st.integers(1, 64))
    b = draw(st.integers(1, 64))
    seed = draw(st.integers(0, 2**31 - 1))
    return d, b, seed


@given(rerank_case())
@settings(**SETTINGS)
def test_rerank_l2_matches_ref(case):
    d, b, seed = case
    rng = np.random.default_rng(seed)
    q = rand(rng, d)
    xs = rand(rng, b, d)
    out = pq.rerank_l2(q, xs)
    expect = ref.rerank_ref(q, xs, "l2")
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


@given(rerank_case())
@settings(**SETTINGS)
def test_rerank_ip_matches_ref(case):
    d, b, seed = case
    rng = np.random.default_rng(seed)
    q = rand(rng, d)
    xs = rand(rng, b, d)
    out = pq.rerank_ip(q, xs)
    expect = ref.rerank_ref(q, xs, "ip")
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


def test_adt_zero_query_l2_is_squared_norms():
    cb = jnp.ones((2, 3, 4), dtype=jnp.float32) * 2.0
    q = jnp.zeros((2, 1, 4), dtype=jnp.float32)
    out = pq.adt_l2(q, cb)
    np.testing.assert_allclose(out, jnp.full((2, 3), 16.0))


def test_pq_scan_selects_exact_entries():
    adt = jnp.asarray([[1.0, 2.0], [10.0, 20.0]], dtype=jnp.float32)
    codes = jnp.asarray([[0, 1], [1, 0]], dtype=jnp.int32)
    out = pq.pq_scan(adt, codes)
    np.testing.assert_allclose(out, [21.0, 12.0])


def test_kernels_jit_compatible():
    """Kernels must lower inside jax.jit (the AOT precondition)."""
    q = jnp.ones((4, 1, 2), dtype=jnp.float32)
    cb = jnp.ones((4, 8, 2), dtype=jnp.float32)
    jit_adt = jax.jit(pq.adt_l2)
    np.testing.assert_allclose(jit_adt(q, cb), jnp.zeros((4, 8)), atol=1e-6)


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_scan_of_adt_equals_decoded_distance(metric):
    """End-to-end PQ identity: ADT + scan == distance(q, decode(code))."""
    from compile import model

    rng = np.random.default_rng(7)
    m, c, dsub, b = 4, 16, 3, 10
    q = rand(rng, m * dsub)
    cb = rand(rng, m, c, dsub)
    codes = jnp.asarray(rng.integers(0, c, size=(b, m)), dtype=jnp.int32)

    kernel = pq.adt_l2 if metric == "l2" else pq.adt_ip
    adt = kernel(q.reshape(m, 1, dsub), cb)
    dists = pq.pq_scan(adt, codes)

    decoded = model.decode(cb, codes)
    expect = ref.rerank_ref(q, decoded, metric)
    np.testing.assert_allclose(dists, expect, rtol=1e-4, atol=1e-4)
