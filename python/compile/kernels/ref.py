"""Pure-jnp correctness oracles for the Pallas kernels (the pytest suite
asserts allclose between kernels and these references, and the rust unit
tests implement the same formulas natively — three-way agreement)."""

import jax.numpy as jnp


def adt_ref(q_sub, codebook, metric):
    """q_sub: (M, 1, dsub); codebook: (M, C, dsub) -> (M, C).

    metric: "l2" (squared euclidean partials) or "ip" (negated dots; the
    angular bias is applied outside, matching the rust runtime)."""
    if metric == "l2":
        d = codebook - q_sub
        return jnp.sum(d * d, axis=-1)
    elif metric == "ip":
        return -jnp.sum(codebook * q_sub, axis=-1)
    raise ValueError(metric)


def pq_scan_ref(adt, codes):
    """adt: (M, C); codes: (B, M) int -> (B,). out[b] = sum_m adt[m, codes[b,m]]."""
    m = adt.shape[0]
    return jnp.sum(adt[jnp.arange(m)[None, :], codes], axis=-1)


def rerank_ref(q, xs, metric):
    """q: (D,); xs: (B, D) -> (B,)."""
    if metric == "l2":
        d = xs - q[None, :]
        return jnp.sum(d * d, axis=-1)
    elif metric == "ip":
        return -(xs @ q)
    raise ValueError(metric)


def batch_dists_ref(qs, xs, metric):
    """qs: (Q, D); xs: (N, D) -> (Q, N) distance matrix."""
    if metric == "l2":
        qq = jnp.sum(qs * qs, axis=-1, keepdims=True)
        xx = jnp.sum(xs * xs, axis=-1)[None, :]
        return qq + xx - 2.0 * (qs @ xs.T)
    elif metric == "ip":
        return -(qs @ xs.T)
    raise ValueError(metric)
