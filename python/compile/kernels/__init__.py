"""Layer-1 Pallas kernels and their pure-jnp oracles."""

from . import pq, ref  # noqa: F401
