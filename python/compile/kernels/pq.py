"""Layer-1 Pallas kernels for the Proxima search engine's dense hot spots.

Three kernels (paper §IV-D modules):

* ``adt_*`` — the PQ module: build the M x C asymmetric distance table for
  one query against the codebook (Eq. 3's ADT_i tables).
* ``pq_scan`` — the distance-computation module's LUT-accumulate: PQ
  distances for a batch of codes against a prebuilt ADT.
* ``rerank_*`` — accurate distance for a batch of raw vectors (the rerank
  step, §III-C).

All kernels are written for ``interpret=True`` (the CPU PJRT plugin cannot
run Mosaic custom-calls — see /opt/xla-example/README.md). TPU mapping
notes live in DESIGN.md §2: the ADT tiles for VMEM residency (32 KB table),
the scan is a one-hot MXU contraction when B is large, and rerank is a
plain broadcast-reduce; BlockSpecs below express the VMEM tiling intent
even though the interpret path executes them as single blocks.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "adt_l2",
    "adt_ip",
    "pq_scan",
    "rerank_l2",
    "rerank_ip",
]


def _adt_l2_kernel(q_ref, cb_ref, o_ref):
    # q: (M, 1, dsub) broadcast against cb: (M, C, dsub) -> (M, C)
    diff = cb_ref[...] - q_ref[...]
    o_ref[...] = jnp.sum(diff * diff, axis=-1)


def _adt_ip_kernel(q_ref, cb_ref, o_ref):
    o_ref[...] = -jnp.sum(cb_ref[...] * q_ref[...], axis=-1)


def adt_l2(q, codebook):
    """L2 ADT. q: (M, 1, dsub) f32; codebook: (M, C, dsub) f32 -> (M, C)."""
    m, c, _ = codebook.shape
    return pl.pallas_call(
        _adt_l2_kernel,
        out_shape=jax.ShapeDtypeStruct((m, c), jnp.float32),
        interpret=True,
    )(q, codebook)


def adt_ip(q, codebook):
    """Inner-product ADT (negated partial dots; the angular +1 bias is
    folded in by the runtime — see ``distance::Metric::adt_bias``)."""
    m, c, _ = codebook.shape
    return pl.pallas_call(
        _adt_ip_kernel,
        out_shape=jax.ShapeDtypeStruct((m, c), jnp.float32),
        interpret=True,
    )(q, codebook)


def _pq_scan_kernel(adt_ref, codes_ref, o_ref):
    # adt: (M, C) flattened gather; codes: (B, M) int32.
    adt = adt_ref[...]
    codes = codes_ref[...]
    m, c = adt.shape
    flat = adt.reshape(m * c)
    # out[b] = sum_m adt[m, codes[b, m]]
    idx = codes + (jnp.arange(m, dtype=jnp.int32) * c)[None, :]
    o_ref[...] = jnp.sum(flat[idx], axis=-1)


def pq_scan(adt, codes):
    """Batched Eq. 3: adt (M, C) f32, codes (B, M) int32 -> (B,) f32."""
    b, _ = codes.shape
    return pl.pallas_call(
        _pq_scan_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(adt, codes)


def _rerank_l2_kernel(q_ref, x_ref, o_ref):
    diff = x_ref[...] - q_ref[...][None, :]
    o_ref[...] = jnp.sum(diff * diff, axis=-1)


def _rerank_ip_kernel(q_ref, x_ref, o_ref):
    o_ref[...] = -jnp.dot(x_ref[...], q_ref[...])


def rerank_l2(q, xs):
    """Squared-L2 rerank distances. q: (D,), xs: (B, D) -> (B,)."""
    b, _ = xs.shape
    return pl.pallas_call(
        _rerank_l2_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(q, xs)


def rerank_ip(q, xs):
    """Negative-inner-product rerank distances (angular bias folded by the
    caller for unit vectors: 1 + ip)."""
    b, _ = xs.shape
    return pl.pallas_call(
        _rerank_ip_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(q, xs)
