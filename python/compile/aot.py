"""AOT lowering: JAX/Pallas -> HLO **text** artifacts + manifest.json.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the image's xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md and aot_recipe). The rust runtime loads these
with ``HloModuleProto::from_text_file``.

Run once per build: ``cd python && python -m compile.aot --out ../artifacts``
(the Makefile's ``artifacts`` target; a no-op when inputs are unchanged
thanks to make's timestamp check).

Artifact set: for every (metric, D, M) the synthetic Table I registry
needs — (l2, 128, 32), (ip, 96, 24), (l2/ip shared tables below) plus
(l2, 100, 25) for GLOVE-like angular data (angular = ip partials + a bias
the rust runtime folds in) — emit ``adt``, ``scan``, ``rerank`` and ``gt``
programs with fixed batch shapes.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Fixed batch shapes shared with the rust runtime (manifest carries them).
SCAN_B = 512
RERANK_B = 256
GT_Q = 16
GT_N = 2048
C = 256

# (dim, m) pairs used by the dataset registry; metric variants for each.
SHAPES = [(128, 32), (96, 24), (100, 25)]
METRICS = ["l2", "ip"]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation (return_tuple=True) -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def build_entries():
    """Yield (name, lowered-fn, example-args, meta) for every artifact."""
    for dim, m in SHAPES:
        dsub = dim // m
        for metric in METRICS:
            yield (
                f"adt_{metric}_d{dim}",
                model.make_adt_fn(metric, m, C, dsub),
                (f32(dim), f32(m, C, dsub)),
                {"kind": "adt", "metric": metric, "dim": dim, "m": m, "c": C, "dsub": dsub},
            )
            yield (
                f"rerank_{metric}_d{dim}",
                model.make_rerank_fn(metric, dim, RERANK_B),
                (f32(dim), f32(RERANK_B, dim)),
                {"kind": "rerank", "metric": metric, "dim": dim, "batch": RERANK_B},
            )
            yield (
                f"gt_{metric}_d{dim}",
                model.make_gt_fn(metric, dim, GT_Q, GT_N),
                (f32(GT_Q, dim), f32(GT_N, dim)),
                {"kind": "gt", "metric": metric, "dim": dim, "q": GT_Q, "n": GT_N},
            )
        # The scan is metric-independent (pure table gather).
        yield (
            f"scan_m{m}",
            model.make_scan_fn(m, C, SCAN_B),
            (f32(m, C), i32(SCAN_B, m)),
            {"kind": "scan", "m": m, "c": C, "batch": SCAN_B},
        )


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", default=None, help="comma list of artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    manifest = {
        "version": 1,
        "scan_b": SCAN_B,
        "rerank_b": RERANK_B,
        "gt_q": GT_Q,
        "gt_n": GT_N,
        "artifacts": [],
    }
    for name, fn, example_args, meta in build_entries():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            f.write(text)
        entry = {"name": name, "file": fname, **meta}
        manifest["artifacts"].append(entry)
        print(f"[aot] {name}: {len(text)} chars -> {path}")

    man_path = os.path.join(args.out, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote {man_path} ({len(manifest['artifacts'])} artifacts)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
