"""Build-time compile path: JAX/Pallas models AOT-lowered to HLO text for
the rust PJRT runtime. Never imported at request time."""
