"""Layer-2 JAX compute graphs wrapping the Layer-1 Pallas kernels.

Each ``make_*`` returns a function with *fixed* shapes (AOT requirement)
that returns a 1-tuple — the ``return_tuple=True`` lowering convention the
rust loader unwraps with ``to_tuple1`` (see /opt/xla-example/README.md).

Functions
---------
* ``make_adt_fn(metric, m, c, dsub)``   — query (D,) -> ADT (M, C)
* ``make_scan_fn(m, c, b)``             — ADT + codes (B, M) -> dists (B,)
* ``make_rerank_fn(metric, d, b)``      — query + raw batch -> dists (B,)
* ``make_gt_fn(metric, d, q, n)``       — brute-force distance matrix
  (ground-truth path; plain jnp so XLA's GEMM does the heavy lifting)
"""

import jax.numpy as jnp

from .kernels import pq, ref


def make_adt_fn(metric, m, c, dsub):
    """ADT builder: (query (m*dsub,), codebook (m, c, dsub)) -> (m, c)."""
    kernel = pq.adt_l2 if metric == "l2" else pq.adt_ip

    def fn(query, codebook):
        q_sub = query.reshape(m, 1, dsub)
        return (kernel(q_sub, codebook),)

    fn.__name__ = f"adt_{metric}_m{m}c{c}d{dsub}"
    return fn


def make_scan_fn(m, c, b):
    """PQ scan: (adt (m, c), codes (b, m) int32) -> (b,)."""
    del c  # shape carried by the adt argument

    def fn(adt, codes):
        return (pq.pq_scan(adt, codes),)

    fn.__name__ = f"scan_m{m}b{b}"
    return fn


def make_rerank_fn(metric, d, b):
    """Rerank: (query (d,), xs (b, d)) -> (b,)."""
    kernel = pq.rerank_l2 if metric == "l2" else pq.rerank_ip

    def fn(query, xs):
        return (kernel(query, xs),)

    fn.__name__ = f"rerank_{metric}_d{d}b{b}"
    return fn


def make_gt_fn(metric, d, q, n):
    """Ground-truth tile: (queries (q, d), base (n, d)) -> (q, n)."""
    del d

    def fn(queries, base):
        return (ref.batch_dists_ref(queries, base, metric),)

    fn.__name__ = f"gt_{metric}_q{q}n{n}"
    return fn


def compose_pq_distance(query, codebook, codes, metric):
    """Reference composition used by tests: ADT + scan == distance between
    the query and each code's decoded vector."""
    m, c, dsub = codebook.shape
    adt = ref.adt_ref(query.reshape(m, 1, dsub), codebook, metric)
    return ref.pq_scan_ref(adt, codes)


def decode(codebook, codes):
    """Decode PQ codes to vectors: (b, m) -> (b, m*dsub)."""
    m, _, dsub = codebook.shape
    b = codes.shape[0]
    sub = codebook[jnp.arange(m)[None, :], codes]  # (b, m, dsub)
    return sub.reshape(b, m * dsub)
