#!/usr/bin/env python3
"""Generate the golden index-artifact fixture for the format-stability gate.

Produces ``rust/tests/fixtures/golden-v1.pxa``, a tiny but complete
format-version-1 index artifact (64 vectors x 8 dims, ring+chord graph,
M=4/C=8 PQ, reorder permutation, DataMapping). ``cargo test --test
artifact_golden`` asserts that today's reader still opens it — every
future PR runs against this file, so a format change without a
version bump (or without migration) fails CI instead of silently
orphaning deployed artifacts.

The byte layout mirrors ``rust/src/artifact/mod.rs`` (header) and
``rust/src/artifact/sections.rs`` (payloads) exactly; checksums are
CRC-32 (IEEE), i.e. ``zlib.crc32``. Deterministic: re-running this
script reproduces the committed fixture byte-for-byte.
"""

import random
import struct
import zlib
from pathlib import Path

MAGIC = b"PXARTIF1"
FORMAT_VERSION = 1
SEC_BASE, SEC_GRAPH, SEC_GAP, SEC_CODEBOOK, SEC_CODES, SEC_REORDER, SEC_MAPPING = range(1, 8)

N, DIM, M, C, R = 64, 8, 4, 8, 4
DSUB = DIM // M


def p_u32(x):
    return struct.pack("<I", x)


def p_u64(x):
    return struct.pack("<Q", x)


def p_f32(x):
    return struct.pack("<f", x)


def p_f64(x):
    return struct.pack("<d", x)


def p_str(s):
    b = s.encode()
    return p_u32(len(b)) + b


def f32(x):
    """Round a python float through f32 (what the file stores)."""
    return struct.unpack("<f", struct.pack("<f", x))[0]


def make_payloads():
    rng = random.Random(1234)
    base = [f32(rng.uniform(0.0, 1.0)) for _ in range(N * DIM)]
    centroids = [f32(rng.uniform(0.0, 1.0)) for _ in range(M * C * DSUB)]

    # BASE: dim u32, n u64, f32 data.
    sec_base = p_u32(DIM) + p_u64(N) + b"".join(p_f32(x) for x in base)

    # GRAPH: ring + second-neighbor chords -> degree 4, connected, no
    # self loops, ids in range.
    targets = []
    offsets = [0]
    for v in range(N):
        nbrs = sorted({(v + 1) % N, (v - 1) % N, (v + 2) % N, (v - 2) % N})
        targets.extend(nbrs)
        offsets.append(len(targets))
    sec_graph = (
        p_u32(0)  # entry_point
        + p_u32(R)  # max_degree
        + p_u64(len(offsets))
        + p_u64(len(targets))
        + b"".join(p_u32(x) for x in offsets)
        + b"".join(p_u32(x) for x in targets)
    )

    # CODEBOOK: metric str, dim u32, m u32, c u32, centroids f32.
    sec_codebook = (
        p_str("l2")
        + p_u32(DIM)
        + p_u32(M)
        + p_u32(C)
        + b"".join(p_f32(x) for x in centroids)
    )

    # CODES: nearest centroid per subspace (plain L2 in the subspace).
    def centroid(sub, ci):
        off = sub * C * DSUB + ci * DSUB
        return centroids[off : off + DSUB]

    codes = bytearray()
    for v in range(N):
        row = base[v * DIM : (v + 1) * DIM]
        for sub in range(M):
            sv = row[sub * DSUB : (sub + 1) * DSUB]
            best = min(
                range(C),
                key=lambda ci: sum((a - b) ** 2 for a, b in zip(sv, centroid(sub, ci))),
            )
            codes.append(best)
    sec_codes = p_u32(M) + p_u64(N) + bytes(codes)

    # REORDER: a real (non-identity) permutation.
    sec_reorder = p_u64(N) + b"".join(p_u32(N - 1 - i) for i in range(N))

    # MAPPING: the 11 DataMapping u32 fields in declaration order.
    mapping = [64, 2, 2, 2, 33, 9, 3, 2, 1088, 2000, 256]
    sec_mapping = b"".join(p_u32(x) for x in mapping)

    return [
        (SEC_BASE, sec_base),
        (SEC_GRAPH, sec_graph),
        (SEC_CODEBOOK, sec_codebook),
        (SEC_CODES, sec_codes),
        (SEC_REORDER, sec_reorder),
        (SEC_MAPPING, sec_mapping),
    ]


def make_artifact():
    spec = (
        p_str("golden-synth")
        + p_str("l2")
        + p_u32(DIM)
        + p_u64(N)
        + p_u32(R)  # graph_r
        + p_u32(16)  # graph_build_l
        + p_f32(1.2)  # graph_alpha
        + p_u32(M)
        + p_u32(C)
        + p_f64(0.03125)  # hot_frac = 2/64
        + p_u64(1234)  # build_seed
    )
    payloads = make_payloads()
    header = spec + p_u32(len(payloads))
    for tag, payload in payloads:
        header += p_u32(tag) + p_u64(len(payload)) + p_u32(zlib.crc32(payload))
    out = MAGIC + p_u32(FORMAT_VERSION) + header + p_u32(zlib.crc32(header))
    for _, payload in payloads:
        out += payload
    return out


def main():
    repo = Path(__file__).resolve().parents[2]
    dst = repo / "rust" / "tests" / "fixtures" / "golden-v1.pxa"
    dst.parent.mkdir(parents=True, exist_ok=True)
    data = make_artifact()
    dst.write_bytes(data)
    print(f"wrote {dst} ({len(data)} bytes, crc32 {zlib.crc32(data):08x})")


if __name__ == "__main__":
    main()
