//! Integration tests for the index lifecycle (ISSUE 4 acceptance
//! criteria): build → save → open parity across every search mode,
//! adversarial decodes (truncation, bit flips, future versions, spec
//! mismatches) surfacing as typed errors on a surviving server
//! connection, and the wire admin plane (`status` / `reload`) hot-swapping
//! the served index while in-flight queries finish on the old epoch.

use proxima::api::{ApiErrorCode, QueryOptions, QueryRequest, SearchMode};
use proxima::artifact::{ArtifactErrorKind, ArtifactReader, IndexArtifact, IndexProvenance};
use proxima::config::{GraphParams, PqParams, SearchParams};
use proxima::coordinator::batcher::{spawn, BatchPolicy};
use proxima::coordinator::server::{Client, Server};
use proxima::coordinator::{SearchService, ServiceCell};
use proxima::dataset::synth::tiny_uniform;
use proxima::dataset::Dataset;
use proxima::distance::Metric;
use proxima::util::json::Json;
use std::path::PathBuf;
use std::sync::Arc;

fn tmpdir() -> PathBuf {
    let d = std::env::temp_dir().join(format!("proxima-artifact-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn service(seed: u64) -> (Dataset, SearchService) {
    let ds = tiny_uniform(400, 12, Metric::L2, seed);
    let svc = SearchService::build(
        &ds,
        &GraphParams {
            r: 12,
            build_l: 24,
            alpha: 1.2,
            seed,
        },
        &PqParams {
            m: 6,
            c: 32,
            train_sample: 400,
            kmeans_iters: 6,
        },
        SearchParams {
            l: 80,
            k: 10,
            ..Default::default()
        },
        false,
    );
    (ds, svc)
}

const MODES: [SearchMode; 3] = [SearchMode::Accurate, SearchMode::PqAdt, SearchMode::Hybrid];

/// Acceptance: save → open reproduces the index exactly — bitwise-equal
/// PQ structures and identical `SearchOutput`s across all three modes.
#[test]
fn saved_and_opened_index_answers_identically_in_every_mode() {
    let (ds, built) = service(7);
    let path = tmpdir().join("roundtrip.pxa");
    built.save(&path).unwrap();
    let opened = SearchService::open(&path, built.params, false).unwrap();

    // Identity card and provenance.
    assert_eq!(opened.spec, built.spec);
    assert_eq!(built.provenance, IndexProvenance::Built);
    match &opened.provenance {
        IndexProvenance::Artifact { path: p } => assert!(p.ends_with("roundtrip.pxa")),
        other => panic!("opened service has provenance {other:?}"),
    }

    // Bitwise-equal stored structures (default opens are fully
    // resident, so the whole base set is in DRAM to compare).
    let built_base = built.resident_base().expect("built services are resident");
    let opened_base = opened.resident_base().expect("default open is resident");
    assert_eq!(opened_base.dim, built_base.dim);
    assert!(
        opened_base
            .data
            .iter()
            .zip(&built_base.data)
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "base vectors must round-trip bitwise"
    );
    assert!(
        opened
            .codebook
            .centroids
            .iter()
            .zip(&built.codebook.centroids)
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "PQ centroids must round-trip bitwise"
    );
    assert_eq!(opened.codes.codes, built.codes.codes);
    assert_eq!(opened.graph.offsets, built.graph.offsets);
    assert_eq!(opened.graph.targets, built.graph.targets);
    assert_eq!(opened.graph.entry_point, built.graph.entry_point);

    // Bitwise-equal ADTs (the per-query PQ table).
    let q = ds.queries.row(0);
    let t_built = built.build_adt(q);
    let t_opened = opened.build_adt(q);
    assert!(
        t_built
            .table
            .iter()
            .zip(&t_opened.table)
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "ADT tables must be bitwise identical"
    );

    // Identical answers, every mode, every query.
    for mode in MODES {
        let opts = QueryOptions {
            mode,
            want_stats: true,
            ..Default::default()
        };
        for qi in 0..ds.n_queries() {
            let req = QueryRequest::single(ds.queries.row(qi), 10).with_options(opts);
            let a = built.query(&req).unwrap();
            let b = opened.query(&req).unwrap();
            assert_eq!(
                a.results[0].ids, b.results[0].ids,
                "{mode:?} query {qi}: ids diverge after reopen"
            );
            assert_eq!(
                a.results[0].dists, b.results[0].dists,
                "{mode:?} query {qi}: dists diverge after reopen"
            );
        }
    }

    // The stored artifact also carries the §IV-E layout for the
    // engine/simulator: same file, same mapping.
    let art = IndexArtifact::open(&path).unwrap();
    let mapping = art.mapping.expect("service artifacts carry a DataMapping");
    assert_eq!(mapping, built.default_mapping());
    assert_eq!(mapping.n_nodes as usize, ds.n_base());
    std::fs::remove_file(&path).ok();
}

/// Adversarial decode: flipping ANY byte of the artifact yields a typed
/// error — never a panic, never a silently-wrong open.
#[test]
fn every_byte_flip_is_rejected_with_a_typed_error() {
    let (_ds, svc) = service(11);
    let path = tmpdir().join("flips.pxa");
    svc.save(&path).unwrap();
    let good = std::fs::read(&path).unwrap();
    assert!(ArtifactReader::from_bytes(good.clone()).is_ok());

    // Sampled sweep (every byte would be minutes in debug builds):
    // dense over the header, strided over the payloads, always the
    // first/last payload bytes.
    let mut offsets: Vec<usize> = (0..256.min(good.len())).collect();
    offsets.extend((256..good.len()).step_by(97));
    offsets.push(good.len() - 1);
    for off in offsets {
        let mut bad = good.clone();
        bad[off] ^= 0x10;
        assert!(
            ArtifactReader::from_bytes(bad).is_err(),
            "byte flip at offset {off} went undetected"
        );
    }

    // Targeted kinds at known offsets.
    let mut magic = good.clone();
    magic[0] ^= 0xFF;
    assert_eq!(
        ArtifactReader::from_bytes(magic).unwrap_err().kind,
        ArtifactErrorKind::BadMagic
    );
    let mut payload = good.clone();
    let last = payload.len() - 1;
    payload[last] ^= 0x01;
    assert_eq!(
        ArtifactReader::from_bytes(payload).unwrap_err().kind,
        ArtifactErrorKind::Corrupt
    );
    std::fs::remove_file(&path).ok();
}

/// Adversarial decode: truncation at any length is a typed error.
#[test]
fn truncated_artifacts_are_rejected_with_typed_errors() {
    let (_ds, svc) = service(13);
    let path = tmpdir().join("trunc.pxa");
    svc.save(&path).unwrap();
    let good = std::fs::read(&path).unwrap();
    for frac in [0.0, 0.1, 0.5, 0.9, 0.999] {
        let cut = ((good.len() as f64) * frac) as usize;
        let e = ArtifactReader::from_bytes(good[..cut].to_vec()).unwrap_err();
        assert!(
            matches!(
                e.kind,
                ArtifactErrorKind::Truncated
                    | ArtifactErrorKind::Corrupt
                    | ArtifactErrorKind::BadMagic
            ),
            "cut at {cut}: {e}"
        );
    }
    // Cutting the final byte leaves header + TOC intact: the specific
    // kind must be Truncated (payload shorter than its TOC entry).
    let e = ArtifactReader::from_bytes(good[..good.len() - 1].to_vec()).unwrap_err();
    assert_eq!(e.kind, ArtifactErrorKind::Truncated, "{e}");
    std::fs::remove_file(&path).ok();
}

/// Adversarial decode: a future format version fails with a clean
/// version-mismatch before any layout parsing, and a valid artifact for
/// the wrong dataset fails spec compatibility.
#[test]
fn future_versions_and_wrong_datasets_are_typed_failures() {
    let (_ds, svc) = service(17);
    let path = tmpdir().join("versions.pxa");
    svc.save(&path).unwrap();
    let mut future = std::fs::read(&path).unwrap();
    future[8..12].copy_from_slice(&99u32.to_le_bytes());
    let e = ArtifactReader::from_bytes(future).unwrap_err();
    assert_eq!(e.kind, ArtifactErrorKind::VersionMismatch);
    assert!(e.message.contains("99"), "{e}");

    // Spec-vs-dataset compatibility: right artifact, wrong dataset.
    let other_dim = tiny_uniform(50, 16, Metric::L2, 1);
    let e = svc.spec.check_compatible(&other_dim).unwrap_err();
    assert_eq!(e.kind, ArtifactErrorKind::SpecMismatch);
    assert!(e.message.contains("dim"), "{e}");
    let other_metric = tiny_uniform(50, 12, Metric::Ip, 1);
    let e = svc.spec.check_compatible(&other_metric).unwrap_err();
    assert_eq!(e.kind, ArtifactErrorKind::SpecMismatch);
    std::fs::remove_file(&path).ok();
}

/// The epoch-cell contract in-process: a handle loaded before a swap
/// keeps answering on the OLD index, loads after the swap see the new
/// one, and nothing is torn down while the old epoch is in use.
#[test]
fn epoch_cell_swap_preserves_inflight_handles() {
    let (ds, a) = service(19);
    let (_, b) = service(23);
    let expected_a: Vec<Vec<u32>> = (0..8)
        .map(|qi| a.search(ds.queries.row(qi), 10).ids)
        .collect();
    let expected_b: Vec<Vec<u32>> = (0..8)
        .map(|qi| b.search(ds.queries.row(qi), 10).ids)
        .collect();
    assert_ne!(
        expected_a, expected_b,
        "the two builds must answer differently for the swap to be observable"
    );

    let cell = ServiceCell::new(Arc::new(a));
    let old_epoch = cell.load();
    cell.swap(Arc::new(b));
    // The pre-swap handle still serves index A, queries answered mid-swap
    // complete on it.
    for qi in 0..8 {
        let out = old_epoch.search(ds.queries.row(qi), 10);
        assert_eq!(out.ids, expected_a[qi], "query {qi} on the old epoch");
    }
    // Fresh loads see index B.
    for qi in 0..8 {
        let out = cell.load().search(ds.queries.row(qi), 10);
        assert_eq!(out.ids, expected_b[qi], "query {qi} on the new epoch");
    }
}

/// Acceptance: over the wire, `reload` swaps the served index while the
/// connection (and any concurrently submitted batch) survives; bad
/// reloads leave the old index serving.
#[test]
fn wire_reload_hot_swaps_the_served_index() {
    let dir = tmpdir();
    let (ds, a) = service(29);
    // A serve-time execution-width override (dedicated pool) — the
    // reload path must carry it to the swapped-in index.
    let a = a.with_workers(2);
    let (_, b) = service(31);
    let queries: Vec<&[f32]> = (0..8).map(|qi| ds.queries.row(qi)).collect();
    let expected_a: Vec<Vec<u32>> = queries.iter().map(|q| a.search(q, 10).ids).collect();
    let expected_b: Vec<Vec<u32>> = queries.iter().map(|q| b.search(q, 10).ids).collect();
    assert_ne!(expected_a, expected_b);
    let b_path = dir.join("index-b.pxa");
    b.save(&b_path).unwrap();
    drop(b); // only the artifact survives — reload must reconstruct it

    let cell = Arc::new(ServiceCell::new(Arc::new(a)));
    let (handle, _join) = spawn(cell.clone(), BatchPolicy::default());
    let server = Server::start(cell.clone(), handle, 0).unwrap();
    let mut client = Client::connect(server.addr).unwrap();
    let mut admin = Client::connect(server.addr).unwrap();

    // Before any reload: index A answers.
    let resp = client.search_batch(&queries, 10, &QueryOptions::default()).unwrap();
    for (qi, nl) in resp.results.iter().enumerate() {
        assert_eq!(nl.ids, expected_a[qi], "pre-reload query {qi}");
    }

    // Failed reloads (missing file, corrupt artifact) are typed error
    // lines; the connection AND the old index keep serving.
    let e = admin
        .send_raw(r#"{"v":2,"op":"reload","path":"/no/such/file.pxa"}"#)
        .unwrap();
    let code = e
        .get("error")
        .and_then(|x| x.get("code"))
        .and_then(Json::as_str)
        .expect("structured error line");
    assert_eq!(code, "internal", "missing file is an io failure");
    let corrupt_path = dir.join("corrupt.pxa");
    let mut bytes = std::fs::read(&b_path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&corrupt_path, &bytes).unwrap();
    let e = admin
        .send_raw(&format!(
            r#"{{"v":2,"op":"reload","path":"{}"}}"#,
            corrupt_path.display()
        ))
        .unwrap();
    let code = e
        .get("error")
        .and_then(|x| x.get("code"))
        .and_then(Json::as_str)
        .expect("structured error line");
    assert_eq!(code, "bad_request", "corrupt artifact is a typed decode error");
    let resp = client.search_batch(&queries, 10, &QueryOptions::default()).unwrap();
    for (qi, nl) in resp.results.iter().enumerate() {
        assert_eq!(nl.ids, expected_a[qi], "query {qi} after failed reloads");
    }

    // Concurrent in-flight batch + reload: whichever epoch dispatches
    // the batch, it must answer ENTIRELY from one index — never a torn
    // mix — and the post-reload state must serve index B.
    let inflight = std::thread::spawn({
        let addr = server.addr;
        let queries: Vec<Vec<f32>> = queries.iter().map(|q| q.to_vec()).collect();
        move || {
            let mut c = Client::connect(addr).unwrap();
            let refs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
            c.search_batch(&refs, 10, &QueryOptions::default()).unwrap()
        }
    });
    let ok = admin
        .send_raw(&format!(
            r#"{{"v":2,"op":"reload","path":"{}"}}"#,
            b_path.display()
        ))
        .unwrap();
    assert_eq!(ok.get("ok").and_then(Json::as_bool), Some(true), "{ok:?}");
    let inflight = inflight.join().unwrap();
    let got: Vec<Vec<u32>> = inflight.results.iter().map(|nl| nl.ids.clone()).collect();
    assert!(
        got == expected_a || got == expected_b,
        "in-flight batch must be answered wholly by one epoch"
    );

    // After the swap: same connection, index B's answers and provenance.
    let resp = client.search_batch(&queries, 10, &QueryOptions::default()).unwrap();
    for (qi, nl) in resp.results.iter().enumerate() {
        assert_eq!(nl.ids, expected_b[qi], "post-reload query {qi}");
    }
    let status = admin.status().unwrap();
    assert_eq!(
        status
            .get("provenance")
            .and_then(|p| p.get("source"))
            .and_then(Json::as_str),
        Some("artifact")
    );
    let spec = proxima::api::wire::decode_spec(status.get("spec").unwrap()).unwrap();
    assert_eq!(spec.n_base, 400);
    assert_eq!(spec.dim, 12);
    assert_eq!(spec.build_seed, 31, "status must report the RELOADED index's spec");
    let swapped = cell.load();
    assert_eq!(
        swapped.workers, 2,
        "reload must carry the serve-time --workers override to the new index"
    );
    assert!(
        !swapped.uses_shared_pool(),
        "the dedicated pool must survive the hot swap"
    );

    // The single-query (batcher) path follows the swap too.
    let (ids, _, _) = client.search(queries[0], 10).unwrap();
    assert_eq!(ids, expected_b[0], "v1/batcher path must serve the new epoch");

    client.shutdown().ok();
    server.stop();
    std::fs::remove_file(&b_path).ok();
    std::fs::remove_file(&corrupt_path).ok();
}

/// A REORDERED artifact (graph/codes/base permuted into the §IV-E NAND
/// layout, REORDER section carrying `perm[old] = new`) must answer in
/// the ORIGINAL id space — the permutation is a storage-layout detail,
/// invisible to clients. Assembled by the first-class deployment
/// builder (`ReorderedIndex::write_artifact`), not by hand.
#[test]
fn reordered_artifacts_answer_in_original_id_space() {
    use proxima::reorder::{ReorderedIndex, VisitProfile};
    let dir = tmpdir();
    let (ds, svc) = service(41);
    let base = svc.resident_base().expect("built services are resident");
    let profile = VisitProfile::measure(
        &base,
        &svc.graph,
        &svc.codebook,
        &svc.codes,
        &svc.params,
        20,
        41,
    );
    let re = ReorderedIndex::build(&svc.graph, &svc.codes, &profile, 0.05);
    let path = dir.join("reordered.pxa");
    let written = re
        .write_artifact(&svc.spec, &base, &svc.codebook, &path)
        .unwrap();
    assert_eq!(written.hot_frac, re.n_hot as f64 / ds.n_base() as f64);

    let opened = SearchService::open(&path, svc.params, false).unwrap();
    assert_eq!(opened.reorder.as_ref().map(|p| p.len()), Some(ds.n_base()));
    for qi in 0..8 {
        let q = ds.queries.row(qi);
        let orig = svc.search(q, 10);
        let got = opened.search(q, 10);
        // Same candidates, original ids (order may tie-break differently
        // on equal distances, as in the reorder module's own tests).
        let mut a = orig.ids.clone();
        let mut b = got.ids.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "query {qi}: reordered artifact must answer in ORIGINAL ids");
        assert_eq!(orig.dists[0], got.dists[0], "query {qi}: best distance must agree");
    }
    std::fs::remove_file(&path).ok();
}

/// A dim-mismatched QUERY against an opened artifact is an API-level
/// typed error on a surviving connection (the validation boundary holds
/// for opened indices exactly as for built ones).
#[test]
fn opened_index_still_validates_queries_at_the_boundary() {
    let dir = tmpdir();
    let (ds, svc) = service(37);
    let path = dir.join("boundary.pxa");
    svc.save(&path).unwrap();
    let opened = SearchService::open(&path, svc.params, false).unwrap();
    let wrong = vec![0.5f32; ds.dim() + 1];
    let e = opened.query(&QueryRequest::single(&wrong, 5)).unwrap_err();
    assert_eq!(e.code, ApiErrorCode::DimMismatch);
    let ok = opened
        .query(&QueryRequest::single(ds.queries.row(0), 5))
        .unwrap();
    assert_eq!(ok.results[0].ids.len(), 5);
    std::fs::remove_file(&path).ok();
}
