//! Dispatch-control acceptance tests for the SIMD kernel layer. These
//! live in their own integration binary (own process) because they
//! toggle the process-wide dispatch mode — running them alongside the
//! unit tests would race every concurrently-executing distance call.

use proxima::simd;

/// One test fn drives every scenario IN ORDER — `force_scalar` is
/// process-global state, so independent #[test] fns (which run on a
/// shared thread pool) would interleave toggles.
#[test]
fn dispatch_controls_select_and_restore_kernel_tables() {
    let env_forced = std::env::var("PROXIMA_FORCE_SCALAR")
        .map(|v| {
            let t = v.trim().to_ascii_lowercase();
            !(t.is_empty() || t == "0" || t == "false" || t == "no")
        })
        .unwrap_or(false);

    // 1. The env contract: a forcing PROXIMA_FORCE_SCALAR (the CI
    //    forced-scalar job sets "1") must pin the scalar table from the
    //    very first dispatch; otherwise auto-detection picks the best
    //    table for this host.
    if env_forced {
        assert_eq!(simd::dispatch_name(), "scalar", "env must force scalar");
    } else {
        let name = simd::dispatch_name();
        assert!(
            ["scalar", "avx2", "avx512", "neon"].contains(&name),
            "unknown dispatch table {name:?}"
        );
    }

    // 2. The API escape hatch selects the fallback regardless of host
    //    features, and kernels() then IS the scalar table.
    simd::force_scalar(true);
    assert_eq!(simd::dispatch_name(), "scalar");
    let forced = simd::kernels();
    let scalar = simd::scalar_kernels();
    assert_eq!(forced.name, scalar.name);
    assert!(std::ptr::eq(forced, scalar), "forced table must BE the scalar table");

    // 3. Forced-scalar results are bitwise the reference scalar loops.
    let a: Vec<f32> = (0..37).map(|i| (i as f32 * 0.37).sin()).collect();
    let b: Vec<f32> = (0..37).map(|i| (i as f32 * 0.73).cos()).collect();
    assert_eq!((forced.l2_sq)(&a, &b).to_bits(), (scalar.l2_sq)(&a, &b).to_bits());
    assert_eq!((forced.dot)(&a, &b).to_bits(), (scalar.dot)(&a, &b).to_bits());

    // 4. Releasing the override re-resolves the ENV (it does not blindly
    //    flip to auto): under the CI forced-scalar job the table must
    //    stay scalar after a force_scalar(true)/false round trip.
    simd::force_scalar(false);
    if env_forced {
        assert_eq!(
            simd::dispatch_name(),
            "scalar",
            "force_scalar(false) must yield back to PROXIMA_FORCE_SCALAR"
        );
    } else {
        let name = simd::dispatch_name();
        assert!(
            ["scalar", "avx2", "avx512", "neon"].contains(&name),
            "auto dispatch must be restored, got {name:?}"
        );
    }

    // 5. Whatever table is live, the batch forms remain bitwise the
    //    pairwise kernel per row (the invariant every caller leans on).
    let k = simd::kernels();
    let dim = 24;
    let stride = simd::stride_for(dim);
    assert_eq!(stride, 32);
    let mut rows = vec![0.0f32; 4 * stride];
    for (i, r) in rows.chunks_exact_mut(stride).enumerate() {
        for (j, x) in r[..dim].iter_mut().enumerate() {
            *x = ((i * 17 + j) as f32 * 0.21).sin();
        }
    }
    let q: Vec<f32> = (0..dim).map(|j| (j as f32 * 0.11).cos()).collect();
    let mut out = vec![0.0f32; 4];
    (k.l2_sq_batch)(&q, &rows, stride, &mut out);
    for (i, &o) in out.iter().enumerate() {
        let want = (k.l2_sq)(&q, &rows[i * stride..i * stride + dim]);
        assert_eq!(o.to_bits(), want.to_bits(), "row {i}");
    }
}
