//! Format-stability gate: `tests/fixtures/golden-v1.pxa` is a COMMITTED
//! format-version-1 artifact (generated once by
//! `python/tools/make_golden_artifact.py`). Every future PR's reader
//! must keep opening it — a layout change without a version bump (or a
//! version bump without a migration) fails here instead of silently
//! orphaning artifacts already deployed in the field.
//!
//! If this test fails because the format legitimately evolved: bump
//! `artifact::FORMAT_VERSION`, keep a reader for v1, and add a new
//! golden fixture alongside this one — do NOT regenerate the v1 file.

use proxima::api::{QueryOptions, QueryRequest, SearchMode};
use proxima::artifact::IndexArtifact;
use proxima::config::SearchParams;
use proxima::coordinator::SearchService;
use proxima::distance::Metric;
use proxima::storage::{OpenOptions, Residency};
use std::path::PathBuf;

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/golden-v1.pxa")
}

#[test]
fn golden_v1_artifact_still_opens() {
    let art = IndexArtifact::open(&golden_path()).expect(
        "the committed v1 golden artifact no longer opens — the format \
         changed incompatibly (see this file's module docs)",
    );
    // The identity card, exactly as the generator wrote it.
    assert_eq!(art.spec.dataset, "golden-synth");
    assert_eq!(art.spec.metric, Metric::L2);
    assert_eq!(art.spec.dim, 8);
    assert_eq!(art.spec.n_base, 64);
    assert_eq!(art.spec.graph_r, 4);
    assert_eq!(art.spec.graph_build_l, 16);
    assert!((art.spec.graph_alpha - 1.2).abs() < 1e-6);
    assert_eq!(art.spec.pq_m, 4);
    assert_eq!(art.spec.pq_c, 8);
    assert_eq!(art.spec.hot_frac, 0.03125);
    assert_eq!(art.spec.build_seed, 1234);

    // Structures decoded and structurally valid.
    assert_eq!(art.base.len(), 64);
    assert_eq!(art.base.dim, 8);
    art.graph.validate().expect("golden graph must validate");
    assert_eq!(art.graph.n(), 64);
    assert_eq!(art.graph.entry_point, 0);
    assert_eq!(art.codebook.centroids.len(), 4 * 8 * 2);
    assert_eq!(art.codes.len(), 64);
    assert!(art.gap.is_none(), "the v1 golden fixture omits the GAP section");
    let perm = art.reorder.expect("golden fixture carries a reorder permutation");
    assert_eq!(perm[0], 63, "reversed permutation as generated");
    let mapping = art.mapping.expect("golden fixture carries a DataMapping");
    assert_eq!(mapping.n_nodes, 64);
    assert_eq!(mapping.idx_frames_per_page, 33);
    assert_eq!(mapping.n_hot, 2);
}

/// Open → save must persist the artifact's hand-crafted layout metadata
/// VERBATIM (the contract with the NAND engine/sim) — not a recomputed
/// default — and carry the reorder permutation through.
#[test]
fn open_then_save_preserves_stored_mapping_and_reorder_verbatim() {
    let svc = SearchService::open(&golden_path(), SearchParams::default(), false).unwrap();
    let stored = svc
        .mapping
        .clone()
        .expect("opened service carries the artifact's mapping");
    assert_eq!(stored.idx_frames_per_page, 33, "the fixture's hand-crafted value");
    let out = std::env::temp_dir().join(format!("golden-resave-{}.pxa", std::process::id()));
    svc.save(&out).unwrap();
    let back = IndexArtifact::open(&out).unwrap();
    assert_eq!(back.mapping.unwrap(), stored);
    assert_eq!(back.reorder.unwrap(), svc.reorder.clone().unwrap());
    std::fs::remove_file(&out).ok();
}

#[test]
fn golden_v1_artifact_still_serves() {
    let svc = SearchService::open(
        &golden_path(),
        SearchParams {
            l: 16,
            k: 4,
            ..Default::default()
        },
        false,
    )
    .expect("the golden artifact must open as a serveable index");
    assert_eq!(svc.name, "golden-synth");
    // Every mode answers real queries off the fixture's own vectors.
    let q = svc.resident_base().unwrap().row(0).to_vec();
    for mode in [SearchMode::Accurate, SearchMode::PqAdt, SearchMode::Hybrid] {
        let req = QueryRequest::single(&q, 4).with_options(QueryOptions {
            mode,
            ..Default::default()
        });
        let resp = svc.query(&req).unwrap();
        assert_eq!(resp.results[0].ids.len(), 4, "{mode:?}");
        assert!(
            resp.results[0].dists.windows(2).all(|w| w[0] <= w[1]),
            "{mode:?}: dists must be ascending"
        );
    }
    // The query vector IS stored base row 0; the fixture's REORDER
    // permutation is the reversal (perm[old] = 63 - old), so the
    // service must report that hit under its ORIGINAL id 63 — the
    // reorder-mapping contract, pinned against the golden bytes.
    let resp = svc
        .query(&QueryRequest::single(&q, 4).with_options(QueryOptions {
            mode: SearchMode::Accurate,
            ..Default::default()
        }))
        .unwrap();
    assert_eq!(resp.results[0].ids[0], 63);
    assert_eq!(resp.results[0].dists[0], 0.0);
    assert_eq!(
        svc.reorder.as_ref().map(|p| p.len()),
        Some(64),
        "the opened service must carry the artifact's permutation"
    );
}

/// Format-stability for the STORAGE backends: the committed v1 fixture
/// must open via the `Cold` and `Tiered` residencies (streaming BASE
/// validation, in-place reads against the v1 TOC offsets) and answer
/// byte-for-byte like a resident open. Part of the golden CI gate.
#[test]
fn golden_v1_artifact_opens_cold_and_tiered_identically() {
    let params = SearchParams {
        l: 16,
        k: 4,
        ..Default::default()
    };
    let resident = SearchService::open(&golden_path(), params, false).unwrap();
    let q = resident.resident_base().unwrap().row(0).to_vec();
    for residency in [Residency::Cold, Residency::Tiered] {
        let svc = SearchService::open_with(
            &golden_path(),
            params,
            false,
            &OpenOptions::with_residency(residency),
        )
        .unwrap_or_else(|e| panic!("golden fixture must open {}: {e}", residency.name()));
        assert_eq!(svc.storage.residency(), residency);
        assert_eq!(svc.n_base(), 64);
        // hot_frac = 0.03125 over 64 vectors → a 2-row DRAM hot tier
        // (rows SIMD-padded: dim 8 pads to stride 16).
        match residency {
            Residency::Tiered => {
                assert_eq!(svc.storage.n_hot(), 2);
                assert_eq!(svc.storage.resident_bytes(), 2 * 16 * 4);
            }
            _ => assert_eq!(svc.storage.resident_bytes(), 0),
        }
        for mode in [SearchMode::Accurate, SearchMode::PqAdt, SearchMode::Hybrid] {
            let req = QueryRequest::single(&q, 4).with_options(QueryOptions {
                mode,
                want_stats: true,
                ..Default::default()
            });
            let a = resident.query(&req).unwrap();
            let b = svc.query(&req).unwrap();
            assert_eq!(
                a.results[0], b.results[0],
                "{mode:?} under {} must match resident",
                residency.name()
            );
            // The fixture's reorder contract holds in every tier.
            assert_eq!(b.results[0].ids[0], 63);
            // Every mode reranks with exact distances, so raw vectors
            // were fetched — from the file in these residencies.
            assert!(
                b.stats.as_ref().unwrap().cold_reads > 0,
                "{mode:?} under {} must read the cold tier",
                residency.name()
            );
        }
    }
}
