//! Integration tests for the observability plane: the `{"op":"metrics"}`
//! Prometheus exposition and the `{"op":"slowlog"}` flight recorder over
//! live servers, and the lifetime-vs-epoch split across a `reload`
//! hot-swap (histograms ADOPTED, slowlog CLEARED, `ServiceStats` reset).

use proxima::config::{GraphParams, PqParams, SearchParams};
use proxima::coordinator::batcher::{spawn, BatchPolicy};
use proxima::coordinator::server::{Client, Server};
use proxima::coordinator::{SearchService, ServiceCell};
use proxima::dataset::synth::tiny_uniform;
use proxima::dataset::Dataset;
use proxima::distance::Metric;
use proxima::util::json::Json;
use std::sync::Arc;

fn build_service(ds: &Dataset, seed: u64) -> SearchService {
    SearchService::build(
        ds,
        &GraphParams {
            r: 8,
            build_l: 16,
            alpha: 1.2,
            seed,
        },
        &PqParams {
            m: 4,
            c: 16,
            train_sample: 200,
            kmeans_iters: 4,
        },
        SearchParams {
            l: 30,
            k: 5,
            ..Default::default()
        },
        false,
    )
}

/// Pull one sample's value out of Prometheus text by its exact
/// `name{labels}` prefix (followed by a space).
fn metric_value(text: &str, series: &str) -> f64 {
    let line = text
        .lines()
        .find(|l| l.strip_prefix(series).is_some_and(|r| r.starts_with(' ')))
        .unwrap_or_else(|| panic!("series {series} not found in exposition"));
    line.rsplit(' ').next().unwrap().parse().unwrap()
}

#[test]
fn metrics_op_exposes_both_planes_and_stages() {
    let ds = tiny_uniform(200, 8, Metric::L2, 111);
    let svc = Arc::new(build_service(&ds, 111));
    let cell = Arc::new(ServiceCell::new(svc));
    let (handle, _join) = spawn(cell.clone(), BatchPolicy::default());

    // One service, both front doors: the threaded JSON server and the
    // nonblocking binary+JSON front door share the service's metrics
    // handle, so one scrape sees traffic from both planes.
    let json_server = Server::start(cell.clone(), handle.clone(), 0).unwrap();
    let net_server =
        proxima::net::NetServer::start(cell, handle, proxima::net::NetConfig::default()).unwrap();

    let mut client = Client::connect(json_server.addr).unwrap();
    for qi in 0..3 {
        client.search(ds.queries.row(qi), 5).unwrap();
    }
    // Binary-plane traffic: a short open-loop burst of framed queries.
    let rep = proxima::coordinator::loadgen::run_open(
        net_server.addr,
        &ds.queries,
        5,
        300.0,
        std::time::Duration::from_millis(100),
        13,
    )
    .unwrap();
    assert!(rep.completed > 0, "bin-plane burst must complete queries");

    let text = client.metrics().unwrap();
    // Valid exposition shape for the histogram family.
    assert!(text.contains("# TYPE proxima_request_duration_us histogram"));
    assert!(text.contains("# TYPE proxima_engine_duration_us histogram"));
    assert!(text.contains("# TYPE proxima_stage_duration_us histogram"));

    // End-to-end request series on BOTH planes.
    let json_n = metric_value(
        &text,
        "proxima_request_duration_us_count{op=\"search\",plane=\"json\"}",
    );
    assert_eq!(json_n, 3.0, "three JSON-plane searches");
    let bin_n = metric_value(
        &text,
        "proxima_request_duration_us_count{op=\"search\",plane=\"bin\"}",
    );
    assert!(
        bin_n >= rep.completed as f64,
        "every completed framed query leaves a bin-plane sample \
         (got {bin_n} for {} completed)",
        rep.completed,
    );

    // Engine latency recorded once per executed query on either plane.
    let engine_n = metric_value(&text, "proxima_engine_duration_us_count");
    assert_eq!(engine_n, 3.0 + rep.completed as f64);
    // Every stage series exists with a fixed label set; zero-duration
    // stage samples are skipped, so counts are bounded by engine_n.
    let walk_n = metric_value(&text, "proxima_stage_duration_us_count{stage=\"graph_walk\"}");
    assert!(walk_n <= engine_n);
    for stage in [
        "admission_wait",
        "queue_wait",
        "adt_build",
        "rerank",
        "cold_read",
        "frame_encode",
        "frame_decode",
    ] {
        assert!(
            text.contains(&format!("proxima_stage_duration_us_count{{stage=\"{stage}\"}}")),
            "stage {stage} series missing",
        );
    }

    // Gauges and counters from the live service.
    assert!(metric_value(&text, "proxima_connections") >= 1.0);
    assert!(metric_value(&text, "proxima_errors_total") >= rep.errors as f64);
    assert_eq!(metric_value(&text, "proxima_exec_pending"), 0.0);
    // The net front door registered its admission controller: every
    // completed query was admitted, and the shed counters split by gate
    // account for exactly what the generator saw shed.
    assert!(metric_value(&text, "proxima_admission_admitted_total") >= rep.completed as f64);
    let shed_admit = metric_value(&text, "proxima_admission_shed_total{gate=\"admit\"}");
    let shed_dispatch = metric_value(&text, "proxima_admission_shed_total{gate=\"dispatch\"}");
    assert_eq!(shed_admit + shed_dispatch, rep.shed as f64);
    // Per-epoch service counters ride along.
    assert_eq!(metric_value(&text, "proxima_epoch_queries_total"), engine_n);

    client.shutdown().unwrap();
    json_server.stop();
    net_server.stop();
}

#[test]
fn slowlog_returns_stage_spans() {
    let ds = tiny_uniform(200, 8, Metric::L2, 113);
    let svc = Arc::new(build_service(&ds, 113));
    let cell = Arc::new(ServiceCell::new(svc));
    let (handle, _join) = spawn(cell.clone(), BatchPolicy::default());
    let server = Server::start(cell, handle, 0).unwrap();
    let mut client = Client::connect(server.addr).unwrap();

    for qi in 0..8 {
        client.search(ds.queries.row(qi), 5).unwrap();
    }
    let log = client.slowlog().unwrap();
    assert_eq!(
        log.get("capacity").and_then(Json::as_usize),
        Some(proxima::obs::slowlog::DEFAULT_CAP),
    );
    let entries = log.get("entries").and_then(Json::as_arr).unwrap();
    assert!(!entries.is_empty(), "eight queries must leave slow entries");
    let mut last = u64::MAX;
    for e in entries {
        let lat = e.get("latency_us").and_then(Json::as_f64).unwrap() as u64;
        assert!(lat <= last, "entries sorted slowest-first");
        last = lat;
        // Each entry carries the full stage breakdown and SearchStats.
        let stages = e.get("stages").expect("entry carries stages");
        let walk = stages.get("graph_walk").and_then(Json::as_f64).unwrap();
        assert!(walk >= 0.0);
        let stats = e.get("stats").expect("entry carries stats");
        assert!(stats.get("hops").and_then(Json::as_usize).unwrap() > 0);
    }

    client.shutdown().unwrap();
    server.stop();
}

#[test]
fn reload_adopts_histograms_clears_slowlog_resets_stats() {
    let ds = tiny_uniform(200, 8, Metric::L2, 117);
    let svc = build_service(&ds, 117);
    let path = std::env::temp_dir().join(format!("obs-reload-{}.pxa", std::process::id()));
    svc.save(&path).unwrap();

    let cell = Arc::new(ServiceCell::new(Arc::new(svc)));
    let (handle, _join) = spawn(cell.clone(), BatchPolicy::default());
    let server = Server::start(cell, handle, 0).unwrap();
    let mut client = Client::connect(server.addr).unwrap();

    for qi in 0..5 {
        client.search(ds.queries.row(qi), 5).unwrap();
    }
    let before = client.metrics().unwrap();
    let engine_before = metric_value(&before, "proxima_engine_duration_us_count");
    assert_eq!(engine_before, 5.0);
    let slow_before = client.slowlog().unwrap();
    assert!(
        !slow_before
            .get("entries")
            .and_then(Json::as_arr)
            .unwrap()
            .is_empty(),
        "slowlog holds entries before the swap",
    );

    client.reload(path.to_str().unwrap()).unwrap();

    // The three-way split across the hot-swap:
    let after = client.metrics().unwrap();
    // 1. Lifetime histograms are ADOPTED — the scrape series continues
    //    (the reload itself adds admin samples, not engine samples).
    assert_eq!(metric_value(&after, "proxima_engine_duration_us_count"), engine_before);
    // 2. The slowlog is CLEARED — old spans described the old epoch.
    let slow_after = client.slowlog().unwrap();
    assert!(
        slow_after
            .get("entries")
            .and_then(Json::as_arr)
            .unwrap()
            .is_empty(),
        "slowlog cleared on hot-swap",
    );
    // 3. ServiceStats stays per-epoch: the query counter reset.
    assert_eq!(client.stats().unwrap().get("queries").and_then(Json::as_usize), Some(0));

    // Continuity: the next query extends the ADOPTED series.
    client.search(ds.queries.row(0), 5).unwrap();
    let resumed = client.metrics().unwrap();
    assert_eq!(metric_value(&resumed, "proxima_engine_duration_us_count"), engine_before + 1.0);

    client.shutdown().unwrap();
    server.stop();
    let _ = std::fs::remove_file(&path);
}
