//! Golden parity: the unified traversal kernel must reproduce the seed
//! implementations' results on a fixed-seed synthetic dataset.
//!
//! The oracles below are line-for-line ports of the seed (pre-refactor)
//! search loops — Bloom-filter visited set, inline expansion loop — kept
//! deliberately independent of `search::kernel`. Traced kernel runs use
//! the same Bloom visited set, so their top-k ids must match the oracle
//! exactly; untraced runs use the exact epoch bitset, which removes Bloom
//! false-positive drops and therefore must match-or-beat the oracle's
//! recall.
//!
//! One deliberate deviation from the seed, mirrored here: rerank sorts
//! are `sort_unstable_by` with an id tie-break instead of the seed's
//! stable `sort_by` (stable sorts allocate, breaking the zero-alloc hot
//! path). For bitwise-equal distances the returned id may differ from
//! the seed's list-order tie-break; the id rule is deterministic and
//! distance-equivalent.

use proxima::config::{GraphParams, SearchParams};
use proxima::dataset::ground_truth::brute_force;
use proxima::dataset::synth::tiny_uniform;
use proxima::dataset::{recall_at_k, Dataset};
use proxima::distance::Metric;
use proxima::graph::{vamana, Graph};
use proxima::pq::{Adt, PqCodebook, PqCodes};
use proxima::search::beam::{accurate_beam_search, pq_beam_search, CandidateList, SearchContext};
use proxima::search::bloom::BloomFilter;
use proxima::search::proxima::{proxima_search, ProximaFeatures};
use std::collections::HashMap;

struct Fixture {
    ds: Dataset,
    g: Graph,
    cb: PqCodebook,
    codes: PqCodes,
}

fn fixture() -> Fixture {
    let ds = tiny_uniform(800, 16, Metric::L2, 31);
    let g = vamana::build(
        &ds.base,
        ds.metric,
        &GraphParams {
            r: 16,
            build_l: 32,
            alpha: 1.2,
            seed: 5,
        },
    );
    let cb = PqCodebook::train(&ds.base, ds.metric, 8, 32, 800, 8, 6);
    let codes = cb.encode(&ds.base);
    Fixture { ds, g, cb, codes }
}

fn ctx(f: &Fixture) -> SearchContext<'_> {
    SearchContext {
        base: &f.ds.base,
        metric: f.ds.metric,
        graph: &f.g,
        codes: Some(&f.codes),
        gap: None,
        storage: None,
        online: None,
        lsh: None,
    }
}

/// Seed `accurate_beam_search` (Bloom visited set), minus instrumentation.
fn oracle_accurate(ctx: &SearchContext, q: &[f32], k: usize, l: usize) -> Vec<u32> {
    let mut visited = BloomFilter::paper_config();
    let mut list = CandidateList::new(l);
    let entry = ctx.graph.entry_point;
    list.insert(ctx.metric.distance(q, ctx.base.row(entry as usize)), entry);
    visited.insert(entry);
    while let Some(pos) = list.first_unevaluated(l) {
        let v = list.items[pos].id;
        list.items[pos].evaluated = true;
        for &nb in ctx.graph.neighbors(v) {
            if visited.insert(nb) {
                continue;
            }
            list.insert(ctx.metric.distance(q, ctx.base.row(nb as usize)), nb);
        }
    }
    list.items.iter().take(k).map(|c| c.id).collect()
}

/// Seed `pq_beam_search` (Bloom visited set), minus instrumentation.
fn oracle_pq(
    ctx: &SearchContext,
    adt: &Adt,
    q: &[f32],
    k: usize,
    l: usize,
    rerank: usize,
) -> Vec<u32> {
    let codes = ctx.codes.unwrap();
    let mut visited = BloomFilter::paper_config();
    let mut list = CandidateList::new(l);
    let entry = ctx.graph.entry_point;
    list.insert(adt.pq_distance(codes.row(entry as usize)), entry);
    visited.insert(entry);
    while let Some(pos) = list.first_unevaluated(l) {
        let v = list.items[pos].id;
        list.items[pos].evaluated = true;
        for &nb in ctx.graph.neighbors(v) {
            if visited.insert(nb) {
                continue;
            }
            list.insert(adt.pq_distance(codes.row(nb as usize)), nb);
        }
    }
    let take = rerank.max(k).min(list.len());
    let mut reranked: Vec<(f32, u32)> = list.items[..take]
        .iter()
        .map(|c| (ctx.metric.distance(q, ctx.base.row(c.id as usize)), c.id))
        .collect();
    reranked.sort_unstable_by(|a, b| {
        a.0.partial_cmp(&b.0).unwrap().then_with(|| a.1.cmp(&b.1))
    });
    reranked.truncate(k);
    reranked.into_iter().map(|(_, v)| v).collect()
}

/// Seed `proxima_search` (Bloom visited set + HashMap exact cache), minus
/// instrumentation: dynamic list, iteration reranks, early termination,
/// final β-rerank.
fn oracle_proxima(
    ctx: &SearchContext,
    adt: &Adt,
    q: &[f32],
    params: &SearchParams,
    features: ProximaFeatures,
) -> Vec<u32> {
    let codes = ctx.codes.unwrap();
    let l_cap = params.l;
    let k = params.k;
    let mut t_limit = params.t_init.clamp(k, l_cap);
    let mut visited = BloomFilter::paper_config();
    let mut list = CandidateList::new(l_cap);
    let mut exact_cache: HashMap<u32, f32> = HashMap::new();

    let entry = ctx.graph.entry_point;
    list.insert(adt.pq_distance(codes.row(entry as usize)), entry);
    visited.insert(entry);

    let mut prev_topk: Vec<u32> = Vec::new();
    let mut stable_iters = 0usize;

    'outer: while t_limit <= l_cap {
        while let Some(pos) = list.first_unevaluated(t_limit) {
            let v = list.items[pos].id;
            list.items[pos].evaluated = true;
            for &nb in ctx.graph.neighbors(v) {
                if visited.insert(nb) {
                    continue;
                }
                list.insert(adt.pq_distance(codes.row(nb as usize)), nb);
            }
        }

        let t_eff = t_limit.min(list.len());
        let mut reranked: Vec<(f32, u32)> = Vec::with_capacity(t_eff);
        for c in &list.items[..t_eff] {
            let d = *exact_cache
                .entry(c.id)
                .or_insert_with(|| ctx.metric.distance(q, ctx.base.row(c.id as usize)));
            reranked.push((d, c.id));
        }
        reranked.sort_unstable_by(|a, b| {
            a.0.partial_cmp(&b.0).unwrap().then_with(|| a.1.cmp(&b.1))
        });
        let topk: Vec<u32> = reranked.iter().take(k).map(|&(_, v)| v).collect();

        if features.early_termination {
            if topk == prev_topk {
                stable_iters += 1;
                if stable_iters >= params.repetition {
                    break 'outer;
                }
            } else {
                stable_iters = 0;
            }
            prev_topk = topk;
        }

        if t_limit >= l_cap || (list.first_unevaluated(l_cap).is_none() && t_limit >= list.len())
        {
            break;
        }
        t_limit = (t_limit + params.t_step).min(l_cap);
    }

    let t_eff = t_limit.min(list.len());
    if t_eff == 0 {
        return vec![];
    }
    let boundary = list.items[t_eff - 1].dist;
    let threshold = if features.beta_rerank {
        if boundary >= 0.0 {
            boundary * params.beta
        } else {
            boundary / params.beta
        }
    } else {
        boundary
    };
    let mut final_cands: Vec<(f32, u32)> = Vec::new();
    for c in &list.items {
        let in_working = final_cands.len() < t_eff;
        if !(c.dist <= threshold || in_working) {
            continue;
        }
        let d = *exact_cache
            .entry(c.id)
            .or_insert_with(|| ctx.metric.distance(q, ctx.base.row(c.id as usize)));
        final_cands.push((d, c.id));
    }
    final_cands.sort_unstable_by(|a, b| {
        a.0.partial_cmp(&b.0).unwrap().then_with(|| a.1.cmp(&b.1))
    });
    final_cands.truncate(k);
    final_cands.into_iter().map(|(_, v)| v).collect()
}

#[test]
fn pq_walk_reproduces_seed_ids() {
    let f = fixture();
    let c = ctx(&f);
    for qi in 0..f.ds.n_queries() {
        let q = f.ds.queries.row(qi);
        let adt = f.cb.build_adt(q);
        let want = oracle_pq(&c, &adt, q, 10, 50, 30);
        // Traced runs use the same Bloom visited set as the seed: ids
        // must match exactly.
        let got = pq_beam_search(&c, &adt, q, 10, 50, 30, true);
        assert_eq!(got.ids, want, "query {qi}: PQ walk diverged from seed");
    }
}

#[test]
fn proxima_reproduces_seed_ids() {
    let f = fixture();
    let c = ctx(&f);
    let params = SearchParams {
        l: 80,
        k: 10,
        ..Default::default()
    };
    for qi in 0..f.ds.n_queries() {
        let q = f.ds.queries.row(qi);
        let adt = f.cb.build_adt(q);
        let want = oracle_proxima(&c, &adt, q, &params, ProximaFeatures::default());
        let got = proxima_search(&c, &adt, q, &params, ProximaFeatures::default(), true);
        assert_eq!(got.ids, want, "query {qi}: Proxima diverged from seed");
    }
}

#[test]
fn accurate_walk_matches_seed_then_beats_it_with_exact_visited() {
    let f = fixture();
    let c = ctx(&f);
    let gt = brute_force(&f.ds, 10);
    let mut oracle_recall = 0.0;
    let mut exact_recall = 0.0;
    for qi in 0..f.ds.n_queries() {
        let q = f.ds.queries.row(qi);
        let want = oracle_accurate(&c, q, 10, 50);
        // Bloom path: exact id parity with the seed.
        let traced = accurate_beam_search(&c, q, 10, 50, true);
        assert_eq!(traced.ids, want, "query {qi}: accurate walk diverged");
        // Exact-visited path: no false-positive drops, so recall must
        // match-or-beat the seed's Bloom-based walk.
        let exact = accurate_beam_search(&c, q, 10, 50, false);
        oracle_recall += recall_at_k(&want, gt.row(qi), 10);
        exact_recall += recall_at_k(&exact.ids, gt.row(qi), 10);
    }
    let n = f.ds.n_queries() as f64;
    // At this fixture scale (<=800 Bloom inserts in 12 kB / 8 hashes) the
    // false-positive probability is ~1e-10, so the two walks are almost
    // surely identical; the small tolerance guards the astronomically
    // unlikely eviction-cascade case where one Bloom drop happens to help.
    assert!(
        exact_recall / n >= oracle_recall / n - 0.02,
        "exact visited set must not lose recall: {} vs {}",
        exact_recall / n,
        oracle_recall / n
    );
}

#[test]
fn pq_exact_visited_matches_or_beats_seed_recall() {
    let f = fixture();
    let c = ctx(&f);
    let gt = brute_force(&f.ds, 10);
    let mut oracle_recall = 0.0;
    let mut exact_recall = 0.0;
    for qi in 0..f.ds.n_queries() {
        let q = f.ds.queries.row(qi);
        let adt = f.cb.build_adt(q);
        let want = oracle_pq(&c, &adt, q, 10, 50, 30);
        let exact = pq_beam_search(&c, &adt, q, 10, 50, 30, false);
        oracle_recall += recall_at_k(&want, gt.row(qi), 10);
        exact_recall += recall_at_k(&exact.ids, gt.row(qi), 10);
    }
    let n = f.ds.n_queries() as f64;
    // At this fixture scale (<=800 Bloom inserts in 12 kB / 8 hashes) the
    // false-positive probability is ~1e-10, so the two walks are almost
    // surely identical; the small tolerance guards the astronomically
    // unlikely eviction-cascade case where one Bloom drop happens to help.
    assert!(
        exact_recall / n >= oracle_recall / n - 0.02,
        "exact visited set must not lose recall: {} vs {}",
        exact_recall / n,
        oracle_recall / n
    );
}
