//! Tiered-storage acceptance tests (ISSUE 5): opening one artifact
//! `Resident`, `Cold` and `Tiered` must serve all three kernel modes
//! with bitwise-identical `SearchOutput`s; `Tiered` DRAM must scale
//! with `hot_frac`, not `n_base`; and every storage failure — truncated
//! BASE section at open, short reads after open — must surface as a
//! typed error, never a torn result.

use proxima::api::{ApiErrorCode, QueryOptions, QueryRequest, SearchMode};
use proxima::artifact::{ArtifactErrorKind, ArtifactParts};
use proxima::config::{GraphParams, PqParams, SearchParams};
use proxima::coordinator::SearchService;
use proxima::dataset::synth::tiny_uniform;
use proxima::dataset::Dataset;
use proxima::distance::Metric;
use proxima::reorder::{ReorderedIndex, VisitProfile};
use proxima::storage::cache::CachePolicy;
use proxima::storage::{OpenOptions, Residency};
use std::path::PathBuf;

fn tmpdir() -> PathBuf {
    let d = std::env::temp_dir().join(format!("proxima-storage-parity-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn service(seed: u64) -> (Dataset, SearchService) {
    let ds = tiny_uniform(400, 12, Metric::L2, seed);
    let svc = SearchService::build(
        &ds,
        &GraphParams {
            r: 12,
            build_l: 24,
            alpha: 1.2,
            seed,
        },
        &PqParams {
            m: 6,
            c: 32,
            train_sample: 400,
            kmeans_iters: 6,
        },
        SearchParams {
            l: 80,
            k: 10,
            ..Default::default()
        },
        false,
    );
    (ds, svc)
}

const MODES: [SearchMode; 3] = [SearchMode::Accurate, SearchMode::PqAdt, SearchMode::Hybrid];

fn open_each(path: &PathBuf, params: SearchParams) -> Vec<SearchService> {
    [Residency::Resident, Residency::Cold, Residency::Tiered]
        .into_iter()
        .map(|r| {
            SearchService::open_with(path, params, false, &OpenOptions::with_residency(r))
                .unwrap_or_else(|e| panic!("open {} failed: {e}", r.name()))
        })
        .collect()
}

/// Acceptance: the same artifact, opened under every residency, answers
/// every mode bitwise-identically — the storage tier is invisible to
/// results; only the metered cold traffic differs.
#[test]
fn all_residencies_answer_bitwise_identically_in_every_mode() {
    let (ds, built) = service(7);
    let path = tmpdir().join("parity.pxa");
    built.save(&path).unwrap();
    let opened = open_each(&path, built.params);

    for mode in MODES {
        let opts = QueryOptions {
            mode,
            want_stats: true,
            ..Default::default()
        };
        for qi in 0..ds.n_queries() {
            let req = QueryRequest::single(ds.queries.row(qi), 10).with_options(opts);
            let resident = opened[0].query(&req).unwrap();
            for svc in &opened[1..] {
                let got = svc.query(&req).unwrap();
                let name = svc.storage.residency().name();
                assert_eq!(
                    got.results[0].ids, resident.results[0].ids,
                    "{mode:?} query {qi}: {name} ids diverge"
                );
                let a: Vec<u32> = resident.results[0].dists.iter().map(|d| d.to_bits()).collect();
                let b: Vec<u32> = got.results[0].dists.iter().map(|d| d.to_bits()).collect();
                assert_eq!(a, b, "{mode:?} query {qi}: {name} dists not bitwise equal");
                // Every mode ends in exact-distance work, which under
                // cold residency is file reads — metered per query.
                let stats = got.stats.as_ref().unwrap();
                assert!(
                    stats.cold_reads > 0,
                    "{mode:?} query {qi}: {name} reported no cold reads"
                );
                assert_eq!(stats.cold_bytes, stats.cold_reads as u64 * ds.dim() as u64 * 4);
            }
            assert_eq!(
                resident.stats.as_ref().unwrap().cold_reads,
                0,
                "resident serving must never touch the cold tier"
            );
        }
    }
    // This spec has hot_frac = 0 (no reordering), so Tiered degrades to
    // an empty hot tier: zero vector bytes resident, like Cold. Resident
    // DRAM counts the SIMD-padded rows (dim 12 pads to stride 16).
    assert_eq!(
        opened[0].storage.resident_bytes(),
        400 * proxima::simd::stride_for(12) as u64 * 4
    );
    assert_eq!(opened[1].storage.resident_bytes(), 0);
    assert_eq!(opened[2].storage.resident_bytes(), 0);
    assert_eq!(opened[2].storage.n_hot(), 0);
    // Epoch-level counters accumulated on the cold services.
    use std::sync::atomic::Ordering;
    assert!(opened[1].stats.cold_reads.load(Ordering::Relaxed) > 0);
    assert_eq!(opened[0].stats.cold_reads.load(Ordering::Relaxed), 0);
    std::fs::remove_file(&path).ok();
}

/// Acceptance: on a REORDER-bearing deployment artifact, `Tiered` pins
/// exactly the `hot_frac` prefix — serving DRAM scales with `hot_frac`,
/// not `n_base` — while answers (in ORIGINAL id space) stay identical
/// across residencies, and the hot tier demonstrably absorbs reads.
#[test]
fn tiered_residency_pins_hot_frac_not_n_base_on_reordered_artifacts() {
    let (ds, svc) = service(41);
    let base = svc.resident_base().unwrap();
    let profile = VisitProfile::measure(
        &base,
        &svc.graph,
        &svc.codebook,
        &svc.codes,
        &svc.params,
        20,
        41,
    );
    let re = ReorderedIndex::build(&svc.graph, &svc.codes, &profile, 0.1);
    let path = tmpdir().join("reordered-parity.pxa");
    re.write_artifact(&svc.spec, &base, &svc.codebook, &path).unwrap();

    let opened = open_each(&path, svc.params);
    let stride_bytes = proxima::simd::stride_for(ds.dim()) as u64 * 4;
    assert_eq!(opened[2].storage.n_hot(), re.n_hot);
    assert_eq!(
        opened[2].storage.resident_bytes(),
        re.n_hot as u64 * stride_bytes,
        "tiered DRAM must be hot_frac-sized (padded rows)"
    );
    assert_eq!(
        opened[0].storage.resident_bytes(),
        ds.n_base() as u64 * stride_bytes,
        "resident DRAM scales with n_base"
    );
    assert!(opened[2].storage.resident_bytes() < opened[0].storage.resident_bytes() / 5);

    let mut cold_reads = [0u64; 3];
    for mode in MODES {
        let opts = QueryOptions {
            mode,
            want_stats: true,
            ..Default::default()
        };
        for qi in 0..ds.n_queries() {
            let req = QueryRequest::single(ds.queries.row(qi), 10).with_options(opts);
            let resident = opened[0].query(&req).unwrap();
            for (s, svc) in opened.iter().enumerate() {
                let got = svc.query(&req).unwrap();
                assert_eq!(
                    got.results[0].ids,
                    resident.results[0].ids,
                    "{mode:?} query {qi}: {} ids diverge on the reordered artifact",
                    svc.storage.residency().name()
                );
                assert_eq!(got.results[0].dists, resident.results[0].dists);
                cold_reads[s] += got.stats.as_ref().unwrap().cold_reads as u64;
            }
        }
    }
    assert_eq!(cold_reads[0], 0);
    assert!(cold_reads[1] > 0);
    // The frequency-ordered hot prefix absorbs fetches: tiered serving
    // must do strictly fewer cold reads than fully-cold serving.
    assert!(
        cold_reads[2] < cold_reads[1],
        "tiered {} !< cold {}",
        cold_reads[2],
        cold_reads[1]
    );
    std::fs::remove_file(&path).ok();
}

/// ISSUE 8 acceptance: the adaptive-cache residencies — `cached` (cold
/// + S3-FIFO row cache), `cached` with the CLOCK fallback, and `tiered`
/// with a cache layered under the pinned prefix — answer every mode
/// bitwise-identically to resident serving, and their hit/miss counters
/// obey the invariants (every miss is a metered cold read; hits appear
/// once the working set re-reads rows; evictions only under pressure).
#[test]
fn cached_residencies_answer_bitwise_identically_in_every_mode() {
    let (ds, built) = service(17);
    let path = tmpdir().join("cached-parity.pxa");
    built.save(&path).unwrap();

    let slot = proxima::simd::stride_for(ds.dim()) as u64 * 4;
    // 40 of 400 rows fit: small enough to force evictions under search.
    let cap = 40 * slot;
    let resident = SearchService::open(&path, built.params, false).unwrap();
    let cached_opts = |policy| OpenOptions {
        residency: Residency::Cached {
            capacity_bytes: cap,
        },
        cache_policy: policy,
        tiered_cache_bytes: None,
        lsh_start: false,
    };
    let opened = vec![
        SearchService::open_with(&path, built.params, false, &cached_opts(CachePolicy::S3Fifo))
            .unwrap(),
        SearchService::open_with(&path, built.params, false, &cached_opts(CachePolicy::Clock))
            .unwrap(),
        SearchService::open_with(
            &path,
            built.params,
            false,
            &OpenOptions {
                residency: Residency::Tiered,
                cache_policy: CachePolicy::S3Fifo,
                tiered_cache_bytes: Some(cap),
                lsh_start: false,
            },
        )
        .unwrap(),
    ];

    for mode in MODES {
        let opts = QueryOptions {
            mode,
            want_stats: true,
            ..Default::default()
        };
        // Two passes so the second revisits cached rows (hits > 0).
        for pass in 0..2 {
            for qi in 0..ds.n_queries() {
                let req = QueryRequest::single(ds.queries.row(qi), 10).with_options(opts);
                let want = resident.query(&req).unwrap();
                for svc in &opened {
                    let got = svc.query(&req).unwrap();
                    let name = svc.storage.residency().name();
                    assert_eq!(
                        got.results[0].ids, want.results[0].ids,
                        "{mode:?} pass {pass} query {qi}: {name} ids diverge"
                    );
                    let a: Vec<u32> =
                        want.results[0].dists.iter().map(|d| d.to_bits()).collect();
                    let b: Vec<u32> =
                        got.results[0].dists.iter().map(|d| d.to_bits()).collect();
                    assert_eq!(
                        a, b,
                        "{mode:?} pass {pass} query {qi}: {name} dists not bitwise equal"
                    );
                    // Per-query invariant: a cache miss IS a cold read.
                    let stats = got.stats.as_ref().unwrap();
                    assert_eq!(
                        stats.cache_misses, stats.cold_reads,
                        "{mode:?} {name}: every miss must be a metered cold read"
                    );
                }
            }
        }
    }

    use std::sync::atomic::Ordering;
    for svc in &opened {
        let name = svc.storage.residency().name();
        let cs = svc.storage.cache_status().expect("cache residency");
        // Epoch counters and the cache's own counters must agree.
        assert_eq!(
            cs.hits,
            svc.stats.cache_hits.load(Ordering::Relaxed),
            "{name}: hit counters disagree"
        );
        assert_eq!(
            cs.misses,
            svc.stats.cache_misses.load(Ordering::Relaxed),
            "{name}: miss counters disagree"
        );
        assert!(cs.hits > 0, "{name}: repeated queries must hit the cache");
        assert!(cs.misses > 0, "{name}: a 10% cache must still miss");
        assert!(
            cs.evictions > 0,
            "{name}: an over-subscribed cache must evict"
        );
        assert!(cs.evictions <= cs.misses, "{name}: evictions outnumber admissions");
        assert!(cs.hit_rate() > 0.0 && cs.hit_rate() < 1.0);
        assert_eq!(cs.capacity_bytes, cap);
        // Ghost readmissions only exist under S3-FIFO.
        if cs.policy == CachePolicy::Clock {
            assert_eq!(cs.ghost_hits, 0, "CLOCK has no ghost queue");
        }
    }
    // The cached stores pin only the slot arena, not the base.
    assert!(opened[0].storage.resident_bytes() <= cap + slot);
    std::fs::remove_file(&path).ok();
}

/// Cached residencies on a REORDER-bearing artifact: answers stay in
/// the ORIGINAL id space and bitwise-match resident serving, and
/// layering the cache under the tiered prefix strictly reduces cold
/// reads vs the same prefix without a cache.
#[test]
fn cached_residencies_match_resident_on_reordered_artifacts() {
    let (ds, svc) = service(43);
    let base = svc.resident_base().unwrap();
    let profile = VisitProfile::measure(
        &base,
        &svc.graph,
        &svc.codebook,
        &svc.codes,
        &svc.params,
        20,
        43,
    );
    let re = ReorderedIndex::build(&svc.graph, &svc.codes, &profile, 0.1);
    let path = tmpdir().join("cached-reordered.pxa");
    re.write_artifact(&svc.spec, &base, &svc.codebook, &path).unwrap();

    let slot = proxima::simd::stride_for(ds.dim()) as u64 * 4;
    let cap = 40 * slot;
    let resident = SearchService::open(&path, svc.params, false).unwrap();
    let tiered = SearchService::open_with(
        &path,
        svc.params,
        false,
        &OpenOptions::with_residency(Residency::Tiered),
    )
    .unwrap();
    let tiered_cached = SearchService::open_with(
        &path,
        svc.params,
        false,
        &OpenOptions {
            residency: Residency::Tiered,
            cache_policy: CachePolicy::S3Fifo,
            tiered_cache_bytes: Some(cap),
            lsh_start: false,
        },
    )
    .unwrap();
    let cached = SearchService::open_with(
        &path,
        svc.params,
        false,
        &OpenOptions {
            residency: Residency::Cached {
                capacity_bytes: cap,
            },
            cache_policy: CachePolicy::S3Fifo,
            tiered_cache_bytes: None,
            lsh_start: false,
        },
    )
    .unwrap();

    for mode in MODES {
        let opts = QueryOptions {
            mode,
            want_stats: true,
            ..Default::default()
        };
        for _pass in 0..2 {
            for qi in 0..ds.n_queries() {
                let req = QueryRequest::single(ds.queries.row(qi), 10).with_options(opts);
                let want = resident.query(&req).unwrap();
                for svc in [&tiered_cached, &cached] {
                    let got = svc.query(&req).unwrap();
                    assert_eq!(
                        got.results[0].ids,
                        want.results[0].ids,
                        "{mode:?} query {qi}: {} ids diverge on reordered artifact",
                        svc.storage.residency().name()
                    );
                    assert_eq!(got.results[0].dists, want.results[0].dists);
                }
                let _ = tiered.query(&req).unwrap();
            }
        }
    }
    use std::sync::atomic::Ordering;
    let plain = tiered.stats.cold_reads.load(Ordering::Relaxed);
    let layered = tiered_cached.stats.cold_reads.load(Ordering::Relaxed);
    assert!(
        layered < plain,
        "cache under the tiered prefix must absorb cold reads: {layered} !< {plain}"
    );
    assert!(tiered_cached.storage.cache_status().unwrap().hits > 0);
    std::fs::remove_file(&path).ok();
}

/// Storage failure paths are typed: a BASE section truncated or
/// corrupted on disk is rejected at cold open (the streaming validation
/// pass), and a file shrinking AFTER a cold open turns the affected
/// queries into per-query `internal` errors — not torn results, not a
/// dead process.
#[test]
fn truncated_and_corrupt_base_sections_are_typed_errors() {
    let dir = tmpdir();
    let (ds, svc) = service(13);
    let path = dir.join("failures.pxa");
    svc.save(&path).unwrap();
    let good = std::fs::read(&path).unwrap();

    // Truncation inside the BASE payload (BASE is the first section, so
    // any cut below ~19 KB lands in it): typed, never a panic.
    for frac in [0.3, 0.6, 0.95] {
        let cut = (good.len() as f64 * frac) as usize;
        let t = dir.join("trunc.pxa");
        std::fs::write(&t, &good[..cut]).unwrap();
        let e = SearchService::open_with(
            &t,
            svc.params,
            false,
            &OpenOptions::with_residency(Residency::Cold),
        )
        .unwrap_err();
        assert!(
            matches!(e.kind, ArtifactErrorKind::Truncated | ArtifactErrorKind::Corrupt),
            "cut at {cut}: {e}"
        );
    }

    // A flipped byte inside the BASE rows is caught by the streaming
    // CRC pass even though the payload is never materialized.
    let mut flipped = good.clone();
    flipped[1000] ^= 0x20;
    let f = dir.join("flip.pxa");
    std::fs::write(&f, &flipped).unwrap();
    let e = SearchService::open_with(
        &f,
        svc.params,
        false,
        &OpenOptions::with_residency(Residency::Cold),
    )
    .unwrap_err();
    assert_eq!(e.kind, ArtifactErrorKind::Corrupt, "{e}");

    // Post-open short read: open cold, then shrink the file underneath
    // the serving handle. The affected query is answered as a typed
    // per-query `internal` error through the query API.
    let cold = SearchService::open_with(
        &path,
        svc.params,
        false,
        &OpenOptions::with_residency(Residency::Cold),
    )
    .unwrap();
    let ok = cold
        .query(&QueryRequest::single(ds.queries.row(0), 5))
        .unwrap();
    assert_eq!(ok.results[0].ids.len(), 5);
    std::fs::OpenOptions::new()
        .write(true)
        .open(&path)
        .unwrap()
        .set_len(64)
        .unwrap();
    let resp = cold
        .query(&QueryRequest::single(ds.queries.row(0), 5))
        .unwrap();
    let e = resp.error_for(0).expect("short read must fail the query");
    assert_eq!(e.code, ApiErrorCode::Internal);
    assert!(resp.results[0].ids.is_empty());
    std::fs::remove_file(&path).ok();
}

/// The cold open performs the SAME angular unit-norm validation the
/// resident open does — streamed, without materializing the payload.
#[test]
fn cold_open_rejects_unnormalized_angular_bases() {
    let dir = tmpdir();
    let ds = tiny_uniform(80, 6, Metric::Angular, 3);
    let svc = SearchService::build(
        &ds,
        &GraphParams {
            r: 6,
            build_l: 12,
            alpha: 1.2,
            seed: 3,
        },
        &PqParams {
            m: 3,
            c: 8,
            train_sample: 80,
            kmeans_iters: 4,
        },
        SearchParams::default(),
        false,
    );
    let mut bad_base = svc.resident_base().unwrap();
    for x in bad_base.data.iter_mut() {
        *x *= 2.0;
    }
    let path = dir.join("bad-angular.pxa");
    ArtifactParts {
        spec: &svc.spec,
        base: &bad_base,
        graph: &svc.graph,
        gap: None,
        codebook: &svc.codebook,
        codes: &svc.codes,
        reorder: None,
        mapping: None,
        lsh: None,
    }
    .write(&path)
    .unwrap();
    let e = SearchService::open_with(
        &path,
        svc.params,
        false,
        &OpenOptions::with_residency(Residency::Cold),
    )
    .unwrap_err();
    assert_eq!(e.kind, ArtifactErrorKind::Corrupt);
    assert!(e.message.contains("unnormalized"), "{e}");
    std::fs::remove_file(&path).ok();
}

/// Batch serving over the exec pool works against a cold store (the
/// file handle is shared by positioned reads, no cursor, no locks) and
/// still matches resident batch results.
#[test]
fn cold_batches_on_the_worker_pool_match_resident() {
    let (ds, built) = service(29);
    let path = tmpdir().join("pool.pxa");
    built.save(&path).unwrap();
    let resident = SearchService::open(&path, built.params, false)
        .unwrap()
        .with_workers(4);
    let cold = SearchService::open_with(
        &path,
        built.params,
        false,
        &OpenOptions::with_residency(Residency::Cold),
    )
    .unwrap()
    .with_workers(4);
    let queries: Vec<&[f32]> = (0..ds.n_queries()).map(|i| ds.queries.row(i)).collect();
    let a = resident.search_batch(&queries, 10);
    let b = cold.search_batch(&queries, 10);
    for (qi, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.ids, y.ids, "query {qi}: pooled cold batch diverges");
        assert_eq!(x.dists, y.dists);
    }
    std::fs::remove_file(&path).ok();
}
