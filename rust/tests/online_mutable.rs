//! Integration tests for the online write plane (ISSUE 7 acceptance
//! criteria): an insert is findable the moment it returns, a delete is
//! excluded the moment it returns (while staying traversable), and
//! `flush` → `open` round-trips — the successor service and a fresh
//! open of the flushed artifact answer bitwise-identically, the spec is
//! re-stamped to the live count, and recall after 10% churn + flush
//! stays within two points of a fresh build over the same vectors.

use proxima::config::{GraphParams, PqParams, SearchParams};
use proxima::coordinator::SearchService;
use proxima::dataset::ground_truth::brute_force;
use proxima::dataset::synth::tiny_uniform;
use proxima::dataset::{recall_at_k, Dataset, VectorSet};
use proxima::distance::Metric;
use std::path::PathBuf;

fn tmpdir() -> PathBuf {
    let d = std::env::temp_dir().join(format!("proxima-online-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn service(seed: u64) -> (Dataset, SearchService) {
    let ds = tiny_uniform(400, 12, Metric::L2, seed);
    let svc = SearchService::build(
        &ds,
        &GraphParams {
            r: 12,
            build_l: 24,
            alpha: 1.2,
            seed,
        },
        &PqParams {
            m: 6,
            c: 32,
            train_sample: 400,
            kmeans_iters: 6,
        },
        SearchParams {
            l: 100,
            k: 10,
            ..Default::default()
        },
        false,
    );
    (ds, svc)
}

/// Acceptance: an inserted vector is returnable by the very next query;
/// a deleted one is excluded by the very next query (and the delete is
/// idempotent). Epochs advance monotonically through both.
#[test]
fn insert_is_findable_and_delete_is_excluded_immediately() {
    let (ds, svc) = service(51);
    let probe = ds.queries.row(0);

    let e0 = svc.online_epoch();
    let (id, e1) = svc.insert(probe).unwrap();
    assert_eq!(id as usize, ds.n_base(), "first insert takes the next id");
    assert!(e1 > e0);
    let found = svc.search(probe, 1);
    assert_eq!(
        found.ids,
        vec![id],
        "an exact duplicate of the query must be its own nearest neighbor"
    );
    assert_eq!(svc.exact_nn_live(probe, 1), vec![id]);

    let (deleted, e2) = svc.delete(id).unwrap();
    assert!(deleted);
    assert!(e2 > e1);
    let gone = svc.search(probe, 10);
    assert!(
        !gone.ids.contains(&id),
        "a tombstoned id must never appear in results"
    );
    assert!(!svc.exact_nn_live(probe, 10).contains(&id));
    // Idempotent: the second delete is a no-op, not an error.
    let (again, _) = svc.delete(id).unwrap();
    assert!(!again);
}

/// Acceptance: flush → open round-trips. The successor service the
/// flush returns and a FRESH open of the flushed artifact answer
/// bitwise-identically; the spec is re-stamped to the live count; and
/// through `FlushOutcome::new_to_old` the compacted answers match the
/// live (pre-flush) index on surviving ids.
#[test]
fn flush_open_round_trip_matches_live_on_surviving_ids() {
    let (ds, svc) = service(53);
    let k = 10;
    let extra = tiny_uniform(20, 12, Metric::L2, 530);
    for i in 0..20 {
        svc.insert(extra.base.row(i)).unwrap();
    }

    // Victims chosen OUTSIDE the current result lists, so the surviving
    // answers have a stable reference to compare against.
    let queries: Vec<&[f32]> = (0..8).map(|qi| ds.queries.row(qi)).collect();
    let mut in_results = std::collections::HashSet::new();
    for q in &queries {
        in_results.extend(svc.search(q, k).ids);
    }
    let victims: Vec<u32> = (0..ds.n_base() as u32)
        .filter(|id| !in_results.contains(id))
        .take(20)
        .collect();
    assert_eq!(victims.len(), 20);
    for &v in &victims {
        svc.delete(v).unwrap();
    }
    // Live answers AFTER the full churn (periodic repair splices change
    // traversal, so this is the reference state the flush compacts).
    let live: Vec<Vec<u32>> = queries.iter().map(|q| svc.search(q, k).ids).collect();

    let path = tmpdir().join("flush-roundtrip.pxa");
    let fo = svc.flush(Some(&path)).unwrap();
    assert_eq!(fo.n_live, 400, "20 in, 20 out");
    assert_eq!(fo.service.spec.n_base, 400, "spec must be re-stamped");
    assert_eq!(fo.new_to_old.len(), 400);
    assert!(fo.epoch > 0);

    // The successor and a fresh open of the artifact are the same index:
    // bitwise-identical answers on every query.
    let reopened = SearchService::open(&path, svc.params, false).unwrap();
    assert_eq!(reopened.spec, fo.service.spec);
    for (qi, q) in queries.iter().enumerate() {
        let a = fo.service.search(q, k);
        let b = reopened.search(q, k);
        assert_eq!(a.ids, b.ids, "query {qi}: flushed vs reopened ids");
        assert_eq!(a.dists, b.dists, "query {qi}: flushed vs reopened dists");
    }

    // Surviving-id match against the live index: every compacted answer
    // maps back to a LIVE pre-flush id, and the mapped top-k keeps a
    // strong majority of the live top-k (compaction splices the victims'
    // backlinks and re-prunes, so exact list equality is not promised).
    for (qi, q) in queries.iter().enumerate() {
        let flushed_ids = fo.service.search(q, k).ids;
        let mapped: Vec<u32> = flushed_ids
            .iter()
            .map(|&new| fo.new_to_old[new as usize])
            .collect();
        assert!(
            mapped.iter().all(|old| !victims.contains(old)),
            "query {qi}: a flushed answer resolved to a deleted id"
        );
        let overlap = mapped.iter().filter(|old| live[qi].contains(old)).count();
        assert!(
            overlap * 10 >= k * 6,
            "query {qi}: only {overlap}/{k} of the live answers survived the flush"
        );
    }
    std::fs::remove_file(&path).ok();
}

/// With zero deletions the compaction renumbering is the identity: the
/// flushed index answers in exactly the pre-flush id space.
#[test]
fn flush_without_deletions_preserves_ids() {
    let (ds, svc) = service(59);
    let extra = tiny_uniform(10, 12, Metric::L2, 590);
    for i in 0..10 {
        svc.insert(extra.base.row(i)).unwrap();
    }
    let live: Vec<Vec<u32>> = (0..8).map(|qi| svc.search(ds.queries.row(qi), 10).ids).collect();
    let path = tmpdir().join("flush-identity.pxa");
    let fo = svc.flush(Some(&path)).unwrap();
    assert!(fo.new_to_old.iter().enumerate().all(|(new, &old)| new as u32 == old));
    for (qi, expect) in live.iter().enumerate() {
        assert_eq!(
            &fo.service.search(ds.queries.row(qi), 10).ids,
            expect,
            "query {qi}: no-deletion flush must answer identically"
        );
    }
    std::fs::remove_file(&path).ok();
}

/// Acceptance: recall after 10% churn + flush stays within two points
/// of a FRESH build over the exact same post-churn vectors — the
/// incremental graph (insert backlinks, repair splices, compaction
/// re-prune) must not rot relative to a from-scratch Vamana pass.
#[test]
fn recall_after_ten_percent_churn_and_flush_is_within_two_points_of_fresh() {
    let (ds, svc) = service(61);
    let k = 10;
    let n = ds.n_base();
    let churn = n / 10;
    let fresh_vecs = tiny_uniform(churn, 12, Metric::L2, 610);
    for i in 0..churn {
        svc.insert(fresh_vecs.base.row(i)).unwrap();
    }
    for id in 0..churn as u32 {
        let (deleted, _) = svc.delete(id).unwrap();
        assert!(deleted);
    }
    let path = tmpdir().join("flush-churn.pxa");
    let flushed = svc.flush(Some(&path)).unwrap();
    assert_eq!(flushed.n_live, n);

    // The post-churn vector set, in exactly the compacted id order:
    // survivors ascending (old ids churn..n), then the delta inserts in
    // insertion order — so flushed id i IS post-churn dataset id i.
    let dim = ds.dim();
    let mut data: Vec<f32> = Vec::with_capacity(n * dim);
    for old in churn..n {
        data.extend_from_slice(ds.base.row(old));
    }
    data.extend_from_slice(&fresh_vecs.base.data);
    let churned = Dataset {
        name: format!("{}-churned", ds.name),
        metric: ds.metric,
        base: VectorSet::new(dim, data),
        queries: ds.queries.clone(),
    };
    let gt = brute_force(&churned, k);
    let fresh = SearchService::build(
        &churned,
        &GraphParams {
            r: 12,
            build_l: 24,
            alpha: 1.2,
            seed: 61,
        },
        &PqParams {
            m: 6,
            c: 32,
            train_sample: n,
            kmeans_iters: 6,
        },
        svc.params,
        false,
    );

    let nq = churned.n_queries();
    let (mut r_flushed, mut r_fresh) = (0.0, 0.0);
    for qi in 0..nq {
        let q = churned.queries.row(qi);
        r_flushed += recall_at_k(&flushed.service.search(q, k).ids, gt.row(qi), k);
        r_fresh += recall_at_k(&fresh.search(q, k).ids, gt.row(qi), k);
    }
    r_flushed /= nq as f64;
    r_fresh /= nq as f64;
    assert!(
        r_flushed >= r_fresh - 0.02,
        "post-churn flushed recall {r_flushed:.4} fell more than 2 points \
         below the fresh build's {r_fresh:.4}"
    );
    std::fs::remove_file(&path).ok();
}
