//! Integration tests for the PJRT runtime against the built AOT artifacts
//! (the L3 ↔ L2 ↔ L1 seam). All tests skip gracefully when `artifacts/`
//! has not been built (`make artifacts`).

use proxima::dataset::synth::tiny_uniform;
use proxima::dataset::{ground_truth, VectorSet};
use proxima::distance::Metric;
use proxima::pq::PqCodebook;
use proxima::runtime::executor::XlaDistance;
use proxima::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    let rt = Runtime::open_default();
    if rt.is_none() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
    }
    rt
}

#[test]
fn adt_xla_matches_native_l2() {
    let Some(rt) = runtime() else { return };
    let ds = tiny_uniform(400, 128, Metric::L2, 1);
    let cb = PqCodebook::train(&ds.base, Metric::L2, 32, 256, 400, 6, 1);
    let dist = XlaDistance::new(&rt, Metric::L2, 128, 32, 256).unwrap();
    for qi in 0..5 {
        let q = ds.queries.row(qi);
        let a = dist.build_adt(&cb, q).unwrap();
        let b = cb.build_adt(q);
        assert_eq!(a.table.len(), b.table.len());
        for (x, y) in a.table.iter().zip(&b.table) {
            assert!((x - y).abs() < 1e-3 * y.abs().max(1.0), "{x} vs {y}");
        }
    }
}

#[test]
fn adt_xla_matches_native_all_dims_metrics() {
    let Some(rt) = runtime() else { return };
    for (dim, m) in [(128usize, 32usize), (96, 24), (100, 25)] {
        for metric in [Metric::L2, Metric::Ip, Metric::Angular] {
            let ds = tiny_uniform(300, dim, metric, 2);
            let cb = PqCodebook::train(&ds.base, metric, m, 256, 300, 4, 2);
            let dist = XlaDistance::new(&rt, metric, dim, m, 256)
                .unwrap_or_else(|e| panic!("bind {metric:?} d{dim}: {e:#}"));
            let q = ds.queries.row(0);
            let a = dist.build_adt(&cb, q).unwrap();
            let b = cb.build_adt(q);
            for (i, (x, y)) in a.table.iter().zip(&b.table).enumerate() {
                assert!(
                    (x - y).abs() < 2e-3 * y.abs().max(1.0),
                    "{metric:?} d{dim} entry {i}: {x} vs {y}"
                );
            }
        }
    }
}

#[test]
fn adt_batch_is_bitwise_identical_to_per_distinct_calls() {
    // The staged batch path submits ALL distinct queries to the runtime
    // thread in one request; the device still runs the per-query adt_*
    // executable, so the concatenated tables must match the per-distinct
    // path BIT FOR BIT — same executable, same inputs, same bias fold.
    let Some(rt) = runtime() else { return };
    let ds = tiny_uniform(400, 128, Metric::L2, 8);
    let cb = PqCodebook::train(&ds.base, Metric::L2, 32, 256, 400, 6, 8);
    let dist = XlaDistance::new(&rt, Metric::L2, 128, 32, 256).unwrap();
    let n = 7usize;
    let mut flat = Vec::with_capacity(n * 128);
    for qi in 0..n {
        flat.extend_from_slice(ds.queries.row(qi));
    }
    let batched = dist.build_adt_batch(&cb, &flat, n).unwrap();
    assert_eq!(batched.len(), n * 32 * 256);
    for qi in 0..n {
        let single = dist.build_adt(&cb, ds.queries.row(qi)).unwrap();
        let got = &batched[qi * single.table.len()..(qi + 1) * single.table.len()];
        assert!(
            got.iter()
                .zip(&single.table)
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "query {qi}: batched ADT table diverged bitwise from the per-distinct call"
        );
    }
}

#[test]
fn rerank_xla_matches_native() {
    let Some(rt) = runtime() else { return };
    for metric in [Metric::L2, Metric::Angular] {
        let ds = tiny_uniform(600, 128, metric, 3);
        let dist = XlaDistance::new(&rt, metric, 128, 32, 256).unwrap();
        let q = ds.queries.row(0);
        // More ids than one batch (256) to exercise padding + chunking.
        let ids: Vec<u32> = (0..300u32).collect();
        let got = dist.rerank(&ds.base, q, &ids).unwrap();
        assert_eq!(got.len(), 300);
        for (i, &id) in ids.iter().enumerate() {
            let want = metric.distance(q, ds.base.row(id as usize));
            assert!(
                (got[i] - want).abs() < 1e-2 * want.abs().max(1.0),
                "{metric:?} id {id}: {} vs {want}",
                got[i]
            );
        }
    }
}

#[test]
fn pq_scan_xla_matches_native() {
    let Some(rt) = runtime() else { return };
    let ds = tiny_uniform(700, 96, Metric::L2, 4);
    let cb = PqCodebook::train(&ds.base, Metric::L2, 24, 256, 700, 5, 4);
    let codes = cb.encode(&ds.base);
    let dist = XlaDistance::new(&rt, Metric::L2, 96, 24, 256).unwrap();
    let q = ds.queries.row(1);
    let adt = cb.build_adt(q);
    let ids: Vec<u32> = (0..600u32).collect(); // > scan batch of 512
    let got = dist.pq_scan(&adt, &codes, &ids).unwrap();
    for (i, &id) in ids.iter().enumerate() {
        let want = adt.pq_distance(codes.row(id as usize));
        assert!(
            (got[i] - want).abs() < 1e-3 * want.abs().max(1.0),
            "id {id}: {} vs {want}",
            got[i]
        );
    }
}

#[test]
fn ground_truth_xla_matches_bruteforce() {
    let Some(rt) = runtime() else { return };
    let ds = tiny_uniform(3000, 128, Metric::L2, 5);
    let dist = XlaDistance::new(&rt, Metric::L2, 128, 32, 256).unwrap();
    let gt_xla = dist.ground_truth(&ds.base, &ds.queries, 10).unwrap();
    let gt_ref = ground_truth::brute_force(&ds, 10);
    let mut agree = 0usize;
    let mut total = 0usize;
    for qi in 0..ds.n_queries() {
        let a: std::collections::HashSet<u32> = gt_xla.row(qi).iter().copied().collect();
        for id in gt_ref.row(qi) {
            total += 1;
            if a.contains(id) {
                agree += 1;
            }
        }
    }
    // f32 GEMM vs native may tie-break on equal distances; demand 99%.
    let frac = agree as f64 / total as f64;
    assert!(frac > 0.99, "agreement {frac}");
}

#[test]
fn service_with_xla_adt_end_to_end() {
    if Runtime::default_dir().join("manifest.json").exists() {
        use proxima::config::{GraphParams, PqParams, SearchParams};
        use proxima::coordinator::SearchService;
        // D=128/M=32 matches the artifact set.
        let ds = tiny_uniform(500, 128, Metric::L2, 6);
        let svc = SearchService::build(
            &ds,
            &GraphParams {
                r: 16,
                build_l: 32,
                alpha: 1.2,
                seed: 6,
            },
            &PqParams {
                m: 32,
                c: 256,
                train_sample: 500,
                kmeans_iters: 4,
            },
            SearchParams {
                l: 60,
                k: 10,
                ..Default::default()
            },
            true,
        );
        assert!(svc.runtime.is_some(), "runtime thread should attach");
        let gt = ground_truth::brute_force(&ds, 10);
        let mut recall = 0.0;
        for qi in 0..ds.n_queries() {
            let out = svc.search(ds.queries.row(qi), 10);
            recall += proxima::dataset::recall_at_k(&out.ids, gt.row(qi), 10);
        }
        recall /= ds.n_queries() as f64;
        assert!(recall > 0.75, "recall through XLA ADT path: {recall}");
    } else {
        eprintln!("skipping: artifacts/ not built");
    }
}

#[test]
fn xla_distance_rejects_unknown_shapes() {
    let Some(rt) = runtime() else { return };
    assert!(XlaDistance::new(&rt, Metric::L2, 77, 11, 256).is_err());
}

#[test]
fn vectorset_roundtrip_through_rerank_padding() {
    let Some(rt) = runtime() else { return };
    // Single id (heavy padding) must still be exact.
    let ds = tiny_uniform(50, 128, Metric::L2, 7);
    let dist = XlaDistance::new(&rt, Metric::L2, 128, 32, 256).unwrap();
    let q = ds.queries.row(0);
    let got = dist.rerank(&ds.base, q, &[17]).unwrap();
    let want = Metric::L2.distance(q, ds.base.row(17));
    assert!((got[0] - want).abs() < 1e-3 * want.max(1.0));
    let empty: Vec<f32> = dist.rerank(&ds.base, q, &[]).unwrap();
    assert!(empty.is_empty());
    let _ = VectorSet::new(2, vec![0.0, 0.0]);
}
