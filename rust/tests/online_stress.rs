//! Concurrency stress for the online write plane (ISSUE 7 acceptance):
//! one writer thread churning insert/delete against N query threads.
//! The contract under fire —
//!
//! * queries NEVER block on the writer (they pin published snapshots);
//!   no panic on either side;
//! * a query started after a delete returned never surfaces that id
//!   (readers track a deleted-id watermark the writer advances only
//!   AFTER each delete returns);
//! * the publish epoch is monotonic from every thread's view;
//! * the post-churn flush compacts to exactly the live census and the
//!   successor serves.
//!
//! CI runs this in release and again under `PROXIMA_FORCE_SCALAR=1`, so
//! snapshot pinning is exercised on both sides of the kernel dispatch.

use proxima::config::{GraphParams, PqParams, SearchParams};
use proxima::coordinator::SearchService;
use proxima::dataset::synth::tiny_uniform;
use proxima::distance::Metric;
use std::sync::atomic::{AtomicUsize, Ordering};

const N_BASE: usize = 400;
const DIM: usize = 12;
const INSERTS: usize = 150;
const DELETES: usize = 100;
const READERS: usize = 3;
const QUERIES_PER_READER: usize = 150;

#[test]
fn concurrent_writer_and_readers_uphold_the_snapshot_contract() {
    let ds = tiny_uniform(N_BASE, DIM, Metric::L2, 71);
    let svc = SearchService::build(
        &ds,
        &GraphParams {
            r: 12,
            build_l: 24,
            alpha: 1.2,
            seed: 71,
        },
        &PqParams {
            m: 6,
            c: 32,
            train_sample: N_BASE,
            kmeans_iters: 6,
        },
        SearchParams {
            l: 60,
            k: 5,
            ..Default::default()
        },
        false,
    );
    let fresh = tiny_uniform(INSERTS, DIM, Metric::L2, 710);

    // The writer deletes base ids ASCENDING and advances this watermark
    // only after each delete has returned — so any query that starts at
    // watermark w is guaranteed ids 0..w were already tombstoned, and
    // must not return them.
    let deleted_watermark = AtomicUsize::new(0);

    std::thread::scope(|scope| {
        let svc = &svc;
        let watermark = &deleted_watermark;
        let fresh = &fresh;
        let ds = &ds;

        scope.spawn(move || {
            let mut last_epoch = svc.online_epoch();
            for i in 0..INSERTS {
                let (id, e) = svc.insert(fresh.base.row(i)).unwrap();
                assert_eq!(id as usize, N_BASE + i, "delta ids are sequential");
                assert!(e > last_epoch, "insert must advance the epoch");
                last_epoch = e;
                if i < DELETES {
                    let (deleted, e) = svc.delete(i as u32).unwrap();
                    assert!(deleted, "base id {i} was live");
                    assert!(e > last_epoch, "delete must advance the epoch");
                    last_epoch = e;
                    watermark.store(i + 1, Ordering::Release);
                }
            }
        });

        for r in 0..READERS {
            scope.spawn(move || {
                let mut last_epoch = 0u64;
                for j in 0..QUERIES_PER_READER {
                    let w = watermark.load(Ordering::Acquire);
                    let q = ds.queries.row((r * QUERIES_PER_READER + j) % ds.n_queries());
                    let out = svc.search(q, 5);
                    assert_eq!(out.ids.len(), 5);
                    for &id in &out.ids {
                        assert!(
                            (id as usize) >= w,
                            "reader {r} query {j}: id {id} was tombstoned at watermark {w}"
                        );
                    }
                    let e = svc.online_epoch();
                    assert!(
                        e >= last_epoch,
                        "reader {r}: epoch went backwards ({e} < {last_epoch})"
                    );
                    last_epoch = e;
                }
            });
        }
    });

    // Post-churn census and a flush of the settled state: compaction
    // must land on exactly the live count and the successor must serve.
    assert_eq!(deleted_watermark.load(Ordering::Acquire), DELETES);
    let counters = svc.online.counters();
    assert_eq!(counters.inserts_total.load(Ordering::Relaxed), INSERTS as u64);
    assert_eq!(counters.deletes_total.load(Ordering::Relaxed), DELETES as u64);

    let path = std::env::temp_dir().join(format!("proxima-stress-{}.pxa", std::process::id()));
    let fo = svc.flush(Some(&path)).unwrap();
    assert_eq!(fo.n_live, N_BASE + INSERTS - DELETES);
    assert_eq!(fo.service.spec.n_base as usize, N_BASE + INSERTS - DELETES);
    assert!(fo.epoch > (INSERTS + DELETES) as u64);
    let out = fo.service.search(ds.queries.row(0), 5);
    assert_eq!(out.ids.len(), 5);
    // Nothing the successor returns maps back to a deleted id.
    for &id in &out.ids {
        assert!(
            fo.new_to_old[id as usize] as usize >= DELETES,
            "successor returned compacted id {id} mapping to a deleted base id"
        );
    }
    std::fs::remove_file(&path).ok();
}
