//! Cross-module integration tests: the full pipeline from synthetic data
//! through index build, Algorithm 1, serving, hardware simulation, and the
//! ablation switches — everything short of the XLA runtime (covered in
//! runtime_integration.rs).

use proxima::config::{GraphParams, PqParams, SearchParams};
use proxima::coordinator::batcher::{spawn, BatchPolicy};
use proxima::coordinator::server::{Client, Server};
use proxima::coordinator::{SearchService, ServiceCell};
use proxima::dataset::ground_truth::brute_force;
use proxima::dataset::synth::SynthSpec;
use proxima::dataset::{mean_recall, recall_at_k};
use proxima::figures::{self, Workbench};
use proxima::search::proxima::ProximaFeatures;
use std::sync::Arc;

/// The headline pipeline: registry dataset -> index -> Algorithm 1 ->
/// recall above the high-recall bar, with PQ doing the traversal work.
#[test]
fn pipeline_sift_like_high_recall() {
    let w = Workbench::get("sift-s", 0.02, 10);
    let ctx = w.context();
    let params = SearchParams {
        l: 120,
        k: 10,
        ..Default::default()
    };
    let mut results = Vec::new();
    let mut stats = proxima::search::SearchStats::default();
    for qi in 0..w.ds.n_queries() {
        let q = w.ds.queries.row(qi);
        let adt = w.codebook.build_adt(q);
        let out = proxima::search::proxima::proxima_search(
            &ctx,
            &adt,
            q,
            &params,
            ProximaFeatures::default(),
            false,
        );
        stats.add(&out.stats);
        results.push(out.ids);
    }
    let recall = mean_recall(&results, &w.gt, 10);
    assert!(recall > 0.9, "recall {recall}");
    // PQ distances dominate; accurate distances stay a bounded tail
    // (the paper's core complexity claim: thousands of PQ lookups vs
    // ~a hundred reranks — the ratio widens with dataset scale since
    // hops grow while the rerank tail stays ~L).
    assert!(
        stats.exact_dists * 2 < stats.pq_dists,
        "exact {} vs pq {}",
        stats.exact_dists,
        stats.pq_dists
    );
}

/// Every registry dataset builds and reaches reasonable recall.
#[test]
fn all_registry_datasets_work() {
    for spec in SynthSpec::registry(0.008) {
        let ds = spec.generate();
        let svc = SearchService::build(
            &ds,
            &GraphParams {
                r: 24,
                build_l: 48,
                alpha: 1.2,
                seed: 9,
            },
            &PqParams::for_dim(ds.dim()),
            SearchParams {
                l: 100,
                k: 10,
                ..Default::default()
            },
            false,
        );
        let gt = brute_force(&ds, 10);
        let mut recall = 0.0;
        let n_eval = ds.n_queries().min(60);
        for qi in 0..n_eval {
            let out = svc.search(ds.queries.row(qi), 10);
            recall += recall_at_k(&out.ids, gt.row(qi), 10);
        }
        recall /= n_eval as f64;
        assert!(recall > 0.6, "{}: recall {recall}", ds.name);
    }
}

/// Ablations move the metrics in the documented direction.
#[test]
fn ablation_switches_behave() {
    let w = Workbench::get("sift-s", 0.015, 10);
    let (t_full, s_full) = figures::collect_traces(&w, figures::Algo::Proxima, 100, 10);
    let (_t_noet, s_noet) = figures::collect_traces(&w, figures::Algo::ProximaNoEt, 100, 10);
    // Early termination saves PQ work.
    assert!(s_full.pq_dists <= s_noet.pq_dists);
    // Gap encoding saves index bytes vs uniform 32-b.
    let edges = w.graph.n_edges();
    assert!(w.gap.compression_ratio(edges) < 0.85);
    assert!(!t_full.is_empty());
}

/// TCP serving end-to-end with concurrent clients (no XLA dependency).
#[test]
fn serve_concurrent_clients_end_to_end() {
    let spec = SynthSpec::by_name("sift-s", 0.006).unwrap();
    let ds = spec.generate();
    let svc = Arc::new(SearchService::build(
        &ds,
        &GraphParams {
            r: 16,
            build_l: 32,
            alpha: 1.2,
            seed: 10,
        },
        &PqParams::for_dim(ds.dim()),
        SearchParams {
            l: 80,
            k: 10,
            ..Default::default()
        },
        false,
    ));
    let gt = brute_force(&ds, 10);
    let cell = Arc::new(ServiceCell::new(svc.clone()));
    let (handle, _join) = spawn(cell.clone(), BatchPolicy::default());
    let server = Server::start(cell, handle, 0).unwrap();
    let addr = server.addr;

    let recalls: Vec<f64> = std::thread::scope(|scope| {
        (0..3usize)
            .map(|c| {
                let ds = &ds;
                let gt = &gt;
                scope.spawn(move || {
                    let mut client = Client::connect(addr).unwrap();
                    let mut r = 0.0;
                    for i in 0..20 {
                        let qi = (c * 20 + i) % ds.n_queries();
                        let (ids, dists, _) = client.search(ds.queries.row(qi), 10).unwrap();
                        assert_eq!(ids.len(), 10);
                        assert!(dists.windows(2).all(|w| w[0] <= w[1] + 1e-6));
                        r += recall_at_k(&ids, gt.row(qi), 10);
                    }
                    r / 20.0
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    for r in &recalls {
        assert!(*r > 0.6, "client recall {r}");
    }
    server.stop();
}

/// Software search -> trace -> DES -> sane hardware numbers, at two hot
/// fractions (the full co-design loop).
#[test]
fn software_to_hardware_loop() {
    let w = Workbench::get("sift-s", 0.015, 10);
    let cfg = proxima::engine::EngineConfig::paper(w.ds.dim(), w.codebook.m);
    let (traces, _) = figures::collect_traces(&w, figures::Algo::Proxima, 80, 10);
    let cold = proxima::engine::sim::simulate(&cfg, &figures::default_mapping(&w, 0.0), &traces);
    assert!(cold.qps > 0.0 && cold.energy_j > 0.0);
    assert!(cold.core_utilization > 0.0 && cold.core_utilization <= 1.0);
    // Latency must exceed the physical floor: hops * one page read.
    let hops = traces[0]
        .ops
        .iter()
        .filter(|o| matches!(o, proxima::search::TraceOp::FetchIndex { .. }))
        .count();
    let floor_ns = hops as f64 * 200.0;
    assert!(
        cold.mean_latency_ns > floor_ns,
        "latency {} below physical floor {floor_ns}",
        cold.mean_latency_ns
    );

    let hot_traces = figures::fig13::proxima_hot_traces(&w, 80, 10, 0.03);
    let hot =
        proxima::engine::sim::simulate(&cfg, &figures::default_mapping(&w, 0.03), &hot_traces);
    assert!(hot.same_page_reads > cold.same_page_reads);
}

/// Reordering + hot nodes preserve search results exactly (id-mapped).
#[test]
fn reordering_preserves_results() {
    let w = Workbench::get("glove-s", 0.008, 10);
    let params = SearchParams {
        l: 60,
        k: 5,
        ..Default::default()
    };
    let profile = proxima::reorder::VisitProfile::measure(
        &w.ds.base,
        &w.graph,
        &w.codebook,
        &w.codes,
        &params,
        30,
        11,
    );
    let re = proxima::reorder::ReorderedIndex::build(&w.graph, &w.codes, &profile, 0.03);
    re.graph.validate().unwrap();
    // Hot nodes are the most frequently visited ones by construction:
    // check rank-0 is the entry point region (visited every query).
    assert!(re.n_hot > 0);
    let entry_new = re.perm[w.graph.entry_point as usize];
    assert!(
        (entry_new as usize) < w.graph.n() / 10,
        "entry point should be hot-ranked, got {entry_new}"
    );
}

/// Config-file driven parameterization reaches the search layer.
#[test]
fn config_file_roundtrip_to_params() {
    let text = "[search]\nl = 42\nbeta = 1.5\nt_step = 2\n[graph]\nr = 24\n";
    let cfg = proxima::config::Config::parse(text).unwrap();
    let sp = SearchParams::from_config(&cfg);
    assert_eq!(sp.l, 42);
    assert!((sp.beta - 1.5).abs() < 1e-6);
    assert_eq!(sp.t_step, 2);
    let gp = GraphParams::from_config(&cfg);
    assert_eq!(gp.r, 24);
}
