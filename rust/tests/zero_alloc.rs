//! Steady-state allocation checks for the query hot path: once scratch,
//! ADT tables and output buffers are warm, (1) answering a query must
//! perform ZERO heap allocations (the acceptance bar for the
//! `QueryScratch` pooling refactor — per-worker scratch persists across
//! batches), and (2) the staged batched ADT build must reuse its pooled
//! tables and dedup state across batches without allocating.
//!
//! The counting allocator tracks a thread-local counter so allocations
//! from other test-harness threads cannot pollute the measurement; each
//! test here runs its whole measured path on its own thread.

use proxima::config::{GraphParams, SearchParams};
use proxima::dataset::synth::tiny_uniform;
use proxima::distance::Metric;
use proxima::graph::vamana;
use proxima::pq::{Adt, AdtBatch, PqCodebook};
use proxima::search::beam::SearchContext;
use proxima::search::kernel::QueryScratch;
use proxima::search::proxima::{proxima_search_into, ProximaFeatures};
use proxima::search::SearchOutput;
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        THREAD_ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        THREAD_ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_query_path_does_not_allocate() {
    let ds = tiny_uniform(500, 16, Metric::L2, 77);
    let g = vamana::build(
        &ds.base,
        ds.metric,
        &GraphParams {
            r: 16,
            build_l: 32,
            alpha: 1.2,
            seed: 77,
        },
    );
    let cb = PqCodebook::train(&ds.base, ds.metric, 8, 32, 500, 6, 77);
    let codes = cb.encode(&ds.base);
    let ctx = SearchContext {
        base: &ds.base,
        metric: ds.metric,
        graph: &g,
        codes: Some(&codes),
        gap: None,
        storage: None,
        online: None,
        lsh: None,
    };
    let params = SearchParams {
        l: 60,
        k: 10,
        ..Default::default()
    };

    let mut scratch = QueryScratch::new();
    let mut adt = Adt::default();
    let mut out = SearchOutput::default();

    // Warm every pooled buffer with two full passes over the query set
    // (the second confirms sizes are stable before measuring).
    for _ in 0..2 {
        for qi in 0..ds.n_queries() {
            let q = ds.queries.row(qi);
            cb.build_adt_into(q, &mut adt);
            proxima_search_into(
                &ctx,
                &adt,
                q,
                &params,
                ProximaFeatures::default(),
                false,
                &mut scratch,
                &mut out,
            );
        }
    }

    // Measured pass: ADT build + full Proxima search per query, zero
    // heap traffic.
    let before = THREAD_ALLOCS.with(|c| c.get());
    let mut checksum = 0u32;
    for qi in 0..ds.n_queries() {
        let q = ds.queries.row(qi);
        cb.build_adt_into(q, &mut adt);
        proxima_search_into(
            &ctx,
            &adt,
            q,
            &params,
            ProximaFeatures::default(),
            false,
            &mut scratch,
            &mut out,
        );
        checksum = checksum.wrapping_add(out.ids[0]);
    }
    let allocs = THREAD_ALLOCS.with(|c| c.get()) - before;
    assert_eq!(
        allocs, 0,
        "steady-state query path allocated {allocs} times over {} queries (checksum {checksum})",
        ds.n_queries()
    );
    assert_eq!(out.ids.len(), 10);
}

#[test]
fn steady_state_query_path_with_obs_recording_does_not_allocate() {
    // Observability must hold the same bar as the bare kernel: stage
    // spans are recorded unconditionally into the Copy array pooled in
    // `QueryScratch`, and the full `obs::Metrics` sink per query —
    // engine + per-stage histograms (lock-free atomic tables) plus a
    // slow-query ring offer (preallocated, atomic-floor fast path) —
    // must add zero heap traffic on top.
    use proxima::obs::Metrics;

    let ds = tiny_uniform(500, 16, Metric::L2, 85);
    let g = vamana::build(
        &ds.base,
        ds.metric,
        &GraphParams {
            r: 16,
            build_l: 32,
            alpha: 1.2,
            seed: 85,
        },
    );
    let cb = PqCodebook::train(&ds.base, ds.metric, 8, 32, 500, 6, 85);
    let codes = cb.encode(&ds.base);
    let ctx = SearchContext {
        base: &ds.base,
        metric: ds.metric,
        graph: &g,
        codes: Some(&codes),
        gap: None,
        storage: None,
        online: None,
        lsh: None,
    };
    let params = SearchParams {
        l: 60,
        k: 10,
        ..Default::default()
    };
    let obs = Metrics::new();
    let mut scratch = QueryScratch::new();
    let mut adt = Adt::default();
    let mut out = SearchOutput::default();

    // Warm passes size the pooled buffers AND fill the slowlog ring, so
    // the measured pass exercises both its fast path (floor rejection)
    // and its replace-min path.
    for _ in 0..2 {
        for qi in 0..ds.n_queries() {
            let q = ds.queries.row(qi);
            cb.build_adt_into(q, &mut adt);
            proxima_search_into(
                &ctx,
                &adt,
                q,
                &params,
                ProximaFeatures::default(),
                false,
                &mut scratch,
                &mut out,
            );
            obs.record_query(&out.spans, &out.stats);
        }
    }

    let before = THREAD_ALLOCS.with(|c| c.get());
    for qi in 0..ds.n_queries() {
        let q = ds.queries.row(qi);
        cb.build_adt_into(q, &mut adt);
        proxima_search_into(
            &ctx,
            &adt,
            q,
            &params,
            ProximaFeatures::default(),
            false,
            &mut scratch,
            &mut out,
        );
        obs.record_query(&out.spans, &out.stats);
    }
    let allocs = THREAD_ALLOCS.with(|c| c.get()) - before;
    assert_eq!(
        allocs, 0,
        "instrumented steady-state query path allocated {allocs} times over {} queries",
        ds.n_queries()
    );
    // The sink really recorded: three passes of engine samples, and the
    // slowlog retained entries with live span payloads.
    assert_eq!(obs.engine_us.count(), 3 * ds.n_queries() as u64);
    assert!(!obs.slowlog().is_empty());
}

#[test]
fn steady_state_cold_reads_do_not_allocate() {
    // The cold storage tier must honor the same bar as the resident hot
    // path: once the pooled ReadBuf is sized (first cold fetch), a
    // query that reranks entirely off the artifact FILE performs zero
    // heap allocations — positioned reads land in the pooled buffer.
    use proxima::config::PqParams;
    use proxima::coordinator::SearchService;
    use proxima::storage::{OpenOptions, Residency};

    let ds = tiny_uniform(400, 16, Metric::L2, 79);
    let svc = SearchService::build(
        &ds,
        &GraphParams {
            r: 12,
            build_l: 24,
            alpha: 1.2,
            seed: 79,
        },
        &PqParams {
            m: 8,
            c: 32,
            train_sample: 400,
            kmeans_iters: 5,
        },
        SearchParams {
            l: 60,
            k: 10,
            ..Default::default()
        },
        false,
    );
    let path = std::env::temp_dir().join(format!("zero-alloc-cold-{}.pxa", std::process::id()));
    svc.save(&path).unwrap();
    let cold = SearchService::open_with(
        &path,
        svc.params,
        false,
        &OpenOptions::with_residency(Residency::Cold),
    )
    .unwrap();
    let ctx = SearchContext {
        base: cold.storage.base_stub(),
        metric: cold.metric,
        graph: &cold.graph,
        codes: Some(&cold.codes),
        gap: None,
        storage: Some(&cold.storage),
        online: None,
        lsh: None,
    };
    let params = SearchParams {
        l: 60,
        k: 10,
        ..Default::default()
    };
    let mut scratch = QueryScratch::new();
    let mut adt = Adt::default();
    let mut out = SearchOutput::default();
    for _ in 0..2 {
        for qi in 0..ds.n_queries() {
            let q = ds.queries.row(qi);
            cold.codebook.build_adt_into(q, &mut adt);
            proxima_search_into(
                &ctx,
                &adt,
                q,
                &params,
                ProximaFeatures::default(),
                false,
                &mut scratch,
                &mut out,
            );
        }
    }

    let before = THREAD_ALLOCS.with(|c| c.get());
    let mut cold_reads = 0usize;
    for qi in 0..ds.n_queries() {
        let q = ds.queries.row(qi);
        cold.codebook.build_adt_into(q, &mut adt);
        proxima_search_into(
            &ctx,
            &adt,
            q,
            &params,
            ProximaFeatures::default(),
            false,
            &mut scratch,
            &mut out,
        );
        cold_reads += out.stats.cold_reads;
    }
    let allocs = THREAD_ALLOCS.with(|c| c.get()) - before;
    assert!(
        cold_reads > 0,
        "the measured pass must actually exercise the cold tier"
    );
    assert_eq!(
        allocs, 0,
        "steady-state COLD query path allocated {allocs} times over {} queries \
         ({cold_reads} cold reads)",
        ds.n_queries()
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn steady_state_cached_reads_do_not_allocate() {
    // The adaptive cold-row cache must not relax the cold-tier bar: with
    // `Cached` residency, a measured pass mixing cache HITS (arena memcpy
    // into the pooled ReadBuf) and MISSES (positioned read + admit, with
    // evictions recycling slots) performs zero heap allocations — all
    // policy queues and the slot arena are pre-sized at open.
    use proxima::config::PqParams;
    use proxima::coordinator::SearchService;
    use proxima::storage::cache::CachePolicy;
    use proxima::storage::{OpenOptions, Residency};

    let ds = tiny_uniform(400, 16, Metric::L2, 83);
    let svc = SearchService::build(
        &ds,
        &GraphParams {
            r: 12,
            build_l: 24,
            alpha: 1.2,
            seed: 83,
        },
        &PqParams {
            m: 8,
            c: 32,
            train_sample: 400,
            kmeans_iters: 5,
        },
        SearchParams {
            l: 60,
            k: 10,
            ..Default::default()
        },
        false,
    );
    let path = std::env::temp_dir().join(format!("zero-alloc-cached-{}.pxa", std::process::id()));
    svc.save(&path).unwrap();
    // Half the rows fit: steady state keeps evicting, so the measured
    // pass exercises hit, miss and slot-recycle paths together.
    let slot_bytes = proxima::simd::stride_for(ds.dim()) as u64 * 4;
    let cached = SearchService::open_with(
        &path,
        svc.params,
        false,
        &OpenOptions {
            residency: Residency::Cached {
                capacity_bytes: 200 * slot_bytes,
            },
            cache_policy: CachePolicy::S3Fifo,
            tiered_cache_bytes: None,
            lsh_start: false,
        },
    )
    .unwrap();
    let ctx = SearchContext {
        base: cached.storage.base_stub(),
        metric: cached.metric,
        graph: &cached.graph,
        codes: Some(&cached.codes),
        gap: None,
        storage: Some(&cached.storage),
        online: None,
        lsh: None,
    };
    let params = SearchParams {
        l: 60,
        k: 10,
        ..Default::default()
    };
    let mut scratch = QueryScratch::new();
    let mut adt = Adt::default();
    let mut out = SearchOutput::default();
    for _ in 0..2 {
        for qi in 0..ds.n_queries() {
            let q = ds.queries.row(qi);
            cached.codebook.build_adt_into(q, &mut adt);
            proxima_search_into(
                &ctx,
                &adt,
                q,
                &params,
                ProximaFeatures::default(),
                false,
                &mut scratch,
                &mut out,
            );
        }
    }

    let before = THREAD_ALLOCS.with(|c| c.get());
    let (mut hits, mut misses) = (0usize, 0usize);
    for qi in 0..ds.n_queries() {
        let q = ds.queries.row(qi);
        cached.codebook.build_adt_into(q, &mut adt);
        proxima_search_into(
            &ctx,
            &adt,
            q,
            &params,
            ProximaFeatures::default(),
            false,
            &mut scratch,
            &mut out,
        );
        hits += out.stats.cache_hits;
        misses += out.stats.cache_misses;
    }
    let allocs = THREAD_ALLOCS.with(|c| c.get()) - before;
    assert!(hits > 0, "the measured pass must serve some rows from cache");
    assert!(misses > 0, "200 of 400 rows: the pass must also miss");
    assert_eq!(
        allocs, 0,
        "steady-state CACHED query path allocated {allocs} times over {} queries \
         ({hits} hits / {misses} misses)",
        ds.n_queries()
    );
    let st = cached.storage.cache_status().unwrap();
    assert!(st.evictions > 0, "half-capacity churn must recycle slots");
    std::fs::remove_file(&path).ok();
}

#[test]
fn steady_state_resident_store_aligned_path_does_not_allocate() {
    // The SIMD-padded service path (storage: Some over a fully-resident
    // aligned store, query padded into scratch.qpad each call) must hold
    // the same zero-allocation bar as the plain unpadded path above —
    // for both the Proxima walk and the DiskANN-PQ gathered rerank
    // (scratch.rerank_ids / rerank_dists through exact_batch).
    use proxima::search::beam::pq_beam_search_into;
    use proxima::storage::VectorStore;

    let ds = tiny_uniform(500, 12, Metric::L2, 81); // dim 12: padded tail in play
    let g = vamana::build(
        &ds.base,
        ds.metric,
        &GraphParams {
            r: 16,
            build_l: 32,
            alpha: 1.2,
            seed: 81,
        },
    );
    let cb = PqCodebook::train(&ds.base, ds.metric, 6, 32, 500, 6, 81);
    let codes = cb.encode(&ds.base);
    let store = VectorStore::resident(&ds.base);
    let ctx = SearchContext {
        base: store.base_stub(),
        metric: ds.metric,
        graph: &g,
        codes: Some(&codes),
        gap: None,
        storage: Some(&store),
        online: None,
        lsh: None,
    };
    let params = SearchParams {
        l: 60,
        k: 10,
        ..Default::default()
    };

    let mut scratch = QueryScratch::new();
    let mut adt = Adt::default();
    let mut out = SearchOutput::default();
    for _ in 0..2 {
        for qi in 0..ds.n_queries() {
            let q = ds.queries.row(qi);
            cb.build_adt_into(q, &mut adt);
            proxima_search_into(
                &ctx,
                &adt,
                q,
                &params,
                ProximaFeatures::default(),
                false,
                &mut scratch,
                &mut out,
            );
            pq_beam_search_into(&ctx, &adt, q, 10, 60, 30, false, &mut scratch, &mut out);
        }
    }

    let before = THREAD_ALLOCS.with(|c| c.get());
    for qi in 0..ds.n_queries() {
        let q = ds.queries.row(qi);
        cb.build_adt_into(q, &mut adt);
        proxima_search_into(
            &ctx,
            &adt,
            q,
            &params,
            ProximaFeatures::default(),
            false,
            &mut scratch,
            &mut out,
        );
        pq_beam_search_into(&ctx, &adt, q, 10, 60, 30, false, &mut scratch, &mut out);
    }
    let allocs = THREAD_ALLOCS.with(|c| c.get()) - before;
    assert_eq!(
        allocs, 0,
        "steady-state ALIGNED query path allocated {allocs} times over {} queries",
        ds.n_queries()
    );
    assert_eq!(out.ids.len(), 10);
}

#[test]
fn steady_state_batched_adt_build_does_not_allocate() {
    let ds = tiny_uniform(300, 16, Metric::L2, 78);
    let cb = PqCodebook::train(&ds.base, ds.metric, 8, 32, 300, 6, 78);
    // Duplicate-heavy batch (24 queries, 8 distinct) — the dedup plan
    // and the distinct tables are both pooled in `AdtBatch`.
    let queries: Vec<&[f32]> = (0..24).map(|i| ds.queries.row(i % 8)).collect();
    let mut batch = AdtBatch::new();

    // Warm: first pass sizes the plan buffers and the 8 pooled tables;
    // second pass confirms the sizes are stable.
    for _ in 0..2 {
        cb.build_adt_batch(&queries, &mut batch);
    }
    assert_eq!(batch.distinct(), 8);

    let before = THREAD_ALLOCS.with(|c| c.get());
    cb.build_adt_batch(&queries, &mut batch);
    let allocs = THREAD_ALLOCS.with(|c| c.get()) - before;
    assert_eq!(
        allocs, 0,
        "steady-state batched ADT build allocated {allocs} times (pooled tables must be reused)"
    );

    // The pooled tables still hold correct results after reuse.
    let want = cb.build_adt(ds.queries.row(3));
    assert_eq!(batch.table(batch.table_index(3)).table, want.table);
}
