//! Adaptive hot set acceptance (ISSUE 8), LSH half: per-query LSH
//! entry-point warm starts must (a) reduce mean hops at equal recall on
//! a clustered dataset — the walk starts O(1) hash probes from a near
//! neighbor instead of the fixed medoid — and (b) stay bitwise-identical
//! ACROSS residencies when enabled uniformly, exactly like every other
//! traversal feature. Both gates are counter-based (hops, recall), not
//! wall-clock.

use proxima::api::{QueryOptions, QueryRequest, SearchMode};
use proxima::config::{GraphParams, PqParams, SearchParams};
use proxima::coordinator::SearchService;
use proxima::dataset::ground_truth::brute_force;
use proxima::dataset::{recall_at_k, Dataset, VectorSet};
use proxima::distance::Metric;
use proxima::graph::vamana;
use proxima::search::beam::{accurate_beam_search, SearchContext};
use proxima::search::lsh_start::LshIndex;
use proxima::storage::cache::CachePolicy;
use proxima::storage::{OpenOptions, Residency};
use proxima::util::rng::Xoshiro256pp;

/// 8 well-separated corner clusters in 8-d (centers at ±10 per
/// coordinate by the cluster id's bits, unit gaussian jitter); queries
/// land near the centers. The medoid entry point sits in ONE cluster,
/// so fixed-entry walks must cross clusters while LSH starts inside the
/// right one.
fn corner_clusters(per_cluster: usize, n_queries: usize, seed: u64) -> Dataset {
    let dim = 8usize;
    let n_clusters = 8usize;
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let center = |c: usize, j: usize| -> f32 {
        if (c >> j) & 1 == 1 {
            10.0
        } else {
            -10.0
        }
    };
    let mut base = Vec::with_capacity(n_clusters * per_cluster * dim);
    for c in 0..n_clusters {
        for _ in 0..per_cluster {
            for j in 0..dim {
                base.push(center(c, j) + rng.next_gaussian() as f32);
            }
        }
    }
    let mut queries = Vec::with_capacity(n_queries * dim);
    for qi in 0..n_queries {
        let c = qi % n_clusters;
        for j in 0..dim {
            queries.push(center(c, j) + rng.next_gaussian() as f32);
        }
    }
    Dataset {
        name: "corner-clusters".into(),
        metric: Metric::L2,
        base: VectorSet::new(dim, base),
        queries: VectorSet::new(dim, queries),
    }
}

/// ISSUE 8 acceptance: LSH warm starts reduce MEAN HOPS vs the fixed
/// entry point at equal recall, asserted via counters.
#[test]
fn lsh_warm_starts_reduce_mean_hops_at_equal_recall() {
    let ds = corner_clusters(50, 40, 91);
    let g = vamana::build(
        &ds.base,
        ds.metric,
        &GraphParams {
            r: 12,
            build_l: 24,
            alpha: 1.2,
            seed: 91,
        },
    );
    let lsh = LshIndex::build(&ds.base, 12, 0xC0FFEE);
    let gt = brute_force(&ds, 10);

    let ctx_plain = SearchContext {
        base: &ds.base,
        metric: ds.metric,
        graph: &g,
        codes: None,
        gap: None,
        storage: None,
        online: None,
        lsh: None,
    };
    let ctx_lsh = SearchContext {
        lsh: Some(&lsh),
        ..ctx_plain
    };

    let (mut hops_plain, mut hops_lsh) = (0usize, 0usize);
    let (mut recall_plain, mut recall_lsh) = (0.0f64, 0.0f64);
    let mut probes = 0usize;
    for qi in 0..ds.n_queries() {
        let q = ds.queries.row(qi);
        let a = accurate_beam_search(&ctx_plain, q, 10, 20, false);
        let b = accurate_beam_search(&ctx_lsh, q, 10, 20, false);
        hops_plain += a.stats.hops;
        hops_lsh += b.stats.hops;
        recall_plain += recall_at_k(&a.ids, gt.row(qi), 10);
        recall_lsh += recall_at_k(&b.ids, gt.row(qi), 10);
        assert_eq!(a.stats.lsh_probes, 0, "no LSH context, no probes");
        probes += b.stats.lsh_probes;
    }
    let n = ds.n_queries() as f64;
    assert!(probes > 0, "warm starts must actually probe buckets");
    assert!(
        hops_lsh < hops_plain,
        "LSH warm starts must cut mean hops: {} !< {} over {} queries",
        hops_lsh,
        hops_plain,
        ds.n_queries()
    );
    assert!(
        recall_lsh / n >= recall_plain / n - 1e-9,
        "hop savings must not cost recall: {} vs {}",
        recall_lsh / n,
        recall_plain / n
    );
    assert!(
        recall_plain / n > 0.9,
        "fixture sanity: the clustered graph should be searchable ({})",
        recall_plain / n
    );
}

/// With warm starts enabled UNIFORMLY, every residency — resident,
/// cold, cached — answers every mode bitwise-identically: the LSH seed
/// set is a pure function of the persisted signatures and the query,
/// never of where the vectors live.
#[test]
fn lsh_outputs_are_bitwise_identical_across_residencies() {
    let ds = corner_clusters(50, 24, 57);
    let mut built = SearchService::build(
        &ds,
        &GraphParams {
            r: 12,
            build_l: 24,
            alpha: 1.2,
            seed: 57,
        },
        &PqParams {
            m: 4,
            c: 16,
            train_sample: 400,
            kmeans_iters: 5,
        },
        SearchParams {
            l: 40,
            k: 10,
            ..Default::default()
        },
        false,
    );
    assert!(built.build_lsh(10), "resident build must accept LSH");
    let path = std::env::temp_dir().join(format!("adaptive-hot-lsh-{}.pxa", std::process::id()));
    built.save(&path).unwrap();

    let slot = proxima::simd::stride_for(ds.dim()) as u64 * 4;
    let open = |residency: Residency| {
        SearchService::open_with(
            &path,
            built.params,
            false,
            &OpenOptions {
                residency,
                cache_policy: CachePolicy::S3Fifo,
                tiered_cache_bytes: None,
                lsh_start: true,
            },
        )
        .unwrap_or_else(|e| panic!("open {} failed: {e}", residency.name()))
    };
    let resident = open(Residency::Resident);
    let cold = open(Residency::Cold);
    let cached = open(Residency::Cached {
        capacity_bytes: 40 * slot,
    });
    assert!(resident.lsh_active() && cold.lsh_active() && cached.lsh_active());

    for mode in [SearchMode::Accurate, SearchMode::PqAdt, SearchMode::Hybrid] {
        let opts = QueryOptions {
            mode,
            want_stats: true,
            ..Default::default()
        };
        for qi in 0..ds.n_queries() {
            let req = QueryRequest::single(ds.queries.row(qi), 10).with_options(opts);
            let want = resident.query(&req).unwrap();
            assert!(
                want.stats.as_ref().unwrap().lsh_probes > 0,
                "{mode:?} query {qi}: warm starts should be live"
            );
            for svc in [&cold, &cached] {
                let got = svc.query(&req).unwrap();
                let name = svc.storage.residency().name();
                assert_eq!(
                    got.results[0].ids, want.results[0].ids,
                    "{mode:?} query {qi}: {name} ids diverge with LSH starts on"
                );
                let a: Vec<u32> = want.results[0].dists.iter().map(|d| d.to_bits()).collect();
                let b: Vec<u32> = got.results[0].dists.iter().map(|d| d.to_bits()).collect();
                assert_eq!(a, b, "{mode:?} query {qi}: {name} dists not bitwise equal");
                assert_eq!(
                    got.stats.as_ref().unwrap().lsh_probes,
                    want.stats.as_ref().unwrap().lsh_probes,
                    "{mode:?} query {qi}: {name} probe count diverges"
                );
            }
        }
    }
    // The service-level counter aggregated the probes.
    use std::sync::atomic::Ordering;
    assert!(resident.stats.lsh_probes.load(Ordering::Relaxed) > 0);

    // An artifact WITHOUT an LSH section still opens with --lsh_start
    // requested: warm starts simply stay off (logged, not an error).
    let plain = corner_clusters(30, 4, 5);
    let no_lsh = SearchService::build(
        &plain,
        &GraphParams {
            r: 8,
            build_l: 16,
            alpha: 1.2,
            seed: 5,
        },
        &PqParams {
            m: 4,
            c: 16,
            train_sample: 240,
            kmeans_iters: 4,
        },
        SearchParams::default(),
        false,
    );
    let path2 =
        std::env::temp_dir().join(format!("adaptive-hot-nolsh-{}.pxa", std::process::id()));
    no_lsh.save(&path2).unwrap();
    let svc = SearchService::open_with(
        &path2,
        no_lsh.params,
        false,
        &OpenOptions {
            residency: Residency::Resident,
            cache_policy: CachePolicy::S3Fifo,
            tiered_cache_bytes: None,
            lsh_start: true,
        },
    )
    .unwrap();
    assert!(!svc.lsh_active(), "no section → warm starts stay off");
    let out = svc.query(&QueryRequest::single(plain.queries.row(0), 5)).unwrap();
    assert_eq!(out.results[0].ids.len(), 5);

    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&path2).ok();
}
