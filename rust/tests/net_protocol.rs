//! Integration tests for the `net` subsystem: the v3 binary frame
//! plane, the JSON compat plane on the SAME port, pipelining, typed
//! admission shedding, adversarial framing, and graceful drain (ISSUE 9
//! acceptance criteria live here).

use proxima::api::{ApiErrorCode, QueryOptions, QueryRequest};
use proxima::config::{GraphParams, PqParams, SearchParams};
use proxima::coordinator::batcher::{spawn, BatchPolicy};
use proxima::coordinator::server::Client;
use proxima::coordinator::{SearchService, ServiceCell};
use proxima::dataset::synth::tiny_uniform;
use proxima::dataset::Dataset;
use proxima::distance::Metric;
use proxima::net::frame::{self, FrameBody, HEADER_LEN, MAGIC, MAX_FRAME_LEN, OP_QUERY};
use proxima::net::{AdmissionConfig, BinClient, NetConfig, NetServer};
use std::sync::Arc;

fn service() -> (Dataset, Arc<SearchService>) {
    let ds = tiny_uniform(400, 12, Metric::L2, 7);
    let svc = Arc::new(SearchService::build(
        &ds,
        &GraphParams {
            r: 12,
            build_l: 24,
            alpha: 1.2,
            seed: 7,
        },
        &PqParams {
            m: 6,
            c: 32,
            train_sample: 400,
            kmeans_iters: 6,
        },
        SearchParams {
            l: 80,
            k: 10,
            ..Default::default()
        },
        false,
    ));
    (ds, svc)
}

fn net_serve(svc: Arc<SearchService>, cfg: NetConfig) -> NetServer {
    let cell = Arc::new(ServiceCell::new(svc));
    let (handle, _join) = spawn(cell.clone(), BatchPolicy::default());
    NetServer::start(cell, handle, cfg).unwrap()
}

/// Acceptance criterion: the same query answered over the v3 binary
/// plane and over the v2 JSON plane — both against ONE live server on
/// ONE port — returns bitwise-identical `NeighborList`s.
#[test]
fn binary_v3_matches_json_v2_bitwise_on_one_port() {
    let (ds, svc) = service();
    let server = net_serve(svc, NetConfig::default());

    let mut json = Client::connect(server.addr).unwrap();
    let mut bin = BinClient::connect(server.addr).unwrap();
    for qi in 0..8 {
        let q = ds.queries.row(qi);
        let (json_ids, json_dists, _) = json.search(q, 10).unwrap();
        let resp = bin
            .query(&QueryRequest::single(q, 10))
            .unwrap()
            .expect("typed OK");
        assert_eq!(resp.results.len(), 1);
        assert_eq!(resp.results[0].ids, json_ids, "query {qi}: ids");
        // Bitwise: the JSON plane's float text must round-trip exactly,
        // and the binary plane ships raw LE f32 — so both planes agree
        // to the bit or something is lossy.
        assert_eq!(resp.results[0].dists, json_dists, "query {qi}: dists");
    }
    server.stop();
}

/// Acceptance criterion: N requests pipelined down one connection (all
/// written before any response is read) return the same results as N
/// serial round-trips, matched by request id.
#[test]
fn pipelined_in_flight_matches_serial_round_trips() {
    let (ds, svc) = service();
    let server = net_serve(svc, NetConfig::default());
    let mut bin = BinClient::connect(server.addr).unwrap();

    const N: usize = 8;
    let serial: Vec<_> = (0..N)
        .map(|qi| {
            bin.query(&QueryRequest::single(ds.queries.row(qi), 10))
                .unwrap()
                .expect("typed OK")
        })
        .collect();

    // Pipelined: N sends, then N receives, responses in ANY order.
    let mut id_to_qi = std::collections::HashMap::new();
    for qi in 0..N {
        let id = bin
            .send_query(&QueryRequest::single(ds.queries.row(qi), 10), 0)
            .unwrap();
        id_to_qi.insert(id, qi);
    }
    let mut seen = 0;
    while seen < N {
        let (id, outcome) = bin.recv().unwrap();
        let qi = id_to_qi.remove(&id).expect("response id matches a request");
        match outcome.expect("typed OK") {
            FrameBody::QueryOk { response } => {
                assert_eq!(
                    response.results, serial[qi].results,
                    "query {qi}: pipelined vs serial"
                );
            }
            other => panic!("unexpected response body {other:?}"),
        }
        seen += 1;
    }
    server.stop();
}

/// The JSON compat plane speaks the FULL v1/v2 op surface through the
/// event-loop server: search, stats, status — same semantics as the
/// threaded server, same port as the binary plane.
#[test]
fn json_plane_serves_admin_ops_on_the_shared_port() {
    let (ds, svc) = service();
    let server = net_serve(svc, NetConfig::default());
    let mut client = Client::connect(server.addr).unwrap();

    let (ids, _, _) = client.search(ds.queries.row(0), 10).unwrap();
    assert_eq!(ids.len(), 10);
    let status = client.status().unwrap();
    assert!(status.get("n_base").and_then(|j| j.as_f64()).unwrap_or(0.0) > 0.0);
    let stats = client.stats().unwrap();
    assert!(stats.get("queries").is_some());

    // And the binary plane can run the same admin ops, framed.
    let mut bin = BinClient::connect(server.addr).unwrap();
    let status2 = bin.admin("{\"v\":2,\"op\":\"status\"}").unwrap();
    assert_eq!(
        status2.get("n_base").and_then(|j| j.as_f64()),
        status.get("n_base").and_then(|j| j.as_f64()),
        "both planes report the same index"
    );
    server.stop();
}

/// Adversarial framing, all on connections that must SURVIVE: every
/// malformed input gets a typed error frame and the next well-formed
/// request still answers.
#[test]
fn adversarial_frames_are_rejected_typed_on_a_surviving_connection() {
    let (ds, svc) = service();
    let server = net_serve(svc, NetConfig::default());
    let mut bin = BinClient::connect(server.addr).unwrap();
    let good = QueryRequest::single(ds.queries.row(0), 10);
    let good_resp = bin.query(&good).unwrap().expect("typed OK");

    // 1. Truncated frame: header declares 13 payload bytes, body runs
    //    out mid-request. Typed error, id attributed.
    let mut raw = Vec::new();
    raw.extend_from_slice(&MAGIC);
    raw.extend_from_slice(&13u32.to_le_bytes());
    raw.extend_from_slice(&42u64.to_le_bytes()); // request id
    raw.push(OP_QUERY);
    raw.extend_from_slice(&10u32.to_le_bytes()); // k, then nothing
    bin.send_raw(&raw).unwrap();
    let (id, outcome) = bin.recv().unwrap();
    assert_eq!(id, 42, "truncation error attributed to the culprit id");
    assert_eq!(outcome.unwrap_err().code, ApiErrorCode::BadRequest);

    // 2. Giant declared length: a header claiming MAX_FRAME_LEN + 1.
    //    Rejected BEFORE allocation, typed, and the stream resyncs.
    let mut raw = Vec::new();
    raw.extend_from_slice(&MAGIC);
    raw.extend_from_slice(&((MAX_FRAME_LEN + 1) as u32).to_le_bytes());
    bin.send_raw(&raw).unwrap();
    let (_, outcome) = bin.recv().unwrap();
    let e = outcome.unwrap_err();
    assert_eq!(e.code, ApiErrorCode::BadRequest);
    assert!(e.message.contains("exceeds"), "got: {}", e.message);

    // 3. Unknown op tag.
    let mut raw = Vec::new();
    raw.extend_from_slice(&MAGIC);
    raw.extend_from_slice(&9u32.to_le_bytes());
    raw.extend_from_slice(&77u64.to_le_bytes());
    raw.push(0x7f);
    bin.send_raw(&raw).unwrap();
    let (id, outcome) = bin.recv().unwrap();
    assert_eq!(id, 77);
    assert_eq!(outcome.unwrap_err().code, ApiErrorCode::BadRequest);

    // 4. A v2 JSON line on the binary plane: typed rejection, frames
    //    continue afterwards.
    bin.send_raw(b"{\"v\":2,\"op\":\"status\"}\n").unwrap();
    let (_, outcome) = bin.recv().unwrap();
    let e = outcome.unwrap_err();
    assert_eq!(e.code, ApiErrorCode::BadRequest);
    assert!(e.message.contains("JSON"), "got: {}", e.message);

    // The SAME connection still answers real queries, identically.
    let again = bin.query(&good).unwrap().expect("typed OK");
    assert_eq!(again.results, good_resp.results, "connection survived");
    server.stop();
}

/// Duplicate in-flight request ids are a protocol error for the SECOND
/// use only: the first request completes normally, the duplicate is
/// rejected typed, the connection survives.
#[test]
fn duplicate_in_flight_request_id_rejected_typed() {
    let (ds, svc) = service();
    let server = net_serve(svc, NetConfig::default());
    let mut bin = BinClient::connect(server.addr).unwrap();

    // A heavy batch keeps id 7 in flight while its duplicate arrives in
    // the same TCP segment (both frames in one write).
    let heavy = QueryRequest {
        vectors: (0..32).map(|qi| ds.queries.row(qi % ds.queries.len()).to_vec()).collect(),
        k: 10,
        options: QueryOptions::default(),
    };
    let mut raw = Vec::new();
    frame::encode_query(&mut raw, 7, &heavy, 0);
    frame::encode_query(&mut raw, 7, &QueryRequest::single(ds.queries.row(0), 10), 0);
    bin.send_raw(&raw).unwrap();

    // Two responses, both for id 7: one typed duplicate rejection, one
    // full result set (order not guaranteed).
    let mut ok = None;
    let mut err = None;
    for _ in 0..2 {
        let (id, outcome) = bin.recv().unwrap();
        assert_eq!(id, 7);
        match outcome {
            Ok(FrameBody::QueryOk { response }) => ok = Some(response),
            Ok(other) => panic!("unexpected body {other:?}"),
            Err(e) => err = Some(e),
        }
    }
    let e = err.expect("one duplicate rejection");
    assert_eq!(e.code, ApiErrorCode::BadRequest);
    assert!(e.message.contains("duplicate"), "got: {}", e.message);
    assert_eq!(ok.expect("one result").results.len(), 32);

    // The id is free again once the first request finished.
    bin.send_query_with_id(7, &QueryRequest::single(ds.queries.row(1), 10), 0)
        .unwrap();
    let (id, outcome) = bin.recv().unwrap();
    assert_eq!(id, 7);
    assert!(matches!(outcome, Ok(FrameBody::QueryOk { .. })));
    server.stop();
}

/// Acceptance criterion: under synthetic overload (a zero-size
/// admission budget — deterministic, no timing games) every query sheds
/// with the typed `overloaded` code, the connection survives, and the
/// ungated admin plane keeps answering.
#[test]
fn overload_sheds_typed_while_admin_plane_stays_up() {
    let (ds, svc) = service();
    let cfg = NetConfig {
        admission: AdmissionConfig {
            max_in_flight: 0, // always over budget
            ..Default::default()
        },
        ..Default::default()
    };
    let server = net_serve(svc, cfg);
    let mut bin = BinClient::connect(server.addr).unwrap();

    for qi in 0..4 {
        let outcome = bin
            .query(&QueryRequest::single(ds.queries.row(qi), 10))
            .unwrap();
        let e = outcome.expect_err("must shed");
        assert_eq!(e.code, ApiErrorCode::Overloaded, "typed shed, attempt {qi}");
    }
    // Admin ops are NOT gated by admission: the ops plane must stay
    // responsive exactly when the server is shedding.
    let status = bin.admin("{\"v\":2,\"op\":\"status\"}").unwrap();
    assert!(status.get("n_base").is_some());
    let c = server.admission().counters();
    assert_eq!(c.shed_admit, 4, "every query shed at admission");
    assert_eq!(c.admitted, 0);
    server.stop();
}

/// Graceful drain: a wire `shutdown` op answers first, THEN the server
/// refuses new connections and `stop()` joins cleanly.
#[test]
fn shutdown_op_drains_and_refuses_new_connections() {
    let (ds, svc) = service();
    let server = net_serve(svc, NetConfig::default());
    let addr = server.addr;
    let mut bin = BinClient::connect(addr).unwrap();
    // Prove the connection works, then shut down over the wire.
    bin.query(&QueryRequest::single(ds.queries.row(0), 10))
        .unwrap()
        .expect("typed OK");
    let resp = bin.admin("{\"v\":2,\"op\":\"shutdown\"}").unwrap();
    assert_eq!(resp.get("ok").and_then(|j| j.as_bool()), Some(true));

    server.stop(); // joins the drained loop + dispatchers
    // The listener is gone: connecting now fails outright, or the
    // accepted-then-dropped socket reads immediate EOF.
    match std::net::TcpStream::connect(addr) {
        Err(_) => {}
        Ok(s) => {
            use std::io::Read;
            s.set_read_timeout(Some(std::time::Duration::from_secs(2))).unwrap();
            let mut buf = [0u8; 1];
            let mut s = s;
            match s.read(&mut buf) {
                Ok(0) => {}
                other => panic!("server accepted work after drain: {other:?}"),
            }
        }
    }
}
