//! Integration tests for the typed, versioned query API: the ONE
//! `QueryRequest`/`QueryResponse` contract from in-process calls to the
//! batch RPC wire (ISSUE 2 acceptance criteria live here).

use proxima::api::{QueryOptions, QueryRequest, SearchMode};
use proxima::config::{GraphParams, PqParams, SearchParams};
use proxima::coordinator::batcher::{spawn, BatchPolicy};
use proxima::coordinator::server::{Client, Server};
use proxima::coordinator::{SearchService, ServiceCell};
use proxima::dataset::synth::tiny_uniform;
use proxima::dataset::Dataset;
use proxima::distance::Metric;
use std::sync::Arc;

fn service() -> (Dataset, Arc<SearchService>) {
    let ds = tiny_uniform(400, 12, Metric::L2, 7);
    let svc = Arc::new(SearchService::build(
        &ds,
        &GraphParams {
            r: 12,
            build_l: 24,
            alpha: 1.2,
            seed: 7,
        },
        &PqParams {
            m: 6,
            c: 32,
            train_sample: 400,
            kmeans_iters: 6,
        },
        SearchParams {
            l: 80,
            k: 10,
            ..Default::default()
        },
        false,
    ));
    (ds, svc)
}

fn serve(svc: Arc<SearchService>) -> Server {
    let cell = Arc::new(ServiceCell::new(svc));
    let (handle, _join) = spawn(cell.clone(), BatchPolicy::default());
    Server::start(cell, handle, 0).unwrap()
}

/// Acceptance criterion: one TCP round-trip carrying N queries returns N
/// `NeighborList`s, matching N serial v1 requests result-for-result.
#[test]
fn batch_of_8_over_the_wire_matches_8_serial_v1_requests() {
    let (ds, svc) = service();
    let server = serve(svc);
    let mut client = Client::connect(server.addr).unwrap();

    let queries: Vec<&[f32]> = (0..8).map(|qi| ds.queries.row(qi)).collect();
    let serial: Vec<(Vec<u32>, Vec<f32>)> = queries
        .iter()
        .map(|q| {
            let (ids, dists, _) = client.search(q, 10).unwrap();
            (ids, dists)
        })
        .collect();

    let resp = client
        .search_batch(&queries, 10, &QueryOptions::default())
        .unwrap();
    assert_eq!(resp.results.len(), 8, "8 queries in, 8 NeighborLists out");
    for (qi, (nl, (ids, dists))) in resp.results.iter().zip(&serial).enumerate() {
        assert_eq!(&nl.ids, ids, "query {qi}: batch vs serial ids");
        assert_eq!(&nl.dists, dists, "query {qi}: batch vs serial dists");
    }

    client.shutdown().unwrap();
    server.stop();
}

/// Acceptance criterion: per-request `mode` / `l_override` demonstrably
/// change search behavior (stats differ) through the same `QueryRequest`
/// path in-process and over TCP.
#[test]
fn per_request_options_change_behavior_in_process_and_over_tcp() {
    let (ds, svc) = service();
    let queries: Vec<&[f32]> = (0..4).map(|qi| ds.queries.row(qi)).collect();
    let small_l = QueryOptions {
        l_override: Some(20),
        want_stats: true,
        ..Default::default()
    };
    let large_l = QueryOptions {
        l_override: Some(80),
        want_stats: true,
        ..Default::default()
    };
    let accurate = QueryOptions {
        mode: SearchMode::Accurate,
        want_stats: true,
        ..Default::default()
    };

    // In-process through the typed contract.
    let q = |o: QueryOptions| {
        svc.query(&QueryRequest::batch(&queries, 10).with_options(o))
            .unwrap()
    };
    let (ip_small, ip_large, ip_acc) = (q(small_l), q(large_l), q(accurate));
    assert!(
        ip_large.stats.as_ref().unwrap().pq_dists > ip_small.stats.as_ref().unwrap().pq_dists,
        "l_override must change PQ work in-process"
    );
    assert_eq!(ip_acc.stats.as_ref().unwrap().pq_dists, 0);
    assert!(ip_acc.stats.as_ref().unwrap().exact_dists > 0);

    // The same requests over TCP: same options, same behavior shift, and
    // identical results to the in-process path.
    let server = serve(svc);
    let mut client = Client::connect(server.addr).unwrap();
    let wire_small = client.search_batch(&queries, 10, &small_l).unwrap();
    let wire_large = client.search_batch(&queries, 10, &large_l).unwrap();
    let wire_acc = client.search_batch(&queries, 10, &accurate).unwrap();
    assert!(
        wire_large.stats.as_ref().unwrap().pq_dists > wire_small.stats.as_ref().unwrap().pq_dists,
        "l_override must change PQ work over the wire"
    );
    assert_eq!(wire_acc.stats.as_ref().unwrap().pq_dists, 0);
    for (a, b) in ip_small.results.iter().zip(&wire_small.results) {
        assert_eq!(a.ids, b.ids, "in-process and wire must answer identically");
    }
    for (a, b) in ip_acc.results.iter().zip(&wire_acc.results) {
        assert_eq!(a.ids, b.ids);
    }

    // Single-query v2 (batcher path) honors options too.
    let one = client
        .search_with_options(ds.queries.row(0), 10, &accurate)
        .unwrap();
    assert_eq!(one.results.len(), 1);
    assert_eq!(one.stats.as_ref().unwrap().pq_dists, 0);

    client.shutdown().unwrap();
    server.stop();
}

/// Acceptance criterion (work-stealing pool): a SKEWED batch — heavy
/// wide-list queries mixed with tiny-list ones — answered over the pool
/// must match per-query serial execution result-for-result, in input
/// order, both in-process and across one v2 wire round-trip.
#[test]
fn skewed_batch_over_the_pool_matches_serial() {
    let (ds, svc) = service();
    let queries: Vec<&[f32]> = (0..8).map(|qi| ds.queries.row(qi)).collect();
    // Heavy options: a wide candidate list with early termination off —
    // the per-query cost skew that used to idle chunked workers.
    let heavy = QueryOptions {
        l_override: Some(300),
        early_term_tau: Some(0),
        want_stats: true,
        ..Default::default()
    };

    let batch = svc
        .query(&QueryRequest::batch(&queries, 10).with_options(heavy))
        .unwrap();
    assert!(!batch.has_errors());

    let server = serve(svc);
    let mut client = Client::connect(server.addr).unwrap();
    let wire = client.search_batch(&queries, 10, &heavy).unwrap();
    for (qi, q) in queries.iter().enumerate() {
        let serial = client.search_with_options(q, 10, &heavy).unwrap();
        assert_eq!(
            batch.results[qi], serial.results[0],
            "query {qi}: pooled batch vs serial under skewed options"
        );
        assert_eq!(
            wire.results[qi], serial.results[0],
            "query {qi}: wire batch vs serial under skewed options"
        );
    }
    client.shutdown().unwrap();
    server.stop();
}

/// The staged batch pipeline is observable end-to-end: a duplicate-heavy
/// v2 wire batch reports FEWER ADT builds than queries (dedup) plus a
/// measurable queue-wait stat, and duplicates answer identically.
#[test]
fn wire_batch_stats_expose_adt_dedup_and_queue_wait() {
    let (ds, svc) = service();
    let server = serve(svc);
    let mut client = Client::connect(server.addr).unwrap();

    // 24 queries cycling 6 distinct vectors.
    let queries: Vec<&[f32]> = (0..24).map(|qi| ds.queries.row(qi % 6)).collect();
    let resp = client
        .search_batch(
            &queries,
            10,
            &QueryOptions {
                want_stats: true,
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(resp.results.len(), 24);
    let stats = resp.stats.unwrap();
    assert_eq!(
        stats.adt_builds, 6,
        "24 duplicate-heavy queries must build exactly 6 ADT tables"
    );
    for qi in 0..24 {
        assert_eq!(
            resp.results[qi], resp.results[qi % 6],
            "duplicate queries share a table but keep their own answer"
        );
    }
    // Accurate mode builds no tables at all.
    let acc = client
        .search_batch(
            &queries[..4],
            10,
            &QueryOptions {
                mode: SearchMode::Accurate,
                want_stats: true,
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(acc.stats.unwrap().adt_builds, 0);

    client.shutdown().unwrap();
    server.stop();
}

/// Satellite: a v1 request (no "v" field) is still answered in the v1
/// response shape.
#[test]
fn v1_compat_request_still_answered() {
    let (ds, svc) = service();
    let server = serve(svc.clone());
    let mut client = Client::connect(server.addr).unwrap();

    // Hand-rolled v1 line, independent of the Client encoder.
    let q: Vec<String> = ds.queries.row(0).iter().map(|x| x.to_string()).collect();
    let line = format!(r#"{{"op":"search","query":[{}],"k":5}}"#, q.join(","));
    let resp = client.send_raw(&line).unwrap();
    assert!(resp.get("error").is_none(), "v1 request must succeed");
    let ids = resp.get("ids").unwrap();
    assert_eq!(ids.as_arr().unwrap().len(), 5);
    assert!(resp.get("latency_us").is_some());
    assert!(
        resp.get("results").is_none(),
        "v1 response keeps the flat single-query shape"
    );

    // And the Client's v1 helper agrees with the in-process answer.
    let (ids, _, _) = client.search(ds.queries.row(0), 5).unwrap();
    let direct = svc.search(ds.queries.row(0), 5);
    assert_eq!(ids, direct.ids);

    client.shutdown().unwrap();
    server.stop();
}

/// Satellite: bad JSON, dimension mismatches and unknown ops are answered
/// with structured errors and the connection KEEPS SERVING.
#[test]
fn error_paths_are_structured_and_keep_the_connection_alive() {
    let (ds, svc) = service();
    let server = serve(svc);
    let mut client = Client::connect(server.addr).unwrap();

    let code_of = |resp: &proxima::util::json::Json| {
        resp.get("error")
            .and_then(|e| e.get("code"))
            .and_then(proxima::util::json::Json::as_str)
            .map(str::to_string)
            .expect("structured error line")
    };

    // Malformed JSON used to kill the whole connection; now it's a
    // structured error line.
    let resp = client.send_raw("{this is not json").unwrap();
    assert_eq!(code_of(&resp), "bad_request");

    // Unknown op on a versionless (= v1) line keeps the legacy string
    // error shape, exactly like the old server.
    let resp = client.send_raw(r#"{"op":"frobnicate"}"#).unwrap();
    let legacy = resp
        .get("error")
        .and_then(proxima::util::json::Json::as_str)
        .expect("v1 decode errors keep the legacy string shape");
    assert!(legacy.starts_with("bad_request"), "{legacy}");

    // The same unknown op on a v2 line gets the structured shape.
    let resp = client.send_raw(r#"{"v":2,"op":"frobnicate"}"#).unwrap();
    assert_eq!(code_of(&resp), "bad_request");

    // Unsupported version.
    let resp = client.send_raw(r#"{"v":9,"op":"search","query":[1.0]}"#).unwrap();
    assert_eq!(code_of(&resp), "bad_request");

    // Wrong-length vector is caught at the API boundary, not in
    // Metric::distance. On the v1 compat path the error keeps the legacy
    // string shape.
    let short = vec![0.5f32; ds.dim() - 2];
    let resp = client
        .send_raw(
            &proxima::api::wire::encode_request_v1(&short, 5).to_string_compact(),
        )
        .unwrap();
    let legacy = resp
        .get("error")
        .and_then(proxima::util::json::Json::as_str)
        .expect("v1 errors keep the legacy string shape");
    assert!(legacy.starts_with("dim_mismatch"), "{legacy}");

    // Mixed batch: one good, one bad vector — whole request rejected.
    let good = ds.queries.row(0);
    let req = QueryRequest::batch(&[good, &short], 5);
    let resp = client
        .send_raw(&proxima::api::wire::encode_request_v2(&req).to_string_compact())
        .unwrap();
    assert_eq!(code_of(&resp), "dim_mismatch");

    // After all that abuse, the SAME connection still answers.
    let (ids, _, _) = client.search(good, 5).unwrap();
    assert_eq!(ids.len(), 5);
    let resp = client
        .search_batch(&[good, good], 5, &QueryOptions::default())
        .unwrap();
    assert_eq!(resp.results.len(), 2);

    client.shutdown().unwrap();
    server.stop();
}
