//! Hand-rolled readiness polling — the `exec::ExecPool` philosophy
//! applied to I/O: no event-loop crate, just the kernel interface the
//! crate already links through std.
//!
//! Three backends behind one tiny API ([`Poller`]):
//!
//! * **Linux**: `epoll` via direct `extern "C"` declarations against
//!   the libc std already links (level-triggered; the loop re-arms
//!   write interest explicitly, so level semantics keep the state
//!   machine simple).
//! * **other unix**: `poll(2)` — the registration list is replayed into
//!   a `pollfd` array per wait. O(n) per call, which is fine at this
//!   crate's connection counts.
//! * **non-unix**: a sleep-scan stub that reports every registered
//!   token ready each tick; correctness then rests entirely on the
//!   nonblocking sockets returning `WouldBlock`, trading efficiency
//!   for portability.
//!
//! [`Waker`] unblocks a sleeping [`Poller::wait`] from another thread
//! (dispatchers finishing work, `stop()`): a loopback TCP self-pipe —
//! the receiving half is registered like any connection, the sending
//! half writes one byte. Std-only, works on every backend.

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Mutex;

/// One readiness report for a registered token.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
}

#[cfg(unix)]
pub use std::os::unix::io::RawFd;
#[cfg(not(unix))]
#[allow(non_camel_case_types)]
pub type RawFd = i32;

/// Extract the registrable handle from a socket.
#[cfg(unix)]
pub fn source_fd<T: std::os::unix::io::AsRawFd>(s: &T) -> RawFd {
    s.as_raw_fd()
}
#[cfg(not(unix))]
pub fn source_fd<T>(_s: &T) -> RawFd {
    0
}

#[cfg(target_os = "linux")]
mod sys {
    use super::Event;
    use std::io;

    // epoll_event is packed on x86_64 only (kernel ABI quirk).
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0x80000;

    pub struct Poller {
        epfd: i32,
        buf: Vec<EpollEvent>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller {
                epfd,
                buf: vec![EpollEvent { events: 0, data: 0 }; 256],
            })
        }

        fn ctl(&self, op: i32, fd: super::RawFd, token: u64, writable: bool) -> io::Result<()> {
            let mut ev = EpollEvent {
                events: EPOLLIN | if writable { EPOLLOUT } else { 0 },
                data: token,
            };
            let arg = if op == EPOLL_CTL_DEL {
                std::ptr::null_mut()
            } else {
                &mut ev as *mut EpollEvent
            };
            if unsafe { epoll_ctl(self.epfd, op, fd, arg) } < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn add(&mut self, fd: super::RawFd, token: u64, writable: bool) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, writable)
        }

        pub fn modify(&mut self, fd: super::RawFd, token: u64, writable: bool) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, writable)
        }

        pub fn remove(&mut self, fd: super::RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, false)
        }

        pub fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
            let n = unsafe {
                epoll_wait(
                    self.epfd,
                    self.buf.as_mut_ptr(),
                    self.buf.len() as i32,
                    timeout_ms,
                )
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(()); // spurious EINTR: caller just re-waits
                }
                return Err(e);
            }
            for i in 0..n as usize {
                let ev = self.buf[i]; // copy out of the packed slot
                let events = ev.events;
                out.push(Event {
                    token: ev.data,
                    readable: events & (EPOLLIN | EPOLLERR | EPOLLHUP) != 0,
                    writable: events & (EPOLLOUT | EPOLLERR | EPOLLHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe { close(self.epfd) };
        }
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
mod sys {
    use super::Event;
    use std::io;

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct PollFd {
        fd: i32,
        events: i16,
        revents: i16,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: u32, timeout: i32) -> i32;
    }

    const POLLIN: i16 = 0x001;
    const POLLOUT: i16 = 0x004;
    const POLLERR: i16 = 0x008;
    const POLLHUP: i16 = 0x010;

    pub struct Poller {
        regs: Vec<(super::RawFd, u64, bool)>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller { regs: Vec::new() })
        }

        pub fn add(&mut self, fd: super::RawFd, token: u64, writable: bool) -> io::Result<()> {
            self.regs.push((fd, token, writable));
            Ok(())
        }

        pub fn modify(&mut self, fd: super::RawFd, token: u64, writable: bool) -> io::Result<()> {
            match self.regs.iter_mut().find(|r| r.0 == fd) {
                Some(r) => {
                    *r = (fd, token, writable);
                    Ok(())
                }
                None => Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered")),
            }
        }

        pub fn remove(&mut self, fd: super::RawFd) -> io::Result<()> {
            self.regs.retain(|r| r.0 != fd);
            Ok(())
        }

        pub fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
            let mut fds: Vec<PollFd> = self
                .regs
                .iter()
                .map(|&(fd, _, writable)| PollFd {
                    fd,
                    events: POLLIN | if writable { POLLOUT } else { 0 },
                    revents: 0,
                })
                .collect();
            let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as u32, timeout_ms) };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for (pf, &(_, token, _)) in fds.iter().zip(self.regs.iter()) {
                if pf.revents != 0 {
                    out.push(Event {
                        token,
                        readable: pf.revents & (POLLIN | POLLERR | POLLHUP) != 0,
                        writable: pf.revents & (POLLOUT | POLLERR | POLLHUP) != 0,
                    });
                }
            }
            Ok(())
        }
    }
}

#[cfg(not(unix))]
mod sys {
    use super::Event;
    use std::io;

    /// Portability stub: every registered token reports ready each
    /// tick; nonblocking sockets' `WouldBlock` does the real gating.
    pub struct Poller {
        regs: Vec<(super::RawFd, u64, bool)>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller { regs: Vec::new() })
        }
        pub fn add(&mut self, fd: super::RawFd, token: u64, writable: bool) -> io::Result<()> {
            self.regs.push((fd, token, writable));
            Ok(())
        }
        pub fn modify(&mut self, fd: super::RawFd, token: u64, writable: bool) -> io::Result<()> {
            if let Some(r) = self.regs.iter_mut().find(|r| r.0 == fd) {
                *r = (fd, token, writable);
            }
            Ok(())
        }
        pub fn remove(&mut self, fd: super::RawFd) -> io::Result<()> {
            self.regs.retain(|r| r.0 != fd);
            Ok(())
        }
        pub fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
            std::thread::sleep(std::time::Duration::from_millis((timeout_ms.max(1) as u64).min(5)));
            for &(_, token, writable) in &self.regs {
                out.push(Event {
                    token,
                    readable: true,
                    writable,
                });
            }
            Ok(())
        }
    }
}

pub use sys::Poller;

/// Cross-thread wakeup for a sleeping [`Poller::wait`]: a loopback TCP
/// self-pipe whose receive half is registered in the poller.
pub struct Waker {
    tx: Mutex<TcpStream>,
    rx: TcpStream,
}

impl Waker {
    pub fn new() -> io::Result<Waker> {
        let listener = TcpListener::bind(("127.0.0.1", 0))?;
        let addr = listener.local_addr()?;
        let tx = TcpStream::connect(addr)?;
        let local = tx.local_addr()?;
        // Accept until we see OUR connection (a local port scanner
        // could theoretically race us onto the ephemeral port).
        let rx = loop {
            let (s, peer) = listener.accept()?;
            if peer == local {
                break s;
            }
        };
        tx.set_nonblocking(true)?;
        tx.set_nodelay(true)?;
        rx.set_nonblocking(true)?;
        Ok(Waker {
            tx: Mutex::new(tx),
            rx,
        })
    }

    /// The half to register in the poller.
    pub fn rx(&self) -> &TcpStream {
        &self.rx
    }

    /// Unblock the poller. A full pipe (`WouldBlock`) already implies a
    /// pending wakeup, so the error is ignorable by design.
    pub fn wake(&self) {
        let _ = self.tx.lock().unwrap().write(&[1u8]);
    }

    /// Consume pending wakeup bytes (call when the rx token fires).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        let mut rx = &self.rx;
        while matches!(rx.read(&mut buf), Ok(n) if n > 0) {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waker_unblocks_wait_and_drains() {
        let mut p = Poller::new().unwrap();
        let w = Waker::new().unwrap();
        p.add(source_fd(w.rx()), 7, false).unwrap();
        // Nothing pending: a zero-timeout wait reports no events
        // (except on the non-unix stub, which over-reports by design).
        let mut out = Vec::new();
        p.wait(&mut out, 0).unwrap();
        if cfg!(unix) {
            assert!(out.is_empty(), "unexpected events: {out:?}");
        }
        w.wake();
        w.wake();
        let mut out = Vec::new();
        // Generous timeout, but the wake byte makes this return at once.
        p.wait(&mut out, 5_000).unwrap();
        assert!(out.iter().any(|e| e.token == 7 && e.readable));
        w.drain();
        let mut out = Vec::new();
        p.wait(&mut out, 0).unwrap();
        if cfg!(unix) {
            assert!(out.is_empty(), "drain left residue: {out:?}");
        }
    }

    #[test]
    fn write_interest_is_reported_and_modifiable() {
        let mut p = Poller::new().unwrap();
        let w = Waker::new().unwrap();
        // A connected TCP socket with an empty send buffer is writable.
        p.add(source_fd(w.rx()), 9, true).unwrap();
        let mut out = Vec::new();
        p.wait(&mut out, 1_000).unwrap();
        assert!(out.iter().any(|e| e.token == 9 && e.writable));
        // Drop write interest: no more events while the pipe is idle.
        p.modify(source_fd(w.rx()), 9, false).unwrap();
        let mut out = Vec::new();
        p.wait(&mut out, 0).unwrap();
        if cfg!(unix) {
            assert!(out.is_empty(), "events after deassert: {out:?}");
        }
        p.remove(source_fd(w.rx())).unwrap();
    }
}
