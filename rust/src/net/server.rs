//! The event-loop front door: one acceptor + readiness loop owning
//! every connection, a small dispatcher pool executing decoded work on
//! the existing [`SearchService`]/[`BatcherHandle`] path, and the
//! [`Admission`] layer between them.
//!
//! ```text
//!            ┌──────────────── event-loop thread ───────────────┐
//! accept ─▶  │ Poller: listener, waker, N conns (nonblocking)   │
//!            │  read → ConnReader → {JsonLine, Frame, ProtoErr} │
//!            │  admission.try_admit (queries) → work queue      │
//!            │  outbox flush ← waker ← dispatchers              │
//!            └──────────────────────────────────────────────────┘
//!                 │ Work::{Query, Admin, JsonLine}      ▲ bytes
//!                 ▼                                     │
//!            dispatcher threads: check_dispatch → ServiceCell
//!            query / respond_json_line → encode → conn outbox
//! ```
//!
//! One thread owns ALL socket I/O (the readiness loop); dispatchers
//! never touch sockets — they append encoded responses to a per-conn
//! outbox and ring the [`Waker`]. A connection therefore pipelines
//! freely: the loop keeps decoding new frames while dispatchers run
//! earlier ones, and responses are matched by request id, not order.
//!
//! Both planes ride one port: the sniff in [`ConnReader`] routes JSON
//! lines through the same [`respond_json_line`] dispatch as the
//! threaded [`crate::coordinator::Server`], so op semantics are shared
//! by construction. Admission control gates QUERY work only — the
//! admin plane must stay responsive exactly when the server is in
//! trouble.
//!
//! Shutdown (`stop()`, or a wire `shutdown` op on either plane) drains:
//! the listener refuses new connections, queued work finishes, outboxes
//! flush, and then the loop exits — with a 5 s hard cap so a wedged
//! peer cannot hold the process open.

use super::admission::{Admission, AdmissionConfig, Clock};
use super::conn::{ConnEvent, ConnReader, Plane};
use super::frame::{self, FrameBody};
use super::poll::{source_fd, Event, Poller, Waker};
use crate::api::{ApiError, QueryRequest};
use crate::coordinator::batcher::BatcherHandle;
use crate::coordinator::server::respond_json_line;
use crate::coordinator::ServiceCell;
use crate::util::error::Result;
use std::collections::{HashMap, HashSet, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Tunables for [`NetServer::start`].
#[derive(Clone)]
pub struct NetConfig {
    /// `127.0.0.1` port to bind (0 = ephemeral).
    pub port: u16,
    pub admission: AdmissionConfig,
    /// Close connections that send nothing for this long.
    pub idle_timeout: Duration,
    /// Dispatcher threads (0 = auto: half the cores, clamped to 2..=8).
    pub dispatchers: usize,
    /// Time source for admission (tests inject [`Clock::fake`]).
    pub clock: Clock,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            port: 0,
            admission: AdmissionConfig::default(),
            idle_timeout: Duration::from_secs(300),
            dispatchers: 0,
            clock: Clock::wall(),
        }
    }
}

const ST_RUNNING: u8 = 0;
const ST_DRAINING: u8 = 1;
const ST_STOPPED: u8 = 2;

/// Per-connection state shared between the loop and dispatchers.
struct ConnShared {
    /// Encoded response bytes awaiting the loop's write.
    out: Mutex<Vec<u8>>,
    /// Set when the loop tore the connection down (dispatchers then
    /// drop their output instead of queueing bytes nobody will send).
    closed: AtomicBool,
    /// Binary request ids currently in flight on this connection
    /// (duplicate detection + response bookkeeping).
    in_flight: Mutex<HashSet<u64>>,
}

impl ConnShared {
    fn push_out(&self, bytes: &[u8]) {
        if !self.closed.load(Ordering::Acquire) {
            self.out.lock().unwrap().extend_from_slice(bytes);
        }
    }
}

/// One decoded unit for the dispatcher pool.
enum Work {
    JsonLine {
        conn: Arc<ConnShared>,
        line: String,
    },
    Query {
        conn: Arc<ConnShared>,
        request_id: u64,
        request: QueryRequest,
        deadline_us: u32,
        ticket: super::admission::AdmitTicket,
    },
    Admin {
        conn: Arc<ConnShared>,
        request_id: u64,
        line: String,
    },
}

struct Shared {
    state: AtomicU8,
    admission: Arc<Admission>,
    /// The served service's lifetime observability plane (adopted
    /// across hot-swaps, so one handle is valid for the server's life):
    /// per-plane request histograms, admission/frame stage timings, and
    /// the connection gauge.
    metrics: Arc<crate::obs::Metrics>,
    queue: Mutex<VecDeque<Work>>,
    cond: Condvar,
    waker: Waker,
    /// Work items enqueued or executing (drain-completion signal).
    pending: AtomicUsize,
}

impl Shared {
    fn state(&self) -> u8 {
        self.state.load(Ordering::Acquire)
    }

    fn begin_drain(&self) {
        let _ = self
            .state
            .compare_exchange(ST_RUNNING, ST_DRAINING, Ordering::AcqRel, Ordering::Relaxed);
        self.cond.notify_all();
        self.waker.wake();
    }

    fn enqueue(&self, w: Work) {
        self.pending.fetch_add(1, Ordering::AcqRel);
        self.queue.lock().unwrap().push_back(w);
        self.cond.notify_one();
    }
}

/// Running binary+JSON front door. Dropping without [`stop`] drains too.
///
/// [`stop`]: NetServer::stop
pub struct NetServer {
    pub addr: std::net::SocketAddr,
    shared: Arc<Shared>,
    loop_thread: Option<std::thread::JoinHandle<()>>,
    dispatch_threads: Vec<std::thread::JoinHandle<()>>,
}

impl NetServer {
    /// Bind `127.0.0.1:cfg.port` and serve whatever `cell` holds.
    pub fn start(cell: Arc<ServiceCell>, batcher: BatcherHandle, cfg: NetConfig) -> Result<NetServer> {
        let listener = TcpListener::bind(("127.0.0.1", cfg.port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let admission = Arc::new(Admission::new(cfg.admission.clone(), cfg.clock.clone()));
        let metrics = cell.load().obs.clone();
        // Expose this front door's admission counters on the metrics /
        // status planes (next to the exec-pool shed signal).
        metrics.register_admission(admission.clone());
        let shared = Arc::new(Shared {
            state: AtomicU8::new(ST_RUNNING),
            admission,
            metrics,
            queue: Mutex::new(VecDeque::new()),
            cond: Condvar::new(),
            waker: Waker::new()?,
            pending: AtomicUsize::new(0),
        });
        let n_dispatch = if cfg.dispatchers > 0 {
            cfg.dispatchers
        } else {
            std::thread::available_parallelism()
                .map(|p| p.get() / 2)
                .unwrap_or(2)
                .clamp(2, 8)
        };
        let mut dispatch_threads = Vec::with_capacity(n_dispatch);
        for _ in 0..n_dispatch {
            let sh = shared.clone();
            let cell = cell.clone();
            let bh = batcher.clone();
            dispatch_threads.push(std::thread::spawn(move || dispatch_loop(&sh, &cell, &bh)));
        }
        let sh = shared.clone();
        let idle_timeout = cfg.idle_timeout;
        let loop_thread =
            std::thread::spawn(move || event_loop(listener, &sh, idle_timeout));
        Ok(NetServer {
            addr,
            shared,
            loop_thread: Some(loop_thread),
            dispatch_threads,
        })
    }

    /// Admission counters (tests, ops introspection).
    pub fn admission(&self) -> &Admission {
        &self.shared.admission
    }

    /// Drain and stop: refuse new connections, finish queued work,
    /// flush outboxes, then tear down (5 s hard cap).
    pub fn stop(mut self) {
        self.shutdown_and_join();
    }

    fn shutdown_and_join(&mut self) {
        self.shared.begin_drain();
        if let Some(t) = self.loop_thread.take() {
            let _ = t.join();
        }
        // The loop sets ST_STOPPED on exit; wake every dispatcher so
        // they observe it.
        self.shared.cond.notify_all();
        for t in self.dispatch_threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        if self.loop_thread.is_some() {
            self.shutdown_and_join();
        }
    }
}

/// Dispatcher: execute one [`Work`] item against the served index and
/// hand the encoded response back to the loop via the conn outbox.
fn dispatch_loop(sh: &Shared, cell: &ServiceCell, batcher: &BatcherHandle) {
    loop {
        let work = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(w) = q.pop_front() {
                    break Some(w);
                }
                if sh.state() == ST_STOPPED {
                    break None;
                }
                let (guard, _) = sh
                    .cond
                    .wait_timeout(q, Duration::from_millis(100))
                    .unwrap();
                q = guard;
            }
        };
        let Some(work) = work else { return };
        match work {
            Work::JsonLine { conn, line } => {
                let (resp, quit) = respond_json_line(&line, cell, batcher, crate::obs::Plane::Json);
                let mut bytes = resp.to_string_compact().into_bytes();
                bytes.push(b'\n');
                conn.push_out(&bytes);
                if quit {
                    sh.begin_drain();
                }
            }
            Work::Query {
                conn,
                request_id,
                request,
                deadline_us,
                ticket,
            } => {
                let t0 = sh.metrics.now_us();
                let mut buf = Vec::new();
                match sh.admission.check_dispatch(&ticket, deadline_us) {
                    Err(e) => {
                        sh.metrics.inc_errors();
                        frame::encode_error_frame(&mut buf, request_id, &e);
                    }
                    Ok(wait_us) => {
                        // Time spent between admission and a dispatcher
                        // lane picking the query up.
                        sh.metrics
                            .record_stage(crate::obs::Stage::AdmissionWait, wait_us);
                        match cell.load().query(&request) {
                            Ok(resp) => {
                                let enc = Instant::now();
                                frame::encode_query_ok(&mut buf, request_id, &resp);
                                sh.metrics.record_stage(
                                    crate::obs::Stage::FrameEncode,
                                    enc.elapsed().as_micros() as u64,
                                );
                            }
                            Err(e) => {
                                sh.metrics.inc_errors();
                                frame::encode_error_frame(&mut buf, request_id, &e);
                            }
                        }
                    }
                }
                sh.admission.finish();
                conn.in_flight.lock().unwrap().remove(&request_id);
                conn.push_out(&buf);
                sh.metrics.record_request(
                    crate::obs::OpClass::Search,
                    crate::obs::Plane::Bin,
                    sh.metrics.now_us().saturating_sub(t0),
                );
            }
            Work::Admin {
                conn,
                request_id,
                line,
            } => {
                // Op classification and per-plane latency are recorded
                // inside the shared dispatch (tagged `plane="bin"`).
                let (resp, quit) = respond_json_line(&line, cell, batcher, crate::obs::Plane::Bin);
                let mut buf = Vec::new();
                frame::encode_admin_ok(&mut buf, request_id, &resp.to_string_compact());
                conn.in_flight.lock().unwrap().remove(&request_id);
                conn.push_out(&buf);
                if quit {
                    sh.begin_drain();
                }
            }
        }
        sh.pending.fetch_sub(1, Ordering::AcqRel);
        sh.waker.wake();
    }
}

/// Loop-side connection bookkeeping.
struct Conn {
    stream: TcpStream,
    reader: ConnReader,
    shared: Arc<ConnShared>,
    last_activity: Instant,
    want_write: bool,
    /// A fatal protocol error was queued: close once the outbox drains.
    close_after_flush: bool,
}

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKER: u64 = 1;
const TOKEN_FIRST_CONN: u64 = 2;
const READ_CHUNK: usize = 16 * 1024;

fn event_loop(listener: TcpListener, sh: &Shared, idle_timeout: Duration) {
    let mut poller = match Poller::new() {
        Ok(p) => p,
        Err(_) => return,
    };
    if poller.add(source_fd(&listener), TOKEN_LISTENER, false).is_err() {
        return;
    }
    let _ = poller.add(source_fd(sh.waker.rx()), TOKEN_WAKER, false);
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_token = TOKEN_FIRST_CONN;
    let mut events: Vec<Event> = Vec::new();
    let mut drain_started: Option<Instant> = None;
    loop {
        events.clear();
        if poller.wait(&mut events, 100).is_err() {
            break;
        }
        let draining = sh.state() != ST_RUNNING;
        if draining && drain_started.is_none() {
            drain_started = Some(Instant::now());
        }
        for ev in events.iter().copied() {
            match ev.token {
                TOKEN_LISTENER => loop {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            if draining {
                                drop(stream); // refuse: drain means drain
                                continue;
                            }
                            if stream.set_nonblocking(true).is_err() {
                                continue;
                            }
                            stream.set_nodelay(true).ok();
                            let token = next_token;
                            next_token += 1;
                            if poller.add(source_fd(&stream), token, false).is_err() {
                                continue;
                            }
                            sh.metrics.conn_opened();
                            conns.insert(
                                token,
                                Conn {
                                    stream,
                                    reader: ConnReader::new(),
                                    shared: Arc::new(ConnShared {
                                        out: Mutex::new(Vec::new()),
                                        closed: AtomicBool::new(false),
                                        in_flight: Mutex::new(HashSet::new()),
                                    }),
                                    last_activity: Instant::now(),
                                    want_write: false,
                                    close_after_flush: false,
                                },
                            );
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                        Err(_) => break,
                    }
                },
                TOKEN_WAKER => sh.waker.drain(),
                token => {
                    let mut dead = false;
                    if let Some(conn) = conns.get_mut(&token) {
                        if ev.readable {
                            dead = read_conn(conn, sh);
                        }
                        if !dead && ev.writable {
                            dead = flush_conn(conn, &mut poller, token).is_err();
                        }
                    }
                    if dead {
                        close_conn(&mut conns, &mut poller, token, sh);
                    }
                }
            }
        }
        // Flush every outbox the dispatchers filled (waker rang, or we
        // were awake anyway). Scanning all conns is fine at these
        // connection counts; partial writes arm write interest.
        let tokens: Vec<u64> = conns.keys().copied().collect();
        for token in tokens {
            let mut dead = false;
            let mut idle = false;
            if let Some(conn) = conns.get_mut(&token) {
                dead = flush_conn(conn, &mut poller, token).is_err();
                if !dead && conn.close_after_flush && conn.shared.out.lock().unwrap().is_empty() {
                    dead = true;
                }
                idle = !dead && conn.last_activity.elapsed() >= idle_timeout;
            }
            if dead || idle {
                close_conn(&mut conns, &mut poller, token, sh);
            }
        }
        if draining {
            let work_done = sh.pending.load(Ordering::Acquire) == 0;
            let flushed = conns
                .values()
                .all(|c| c.shared.out.lock().unwrap().is_empty());
            let expired = drain_started
                .map(|t| t.elapsed() > Duration::from_secs(5))
                .unwrap_or(false);
            if (work_done && flushed) || expired {
                break;
            }
        }
    }
    // Teardown: mark conns closed so dispatchers drop late output.
    // (Conns still here were never `close_conn`ed — balance the gauge.)
    for (_, conn) in conns.iter() {
        conn.shared.closed.store(true, Ordering::Release);
        sh.metrics.conn_closed();
    }
    sh.state.store(ST_STOPPED, Ordering::Release);
    sh.cond.notify_all();
}

/// Drain readable bytes into the conn's `ConnReader` and act on every
/// decoded event. Returns true when the connection is dead (EOF, I/O
/// error, fatal protocol error with nothing left to flush).
fn read_conn(conn: &mut Conn, sh: &Shared) -> bool {
    let mut chunk = [0u8; READ_CHUNK];
    let mut events = Vec::new();
    let mut decode_us = 0u64;
    loop {
        match conn.stream.read(&mut chunk) {
            Ok(0) => return true, // EOF
            Ok(n) => {
                conn.last_activity = Instant::now();
                let dec = Instant::now();
                conn.reader.push(&chunk[..n], &mut events);
                decode_us += dec.elapsed().as_micros() as u64;
                // Keep reading: more may be buffered in the kernel.
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return true,
        }
    }
    // One sample per drained read (not per chunk): how long this
    // connection's bytes took to frame/parse into events.
    if !events.is_empty() {
        sh.metrics
            .record_stage(crate::obs::Stage::FrameDecode, decode_us);
    }
    let draining = sh.state() != ST_RUNNING;
    for event in events {
        match event {
            ConnEvent::JsonLine(line) => {
                if draining {
                    let mut bytes = crate::api::wire::encode_error(&ApiError::closed(
                        "server draining; connection refused new work",
                    ))
                    .to_string_compact()
                    .into_bytes();
                    bytes.push(b'\n');
                    conn.shared.push_out(&bytes);
                } else {
                    sh.enqueue(Work::JsonLine {
                        conn: conn.shared.clone(),
                        line,
                    });
                }
            }
            ConnEvent::Frame(f) => handle_frame(conn, sh, f, draining),
            ConnEvent::ProtocolError {
                request_id,
                error,
                fatal,
            } => {
                let mut buf = Vec::new();
                if conn.reader.plane() == Plane::Json {
                    buf = crate::api::wire::encode_error(&error)
                        .to_string_compact()
                        .into_bytes();
                    buf.push(b'\n');
                } else {
                    frame::encode_error_frame(&mut buf, request_id, &error);
                }
                conn.shared.push_out(&buf);
                if fatal {
                    conn.close_after_flush = true;
                }
            }
        }
    }
    false
}

/// Route one well-formed inbound frame: admission for queries, straight
/// enqueue for admin, typed rejection for response-plane ops and
/// duplicate ids.
fn handle_frame(conn: &mut Conn, sh: &Shared, f: frame::Frame, draining: bool) {
    let request_id = f.request_id;
    let reject = |e: &ApiError| {
        let mut buf = Vec::new();
        frame::encode_error_frame(&mut buf, request_id, e);
        conn.shared.push_out(&buf);
    };
    match f.body {
        FrameBody::Query {
            request,
            deadline_us,
        } => {
            if draining {
                return reject(&ApiError::closed("server draining"));
            }
            if !conn.shared.in_flight.lock().unwrap().insert(request_id) {
                return reject(&ApiError::bad_request(format!(
                    "duplicate in-flight request id {request_id}"
                )));
            }
            match sh.admission.try_admit() {
                Ok(ticket) => sh.enqueue(Work::Query {
                    conn: conn.shared.clone(),
                    request_id,
                    request,
                    deadline_us,
                    ticket,
                }),
                Err(e) => {
                    conn.shared.in_flight.lock().unwrap().remove(&request_id);
                    reject(&e);
                }
            }
        }
        FrameBody::Admin { line } => {
            if draining {
                return reject(&ApiError::closed("server draining"));
            }
            if !conn.shared.in_flight.lock().unwrap().insert(request_id) {
                return reject(&ApiError::bad_request(format!(
                    "duplicate in-flight request id {request_id}"
                )));
            }
            sh.enqueue(Work::Admin {
                conn: conn.shared.clone(),
                request_id,
                line,
            });
        }
        FrameBody::QueryOk { .. } | FrameBody::AdminOk { .. } | FrameBody::Error { .. } => {
            reject(&ApiError::bad_request(
                "response op on the request plane",
            ));
        }
    }
}

/// Write as much of the outbox as the socket accepts; arm or disarm
/// write interest on partial/complete writes. `Err` = connection dead.
fn flush_conn(conn: &mut Conn, poller: &mut Poller, token: u64) -> std::io::Result<()> {
    let mut out = conn.shared.out.lock().unwrap();
    let mut written = 0;
    while written < out.len() {
        match conn.stream.write(&out[written..]) {
            Ok(0) => return Err(std::io::ErrorKind::WriteZero.into()),
            Ok(n) => written += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    if written > 0 {
        out.drain(..written);
        conn.last_activity = Instant::now();
    }
    let need_write = !out.is_empty();
    drop(out);
    if need_write != conn.want_write {
        conn.want_write = need_write;
        let _ = poller.modify(source_fd(&conn.stream), token, need_write);
    }
    Ok(())
}

fn close_conn(conns: &mut HashMap<u64, Conn>, poller: &mut Poller, token: u64, sh: &Shared) {
    if let Some(conn) = conns.remove(&token) {
        // Admission slots held by this connection's queued work release
        // normally: the dispatcher still runs each item, sees the conn
        // marked closed, and drops the encoded bytes.
        conn.shared.closed.store(true, Ordering::Release);
        let _ = poller.remove(source_fd(&conn.stream));
        sh.metrics.conn_closed();
    }
}
