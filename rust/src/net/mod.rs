//! Scalable wire front end: binary framed protocol, readiness event
//! loop, request multiplexing, and admission control.
//!
//! The JSON line protocol in [`crate::api::wire`] is a fine debug and
//! compat plane, but it pays float-to-text costs per vector and the
//! threaded server behind it pins one OS thread per connection. This
//! module is the serving path built for throughput:
//!
//! - [`frame`] — the v3 length-prefixed binary frame format (`PXW3`
//!   magic). Query vectors travel as raw little-endian `f32`, responses
//!   carry the same [`crate::api::QueryResponse`] payloads bit for bit,
//!   and every frame carries a `u64` request id so one connection can
//!   pipeline many requests and match responses out of order. Decoding
//!   is strictly bounded: declared lengths are validated against bytes
//!   actually present before any allocation.
//! - [`conn`] — per-connection incremental decoder. Sniffs the first
//!   byte to pick the plane (`{` = JSON lines, `P` = binary frames), so
//!   both protocols share one port; resynchronises on corrupt framing
//!   instead of dying.
//! - [`poll`] — the readiness primitive: raw `epoll(7)` on Linux,
//!   `poll(2)` on other unix, both via direct syscall declarations (no
//!   new dependencies), plus a loopback-socket [`poll::Waker`].
//! - [`admission`] — typed load shedding. A bounded in-flight budget
//!   rejects at arrival; queue-wait and per-request deadlines reject at
//!   dispatch; both surface as [`crate::api::ApiErrorCode::Overloaded`]
//!   so clients can tell "backoff and retry" from "your request is
//!   broken". A [`Clock`] injection point keeps the policy testable
//!   with simulated time.
//! - [`server`] — [`NetServer`]: one acceptor + event-loop thread
//!   owning all sockets, a dispatcher pool executing decoded requests
//!   on the existing [`crate::coordinator::SearchService`] path, and
//!   graceful drain shared by both planes.
//! - [`client`] — [`BinClient`]: the pipelining binary-plane client the
//!   tests, examples, and open-loop load generator build on.

pub mod admission;
pub mod client;
pub mod conn;
pub mod frame;
pub mod poll;
pub mod server;

pub use admission::{Admission, AdmissionConfig, AdmissionCounters, Clock};
pub use client::BinClient;
pub use conn::{ConnEvent, ConnReader, Plane};
pub use server::{NetConfig, NetServer};
