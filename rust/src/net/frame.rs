//! Binary v3 frame codec — the length-prefixed wire format of the
//! binary plane.
//!
//! # Frame grammar
//!
//! ```text
//! frame   := magic[4]="PXW3"  payload_len:u32  payload
//! payload := request_id:u64  op:u8  body
//! ```
//!
//! All integers and floats are little-endian, written through the same
//! `dataset::io` bulk codecs the artifact format uses (`put_f32_slice`
//! is one memcpy on LE targets), so query payloads ship as raw f32
//! bytes instead of JSON decimal text. The trailing `3` in the magic is
//! the protocol version: a future incompatible revision changes the
//! magic, so an old server sees a bad magic (fatal, typed) rather than
//! misparsing. The first magic byte `P` is disjoint from `{` and
//! whitespace, which is what lets one port carry both planes via a
//! first-byte sniff.
//!
//! # Ops
//!
//! Request ops (client → server): [`OP_QUERY`] carries a typed
//! [`QueryRequest`] plus a per-request deadline; [`OP_ADMIN`] carries
//! one v2 JSON admin line verbatim (status/reload/insert/...), so the
//! JSON codec in [`crate::api::wire`] remains the single source of
//! truth for admin semantics. Response ops (server → client):
//! [`OP_QUERY_OK`] (typed [`QueryResponse`]), [`OP_ADMIN_OK`] (JSON
//! response line), [`OP_ERROR`] (typed [`ApiError`] — decode failures,
//! admission sheds). Responses echo the request id, which is how one
//! connection pipelines many in-flight requests: ids need not return in
//! send order.
//!
//! # Bounded decode
//!
//! Decoding NEVER allocates a frame's self-declared length up front.
//! The connection layer caps `payload_len` at [`MAX_FRAME_LEN`] before
//! buffering and only ever grows buffers by bytes actually received;
//! [`decode_payload`] then parses a fully-received slice through
//! [`Reader`], whose `take` bounds every vector length against the real
//! remaining bytes (with `checked_mul` on counts) before allocating.

use crate::api::wire;
use crate::api::{
    ApiError, ApiErrorCode, NeighborList, QueryOptions, QueryRequest, QueryResponse, SearchMode,
    MAX_BATCH_QUERIES,
};
use crate::dataset::io::{put_f32_slice, put_str, put_u32, put_u32_slice, put_u64, Reader};
use crate::search::SearchStats;
use crate::util::json::{self, Json};

/// Frame magic; the trailing ASCII digit is the wire protocol version.
pub const MAGIC: [u8; 4] = *b"PXW3";
/// Fixed bytes before the payload: magic + u32 payload length.
pub const HEADER_LEN: usize = 8;
/// Upper bound on a payload a peer may declare (64 MiB — comfortably
/// above `MAX_BATCH_QUERIES` float queries, far below an allocation
/// that could be weaponized).
pub const MAX_FRAME_LEN: usize = 1 << 26;

/// Client → server: typed query batch.
pub const OP_QUERY: u8 = 0x01;
/// Client → server: one v2 JSON admin line in the body.
pub const OP_ADMIN: u8 = 0x02;
/// Server → client: typed [`QueryResponse`].
pub const OP_QUERY_OK: u8 = 0x81;
/// Server → client: JSON admin response line in the body.
pub const OP_ADMIN_OK: u8 = 0x82;
/// Server → client: typed [`ApiError`] for the echoed request id.
pub const OP_ERROR: u8 = 0x83;

/// One decoded frame: the multiplexing id plus a typed body.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub request_id: u64,
    pub body: FrameBody,
}

/// Typed frame bodies (see module docs for the op inventory).
#[derive(Clone, Debug, PartialEq)]
pub enum FrameBody {
    Query {
        request: QueryRequest,
        /// Per-request deadline in µs of queue wait the client will
        /// tolerate; 0 means "server default".
        deadline_us: u32,
    },
    Admin {
        line: String,
    },
    QueryOk {
        response: QueryResponse,
    },
    AdminOk {
        line: String,
    },
    Error {
        error: ApiError,
    },
}

fn code_to_u8(c: ApiErrorCode) -> u8 {
    match c {
        ApiErrorCode::BadRequest => 1,
        ApiErrorCode::DimMismatch => 2,
        ApiErrorCode::Closed => 3,
        ApiErrorCode::Internal => 4,
        ApiErrorCode::Overloaded => 5,
    }
}

fn code_from_u8(b: u8) -> ApiErrorCode {
    match b {
        1 => ApiErrorCode::BadRequest,
        2 => ApiErrorCode::DimMismatch,
        3 => ApiErrorCode::Closed,
        5 => ApiErrorCode::Overloaded,
        // Unknown codes degrade to Internal — same forward-compat rule
        // as the JSON plane's decode_error.
        _ => ApiErrorCode::Internal,
    }
}

fn mode_to_u8(m: SearchMode) -> u8 {
    match m {
        SearchMode::Accurate => 0,
        SearchMode::PqAdt => 1,
        SearchMode::Hybrid => 2,
    }
}

fn mode_from_u8(b: u8) -> Result<SearchMode, ApiError> {
    match b {
        0 => Ok(SearchMode::Accurate),
        1 => Ok(SearchMode::PqAdt),
        2 => Ok(SearchMode::Hybrid),
        _ => Err(ApiError::bad_request(format!("frame: unknown mode {b}"))),
    }
}

/// `Option<usize>` on the wire: `u32::MAX` is `None`.
fn opt_to_u32(o: Option<usize>) -> u32 {
    match o {
        Some(v) => (v as u32).min(u32::MAX - 1),
        None => u32::MAX,
    }
}

fn opt_from_u32(x: u32) -> Option<usize> {
    if x == u32::MAX {
        None
    } else {
        Some(x as usize)
    }
}

/// Start a frame: magic + length placeholder. Returns the payload start
/// offset for [`finish_frame`].
fn begin_frame(buf: &mut Vec<u8>, request_id: u64, op: u8) -> usize {
    buf.extend_from_slice(&MAGIC);
    put_u32(buf, 0); // patched by finish_frame
    let start = buf.len();
    put_u64(buf, request_id);
    buf.push(op);
    start
}

fn finish_frame(buf: &mut Vec<u8>, start: usize) {
    let len = (buf.len() - start) as u32;
    buf[start - 4..start].copy_from_slice(&len.to_le_bytes());
}

/// Append an [`OP_QUERY`] frame.
pub fn encode_query(buf: &mut Vec<u8>, request_id: u64, req: &QueryRequest, deadline_us: u32) {
    let start = begin_frame(buf, request_id, OP_QUERY);
    put_u32(buf, req.k as u32);
    put_u32(buf, deadline_us);
    buf.push(req.options.want_stats as u8);
    buf.push(mode_to_u8(req.options.mode));
    put_u32(buf, opt_to_u32(req.options.l_override));
    put_u32(buf, opt_to_u32(req.options.early_term_tau));
    put_u32(buf, opt_to_u32(req.options.rerank));
    put_u32(buf, req.vectors.len() as u32);
    let dim = req.vectors.first().map_or(0, Vec::len);
    put_u32(buf, dim as u32);
    for v in &req.vectors {
        debug_assert_eq!(v.len(), dim, "ragged batches are not encodable");
        put_f32_slice(buf, v);
    }
    finish_frame(buf, start);
}

/// Append an [`OP_ADMIN`] frame carrying one v2 JSON request line.
pub fn encode_admin(buf: &mut Vec<u8>, request_id: u64, line: &str) {
    let start = begin_frame(buf, request_id, OP_ADMIN);
    buf.extend_from_slice(line.as_bytes());
    finish_frame(buf, start);
}

/// Append an [`OP_QUERY_OK`] frame.
pub fn encode_query_ok(buf: &mut Vec<u8>, request_id: u64, resp: &QueryResponse) {
    let start = begin_frame(buf, request_id, OP_QUERY_OK);
    put_u64(buf, resp.server_latency_us);
    match &resp.stats {
        Some(s) => {
            buf.push(1);
            put_stats(buf, s);
        }
        None => buf.push(0),
    }
    put_u32(buf, resp.results.len() as u32);
    for (i, nl) in resp.results.iter().enumerate() {
        match resp.errors.get(i).and_then(Option::as_ref) {
            Some(e) => {
                buf.push(1);
                buf.push(code_to_u8(e.code));
                put_str(buf, &e.message);
            }
            None => {
                buf.push(0);
                put_u32(buf, nl.ids.len() as u32);
                put_u32_slice(buf, &nl.ids);
                put_f32_slice(buf, &nl.dists);
            }
        }
    }
    finish_frame(buf, start);
}

/// Append an [`OP_ADMIN_OK`] frame carrying one JSON response line.
pub fn encode_admin_ok(buf: &mut Vec<u8>, request_id: u64, line: &str) {
    let start = begin_frame(buf, request_id, OP_ADMIN_OK);
    buf.extend_from_slice(line.as_bytes());
    finish_frame(buf, start);
}

/// Append an [`OP_ERROR`] frame.
pub fn encode_error_frame(buf: &mut Vec<u8>, request_id: u64, e: &ApiError) {
    let start = begin_frame(buf, request_id, OP_ERROR);
    buf.push(code_to_u8(e.code));
    put_str(buf, &e.message);
    finish_frame(buf, start);
}

fn put_stats(buf: &mut Vec<u8>, s: &SearchStats) {
    put_u64(buf, s.pq_dists as u64);
    put_u64(buf, s.exact_dists as u64);
    put_u64(buf, s.hops as u64);
    put_u64(buf, s.sorts as u64);
    put_u64(buf, s.bytes_index);
    put_u64(buf, s.bytes_pq);
    put_u64(buf, s.bytes_raw);
    put_u64(buf, s.et_iterations as u64);
    put_u64(buf, s.adt_builds as u64);
    put_u64(buf, s.queue_wait_us);
    put_u64(buf, s.cold_reads as u64);
    put_u64(buf, s.cold_bytes);
    put_u64(buf, s.cache_hits as u64);
    put_u64(buf, s.cache_misses as u64);
    put_u64(buf, s.lsh_probes as u64);
    buf.push(s.early_terminated as u8);
}

fn read_stats(r: &mut Reader<'_>) -> crate::util::error::Result<SearchStats> {
    Ok(SearchStats {
        pq_dists: r.u64()? as usize,
        exact_dists: r.u64()? as usize,
        hops: r.u64()? as usize,
        sorts: r.u64()? as usize,
        bytes_index: r.u64()?,
        bytes_pq: r.u64()?,
        bytes_raw: r.u64()?,
        et_iterations: r.u64()? as usize,
        adt_builds: r.u64()? as usize,
        queue_wait_us: r.u64()?,
        cold_reads: r.u64()? as usize,
        cold_bytes: r.u64()?,
        cache_hits: r.u64()? as usize,
        cache_misses: r.u64()? as usize,
        lsh_probes: r.u64()? as usize,
        early_terminated: r.take(1)?[0] != 0,
    })
}

/// Validate a frame header. `h` must hold at least [`HEADER_LEN`]
/// bytes; returns the declared payload length, rejecting a bad magic or
/// a length above [`MAX_FRAME_LEN`] BEFORE anyone allocates for it.
pub fn parse_header(h: &[u8]) -> Result<usize, ApiError> {
    assert!(h.len() >= HEADER_LEN);
    if h[..4] != MAGIC {
        return Err(ApiError::bad_request(format!(
            "frame: bad magic {:02x}{:02x}{:02x}{:02x}",
            h[0], h[1], h[2], h[3]
        )));
    }
    let len = u32::from_le_bytes([h[4], h[5], h[6], h[7]]) as usize;
    if len < 9 {
        // request_id + op is the minimum payload.
        return Err(ApiError::bad_request(format!("frame: runt payload {len}")));
    }
    if len > MAX_FRAME_LEN {
        return Err(ApiError::bad_request(format!(
            "frame: declared payload {len} exceeds max {MAX_FRAME_LEN}"
        )));
    }
    Ok(len)
}

/// Decode one fully-received payload (the bytes after the header).
///
/// On failure the error is attributed to the best-effort request id
/// parsed from the payload prefix (0 when even that is missing), so the
/// server can answer the offending request with a typed [`OP_ERROR`]
/// frame while the connection survives.
pub fn decode_payload(payload: &[u8]) -> Result<Frame, (u64, ApiError)> {
    let mut r = Reader::new(payload);
    let request_id = r.u64().map_err(|_| {
        (0u64, ApiError::bad_request("frame: payload too short for request id"))
    })?;
    let fail = |m: String| (request_id, ApiError::bad_request(m));
    let op = r.take(1).map_err(|e| fail(format!("frame: {e}")))?[0];
    let body = match op {
        OP_QUERY => decode_query_body(&mut r).map_err(|e| (request_id, e))?,
        OP_ADMIN => FrameBody::Admin {
            line: utf8_rest(&mut r, payload).map_err(|e| (request_id, e))?,
        },
        OP_QUERY_OK => decode_query_ok_body(&mut r).map_err(|e| (request_id, e))?,
        OP_ADMIN_OK => FrameBody::AdminOk {
            line: utf8_rest(&mut r, payload).map_err(|e| (request_id, e))?,
        },
        OP_ERROR => {
            let code = code_from_u8(r.take(1).map_err(|e| fail(format!("frame: {e}")))?[0]);
            let message = r.str().map_err(|e| fail(format!("frame: {e}")))?;
            FrameBody::Error {
                error: ApiError::new(code, message),
            }
        }
        other => return Err(fail(format!("frame: unknown op tag {other:#04x}"))),
    };
    if r.pos() != payload.len() {
        return Err(fail(format!(
            "frame: {} trailing bytes after body",
            payload.len() - r.pos()
        )));
    }
    Ok(Frame { request_id, body })
}

fn utf8_rest(r: &mut Reader<'_>, payload: &[u8]) -> Result<String, ApiError> {
    let rest = r
        .take(payload.len() - r.pos())
        .map_err(|e| ApiError::bad_request(format!("frame: {e}")))?;
    String::from_utf8(rest.to_vec())
        .map_err(|_| ApiError::bad_request("frame: admin body is not UTF-8"))
}

fn decode_query_body(r: &mut Reader<'_>) -> Result<FrameBody, ApiError> {
    let bad = |e: crate::util::error::Error| ApiError::bad_request(format!("frame: {e}"));
    let k = r.u32().map_err(bad)? as usize;
    let deadline_us = r.u32().map_err(bad)?;
    let flags = r.take(1).map_err(bad)?[0];
    let mode = mode_from_u8(r.take(1).map_err(bad)?[0])?;
    let l_override = opt_from_u32(r.u32().map_err(bad)?);
    let early_term_tau = opt_from_u32(r.u32().map_err(bad)?);
    let rerank = opt_from_u32(r.u32().map_err(bad)?);
    let n = r.u32().map_err(bad)? as usize;
    let dim = r.u32().map_err(bad)? as usize;
    if n > MAX_BATCH_QUERIES {
        return Err(ApiError::bad_request(format!(
            "frame: batch of {n} exceeds max {MAX_BATCH_QUERIES}"
        )));
    }
    // f32_vec bounds dim against the bytes actually present (take +
    // checked_mul), so a lying dim fails typed instead of allocating.
    let mut vectors = Vec::with_capacity(n.min(1024));
    for _ in 0..n {
        vectors.push(r.f32_vec(dim).map_err(bad)?);
    }
    Ok(FrameBody::Query {
        request: QueryRequest {
            vectors,
            k,
            options: QueryOptions {
                mode,
                l_override,
                early_term_tau,
                rerank,
                want_stats: flags & 1 != 0,
            },
        },
        deadline_us,
    })
}

fn decode_query_ok_body(r: &mut Reader<'_>) -> Result<FrameBody, ApiError> {
    let bad = |e: crate::util::error::Error| ApiError::bad_request(format!("frame: {e}"));
    let server_latency_us = r.u64().map_err(bad)?;
    let stats = match r.take(1).map_err(bad)?[0] {
        0 => None,
        _ => Some(read_stats(r).map_err(bad)?),
    };
    let n = r.u32().map_err(bad)? as usize;
    if n > MAX_BATCH_QUERIES {
        return Err(ApiError::bad_request(format!(
            "frame: response batch of {n} exceeds max {MAX_BATCH_QUERIES}"
        )));
    }
    let mut results = Vec::with_capacity(n.min(1024));
    let mut errors = Vec::with_capacity(n.min(1024));
    for _ in 0..n {
        match r.take(1).map_err(bad)?[0] {
            0 => {
                let m = r.u32().map_err(bad)? as usize;
                let ids = r.u32_vec(m).map_err(bad)?;
                let dists = r.f32_vec(m).map_err(bad)?;
                results.push(NeighborList { ids, dists });
                errors.push(None);
            }
            1 => {
                let code = code_from_u8(r.take(1).map_err(bad)?[0]);
                let message = r.str().map_err(bad)?;
                results.push(NeighborList {
                    ids: Vec::new(),
                    dists: Vec::new(),
                });
                errors.push(Some(ApiError::new(code, message)));
            }
            t => {
                return Err(ApiError::bad_request(format!(
                    "frame: unknown result tag {t}"
                )))
            }
        }
    }
    Ok(FrameBody::QueryOk {
        response: QueryResponse {
            results,
            errors,
            stats,
            server_latency_us,
        },
    })
}

/// Encode one whole frame from its typed form — the symmetric inverse
/// of header parse + [`decode_payload`]; used by the loopback bench and
/// anywhere a [`Frame`] value is already in hand.
pub fn encode_frame(buf: &mut Vec<u8>, frame: &Frame) {
    match &frame.body {
        FrameBody::Query {
            request,
            deadline_us,
        } => encode_query(buf, frame.request_id, request, *deadline_us),
        FrameBody::Admin { line } => encode_admin(buf, frame.request_id, line),
        FrameBody::QueryOk { response } => encode_query_ok(buf, frame.request_id, response),
        FrameBody::AdminOk { line } => encode_admin_ok(buf, frame.request_id, line),
        FrameBody::Error { error } => encode_error_frame(buf, frame.request_id, error),
    }
}

/// Decode one whole frame from a buffer that holds exactly one frame.
pub fn decode_frame(buf: &[u8]) -> Result<Frame, ApiError> {
    if buf.len() < HEADER_LEN {
        return Err(ApiError::bad_request("frame: short header"));
    }
    let len = parse_header(&buf[..HEADER_LEN])?;
    if buf.len() != HEADER_LEN + len {
        return Err(ApiError::bad_request(format!(
            "frame: buffer holds {} payload bytes, header declares {len}",
            buf.len() - HEADER_LEN
        )));
    }
    decode_payload(&buf[HEADER_LEN..]).map_err(|(_, e)| e)
}

/// Convenience used by clients: turn a decoded response-plane frame into
/// the per-request outcome, typed. Request-plane ops are a protocol
/// violation in a response stream.
pub fn response_outcome(frame: Frame) -> (u64, Result<FrameBody, ApiError>) {
    let id = frame.request_id;
    match frame.body {
        FrameBody::Error { error } => (id, Err(error)),
        FrameBody::Query { .. } | FrameBody::Admin { .. } => (
            id,
            Err(ApiError::bad_request(
                "frame: request op on the response plane",
            )),
        ),
        ok => (id, Ok(ok)),
    }
}

/// Parse an admin response line back into [`Json`] (clients of
/// [`OP_ADMIN_OK`] bodies).
pub fn parse_admin_line(line: &str) -> Result<Json, ApiError> {
    json::parse(line).map_err(|e| ApiError::internal(format!("admin line: {e}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_request() -> QueryRequest {
        QueryRequest {
            vectors: vec![vec![1.0, -2.5, 3.25], vec![0.0, 7.5, -0.125]],
            k: 9,
            options: QueryOptions {
                mode: SearchMode::Accurate,
                l_override: Some(77),
                early_term_tau: None,
                rerank: Some(3),
                want_stats: true,
            },
        }
    }

    #[test]
    fn query_frame_roundtrip() {
        let req = sample_request();
        let mut buf = Vec::new();
        encode_query(&mut buf, 42, &req, 1500);
        let f = decode_frame(&buf).unwrap();
        assert_eq!(f.request_id, 42);
        match f.body {
            FrameBody::Query {
                request,
                deadline_us,
            } => {
                assert_eq!(deadline_us, 1500);
                assert_eq!(request.k, req.k);
                assert_eq!(request.vectors, req.vectors);
                assert_eq!(request.options.mode, req.options.mode);
                assert_eq!(request.options.l_override, req.options.l_override);
                assert_eq!(request.options.early_term_tau, None);
                assert_eq!(request.options.rerank, Some(3));
                assert!(request.options.want_stats);
            }
            other => panic!("wrong body: {other:?}"),
        }
    }

    #[test]
    fn empty_and_default_option_queries_roundtrip() {
        // None options map through the u32::MAX sentinel; empty batch is
        // representable (the service rejects it, but the wire must not).
        let req = QueryRequest {
            vectors: vec![],
            k: 1,
            options: QueryOptions::default(),
        };
        let mut buf = Vec::new();
        encode_query(&mut buf, 7, &req, 0);
        match decode_frame(&buf).unwrap().body {
            FrameBody::Query { request, .. } => {
                assert!(request.vectors.is_empty());
                assert_eq!(request.options, QueryOptions::default());
            }
            other => panic!("wrong body: {other:?}"),
        }
    }

    #[test]
    fn query_ok_roundtrip_with_stats_and_per_query_error() {
        let response = QueryResponse {
            results: vec![
                NeighborList {
                    ids: vec![3, 1, 4],
                    dists: vec![0.5, 1.5, 2.5],
                },
                NeighborList {
                    ids: vec![],
                    dists: vec![],
                },
            ],
            errors: vec![None, Some(ApiError::internal("worker panic"))],
            stats: Some(SearchStats {
                pq_dists: 10,
                exact_dists: 20,
                hops: 30,
                sorts: 40,
                bytes_index: 50,
                bytes_pq: 60,
                bytes_raw: 70,
                et_iterations: 80,
                early_terminated: true,
                adt_builds: 90,
                queue_wait_us: 100,
                cold_reads: 110,
                cold_bytes: 120,
                cache_hits: 130,
                cache_misses: 140,
                lsh_probes: 150,
            }),
            server_latency_us: 777,
        };
        let mut buf = Vec::new();
        encode_query_ok(&mut buf, 999, &response);
        let f = decode_frame(&buf).unwrap();
        assert_eq!(f.request_id, 999);
        match f.body {
            FrameBody::QueryOk { response: got } => {
                assert_eq!(got.server_latency_us, 777);
                assert_eq!(got.results, response.results);
                assert_eq!(got.errors, response.errors);
                let s = got.stats.unwrap();
                assert_eq!(s, response.stats.unwrap());
            }
            other => panic!("wrong body: {other:?}"),
        }
    }

    #[test]
    fn admin_and_error_frames_roundtrip() {
        let mut buf = Vec::new();
        encode_admin(&mut buf, 1, r#"{"v":2,"op":"status"}"#);
        encode_admin_ok(&mut buf, 1, r#"{"ok":true}"#);
        encode_error_frame(&mut buf, 2, &ApiError::overloaded("shed"));
        // Three frames back to back: walk them via the header.
        let mut off = 0;
        let mut frames = Vec::new();
        while off < buf.len() {
            let len = parse_header(&buf[off..off + HEADER_LEN]).unwrap();
            frames.push(decode_payload(&buf[off + HEADER_LEN..off + HEADER_LEN + len]).unwrap());
            off += HEADER_LEN + len;
        }
        assert_eq!(frames.len(), 3);
        assert_eq!(
            frames[0].body,
            FrameBody::Admin {
                line: r#"{"v":2,"op":"status"}"#.into()
            }
        );
        assert_eq!(
            frames[1].body,
            FrameBody::AdminOk {
                line: r#"{"ok":true}"#.into()
            }
        );
        match &frames[2].body {
            FrameBody::Error { error } => {
                assert_eq!(error.code, ApiErrorCode::Overloaded);
                assert_eq!(error.message, "shed");
            }
            other => panic!("wrong body: {other:?}"),
        }
    }

    #[test]
    fn header_rejects_bad_magic_runt_and_giant_lengths() {
        let mut h = [0u8; HEADER_LEN];
        h[..4].copy_from_slice(b"JUNK");
        assert!(parse_header(&h).unwrap_err().message.contains("bad magic"));
        h[..4].copy_from_slice(&MAGIC);
        h[4..].copy_from_slice(&3u32.to_le_bytes());
        assert!(parse_header(&h).unwrap_err().message.contains("runt"));
        h[4..].copy_from_slice(&(u32::MAX).to_le_bytes());
        let e = parse_header(&h).unwrap_err();
        assert_eq!(e.code, ApiErrorCode::BadRequest);
        assert!(e.message.contains("exceeds max"));
        h[4..].copy_from_slice(&(MAX_FRAME_LEN as u32).to_le_bytes());
        assert_eq!(parse_header(&h).unwrap(), MAX_FRAME_LEN);
    }

    #[test]
    fn truncated_payload_fails_typed_with_attributed_id() {
        let mut buf = Vec::new();
        encode_query(&mut buf, 12345, &sample_request(), 0);
        // Chop bytes off the payload tail: every prefix that still holds
        // the request id must attribute the error to id 12345.
        for cut in HEADER_LEN + 9..buf.len() - 1 {
            let (id, e) = decode_payload(&buf[HEADER_LEN..cut]).unwrap_err();
            assert_eq!(id, 12345, "cut at {cut}");
            assert_eq!(e.code, ApiErrorCode::BadRequest);
        }
        // Shorter than the id: attribution falls back to 0.
        let (id, _) = decode_payload(&buf[HEADER_LEN..HEADER_LEN + 4]).unwrap_err();
        assert_eq!(id, 0);
    }

    #[test]
    fn unknown_op_and_trailing_garbage_fail_typed() {
        let mut payload = Vec::new();
        put_u64(&mut payload, 5);
        payload.push(0x7f);
        let (id, e) = decode_payload(&payload).unwrap_err();
        assert_eq!(id, 5);
        assert!(e.message.contains("unknown op"));

        let mut buf = Vec::new();
        encode_admin(&mut buf, 6, "{}");
        // Rewrite the op to OP_ERROR whose body won't consume the rest.
        let mut payload = buf[HEADER_LEN..].to_vec();
        payload[8] = OP_QUERY;
        let (id, e) = decode_payload(&payload).unwrap_err();
        assert_eq!(id, 6);
        assert_eq!(e.code, ApiErrorCode::BadRequest);
    }

    #[test]
    fn oversized_batch_count_rejected_before_allocation() {
        let mut payload = Vec::new();
        put_u64(&mut payload, 9);
        payload.push(OP_QUERY);
        put_u32(&mut payload, 10); // k
        put_u32(&mut payload, 0); // deadline
        payload.push(0); // flags
        payload.push(2); // mode hybrid
        put_u32(&mut payload, u32::MAX);
        put_u32(&mut payload, u32::MAX);
        put_u32(&mut payload, u32::MAX);
        put_u32(&mut payload, u32::MAX); // n: absurd
        put_u32(&mut payload, 1024); // dim
        let (id, e) = decode_payload(&payload).unwrap_err();
        assert_eq!(id, 9);
        assert!(e.message.contains("exceeds max"));
    }

    #[test]
    fn response_outcome_types_errors_and_rejects_request_ops() {
        let (id, out) = response_outcome(Frame {
            request_id: 3,
            body: FrameBody::Error {
                error: ApiError::overloaded("x"),
            },
        });
        assert_eq!(id, 3);
        assert_eq!(out.unwrap_err().code, ApiErrorCode::Overloaded);
        let (_, out) = response_outcome(Frame {
            request_id: 4,
            body: FrameBody::Admin { line: "{}".into() },
        });
        assert_eq!(out.unwrap_err().code, ApiErrorCode::BadRequest);
    }
}
