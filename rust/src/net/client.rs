//! Binary-plane client: speaks the [`frame`] format over one TCP
//! connection and pipelines — many requests may be in flight before the
//! first response is read, matched back by request id.
//!
//! This is deliberately thinner than [`crate::coordinator::server::Client`]
//! (the JSON-plane client): no reconnect machinery, blocking I/O, and
//! the send/receive halves are exposed separately so tests and the
//! open-loop load generator can drive them from different threads via
//! [`TcpStream::try_clone`].

use super::frame::{self, Frame, FrameBody};
use crate::anyhow;
use crate::api::{ApiError, QueryRequest, QueryResponse};
use crate::util::error::Result;
use crate::util::json::{self, Json};
use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};

/// One binary-plane connection.
pub struct BinClient {
    stream: TcpStream,
    inbuf: Vec<u8>,
    next_id: u64,
}

impl BinClient {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<BinClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(BinClient {
            stream,
            inbuf: Vec::new(),
            next_id: 1,
        })
    }

    /// The underlying stream — `try_clone` it to split send/receive
    /// across threads (open-loop load generation).
    pub fn stream(&self) -> &TcpStream {
        &self.stream
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Send a query frame with an auto-assigned id; returns the id to
    /// match the response with. Does NOT wait for the response.
    pub fn send_query(&mut self, req: &QueryRequest, deadline_us: u32) -> Result<u64> {
        let id = self.fresh_id();
        self.send_query_with_id(id, req, deadline_us)?;
        Ok(id)
    }

    /// Send a query frame with an EXPLICIT id (tests exercise duplicate
    /// in-flight ids with this).
    pub fn send_query_with_id(
        &mut self,
        id: u64,
        req: &QueryRequest,
        deadline_us: u32,
    ) -> Result<()> {
        let mut buf = Vec::new();
        frame::encode_query(&mut buf, id, req, deadline_us);
        self.stream.write_all(&buf)?;
        Ok(())
    }

    /// Send an admin op (a JSON op line, e.g. `{"op":"status"}`) on the
    /// binary plane; returns the request id.
    pub fn send_admin(&mut self, line: &str) -> Result<u64> {
        let id = self.fresh_id();
        let mut buf = Vec::new();
        frame::encode_admin(&mut buf, id, line);
        self.stream.write_all(&buf)?;
        Ok(id)
    }

    /// Write raw bytes verbatim (adversarial protocol tests).
    pub fn send_raw(&mut self, bytes: &[u8]) -> Result<()> {
        self.stream.write_all(bytes)?;
        Ok(())
    }

    /// Block until one full frame arrives. An `Err` here is a transport
    /// or codec failure — server-reported errors come back as
    /// well-formed [`FrameBody::Error`] frames via [`recv`].
    ///
    /// [`recv`]: BinClient::recv
    pub fn recv_frame(&mut self) -> Result<Frame> {
        self.fill(frame::HEADER_LEN)?;
        let payload_len = frame::parse_header(&self.inbuf[..frame::HEADER_LEN])
            .map_err(|e| anyhow!("bad response header: {}", e.message))?;
        let total = frame::HEADER_LEN + payload_len;
        self.fill(total)?;
        let decoded = frame::decode_payload(&self.inbuf[frame::HEADER_LEN..total])
            .map_err(|(id, e)| anyhow!("bad response payload (id {}): {}", id, e.message));
        self.inbuf.drain(..total);
        decoded
    }

    /// Receive one response: `(request_id, Ok(body) | Err(api_error))`.
    /// Typed server-side failures (overloaded, bad_request, ...) land in
    /// the inner `Err` with the id they belong to.
    pub fn recv(&mut self) -> Result<(u64, std::result::Result<FrameBody, ApiError>)> {
        let f = self.recv_frame()?;
        Ok(frame::response_outcome(f))
    }

    /// One blocking round trip; the common non-pipelined path. The
    /// inner result carries typed server-side errors.
    pub fn query(
        &mut self,
        req: &QueryRequest,
    ) -> Result<std::result::Result<QueryResponse, ApiError>> {
        let id = self.send_query(req, 0)?;
        let (rid, outcome) = self.recv()?;
        if rid != id {
            return Err(anyhow!(
                "response id {} does not match request id {} (interleaved use of a \
                 round-trip helper on a pipelined connection?)",
                rid,
                id
            ));
        }
        match outcome {
            Ok(FrameBody::QueryOk { response }) => Ok(Ok(response)),
            Ok(_) => Err(anyhow!("server answered a query with a non-query op")),
            Err(e) => Ok(Err(e)),
        }
    }

    /// One blocking admin round trip; parses the response line back to
    /// JSON (same shape as the JSON plane returns for the op).
    pub fn admin(&mut self, line: &str) -> Result<Json> {
        let id = self.send_admin(line)?;
        let (rid, outcome) = self.recv()?;
        if rid != id {
            return Err(anyhow!("response id {} does not match admin id {}", rid, id));
        }
        match outcome {
            Ok(FrameBody::AdminOk { line }) => {
                json::parse(&line).map_err(|e| anyhow!("bad admin response JSON: {:?}", e))
            }
            Ok(_) => Err(anyhow!("server answered an admin op with a non-admin op")),
            Err(e) => Err(anyhow!("admin op failed [{}]: {}", e.code.name(), e.message)),
        }
    }

    fn fill(&mut self, need: usize) -> Result<()> {
        let mut chunk = [0u8; 4096];
        while self.inbuf.len() < need {
            let n = self.stream.read(&mut chunk)?;
            if n == 0 {
                return Err(anyhow!("server closed the connection"));
            }
            self.inbuf.extend_from_slice(&chunk[..n]);
        }
        Ok(())
    }
}
