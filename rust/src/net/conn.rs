//! Per-connection byte-stream state machine: plane sniffing and
//! incremental framing, pure over byte slices so every adversarial
//! shape is unit-testable without a socket.
//!
//! The first byte a connection sends picks its plane for life:
//! `{` or ASCII whitespace → the line-delimited JSON compat plane,
//! the `P` of the `PXW3` magic → the binary frame plane, anything
//! else → a fatal protocol error. On the binary plane the reader
//! enforces the bounded-decode contract: the internal buffer only ever
//! grows by bytes actually received, a declared length above
//! [`frame::MAX_FRAME_LEN`] is rejected at header time (typed,
//! non-fatal) and the stream resynchronizes by scanning for the next
//! magic, so one malicious or buggy frame cannot take down a pipelined
//! connection's other in-flight requests.

use super::frame::{self, Frame, HEADER_LEN, MAGIC};
use crate::api::ApiError;

/// Which protocol a connection speaks (decided by its first byte).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Plane {
    /// No bytes seen yet.
    Unknown,
    /// Line-delimited v1/v2 JSON.
    Json,
    /// Length-prefixed v3 binary frames.
    Binary,
}

/// One unit of decoded inbound traffic.
#[derive(Debug, PartialEq)]
pub enum ConnEvent {
    /// A complete JSON request line (without the trailing newline).
    JsonLine(String),
    /// A complete, well-formed binary frame.
    Frame(Frame),
    /// A malformed unit. `fatal` means the stream can no longer be
    /// framed and the connection must close after the error is sent;
    /// otherwise the connection survives and later frames still parse.
    ProtocolError {
        request_id: u64,
        error: ApiError,
        fatal: bool,
    },
}

/// Incremental decoder for one connection's inbound bytes.
pub struct ConnReader {
    plane: Plane,
    buf: Vec<u8>,
    /// Binary plane: payload length from an accepted header, while the
    /// payload is still arriving.
    pending_len: Option<usize>,
    /// Framing lost; scanning for the next magic. One typed error is
    /// emitted when the state is entered, not per garbage byte.
    resyncing: bool,
}

impl Default for ConnReader {
    fn default() -> Self {
        Self::new()
    }
}

impl ConnReader {
    pub fn new() -> ConnReader {
        ConnReader {
            plane: Plane::Unknown,
            buf: Vec::new(),
            pending_len: None,
            resyncing: false,
        }
    }

    pub fn plane(&self) -> Plane {
        self.plane
    }

    /// Bytes buffered but not yet decodable (partial line or frame).
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Feed freshly-received bytes; append every decodable unit to
    /// `out`. After an event with `fatal: true` the caller must stop
    /// feeding and close.
    pub fn push(&mut self, bytes: &[u8], out: &mut Vec<ConnEvent>) {
        if bytes.is_empty() {
            return;
        }
        self.buf.extend_from_slice(bytes);
        if self.plane == Plane::Unknown {
            // Skip leading whitespace before sniffing, so `  {"op"..`
            // and a bare keepalive newline both stay on the JSON plane.
            let first = match self.buf.iter().find(|b| !b" \t\r\n".contains(b)) {
                Some(&b) => b,
                None => {
                    // All whitespace so far: harmless JSON-plane filler.
                    self.plane = Plane::Json;
                    b'{'
                }
            };
            self.plane = match first {
                b'{' => Plane::Json,
                b if b == MAGIC[0] => Plane::Binary,
                other => {
                    out.push(ConnEvent::ProtocolError {
                        request_id: 0,
                        error: ApiError::bad_request(format!(
                            "unrecognized protocol (first byte {other:#04x})"
                        )),
                        fatal: true,
                    });
                    self.buf.clear();
                    return;
                }
            };
        }
        match self.plane {
            Plane::Json => self.drain_json(out),
            Plane::Binary => self.drain_binary(out),
            Plane::Unknown => unreachable!(),
        }
    }

    fn drain_json(&mut self, out: &mut Vec<ConnEvent>) {
        while let Some(nl) = self.buf.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = self.buf.drain(..=nl).collect();
            let line = String::from_utf8_lossy(&line).trim().to_string();
            if !line.is_empty() {
                out.push(ConnEvent::JsonLine(line));
            }
        }
    }

    fn drain_binary(&mut self, out: &mut Vec<ConnEvent>) {
        loop {
            // Finish a frame whose header was already accepted.
            if let Some(len) = self.pending_len {
                if self.buf.len() < HEADER_LEN + len {
                    return; // payload still arriving
                }
                let payload: Vec<u8> = self.buf.drain(..HEADER_LEN + len).collect();
                self.pending_len = None;
                match frame::decode_payload(&payload[HEADER_LEN..]) {
                    Ok(f) => out.push(ConnEvent::Frame(f)),
                    Err((request_id, error)) => out.push(ConnEvent::ProtocolError {
                        request_id,
                        error,
                        fatal: false,
                    }),
                }
                continue;
            }
            // A stray JSON line on the binary plane (a confused client
            // mixing planes): consume through its newline and reject
            // typed, keeping the frame stream alive.
            if self.buf.first() == Some(&b'{') {
                match self.buf.iter().position(|&b| b == b'\n') {
                    Some(nl) => {
                        self.buf.drain(..=nl);
                        out.push(ConnEvent::ProtocolError {
                            request_id: 0,
                            error: ApiError::bad_request(
                                "JSON line on the binary plane; use the PXW3 frame format \
                                 or a fresh connection for the JSON plane",
                            ),
                            fatal: false,
                        });
                        continue;
                    }
                    None => return, // wait for the newline
                }
            }
            if self.buf.len() < HEADER_LEN {
                return;
            }
            match frame::parse_header(&self.buf[..HEADER_LEN]) {
                Ok(len) => {
                    self.pending_len = Some(len);
                    self.resyncing = false;
                    // loop: payload may already be buffered
                }
                Err(error) => {
                    if self.buf[..4] == MAGIC {
                        // Good magic, bad length (runt/giant). The
                        // declared length is untrustworthy, so skipping
                        // it would desync: consume just the header,
                        // report typed, and scan for the next frame.
                        out.push(ConnEvent::ProtocolError {
                            request_id: 0,
                            error,
                            fatal: false,
                        });
                        self.buf.drain(..HEADER_LEN);
                        self.resync();
                    } else {
                        // Framing lost mid-stream: report once, then
                        // scan quietly for the next magic.
                        if !self.resyncing {
                            self.resyncing = true;
                            out.push(ConnEvent::ProtocolError {
                                request_id: 0,
                                error,
                                fatal: false,
                            });
                        }
                        if !self.resync() {
                            return; // need more bytes to find a magic
                        }
                    }
                }
            }
        }
    }

    /// Drop garbage up to the next `MAGIC` occurrence (exclusive).
    /// Returns true when a full magic is positioned at the buffer head.
    /// The caller guarantees position 0 is not a valid header, so this
    /// cannot loop without consuming.
    fn resync(&mut self) -> bool {
        match find_magic(&self.buf) {
            Some(i) => {
                self.buf.drain(..i);
                true
            }
            None => {
                // Keep a tail shorter than the magic: it may be the
                // prefix of a magic whose rest is still in flight.
                let keep = self.buf.len().min(MAGIC.len() - 1);
                self.buf.drain(..self.buf.len() - keep);
                false
            }
        }
    }
}

fn find_magic(hay: &[u8]) -> Option<usize> {
    hay.windows(MAGIC.len()).position(|w| w == MAGIC)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{ApiErrorCode, QueryOptions, QueryRequest};
    use crate::net::frame::FrameBody;

    fn query_frame(id: u64) -> Vec<u8> {
        let req = QueryRequest {
            vectors: vec![vec![1.0, 2.0]],
            k: 3,
            options: QueryOptions::default(),
        };
        let mut buf = Vec::new();
        frame::encode_query(&mut buf, id, &req, 0);
        buf
    }

    fn push_all(r: &mut ConnReader, bytes: &[u8]) -> Vec<ConnEvent> {
        let mut out = Vec::new();
        r.push(bytes, &mut out);
        out
    }

    #[test]
    fn sniffs_json_plane_and_splits_lines() {
        let mut r = ConnReader::new();
        assert_eq!(r.plane(), Plane::Unknown);
        let ev = push_all(&mut r, b"  {\"op\":\"stats\"}\n{\"op\":");
        assert_eq!(r.plane(), Plane::Json);
        assert_eq!(ev, vec![ConnEvent::JsonLine("{\"op\":\"stats\"}".into())]);
        let ev = push_all(&mut r, b"\"status\"}\n");
        assert_eq!(ev, vec![ConnEvent::JsonLine("{\"op\":\"status\"}".into())]);
    }

    #[test]
    fn sniffs_binary_plane_and_reassembles_split_frames() {
        let mut r = ConnReader::new();
        let buf = query_frame(11);
        // Byte-at-a-time delivery: exactly one frame event at the end.
        let mut events = Vec::new();
        for b in &buf {
            r.push(std::slice::from_ref(b), &mut events);
        }
        assert_eq!(r.plane(), Plane::Binary);
        assert_eq!(events.len(), 1);
        match &events[0] {
            ConnEvent::Frame(f) => assert_eq!(f.request_id, 11),
            other => panic!("wrong event: {other:?}"),
        }
        assert_eq!(r.buffered(), 0);
    }

    #[test]
    fn pipelined_frames_in_one_read() {
        let mut r = ConnReader::new();
        let mut buf = query_frame(1);
        buf.extend_from_slice(&query_frame(2));
        buf.extend_from_slice(&query_frame(3));
        let ev = push_all(&mut r, &buf);
        let ids: Vec<u64> = ev
            .iter()
            .map(|e| match e {
                ConnEvent::Frame(f) => f.request_id,
                other => panic!("wrong event: {other:?}"),
            })
            .collect();
        assert_eq!(ids, vec![1, 2, 3]);
    }

    #[test]
    fn unknown_first_byte_is_fatal() {
        let mut r = ConnReader::new();
        let ev = push_all(&mut r, b"GET / HTTP/1.1\r\n");
        assert_eq!(ev.len(), 1);
        match &ev[0] {
            ConnEvent::ProtocolError { fatal, error, .. } => {
                assert!(*fatal);
                assert_eq!(error.code, ApiErrorCode::BadRequest);
            }
            other => panic!("wrong event: {other:?}"),
        }
    }

    #[test]
    fn giant_declared_length_rejected_then_resyncs_on_next_magic() {
        let mut r = ConnReader::new();
        let mut buf = Vec::new();
        buf.extend_from_slice(&MAGIC);
        buf.extend_from_slice(&(u32::MAX).to_le_bytes()); // 4 GiB claim
        buf.extend_from_slice(b"garbage-that-is-not-a-frame");
        buf.extend_from_slice(&query_frame(21));
        let ev = push_all(&mut r, &buf);
        assert_eq!(ev.len(), 2, "events: {ev:?}");
        match &ev[0] {
            ConnEvent::ProtocolError { error, fatal, .. } => {
                assert!(!fatal, "giant length must not kill the connection");
                assert!(error.message.contains("exceeds max"));
            }
            other => panic!("wrong event: {other:?}"),
        }
        match &ev[1] {
            ConnEvent::Frame(f) => assert_eq!(f.request_id, 21),
            other => panic!("wrong event: {other:?}"),
        }
        // Never buffered anything near the declared 4 GiB.
        assert!(r.buffered() < 64);
    }

    #[test]
    fn corrupt_magic_midstream_resyncs_without_killing_later_frames() {
        let mut r = ConnReader::new();
        let mut buf = query_frame(1);
        buf.extend_from_slice(b"PXXXnoise"); // starts like magic, is not
        buf.extend_from_slice(&query_frame(2));
        let ev = push_all(&mut r, &buf);
        let frames: Vec<u64> = ev
            .iter()
            .filter_map(|e| match e {
                ConnEvent::Frame(f) => Some(f.request_id),
                _ => None,
            })
            .collect();
        assert_eq!(frames, vec![1, 2]);
        assert!(ev.iter().any(|e| matches!(
            e,
            ConnEvent::ProtocolError { fatal: false, .. }
        )));
    }

    #[test]
    fn truncated_payload_within_declared_length_is_typed_nonfatal() {
        let mut r = ConnReader::new();
        let good = query_frame(31);
        // Keep the header but declare the true length while cutting the
        // body content: corrupt a count field so decode fails inside a
        // fully-delivered payload.
        let mut bad = good.clone();
        let n_off = HEADER_LEN + 8 + 1 + 4 + 4 + 1 + 1 + 4 + 4 + 4;
        bad[n_off..n_off + 4].copy_from_slice(&900u32.to_le_bytes()); // n lies
        let mut buf = bad;
        buf.extend_from_slice(&query_frame(32));
        let ev = push_all(&mut r, &buf);
        assert_eq!(ev.len(), 2);
        match &ev[0] {
            ConnEvent::ProtocolError {
                request_id,
                error,
                fatal,
            } => {
                assert_eq!(*request_id, 31);
                assert_eq!(error.code, ApiErrorCode::BadRequest);
                assert!(!fatal);
            }
            other => panic!("wrong event: {other:?}"),
        }
        match &ev[1] {
            ConnEvent::Frame(f) => assert_eq!(f.request_id, 32),
            other => panic!("wrong event: {other:?}"),
        }
    }

    #[test]
    fn json_line_on_binary_plane_rejected_typed_frames_continue() {
        let mut r = ConnReader::new();
        let mut buf = query_frame(41);
        buf.extend_from_slice(b"{\"v\":2,\"op\":\"status\"}\n");
        buf.extend_from_slice(&query_frame(42));
        let ev = push_all(&mut r, &buf);
        assert_eq!(ev.len(), 3);
        assert!(matches!(&ev[0], ConnEvent::Frame(f) if f.request_id == 41));
        match &ev[1] {
            ConnEvent::ProtocolError { error, fatal, .. } => {
                assert!(!fatal);
                assert!(error.message.contains("JSON line on the binary plane"));
            }
            other => panic!("wrong event: {other:?}"),
        }
        assert!(matches!(&ev[2], ConnEvent::Frame(f) if f.request_id == 42));
    }

    #[test]
    fn admin_frame_decodes_on_binary_plane() {
        let mut r = ConnReader::new();
        let mut buf = Vec::new();
        frame::encode_admin(&mut buf, 51, r#"{"v":2,"op":"status"}"#);
        let ev = push_all(&mut r, &buf);
        match &ev[0] {
            ConnEvent::Frame(Frame {
                request_id: 51,
                body: FrameBody::Admin { line },
            }) => assert_eq!(line, r#"{"v":2,"op":"status"}"#),
            other => panic!("wrong event: {other:?}"),
        }
    }
}
