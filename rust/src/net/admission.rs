//! Admission control for the serving front door: a bounded in-flight
//! budget, per-request queue-wait deadlines, and load shedding.
//!
//! The policy is deliberately boring — admit at decode time while the
//! in-flight budget holds, then re-check at dispatch time whether the
//! request's queue wait has crossed its deadline or the global shed
//! threshold — because the *property* it buys is the interesting part:
//! under an offered load above capacity, an open-loop arrival process
//! drives an unprotected queue's wait to infinity (every accepted
//! request eventually waits arbitrarily long), while with shedding the
//! wait of every ACCEPTED request is bounded by `shed_queue_us` and the
//! overflow converts into typed [`ApiErrorCode::Overloaded`] answers
//! the client can retry against another replica. Shedding at dispatch
//! (not only admission) matters: a request that was admissible when it
//! arrived but has already waited past the threshold is *guaranteed
//! late* — serving it wastes capacity on an answer the client gave up
//! on (the classic goodput-vs-throughput collapse).
//!
//! Time is injected via [`Clock`] so the whole policy is testable as a
//! discrete-event simulation: a fake microsecond counter advances
//! explicitly, queues form deterministically, and the bounded-p99
//! property is asserted without a single wall-clock sleep.

use crate::api::{ApiError, ApiErrorCode};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Microsecond time source: real or simulated.
#[derive(Clone)]
pub enum Clock {
    /// Monotonic wall time since server start.
    Wall(Instant),
    /// Shared counter advanced explicitly by a test harness.
    Fake(Arc<AtomicU64>),
}

impl Clock {
    pub fn wall() -> Clock {
        Clock::Wall(Instant::now())
    }

    /// A simulated clock plus the handle that advances it.
    pub fn fake() -> (Clock, Arc<AtomicU64>) {
        let t = Arc::new(AtomicU64::new(0));
        (Clock::Fake(t.clone()), t)
    }

    pub fn now_us(&self) -> u64 {
        match self {
            Clock::Wall(start) => start.elapsed().as_micros() as u64,
            Clock::Fake(t) => t.load(Ordering::Acquire),
        }
    }
}

/// Tunables for the admission layer.
#[derive(Clone, Debug)]
pub struct AdmissionConfig {
    /// Maximum requests admitted but not yet finished, across all
    /// connections. Admission beyond this sheds immediately.
    pub max_in_flight: usize,
    /// Queue wait (µs) beyond which a request is shed at dispatch even
    /// if it carried no explicit deadline. 0 disables the threshold.
    pub shed_queue_us: u64,
    /// Deadline (µs of queue wait) applied to requests that carry none.
    /// 0 means "no default deadline".
    pub default_deadline_us: u64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            max_in_flight: 1024,
            shed_queue_us: 50_000,
            default_deadline_us: 0,
        }
    }
}

/// Ticket for one admitted request; its timestamp is the arrival used
/// for queue-wait accounting. Callers MUST pair every successful
/// [`Admission::try_admit`] with exactly one [`Admission::finish`].
#[derive(Clone, Copy, Debug)]
pub struct AdmitTicket {
    pub enqueued_us: u64,
}

/// Shared admission state (one per listener).
pub struct Admission {
    cfg: AdmissionConfig,
    clock: Clock,
    in_flight: AtomicUsize,
    admitted: AtomicU64,
    shed_admit: AtomicU64,
    shed_dispatch: AtomicU64,
}

/// Counter snapshot for `status` reporting and tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AdmissionCounters {
    pub in_flight: usize,
    pub admitted: u64,
    pub shed_admit: u64,
    pub shed_dispatch: u64,
}

impl Admission {
    pub fn new(cfg: AdmissionConfig, clock: Clock) -> Admission {
        Admission {
            cfg,
            clock,
            in_flight: AtomicUsize::new(0),
            admitted: AtomicU64::new(0),
            shed_admit: AtomicU64::new(0),
            shed_dispatch: AtomicU64::new(0),
        }
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    /// Decode-time gate: claim an in-flight slot or shed typed.
    pub fn try_admit(&self) -> Result<AdmitTicket, ApiError> {
        let mut cur = self.in_flight.load(Ordering::Relaxed);
        loop {
            if cur >= self.cfg.max_in_flight {
                self.shed_admit.fetch_add(1, Ordering::Relaxed);
                return Err(ApiError::overloaded(format!(
                    "in-flight budget exhausted ({} of {})",
                    cur, self.cfg.max_in_flight
                )));
            }
            match self.in_flight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        self.admitted.fetch_add(1, Ordering::Relaxed);
        Ok(AdmitTicket {
            enqueued_us: self.clock.now_us(),
        })
    }

    /// Dispatch-time gate: shed the request if its queue wait crossed
    /// its deadline (`deadline_us`, or the configured default when 0)
    /// or the global shed threshold. Returns the measured queue wait on
    /// success so it can be surfaced as `SearchStats::queue_wait_us`.
    pub fn check_dispatch(&self, ticket: &AdmitTicket, deadline_us: u32) -> Result<u64, ApiError> {
        let wait = self.clock.now_us().saturating_sub(ticket.enqueued_us);
        let deadline = if deadline_us > 0 {
            deadline_us as u64
        } else {
            self.cfg.default_deadline_us
        };
        if deadline > 0 && wait > deadline {
            self.shed_dispatch.fetch_add(1, Ordering::Relaxed);
            return Err(ApiError::overloaded(format!(
                "deadline exceeded: queued {wait}us > deadline {deadline}us"
            )));
        }
        if self.cfg.shed_queue_us > 0 && wait > self.cfg.shed_queue_us {
            self.shed_dispatch.fetch_add(1, Ordering::Relaxed);
            return Err(ApiError::overloaded(format!(
                "shed: queue_wait_us {wait} > threshold {}",
                self.cfg.shed_queue_us
            )));
        }
        Ok(wait)
    }

    /// Release the in-flight slot (on response write, shed, or
    /// connection teardown).
    pub fn finish(&self) {
        let prev = self.in_flight.fetch_sub(1, Ordering::AcqRel);
        debug_assert!(prev > 0, "finish without a matching admit");
    }

    pub fn counters(&self) -> AdmissionCounters {
        AdmissionCounters {
            in_flight: self.in_flight.load(Ordering::Relaxed),
            admitted: self.admitted.load(Ordering::Relaxed),
            shed_admit: self.shed_admit.load(Ordering::Relaxed),
            shed_dispatch: self.shed_dispatch.load(Ordering::Relaxed),
        }
    }

    /// True when a shed produced this error (clients: back off, retry).
    pub fn is_shed(e: &ApiError) -> bool {
        e.code == ApiErrorCode::Overloaded
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;
    use std::collections::VecDeque;
    use std::sync::atomic::Ordering;

    #[test]
    fn budget_sheds_typed_and_recovers() {
        let (clock, _t) = Clock::fake();
        let a = Admission::new(
            AdmissionConfig {
                max_in_flight: 2,
                ..Default::default()
            },
            clock,
        );
        let _t1 = a.try_admit().unwrap();
        let _t2 = a.try_admit().unwrap();
        let e = a.try_admit().unwrap_err();
        assert_eq!(e.code, ApiErrorCode::Overloaded);
        assert!(Admission::is_shed(&e));
        a.finish();
        assert!(a.try_admit().is_ok());
        let c = a.counters();
        assert_eq!(c.admitted, 3);
        assert_eq!(c.shed_admit, 1);
        assert_eq!(c.in_flight, 2);
    }

    #[test]
    fn dispatch_sheds_on_threshold_and_deadline() {
        let (clock, t) = Clock::fake();
        let a = Admission::new(
            AdmissionConfig {
                max_in_flight: 16,
                shed_queue_us: 1000,
                default_deadline_us: 0,
            },
            clock,
        );
        let ticket = a.try_admit().unwrap();
        t.store(900, Ordering::Release);
        assert_eq!(a.check_dispatch(&ticket, 0).unwrap(), 900);
        t.store(1001, Ordering::Release);
        let e = a.check_dispatch(&ticket, 0).unwrap_err();
        assert!(e.message.contains("queue_wait_us"));
        // A tighter per-request deadline fires before the threshold.
        let ticket2 = AdmitTicket {
            enqueued_us: t.load(Ordering::Acquire),
        };
        t.store(1501, Ordering::Release);
        let e = a.check_dispatch(&ticket2, 200).unwrap_err();
        assert!(e.message.contains("deadline exceeded"));
        assert_eq!(a.counters().shed_dispatch, 2);
    }

    /// Single-server FIFO queue state for the DES harness.
    struct Sim {
        queue: VecDeque<AdmitTicket>,
        server_free_at: u64,
        service_us: u64,
        waits: Vec<u64>,
        shed: u64,
    }

    impl Sim {
        /// Serve whatever completes by `now`, shedding stale work.
        fn drain(&mut self, now: u64, a: &Admission, t: &AtomicU64) {
            while let Some(ticket) = self.queue.front().copied() {
                let start = self.server_free_at.max(ticket.enqueued_us);
                if start > now {
                    break;
                }
                self.queue.pop_front();
                t.store(start, Ordering::Release);
                match a.check_dispatch(&ticket, 0) {
                    Ok(wait) => {
                        self.waits.push(wait);
                        self.server_free_at = start + self.service_us;
                    }
                    Err(_) => self.shed += 1, // shed consumes no service time
                }
                a.finish();
            }
        }
    }

    /// DES single-server queue: Poisson arrivals, fixed service time.
    /// Returns (accepted waits µs, shed count). No wall-clock sleeps.
    fn simulate(
        offered_qps: f64,
        service_us: u64,
        n: usize,
        a: &Admission,
        t: &AtomicU64,
    ) -> (Vec<u64>, u64) {
        let mut rng = Xoshiro256pp::seed_from_u64(42);
        let mut arrivals = Vec::with_capacity(n);
        let mut at = 0.0f64;
        for _ in 0..n {
            let gap = -rng.next_f64().max(1e-12).ln() / offered_qps;
            at += gap;
            arrivals.push((at * 1e6) as u64);
        }
        let mut sim = Sim {
            queue: VecDeque::new(),
            server_free_at: 0,
            service_us,
            waits: Vec::new(),
            shed: 0,
        };
        for arrive in arrivals {
            sim.drain(arrive, a, t);
            t.store(arrive, Ordering::Release);
            match a.try_admit() {
                Ok(ticket) => sim.queue.push_back(ticket),
                Err(_) => sim.shed += 1,
            }
        }
        sim.drain(u64::MAX, a, t);
        (sim.waits, sim.shed)
    }

    #[test]
    fn underload_sheds_nothing() {
        let (clock, t) = Clock::fake();
        let a = Admission::new(
            AdmissionConfig {
                max_in_flight: 64,
                shed_queue_us: 50_000,
                default_deadline_us: 0,
            },
            clock,
        );
        // Capacity 1000 qps (1ms service), offered 300 qps.
        let (waits, shed) = simulate(300.0, 1000, 2000, &a, &t);
        assert_eq!(shed, 0, "underload must not shed");
        assert_eq!(waits.len(), 2000);
        assert_eq!(a.counters().in_flight, 0);
    }

    #[test]
    fn overload_sheds_typed_while_accepted_p99_stays_bounded() {
        let (clock, t) = Clock::fake();
        let shed_queue_us = 20_000;
        let a = Admission::new(
            AdmissionConfig {
                max_in_flight: 10_000, // budget wide open: isolate the wait policy
                shed_queue_us,
                default_deadline_us: 0,
            },
            clock,
        );
        // Capacity 1000 qps, offered 3000 qps: 3x overload. Without
        // shedding, mean wait grows linearly with time and the tail is
        // unbounded; with it, every ACCEPTED request waited at most the
        // threshold.
        let (mut waits, shed) = simulate(3000.0, 1000, 6000, &a, &t);
        assert!(shed > 2000, "3x overload must shed heavily, shed {shed}");
        assert!(!waits.is_empty(), "some requests must still be served");
        waits.sort_unstable();
        let p99 = waits[(waits.len() - 1) * 99 / 100];
        assert!(
            p99 <= shed_queue_us,
            "accepted p99 {p99}us exceeds the shed threshold {shed_queue_us}us"
        );
        // The unprotected comparison: same arrivals, shedding disabled.
        let (clock2, t2) = Clock::fake();
        let free = Admission::new(
            AdmissionConfig {
                max_in_flight: usize::MAX,
                shed_queue_us: 0,
                default_deadline_us: 0,
            },
            clock2,
        );
        let (mut waits2, shed2) = simulate(3000.0, 1000, 6000, &free, &t2);
        assert_eq!(shed2, 0);
        waits2.sort_unstable();
        let p99_free = waits2[(waits2.len() - 1) * 99 / 100];
        assert!(
            p99_free > 10 * shed_queue_us,
            "unprotected overload tail should collapse (got {p99_free}us)"
        );
    }
}
