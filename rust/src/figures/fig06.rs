//! Fig 6: (a) search-convergence trend — fraction of queries whose true
//! top-k is already found at working-list size T; (b) memory-traffic
//! breakdown vs graph degree R.

use super::Workbench;
use crate::config::GraphParams;
use crate::dataset::recall_at_k;
use crate::graph::vamana;
use crate::search::beam::pq_beam_search;
use crate::util::bench::Table;

/// Convergence ratio at each T (fraction of queries with recall == 1).
pub fn convergence(w: &Workbench, k: usize, t_sweep: &[usize]) -> Vec<(usize, f64)> {
    let ctx = w.context();
    t_sweep
        .iter()
        .map(|&t| {
            let mut converged = 0usize;
            for q in 0..w.ds.n_queries() {
                let adt = w.codebook.build_adt(w.ds.queries.row(q));
                let out = pq_beam_search(&ctx, &adt, w.ds.queries.row(q), k, t, t, false);
                if recall_at_k(&out.ids, w.gt.row(q), k) >= 1.0 {
                    converged += 1;
                }
            }
            (t, converged as f64 / w.ds.n_queries() as f64)
        })
        .collect()
}

/// Traffic split (index vs PQ vs raw bytes per query) as R varies.
pub fn traffic_vs_degree(name: &str, scale: f64, r_sweep: &[usize]) -> Vec<(usize, f64, f64, f64)> {
    let mut rows = Vec::new();
    for &r in r_sweep {
        let spec = crate::dataset::synth::SynthSpec::by_name(name, scale).unwrap();
        let ds = spec.generate();
        let gp = GraphParams {
            r,
            ..Default::default()
        };
        let graph = vamana::build(&ds.base, ds.metric, &gp);
        let pqp = crate::config::PqParams::for_dim(ds.dim());
        let cb = crate::pq::PqCodebook::train(
            &ds.base, ds.metric, pqp.m, pqp.c, pqp.train_sample, 8, 1,
        );
        let codes = cb.encode(&ds.base);
        let ctx = crate::search::beam::SearchContext {
            base: &ds.base,
            metric: ds.metric,
            graph: &graph,
            codes: Some(&codes),
            gap: None,
            storage: None,
            online: None,
            lsh: None,
        };
        // Traversal traffic (the quantity Fig 6b varies with R): a
        // PQ-guided beam search with a fixed top-2k rerank, so the rerank
        // tail does not swamp the degree effect on small test corpora.
        let mut idx = 0u64;
        let mut pqb = 0u64;
        let mut raw = 0u64;
        for q in 0..ds.n_queries().min(100) {
            let adt = cb.build_adt(ds.queries.row(q));
            let out = crate::search::beam::pq_beam_search(
                &ctx,
                &adt,
                ds.queries.row(q),
                10,
                100,
                20,
                false,
            );
            idx += out.stats.bytes_index;
            pqb += out.stats.bytes_pq;
            raw += out.stats.bytes_raw;
        }
        let total = (idx + pqb + raw) as f64;
        rows.push((
            r,
            idx as f64 / total,
            pqb as f64 / total,
            raw as f64 / total,
        ));
    }
    rows
}

pub fn run(datasets: &[&str], scale: f64) -> Vec<Table> {
    let mut t_conv = Table::new(
        "Fig 6a: convergence ratio vs working list size T",
        &["dataset", "T", "converged"],
    );
    for name in datasets {
        let w = Workbench::get(name, scale, 10);
        for (t, c) in convergence(&w, 10, &[10, 20, 40, 80, 150]) {
            t_conv.row(vec![
                w.ds.name.clone(),
                t.to_string(),
                format!("{c:.3}"),
            ]);
        }
    }
    let mut t_traffic = Table::new(
        "Fig 6b: memory traffic share vs degree R (Proxima, no gap enc.)",
        &["R", "index", "pq", "raw"],
    );
    for (r, i, p, w) in traffic_vs_degree(datasets[0], scale, &[16, 32, 64]) {
        t_traffic.row(vec![
            r.to_string(),
            format!("{i:.2}"),
            format!("{p:.2}"),
            format!("{w:.2}"),
        ]);
    }
    vec![t_conv, t_traffic]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn convergence_monotone_nondecreasing() {
        let w = Workbench::get("sift-s", 0.012, 10);
        let c = convergence(&w, 10, &[10, 40, 150]);
        assert!(c[1].1 >= c[0].1 - 0.05, "{c:?}");
        assert!(c[2].1 >= c[1].1 - 0.05, "{c:?}");
        // Rapid rise at small T (paper Fig 6a): most queries converge
        // well before T = L.
        assert!(c[2].1 > 0.5, "{c:?}");
    }

    #[test]
    fn index_traffic_dominates_at_high_degree() {
        // Paper Fig 6b: fetching "NN indices" accounts for 80-90% of
        // traffic. In the §IV-E layout the neighbor PQ codes are stored
        // coupled with the index rows ("PQ codes and graph indices are
        // stored together"), so the index-side share is idx+pq vs raw.
        let rows = traffic_vs_degree("sift-s", 0.012, &[16, 64]);
        let (_, idx16, pq16, _) = rows[0];
        let (_, idx64, pq64, _) = rows[1];
        assert!(
            idx64 + pq64 > idx16 + pq16 - 0.05,
            "share should grow with R: {rows:?}"
        );
        assert!(
            idx64 + pq64 > 0.6,
            "index-side share at R=64: {}",
            idx64 + pq64
        );
        // And the raw-index split itself grows with R.
        assert!(idx64 > idx16, "{rows:?}");
    }
}
