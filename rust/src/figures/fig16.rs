//! Fig 16: queue-size sensitivity on 100M-class datasets (no hot nodes):
//! N_q 32→256 should buy ~3.8× QPS, raise core utilization from ~18% to
//! ~68%, and cost ~20% energy efficiency.

use super::{collect_traces, default_mapping, Algo, Workbench};
use crate::engine::{sim, EngineConfig, EngineResult};
use crate::util::bench::Table;

pub fn sweep(w: &Workbench, l: usize, queue_sizes: &[usize]) -> Vec<(usize, EngineResult)> {
    let (traces, _) = collect_traces(w, Algo::Proxima, l, 10);
    let mapping = default_mapping(w, 0.0);
    queue_sizes
        .iter()
        .map(|&nq| {
            let mut cfg = EngineConfig::paper(w.ds.dim(), w.codebook.m);
            cfg.n_queues = nq;
            (nq, sim::simulate(&cfg, &mapping, &traces))
        })
        .collect()
}

pub fn run(datasets: &[&str], scale: f64) -> Table {
    let mut table = Table::new(
        "Fig 16: queue-size sweep (normalized to N_q=32)",
        &[
            "dataset",
            "N_q",
            "QPS",
            "norm QPS",
            "QPS/W",
            "norm QPS/W",
            "core util",
        ],
    );
    for name in datasets {
        let w = Workbench::get(name, scale, 10);
        let rows = sweep(&w, 100, &[32, 64, 128, 256]);
        let (q0, e0) = (rows[0].1.qps, rows[0].1.qps_per_watt);
        for (nq, r) in &rows {
            table.row(vec![
                w.ds.name.clone(),
                nq.to_string(),
                Table::fmt(r.qps),
                format!("{:.2}", r.qps / q0),
                Table::fmt(r.qps_per_watt),
                format!("{:.2}", r.qps_per_watt / e0),
                format!("{:.1}%", r.core_utilization * 100.0),
            ]);
        }
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_scaling_shape() {
        // Quick-scale traces are light (tens of µs/query), so 32 queues
        // already push against the shared ADT module — exactly the
        // saturation the paper reports *at* 256 queues on ms-scale 100M
        // workloads. The scaling law is therefore asserted on the
        // latency-bound region (4 -> 32 queues); the bench records the
        // paper's 32 -> 256 sweep at full scale.
        let w = Workbench::get("deep-10m-s", 0.01, 10);
        let rows = sweep(&w, 250, &[4, 32, 256]);
        let q_lo = &rows[0].1;
        let q_mid = &rows[1].1;
        let q_hi = &rows[2].1;
        // Clear throughput scaling in the latency-bound region (paper:
        // 3.8x over its 8x queue range).
        assert!(
            q_mid.qps > 2.0 * q_lo.qps,
            "qps {} -> {}",
            q_lo.qps,
            q_mid.qps
        );
        // Utilization rises.
        assert!(q_mid.core_utilization > q_lo.core_utilization);
        // In the saturated region more queues burn static power without
        // buying throughput: efficiency stops improving (paper: ~20% drop
        // at full scale; at quick scale we assert it is flat-to-down).
        assert!(
            q_hi.qps_per_watt < q_mid.qps_per_watt * 1.05,
            "eff {} -> {}",
            q_mid.qps_per_watt,
            q_hi.qps_per_watt
        );
    }
}
