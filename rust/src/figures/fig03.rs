//! Fig 3: profiling the software baselines — (a) roofline placement,
//! (b) LLC miss rate and distance-computation runtime share.

use super::Workbench;
use crate::accel::cachesim::CacheSim;
use crate::accel::roofline::{self, Roofline};
use crate::search::beam::accurate_beam_search;
use crate::search::{SearchStats, TraceOp};
use crate::util::bench::Table;

/// Per-algorithm profile.
pub struct Profile {
    pub algo: &'static str,
    pub intensity: f64,
    pub attainable_gflops: f64,
    pub memory_bound: bool,
    pub llc_miss_rate: f64,
    pub dist_share: f64,
}

/// Profile the HNSW-style accurate search (the paper profiles HNSW, NSG,
/// DiskANN — all share the traversal pattern; we report HNSW-flat and the
/// PQ variant).
pub fn profile(w: &Workbench, l: usize) -> Vec<Profile> {
    let ctx = w.context_no_gap();
    let roof = Roofline::epyc_7543();
    let mut out = Vec::new();

    // Accurate-distance traversal (HNSW-like).
    let mut stats = SearchStats::default();
    let mut cache = CacheSim::epyc_llc();
    let dim_bytes = (w.ds.dim() * 4) as u64;
    // Address map: raw vectors then adjacency, contiguous by vertex.
    let adj_base = w.ds.n_base() as u64 * dim_bytes;
    for qi in 0..w.ds.n_queries() {
        let outp = accurate_beam_search(&ctx, w.ds.queries.row(qi), 10, l, true);
        stats.add(&outp.stats);
        for op in &outp.trace.as_ref().unwrap().ops {
            match *op {
                TraceOp::FetchRaw { node, .. } => {
                    cache.access(node as u64 * dim_bytes, dim_bytes);
                }
                TraceOp::FetchIndex { node, bits } => {
                    cache.access(adj_base + node as u64 * 256, (bits as u64) / 8);
                }
                _ => {}
            }
        }
    }
    let n = w.ds.n_queries();
    let per_q = SearchStats {
        pq_dists: stats.pq_dists / n,
        exact_dists: stats.exact_dists / n,
        bytes_index: stats.bytes_index / n as u64,
        bytes_pq: stats.bytes_pq / n as u64,
        bytes_raw: stats.bytes_raw / n as u64,
        ..Default::default()
    };
    let intensity = roofline::intensity(&per_q, w.ds.dim(), w.codebook.m, true);
    // Runtime share of distance computation: compute time vs memory time
    // under the CPU model (Fig 3b reports >50%).
    let flops = per_q.exact_dists as f64 * roofline::dist_flops(w.ds.dim(), true);
    let mem_ns = (per_q.total_bytes() as f64 / 64.0) * cache.miss_rate() * 85.0 / 2.0;
    let compute_ns = flops / 35.0;
    out.push(Profile {
        algo: "HNSW",
        intensity,
        attainable_gflops: roof.attainable(intensity),
        memory_bound: roof.is_memory_bound(intensity),
        llc_miss_rate: cache.miss_rate(),
        dist_share: compute_ns / (compute_ns + mem_ns),
    });
    out
}

pub fn run(datasets: &[&str], scale: f64) -> Table {
    let mut table = Table::new(
        "Fig 3: graph-ANNS profiling (roofline + LLC model)",
        &[
            "dataset",
            "algo",
            "intensity(F/B)",
            "attainable GF/s",
            "bound",
            "LLC miss",
            "dist-compute share",
        ],
    );
    for name in datasets {
        let w = Workbench::get(name, scale, 10);
        for p in profile(&w, 100) {
            table.row(vec![
                w.ds.name.clone(),
                p.algo.to_string(),
                format!("{:.3}", p.intensity),
                Table::fmt(p.attainable_gflops),
                if p.memory_bound { "memory" } else { "compute" }.into(),
                format!("{:.2}", p.llc_miss_rate),
                format!("{:.2}", p.dist_share),
            ]);
        }
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_land_in_paper_regime() {
        let w = Workbench::get("sift-s", 0.012, 10);
        let ps = profile(&w, 80);
        let p = &ps[0];
        // Fig 3a: memory bound, intensity << ridge (~14).
        assert!(p.memory_bound, "intensity {}", p.intensity);
        assert!(p.intensity < 5.0);
        // Fig 3b: distance computation is a major share (>30% even in the
        // model; paper reports >50% on real HW).
        assert!(p.dist_share > 0.2, "share {}", p.dist_share);
        assert!(p.llc_miss_rate > 0.0);
    }
}
