//! Fig 14: memory-traffic breakdown — HNSW vs DiskANN-PQ vs Proxima with
//! gap encoding + early termination. Expected: Proxima reduces total
//! traffic 1.9–2.4× vs HNSW; DiskANN-PQ saves 12–40% by skipping raw data.

use super::{collect_traces, Algo, Workbench};
use crate::util::bench::Table;

pub struct TrafficRow {
    pub algo: &'static str,
    pub index_kb: f64,
    pub pq_kb: f64,
    pub raw_kb: f64,
}

impl TrafficRow {
    pub fn total_kb(&self) -> f64 {
        self.index_kb + self.pq_kb + self.raw_kb
    }
}

pub fn compare(w: &Workbench, l: usize) -> Vec<TrafficRow> {
    let k = 10;
    let n = w.ds.n_queries() as f64;
    let mut rows = Vec::new();
    for (name, algo) in [
        ("HNSW", Algo::Hnsw),
        ("DiskANN-PQ", Algo::DiskannPq),
        ("Proxima(G,E)", Algo::Proxima),
    ] {
        let (_traces, s) = collect_traces(w, algo, l, k);
        rows.push(TrafficRow {
            algo: name,
            index_kb: s.bytes_index as f64 / n / 1024.0,
            pq_kb: s.bytes_pq as f64 / n / 1024.0,
            raw_kb: s.bytes_raw as f64 / n / 1024.0,
        });
    }
    rows
}

pub fn run(datasets: &[&str], scale: f64) -> Table {
    let mut table = Table::new(
        "Fig 14: per-query memory traffic breakdown (KB)",
        &["dataset", "algo", "index", "pq", "raw", "total", "vs HNSW"],
    );
    for name in datasets {
        let w = Workbench::get(name, scale, 10);
        let rows = compare(&w, 100);
        let hnsw_total = rows[0].total_kb();
        for r in &rows {
            table.row(vec![
                w.ds.name.clone(),
                r.algo.to_string(),
                format!("{:.1}", r.index_kb),
                format!("{:.1}", r.pq_kb),
                format!("{:.1}", r.raw_kb),
                format!("{:.1}", r.total_kb()),
                format!("{:.2}x", hnsw_total / r.total_kb()),
            ]);
        }
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traffic_reduction_band() {
        let w = Workbench::get("sift-s", 0.012, 10);
        let rows = compare(&w, 80);
        let hnsw = rows.iter().find(|r| r.algo == "HNSW").unwrap();
        let dpq = rows.iter().find(|r| r.algo == "DiskANN-PQ").unwrap();
        let prox = rows.iter().find(|r| r.algo == "Proxima(G,E)").unwrap();
        // HNSW carries raw-vector traffic everywhere.
        assert!(hnsw.raw_kb > dpq.raw_kb * 2.0);
        // Proxima total well below HNSW (paper: 1.9-2.4x).
        let ratio = hnsw.total_kb() / prox.total_kb();
        assert!(ratio > 1.5, "reduction ratio {ratio}");
        // Gap encoding: Proxima index bytes below DiskANN-PQ's.
        assert!(prox.index_kb < dpq.index_kb);
    }
}
