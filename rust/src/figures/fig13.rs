//! Fig 13: different graph-ANNS algorithms running **on the Proxima
//! accelerator** — HNSW, DiskANN-PQ, Proxima(G,E) and Proxima(G,E,H) —
//! showing the contribution of each software optimization on the same
//! hardware (plus ~2× QPS / ~3× latency from hot-node repetition).

use super::{collect_traces, default_mapping, Algo, Workbench};
use crate::config::SearchParams;
use crate::engine::{sim, EngineConfig};
use crate::reorder::{ReorderedIndex, VisitProfile};
use crate::search::proxima::{proxima_search, ProximaFeatures};
use crate::util::bench::Table;

pub struct AlgoRow {
    pub algo: &'static str,
    pub qps: f64,
    pub qps_per_watt: f64,
    pub latency_us: f64,
}

/// Collect Proxima traces on a frequency-reordered index with `hot_frac`
/// hot nodes (node ids in the traces are in the reordered space, which is
/// what the mapping's `is_hot` checks).
pub fn proxima_hot_traces(
    w: &Workbench,
    l: usize,
    k: usize,
    hot_frac: f64,
) -> Vec<crate::search::Trace> {
    let params = SearchParams {
        l,
        k,
        ..Default::default()
    };
    let profile = VisitProfile::measure(
        &w.ds.base,
        &w.graph,
        &w.codebook,
        &w.codes,
        &params,
        (w.ds.n_base() / 20).clamp(16, 200),
        0xF15,
    );
    let re = ReorderedIndex::build(&w.graph, &w.codes, &profile, hot_frac);
    // Permuted base for searching in the new id space.
    let mut base2 = crate::dataset::VectorSet::zeros(w.ds.n_base(), w.ds.dim());
    for old in 0..w.ds.n_base() {
        base2
            .row_mut(re.perm[old] as usize)
            .copy_from_slice(w.ds.base.row(old));
    }
    let gap = crate::gap::GapGraph::encode(&re.graph.to_lists());
    let ctx = crate::search::beam::SearchContext {
        base: &base2,
        metric: w.ds.metric,
        graph: &re.graph,
        codes: Some(&re.codes),
        gap: Some(&gap),
        storage: None,
        online: None,
        lsh: None,
    };
    let mut traces = Vec::with_capacity(w.ds.n_queries());
    for qi in 0..w.ds.n_queries() {
        let q = w.ds.queries.row(qi);
        let adt = w.codebook.build_adt(q);
        let out = proxima_search(&ctx, &adt, q, &params, ProximaFeatures::default(), true);
        traces.push(out.trace.unwrap());
    }
    traces
}

/// Run the four algorithm variants through the DES.
pub fn compare(w: &Workbench, l: usize) -> Vec<AlgoRow> {
    let k = 10;
    let cfg = EngineConfig::paper(w.ds.dim(), w.codebook.m);
    let mapping_cold = default_mapping(w, 0.0);
    let mut rows = Vec::new();
    for (name, algo) in [
        ("HNSW", Algo::Hnsw),
        ("DiskANN-PQ", Algo::DiskannPq),
        ("Proxima(G,E)", Algo::Proxima),
    ] {
        let (traces, _) = collect_traces(w, algo, l, k);
        let r = sim::simulate(&cfg, &mapping_cold, &traces);
        rows.push(AlgoRow {
            algo: name,
            qps: r.qps,
            qps_per_watt: r.qps_per_watt,
            latency_us: r.mean_latency_ns / 1000.0,
        });
    }
    // Proxima with hot nodes on the reordered mapping.
    let traces = proxima_hot_traces(w, l, k, 0.03);
    let mapping_hot = default_mapping(w, 0.03);
    let r = sim::simulate(&cfg, &mapping_hot, &traces);
    rows.push(AlgoRow {
        algo: "Proxima(G,E,H)",
        qps: r.qps,
        qps_per_watt: r.qps_per_watt,
        latency_us: r.mean_latency_ns / 1000.0,
    });
    rows
}

pub fn run(datasets: &[&str], scale: f64) -> Table {
    let mut table = Table::new(
        "Fig 13: graph algorithms on the Proxima NSP accelerator",
        &["dataset", "algo", "QPS", "QPS/W", "latency (us)"],
    );
    for name in datasets {
        let w = Workbench::get(name, scale, 10);
        for row in compare(&w, 100) {
            table.row(vec![
                w.ds.name.clone(),
                row.algo.to_string(),
                Table::fmt(row.qps),
                Table::fmt(row.qps_per_watt),
                Table::fmt(row.latency_us),
            ]);
        }
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig13_ordering_holds() {
        let w = Workbench::get("sift-s", 0.012, 10);
        let rows = compare(&w, 100);
        let get = |a: &str| rows.iter().find(|r| r.algo == a).unwrap();
        // HNSW (accurate distances -> multi-granule raw fetches + D-cycle
        // MACs everywhere) has the worst per-query service latency.
        let hnsw = get("HNSW");
        let prox = get("Proxima(G,E)");
        assert!(
            prox.latency_us < hnsw.latency_us,
            "proxima {} vs hnsw {} us",
            prox.latency_us,
            hnsw.latency_us
        );
        // Hot nodes speed Proxima up further (paper: ~2x QPS, ~3x latency;
        // the QPS gap over HNSW needs paper-scale workloads where the ADT
        // module is amortized — recorded by the full-scale bench).
        let hot = get("Proxima(G,E,H)");
        assert!(
            hot.latency_us < prox.latency_us,
            "hot {} vs cold {} us",
            hot.latency_us,
            prox.latency_us
        );
    }
}
