//! Figure/table regeneration harnesses — one submodule per paper exhibit
//! (DESIGN.md §4 maps each to its bench target). All harnesses run at a
//! configurable `scale` (fraction of the default synthetic dataset sizes)
//! so `cargo bench` finishes on a laptop while `PROXIMA_SCALE=full` runs
//! the record sizes.

pub mod ablations;
pub mod fig03;
pub mod fig06;
pub mod fig09;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig14;
pub mod fig15;
pub mod fig16;
pub mod fig17;
pub mod tables;

use crate::config::{GraphParams, PqParams, SearchParams};
use crate::dataset::synth::SynthSpec;
use crate::dataset::{ground_truth, Dataset, GroundTruth};
use crate::gap::GapGraph;
use crate::graph::{vamana, Graph};
use crate::pq::{PqCodebook, PqCodes};
use crate::search::beam::SearchContext;

/// Default scale for quick (CI/bench) runs; `full` uses 1.0.
pub fn default_scale() -> f64 {
    if crate::util::bench::full_scale() {
        0.5
    } else {
        std::env::var("PROXIMA_FIG_SCALE")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0.04)
    }
}

/// A fully built index stack over one synthetic dataset — the common
/// fixture every figure shares. Built artifacts are cached under
/// `results/cache/` because Vamana builds dominate harness time.
pub struct Workbench {
    pub ds: Dataset,
    pub graph: Graph,
    pub codebook: PqCodebook,
    pub codes: PqCodes,
    pub gap: GapGraph,
    pub gt: GroundTruth,
    pub graph_params: GraphParams,
}

impl Workbench {
    /// Build (or load from cache) the stack for a registry dataset.
    pub fn get(name: &str, scale: f64, k: usize) -> Workbench {
        let spec = SynthSpec::by_name(name, scale)
            .unwrap_or_else(|| panic!("unknown dataset {name}"));
        let gp = GraphParams::default();
        let cache = std::path::PathBuf::from("results/cache");
        let tag = format!("{name}-s{scale}-r{}-k{k}", gp.r);
        let graph_path = cache.join(format!("{tag}.graph"));
        let gt_path = cache.join(format!("{tag}.gt"));

        let ds = spec.generate();
        let graph = match crate::dataset::io::load_csr(&graph_path) {
            Ok((offsets, targets)) if offsets.len() == ds.n_base() + 1 => Graph {
                offsets,
                targets,
                entry_point: vamana::medoid(&ds.base, ds.metric),
                max_degree: gp.r,
            },
            _ => {
                let g = vamana::build(&ds.base, ds.metric, &gp);
                let _ = crate::dataset::io::save_csr(&g.offsets, &g.targets, &graph_path);
                g
            }
        };
        let pq = PqParams::for_dim(ds.dim());
        let codebook = PqCodebook::train(
            &ds.base,
            ds.metric,
            pq.m,
            pq.c,
            pq.train_sample,
            pq.kmeans_iters,
            gp.seed ^ 0xC0DE,
        );
        let codes = codebook.encode(&ds.base);
        let gap = GapGraph::encode(&graph.to_lists());
        let gt = match crate::dataset::io::load_ground_truth(&gt_path) {
            Ok(g) if g.k == k && g.n_queries() == ds.n_queries() => g,
            _ => {
                let g = ground_truth::brute_force(&ds, k);
                let _ = crate::dataset::io::save_ground_truth(&g, &gt_path);
                g
            }
        };
        Workbench {
            ds,
            graph,
            codebook,
            codes,
            gap,
            gt,
            graph_params: gp,
        }
    }

    pub fn context(&self) -> SearchContext<'_> {
        SearchContext {
            base: &self.ds.base,
            metric: self.ds.metric,
            graph: &self.graph,
            codes: Some(&self.codes),
            gap: Some(&self.gap),
            storage: None,
            online: None,
            lsh: None,
        }
    }

    /// Context without gap encoding (uniform 32-b indices) for ablations.
    pub fn context_no_gap(&self) -> SearchContext<'_> {
        SearchContext {
            base: &self.ds.base,
            metric: self.ds.metric,
            graph: &self.graph,
            codes: Some(&self.codes),
            gap: None,
            storage: None,
            online: None,
            lsh: None,
        }
    }

    pub fn default_params(&self, l: usize, k: usize) -> SearchParams {
        SearchParams {
            l,
            k,
            ..Default::default()
        }
    }
}

/// Which algorithm to trace for the hardware simulator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// Accurate-distance best-first (HNSW-like) on the flat graph.
    Hnsw,
    /// DiskANN-PQ: PQ traversal + plain rerank.
    DiskannPq,
    /// Proxima with gap encoding + early termination (no hot nodes).
    Proxima,
    /// Proxima without early termination (ablation).
    ProximaNoEt,
}

/// Run `algo` over all queries collecting hardware traces + mean stats.
/// Traced runs use the paper's Bloom visited set (§IV-B fidelity for the
/// DES); scratch and the ADT table are reused across the query loop.
pub fn collect_traces(
    w: &Workbench,
    algo: Algo,
    l: usize,
    k: usize,
) -> (Vec<crate::search::Trace>, crate::search::SearchStats) {
    use crate::search::beam::{accurate_beam_search_with, pq_beam_search_with};
    use crate::search::kernel::QueryScratch;
    use crate::search::proxima::{proxima_search_with, ProximaFeatures};
    let ctx = w.context();
    let mut traces = Vec::with_capacity(w.ds.n_queries());
    let mut stats = crate::search::SearchStats::default();
    let mut scratch = QueryScratch::new();
    let mut adt = crate::pq::Adt::default();
    for qi in 0..w.ds.n_queries() {
        let q = w.ds.queries.row(qi);
        let out = match algo {
            Algo::Hnsw => accurate_beam_search_with(&ctx, q, k, l, true, &mut scratch),
            Algo::DiskannPq => {
                w.codebook.build_adt_into(q, &mut adt);
                pq_beam_search_with(&ctx, &adt, q, k, l, (l / 3).max(k), true, &mut scratch)
            }
            Algo::Proxima | Algo::ProximaNoEt => {
                w.codebook.build_adt_into(q, &mut adt);
                let feats = ProximaFeatures {
                    early_termination: algo == Algo::Proxima,
                    beta_rerank: true,
                };
                let params = SearchParams {
                    l,
                    k,
                    ..Default::default()
                };
                proxima_search_with(&ctx, &adt, q, &params, feats, true, &mut scratch)
            }
        };
        stats.add(&out.stats);
        traces.push(out.trace.unwrap());
    }
    (traces, stats)
}

/// Mean per-query stats from an aggregate.
pub fn per_query(stats: &crate::search::SearchStats, n: usize) -> crate::search::SearchStats {
    let n = n.max(1);
    crate::search::SearchStats {
        pq_dists: stats.pq_dists / n,
        exact_dists: stats.exact_dists / n,
        hops: stats.hops / n,
        sorts: stats.sorts / n,
        bytes_index: stats.bytes_index / n as u64,
        bytes_pq: stats.bytes_pq / n as u64,
        bytes_raw: stats.bytes_raw / n as u64,
        et_iterations: stats.et_iterations / n,
        early_terminated: stats.early_terminated,
        // Kept as the aggregate DISTINCT-table count: dividing by n would
        // truncate to 0 exactly when dedup worked (adt_builds < n).
        adt_builds: stats.adt_builds,
        queue_wait_us: stats.queue_wait_us / n as u64,
        cold_reads: stats.cold_reads / n,
        cold_bytes: stats.cold_bytes / n as u64,
        cache_hits: stats.cache_hits / n,
        cache_misses: stats.cache_misses / n,
        lsh_probes: stats.lsh_probes / n,
    }
}

/// Default hardware mapping for a workbench (gap-encoded index width).
pub fn default_mapping(w: &Workbench, hot_frac: f64) -> crate::engine::mapping::DataMapping {
    let b_index = (w.gap.mean_bits_per_edge(w.graph.n_edges()).ceil() as u32).clamp(8, 32);
    crate::engine::mapping::DataMapping::new(
        &crate::nand::NandConfig::proxima(),
        w.ds.n_base() as u32,
        w.graph_params.r as u32,
        b_index,
        (w.codebook.m * 8) as u32,
        w.ds.dim() as u32,
        32,
        hot_frac,
    )
}

/// The dataset subsets each figure uses (small pair for quick runs, the
/// paper's large pair when scale permits).
pub fn small_datasets() -> Vec<&'static str> {
    vec!["sift-s", "glove-s"]
}

pub fn large_datasets() -> Vec<&'static str> {
    vec!["bigann-100m-s", "deep-100m-s"]
}

pub fn all_datasets() -> Vec<&'static str> {
    vec![
        "sift-s",
        "glove-s",
        "deep-10m-s",
        "bigann-10m-s",
        "deep-100m-s",
        "bigann-100m-s",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workbench_builds_and_caches() {
        let w = Workbench::get("sift-s", 0.01, 5);
        assert!(w.graph.validate().is_ok());
        assert_eq!(w.gt.k, 5);
        assert_eq!(w.codes.len(), w.ds.n_base());
        // Second call hits the cache (same shapes).
        let w2 = Workbench::get("sift-s", 0.01, 5);
        assert_eq!(w2.graph.targets, w.graph.targets);
    }
}
