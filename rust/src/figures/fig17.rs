//! Fig 17: recall vs raw bit-error rate — the ECC-free SLC justification.
//! Expected: <3% recall loss up to 1e-4 (SLC/MLC band); collapse at 1e-3+.

use super::Workbench;
use crate::config::SearchParams;
use crate::dataset::recall_at_k;
use crate::error_model::{self, ber};
use crate::search::beam::SearchContext;
use crate::search::proxima::{proxima_search, ProximaFeatures};
use crate::util::bench::Table;

/// Mean recall with all stored representations corrupted at `rate`.
pub fn recall_at_ber(w: &Workbench, rate: f64, seed: u64) -> f64 {
    let params = SearchParams {
        l: 100,
        k: 10,
        ..Default::default()
    };
    let (base, graph, codes);
    let ctx = if rate > 0.0 {
        let cor = error_model::corrupt(&w.ds.base, &w.graph, &w.codes, w.codebook.c, rate, seed);
        let mut b = cor.base;
        error_model::scrub_nonfinite(&mut b);
        base = b;
        graph = error_model::graph_from_corrupted_gap(
            &cor.gap,
            w.graph.n(),
            w.graph.max_degree,
            w.graph.entry_point,
        );
        codes = cor.codes;
        SearchContext {
            base: &base,
            metric: w.ds.metric,
            graph: &graph,
            codes: Some(&codes),
            gap: None,
            storage: None,
            online: None,
            lsh: None,
        }
    } else {
        w.context_no_gap()
    };
    let mut recall = 0.0;
    for qi in 0..w.ds.n_queries() {
        let q = w.ds.queries.row(qi);
        let adt = w.codebook.build_adt(q);
        let out = proxima_search(&ctx, &adt, q, &params, ProximaFeatures::default(), false);
        recall += recall_at_k(&out.ids, w.gt.row(qi), 10);
    }
    recall / w.ds.n_queries() as f64
}

pub fn run(datasets: &[&str], scale: f64) -> Table {
    let mut table = Table::new(
        "Fig 17: search recall vs 3D NAND raw bit-error rate",
        &["dataset", "BER", "recall@10", "delta vs clean"],
    );
    for name in datasets {
        let w = Workbench::get(name, scale, 10);
        let clean = recall_at_ber(&w, 0.0, 0);
        for rate in [0.0, 1e-6, ber::SLC, ber::MLC, ber::TLC, 1e-3, 1e-2] {
            let r = recall_at_ber(&w, rate, 17);
            table.row(vec![
                w.ds.name.clone(),
                format!("{rate:.0e}"),
                format!("{r:.4}"),
                format!("{:+.4}", r - clean),
            ]);
        }
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slc_safe_extreme_fatal() {
        let w = Workbench::get("sift-s", 0.012, 10);
        let clean = recall_at_ber(&w, 0.0, 0);
        let slc = recall_at_ber(&w, ber::SLC, 5);
        let fatal = recall_at_ber(&w, 1e-2, 5);
        assert!(clean - slc < 0.03, "SLC loss {}", clean - slc);
        assert!(fatal < clean - 0.05, "fatal {fatal} vs clean {clean}");
    }
}
