//! Ablation studies for the design choices DESIGN.md §7 calls out:
//!
//! * β (PQ error ratio) sweep — recall/extra-rerank trade-off (§III-C);
//! * repetition rate r sweep 1..15 — the paper's stated ET range (§III-D);
//! * T_step sweep — dynamic-list growth granularity;
//! * Bloom filter vs exact visited set — false-positive recall impact;
//! * BL MUX ratio sweep — granularity vs page-buffer area (§IV-C);
//! * custom Proxima core vs commodity-SSD core on identical traces.

use super::Workbench;
use crate::config::SearchParams;
use crate::dataset::mean_recall;
use crate::engine::{sim, EngineConfig};
use crate::nand::area::AreaModel;
use crate::nand::timing::TimingModel;
use crate::nand::NandConfig;
use crate::search::proxima::{proxima_search, ProximaFeatures};
use crate::util::bench::Table;

/// β sweep: recall and exact-distance count per query.
pub fn beta_sweep(w: &Workbench, betas: &[f32]) -> Vec<(f32, f64, f64)> {
    let ctx = w.context();
    betas
        .iter()
        .map(|&beta| {
            let params = SearchParams {
                l: 100,
                k: 10,
                beta,
                ..Default::default()
            };
            let mut results = Vec::new();
            let mut exact = 0usize;
            for qi in 0..w.ds.n_queries() {
                let q = w.ds.queries.row(qi);
                let adt = w.codebook.build_adt(q);
                let out =
                    proxima_search(&ctx, &adt, q, &params, ProximaFeatures::default(), false);
                exact += out.stats.exact_dists;
                results.push(out.ids);
            }
            (
                beta,
                mean_recall(&results, &w.gt, 10),
                exact as f64 / w.ds.n_queries() as f64,
            )
        })
        .collect()
}

/// Repetition-rate sweep (paper: r in 1..15).
pub fn repetition_sweep(w: &Workbench, rs: &[usize]) -> Vec<(usize, f64, f64)> {
    let ctx = w.context();
    rs.iter()
        .map(|&r| {
            let params = SearchParams {
                l: 100,
                k: 10,
                repetition: r,
                ..Default::default()
            };
            let mut results = Vec::new();
            let mut pq = 0usize;
            for qi in 0..w.ds.n_queries() {
                let q = w.ds.queries.row(qi);
                let adt = w.codebook.build_adt(q);
                let out =
                    proxima_search(&ctx, &adt, q, &params, ProximaFeatures::default(), false);
                pq += out.stats.pq_dists;
                results.push(out.ids);
            }
            (
                r,
                mean_recall(&results, &w.gt, 10),
                pq as f64 / w.ds.n_queries() as f64,
            )
        })
        .collect()
}

/// MUX-ratio sweep: read latency, granularity and core area.
pub fn mux_sweep(ratios: &[u32]) -> Vec<(u32, f64, u64, f64)> {
    let timing = TimingModel::default();
    let area = AreaModel::default();
    ratios
        .iter()
        .map(|&mux| {
            let mut cfg = NandConfig::proxima();
            cfg.mux = mux;
            (
                mux,
                timing.read_latency_ns(&cfg),
                cfg.granularity_bytes(),
                area.core_mm2(&cfg),
            )
        })
        .collect()
}

/// Custom core vs commodity-SSD core on identical Proxima traces.
pub fn core_comparison(w: &Workbench, l: usize) -> Vec<(&'static str, f64, f64)> {
    let (traces, _) = super::collect_traces(w, super::Algo::Proxima, l, 10);
    let mapping = super::default_mapping(w, 0.0);
    let mut out = Vec::new();
    for (tag, nand) in [
        ("Proxima core", NandConfig::proxima()),
        ("commodity SSD core", {
            // Same tile/core counts so only the array geometry differs.
            let mut c = NandConfig::commodity_ssd();
            c.cores_per_tile = 32;
            c.n_tiles = 16;
            c
        }),
    ] {
        let mut cfg = EngineConfig::paper(w.ds.dim(), w.codebook.m);
        cfg.nand = nand;
        let r = sim::simulate(&cfg, &mapping, &traces);
        out.push((tag, r.qps, r.mean_latency_ns / 1000.0));
    }
    out
}

pub fn run(name: &str, scale: f64) -> Vec<Table> {
    let w = Workbench::get(name, scale, 10);
    let mut tables = Vec::new();

    let mut t = Table::new(
        "Ablation: β (PQ error ratio) — recall vs rerank cost",
        &["beta", "recall@10", "exact dists/query"],
    );
    for (b, rec, ex) in beta_sweep(&w, &[1.0, 1.03, 1.06, 1.1, 1.2, 1.4]) {
        t.row(vec![format!("{b}"), format!("{rec:.4}"), Table::fmt(ex)]);
    }
    tables.push(t);

    let mut t = Table::new(
        "Ablation: early-termination repetition rate r",
        &["r", "recall@10", "pq dists/query"],
    );
    for (r, rec, pq) in repetition_sweep(&w, &[1, 2, 3, 5, 9, 15]) {
        t.row(vec![r.to_string(), format!("{rec:.4}"), Table::fmt(pq)]);
    }
    tables.push(t);

    let mut t = Table::new(
        "Ablation: BL MUX ratio (§IV-C)",
        &["mux", "read (ns)", "granule (B)", "core (mm2)"],
    );
    for (m, lat, g, a) in mux_sweep(&[1, 4, 8, 16, 32, 64]) {
        t.row(vec![
            m.to_string(),
            Table::fmt(lat),
            g.to_string(),
            format!("{a:.3}"),
        ]);
    }
    tables.push(t);

    let mut t = Table::new(
        "Ablation: custom core vs commodity SSD core (same traces)",
        &["core", "QPS", "latency (us)"],
    );
    for (tag, qps, lat) in core_comparison(&w, 100) {
        t.row(vec![tag.to_string(), Table::fmt(qps), Table::fmt(lat)]);
    }
    tables.push(t);
    tables
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn beta_widens_rerank_and_never_hurts_recall_much() {
        let w = Workbench::get("sift-s", 0.012, 10);
        let rows = beta_sweep(&w, &[1.0, 1.4]);
        let (r1, e1) = (rows[0].1, rows[0].2);
        let (r2, e2) = (rows[1].1, rows[1].2);
        assert!(e2 >= e1, "bigger beta must rerank more: {e1} -> {e2}");
        assert!(r2 >= r1 - 0.02, "recall {r1} -> {r2}");
    }

    #[test]
    fn larger_repetition_does_more_work_higher_recall() {
        let w = Workbench::get("sift-s", 0.012, 10);
        let rows = repetition_sweep(&w, &[1, 15]);
        assert!(rows[1].2 >= rows[0].2, "pq work {:?}", rows);
        assert!(rows[1].1 >= rows[0].1 - 0.01, "recall {:?}", rows);
    }

    #[test]
    fn mux_trades_granularity_for_area() {
        let rows = mux_sweep(&[1, 32]);
        let (_, lat1, g1, a1) = rows[0];
        let (_, lat32, g32, a32) = rows[1];
        assert!(g1 > g32); // finer granularity with MUX
        assert!(a1 > a32); // bigger page buffer without MUX
        assert!(lat32 <= lat1 + 1.0);
    }

    #[test]
    fn custom_core_orders_of_magnitude_faster() {
        let w = Workbench::get("sift-s", 0.012, 10);
        let rows = core_comparison(&w, 60);
        let proxima = rows[0];
        let ssd = rows[1];
        assert!(
            proxima.2 < ssd.2 / 20.0,
            "custom {} us vs ssd {} us",
            proxima.2,
            ssd.2
        );
    }
}
