//! Fig 15: runtime breakdown vs hot-node percentage (0–7%). Expected:
//! data access dominates (~80%) without hot nodes; ~2.2× latency cut at
//! 1%, ~3× at 3%, plateau beyond.

use super::{default_mapping, fig13::proxima_hot_traces, Workbench};
use crate::engine::{sim, EngineConfig, EngineResult};
use crate::util::bench::Table;

pub fn sweep(w: &Workbench, l: usize, hots: &[f64]) -> Vec<(f64, EngineResult)> {
    let cfg = EngineConfig::paper(w.ds.dim(), w.codebook.m);
    hots.iter()
        .map(|&h| {
            let traces = proxima_hot_traces(w, l, 10, h);
            let mapping = default_mapping(w, h);
            (h, sim::simulate(&cfg, &mapping, &traces))
        })
        .collect()
}

pub fn run(datasets: &[&str], scale: f64) -> Table {
    let mut table = Table::new(
        "Fig 15: runtime breakdown vs hot-node percentage",
        &[
            "dataset",
            "hot%",
            "latency(us)",
            "nand",
            "bus",
            "compute",
            "sort",
            "adt",
            "speedup",
        ],
    );
    for name in datasets {
        let w = Workbench::get(name, scale, 10);
        let rows = sweep(&w, 100, &[0.0, 0.01, 0.03, 0.05, 0.07]);
        let base_lat = rows[0].1.mean_latency_ns;
        for (h, r) in &rows {
            let b = &r.breakdown;
            let total = b.total().max(1e-9);
            table.row(vec![
                w.ds.name.clone(),
                format!("{:.0}%", h * 100.0),
                Table::fmt(r.mean_latency_ns / 1000.0),
                format!("{:.2}", b.nand_ns / total),
                format!("{:.2}", b.bus_ns / total),
                format!("{:.2}", b.compute_ns / total),
                format!("{:.2}", b.sort_ns / total),
                format!("{:.2}", b.adt_ns / total),
                format!("{:.2}x", base_lat / r.mean_latency_ns),
            ]);
        }
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hot_nodes_reduce_latency_then_plateau() {
        let w = Workbench::get("sift-s", 0.012, 10);
        let rows = sweep(&w, 60, &[0.0, 0.03, 0.07]);
        let l0 = rows[0].1.mean_latency_ns;
        let l3 = rows[1].1.mean_latency_ns;
        let l7 = rows[2].1.mean_latency_ns;
        assert!(l3 < l0, "3% hot: {l3} vs 0%: {l0}");
        // Plateau: going 3% -> 7% gains much less than 0% -> 3%.
        let gain_03 = l0 / l3;
        let gain_37 = l3 / l7.max(1.0);
        assert!(gain_03 > gain_37 * 0.8, "gains {gain_03} then {gain_37}");
    }

    #[test]
    fn data_access_dominates_without_hot_nodes() {
        // Paper: NAND + H-tree ≈ 80% of latency at 0% hot nodes.
        let w = Workbench::get("sift-s", 0.012, 10);
        let rows = sweep(&w, 60, &[0.0]);
        let b = &rows[0].1.breakdown;
        let share = (b.nand_ns + b.bus_ns) / b.total();
        assert!(share > 0.5, "data-access share {share}");
    }
}
