//! Tables I–III regeneration.

use crate::accel::models::table3_rows;
use crate::dataset::synth::SynthSpec;
use crate::nand::area::{AreaModel, EngineAreaModel};
use crate::nand::energy::EnergyModel;
use crate::nand::timing::HtreeModel;
use crate::nand::NandConfig;
use crate::util::bench::Table;

/// Table I: the synthetic dataset registry mirroring the paper's datasets.
pub fn table1(scale: f64) -> Table {
    let mut t = Table::new(
        "Table I: evaluated datasets (synthetic stand-ins, see DESIGN.md)",
        &["dataset", "distance", "#base", "#query", "D"],
    );
    for s in SynthSpec::registry(scale) {
        t.row(vec![
            s.name.clone(),
            s.metric.name().to_string(),
            s.n_base.to_string(),
            s.n_queries.to_string(),
            s.dim.to_string(),
        ]);
    }
    t
}

/// Table II: area and power breakdown of the accelerator.
pub fn table2() -> Table {
    let cfg = NandConfig::proxima();
    let area = AreaModel::default();
    let engine = EngineAreaModel::default();
    let energy = EnergyModel::default();
    let mut t = Table::new(
        "Table II: area and power breakdown",
        &["unit", "config", "area (mm2)", "power/energy"],
    );
    t.row(vec![
        "3D NAND core".into(),
        format!("96-layer, {} SSL, {} BL", cfg.n_ssl, cfg.n_bl),
        format!("{:.3}", area.core_mm2(&cfg)),
        format!("{:.0} pJ/read", energy.e_read_pj),
    ]);
    t.row(vec![
        "Core H-tree bus".into(),
        format!("x{}", cfg.cores_per_tile),
        format!("{:.3}", 0.163),
        format!("{:.1} pJ/xfer", energy.e_core_htree_pj),
    ]);
    t.row(vec![
        "Tile".into(),
        format!("{} cores", cfg.cores_per_tile),
        format!("{:.2}", area.tile_mm2(&cfg)),
        "-".into(),
    ]);
    t.row(vec![
        "Tile H-tree bus".into(),
        "x1".into(),
        "1.309".into(),
        format!("{:.1} pJ/xfer", energy.e_tile_htree_pj),
    ]);
    let total_bits = cfg.total_bits() as f64 / (1u64 << 30) as f64;
    t.row(vec![
        "3D NAND total".into(),
        format!("{} tiles ({:.0} Gb)", cfg.n_tiles, total_bits),
        format!("{:.2}", area.total_mm2(&cfg)),
        "-".into(),
    ]);
    let b = engine.breakdown(256, 256, 32);
    for (name, mm2) in &b.rows {
        t.row(vec![
            format!("SE: {name}"),
            "-".into(),
            format!("{mm2:.3}"),
            "-".into(),
        ]);
    }
    t.row(vec![
        "Search engine total".into(),
        "256 queues @ 1 GHz, 22 nm".into(),
        format!("{:.3}", b.total_mm2),
        format!(
            "{:.0} mW dyn + {:.0} mW static",
            energy.engine_dynamic_mw,
            energy.static_mw(256)
        ),
    ]);
    t
}

/// Table III: cross-accelerator comparison.
pub fn table3() -> Table {
    let cfg = NandConfig::proxima();
    let area = AreaModel::default();
    let htree = HtreeModel::default();
    let mut t = Table::new(
        "Table III: CPU/GPU/ASIC/NSP accelerator comparison",
        &[
            "design",
            "platform",
            "storage?",
            "memory",
            "capacity (GB)",
            "peak BW (GB/s)",
            "density (Gb/mm2)",
        ],
    );
    for r in table3_rows() {
        let (cap, bw, dens) = if r.design == "Proxima" {
            // Recompute our design's row from the models.
            (
                cfg.total_bits() as f64 / 8.0 / (1u64 << 30) as f64,
                htree.peak_bandwidth_gbps(cfg.n_tiles),
                area.density_gb_per_mm2(&cfg),
            )
        } else {
            (r.capacity_gb, r.peak_bw_gbps, r.density_gb_per_mm2)
        };
        t.row(vec![
            r.design.into(),
            r.platform.into(),
            if r.includes_storage { "yes" } else { "no" }.into(),
            r.memory.into(),
            format!("{cap:.0}"),
            format!("{bw:.0}"),
            format!("{dens:.1}"),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_totals_near_paper() {
        let t = table2();
        // The table renders with the NAND + engine sections.
        assert!(t.n_rows() > 10);
        let nand = t.find_row("3D NAND total").unwrap();
        let total: f64 = nand[2].parse().unwrap();
        assert!((total - 258.56).abs() < 10.0, "nand total {total}");
        let se = t.find_row("Search engine total").unwrap();
        let se_mm2: f64 = se[2].parse().unwrap();
        assert!((se_mm2 - 9.331).abs() < 0.6, "engine total {se_mm2}");
    }

    #[test]
    fn table3_proxima_row_recomputed() {
        let t = table3();
        let prox = t.find_row("Proxima").unwrap();
        // 54 GB capacity, ~254-256 GB/s, ~1.7 Gb/mm² (Table III).
        let cap: f64 = prox[4].parse().unwrap();
        let bw: f64 = prox[5].parse().unwrap();
        let dens: f64 = prox[6].parse().unwrap();
        assert!((cap - 54.0).abs() < 2.0, "capacity {cap}");
        assert!((bw - 254.0).abs() < 16.0, "bw {bw}");
        assert!((dens - 1.7).abs() < 0.2, "density {dens}");
    }

    #[test]
    fn table1_mirrors_paper_shapes() {
        let t = table1(1.0);
        assert_eq!(t.n_rows(), 6);
        let sift = t.find_row("sift-s").unwrap();
        assert_eq!(sift[1], "l2");
        assert_eq!(sift[4], "128");
        let glove = t.find_row("glove-s").unwrap();
        assert_eq!(glove[1], "angular");
    }
}
