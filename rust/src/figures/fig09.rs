//! Fig 9: density / area / read-latency trade-offs for 96-layer 3D NAND
//! as the array geometry (page size, blocks) varies — the design-space
//! sweep that motivates the custom Proxima core (§IV-C).

use crate::nand::area::AreaModel;
use crate::nand::timing::TimingModel;
use crate::nand::NandConfig;
use crate::util::bench::Table;

/// One design point.
pub struct DesignPoint {
    pub n_bl: u32,
    pub n_block: u32,
    pub mux: u32,
    pub read_ns: f64,
    pub density_gb_mm2: f64,
    pub core_mm2: f64,
    pub granularity_b: u64,
}

/// Sweep page width and block count around the Proxima design point.
pub fn sweep() -> Vec<DesignPoint> {
    let timing = TimingModel::default();
    let area = AreaModel::default();
    let mut out = Vec::new();
    for &n_bl in &[9216u32, 18432, 36864, 73728, 147456] {
        for &n_block in &[32u32, 64, 256, 1024] {
            let mut cfg = NandConfig::proxima();
            cfg.n_bl = n_bl;
            cfg.n_block = n_block;
            out.push(DesignPoint {
                n_bl,
                n_block,
                mux: cfg.mux,
                read_ns: timing.read_latency_ns(&cfg),
                density_gb_mm2: area.density_gb_per_mm2(&cfg),
                core_mm2: area.core_mm2(&cfg),
                granularity_b: cfg.granularity_bytes(),
            });
        }
    }
    out
}

pub fn run() -> Table {
    let mut table = Table::new(
        "Fig 9: 96-layer 3D NAND density/area/latency trade-off",
        &[
            "N_BL",
            "N_block",
            "read (ns)",
            "density (Gb/mm2)",
            "core (mm2)",
            "granule (B)",
        ],
    );
    for p in sweep() {
        table.row(vec![
            p.n_bl.to_string(),
            p.n_block.to_string(),
            Table::fmt(p.read_ns),
            format!("{:.2}", p.density_gb_mm2),
            format!("{:.3}", p.core_mm2),
            p.granularity_b.to_string(),
        ]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bigger_arrays_are_denser_but_slower() {
        let pts = sweep();
        let small = pts
            .iter()
            .find(|p| p.n_bl == 9216 && p.n_block == 32)
            .unwrap();
        let large = pts
            .iter()
            .find(|p| p.n_bl == 147456 && p.n_block == 1024)
            .unwrap();
        assert!(large.read_ns > 10.0 * small.read_ns);
        assert!(large.density_gb_mm2 > small.density_gb_mm2);
    }

    #[test]
    fn proxima_point_balances() {
        // The chosen config: sub-300ns and density within 2x of the
        // densest corner (Fig 9's "working as design guidance").
        let pts = sweep();
        let chosen = pts
            .iter()
            .find(|p| p.n_bl == 36864 && p.n_block == 64)
            .unwrap();
        let max_density = pts.iter().map(|p| p.density_gb_mm2).fold(0.0, f64::max);
        assert!(chosen.read_ns < 300.0);
        assert!(chosen.density_gb_mm2 > max_density / 2.0);
    }
}
