//! Fig 11: throughput (QPS) vs recall for Proxima vs HNSW vs DiskANN-PQ
//! vs FAISS-IVF on the six Table I datasets.
//!
//! Expected shape: graph methods dominate IVF at high recall; Proxima
//! tracks or beats DiskANN-PQ recall at matched throughput (up to ~10%
//! better at the low-recall end on 1M-scale sets).

use super::Workbench;
use crate::config::SearchParams;
use crate::dataset::mean_recall;
use crate::search::beam::{accurate_beam_search_with, pq_beam_search_with};
use crate::search::ivf::IvfPq;
use crate::search::kernel::QueryScratch;
use crate::search::proxima::{proxima_search_with, ProximaFeatures};
use crate::search::SearchStats;
use crate::util::bench::Table;
use std::time::Instant;

/// One measured operating point.
#[derive(Clone, Debug)]
pub struct OpPoint {
    pub algo: &'static str,
    pub dataset: String,
    pub knob: usize,
    pub recall: f64,
    pub qps: f64,
    pub stats: SearchStats,
}

/// Run every query through `f`, measuring recall@k and native QPS.
pub fn measure<F>(w: &Workbench, k: usize, mut f: F) -> (f64, f64, SearchStats)
where
    F: FnMut(&[f32]) -> crate::search::SearchOutput,
{
    let t0 = Instant::now();
    let mut results = Vec::with_capacity(w.ds.n_queries());
    let mut stats = SearchStats::default();
    for q in 0..w.ds.n_queries() {
        let out = f(w.ds.queries.row(q));
        stats.add(&out.stats);
        results.push(out.ids);
    }
    let secs = t0.elapsed().as_secs_f64();
    let recall = mean_recall(&results, &w.gt, k);
    (recall, w.ds.n_queries() as f64 / secs, stats)
}

/// Sweep the three graph algorithms + IVF over their accuracy knobs.
/// QPS is measured over pooled scratch + reused ADT tables — the same
/// steady-state path the serving layer runs. Note: untraced sweeps use
/// the exact epoch visited set, not the paper's Bloom filter, so recall
/// can only match-or-beat the seed's numbers (no false-positive drops);
/// the DES-bound figures (13/14 via `collect_traces`) keep the Bloom
/// filter for §IV-B fidelity.
pub fn sweep(w: &Workbench, k: usize, l_sweep: &[usize]) -> Vec<OpPoint> {
    let mut points = Vec::new();
    let ctx = w.context();
    let mut scratch = QueryScratch::new();
    let mut adt = crate::pq::Adt::default();

    for &l in l_sweep {
        // HNSW-like: accurate distances on the flat graph.
        let (recall, qps, stats) = measure(w, k, |q| {
            accurate_beam_search_with(&ctx, q, k, l, false, &mut scratch)
        });
        points.push(OpPoint {
            algo: "HNSW",
            dataset: w.ds.name.clone(),
            knob: l,
            recall,
            qps,
            stats,
        });

        // DiskANN-PQ: PQ traversal + top-L/3 rerank.
        let (recall, qps, stats) = measure(w, k, |q| {
            w.codebook.build_adt_into(q, &mut adt);
            pq_beam_search_with(&ctx, &adt, q, k, l, (l / 3).max(k), false, &mut scratch)
        });
        points.push(OpPoint {
            algo: "DiskANN-PQ",
            dataset: w.ds.name.clone(),
            knob: l,
            recall,
            qps,
            stats,
        });

        // Proxima (Algorithm 1).
        let params = SearchParams {
            l,
            k,
            ..Default::default()
        };
        let (recall, qps, stats) = measure(w, k, |q| {
            w.codebook.build_adt_into(q, &mut adt);
            let feats = ProximaFeatures::default();
            proxima_search_with(&ctx, &adt, q, &params, feats, false, &mut scratch)
        });
        points.push(OpPoint {
            algo: "Proxima",
            dataset: w.ds.name.clone(),
            knob: l,
            recall,
            qps,
            stats,
        });
    }

    // FAISS-IVF baseline: nprobe sweep.
    let nlist = (w.ds.n_base() as f64).sqrt() as usize;
    let ivf = IvfPq::build(
        &w.ds.base,
        w.ds.metric,
        nlist.clamp(8, 4096),
        w.codebook.m,
        w.codebook.c,
        7,
    );
    for nprobe in [1usize, 2, 4, 8, 16, 32] {
        if nprobe > ivf.nlist {
            break;
        }
        let (recall, qps, stats) = measure(w, k, |q| {
            ivf.search(&w.ds.base, q, k, nprobe, 4 * k)
        });
        points.push(OpPoint {
            algo: "FAISS-IVF",
            dataset: w.ds.name.clone(),
            knob: nprobe,
            recall,
            qps,
            stats,
        });
    }
    points
}

/// Generate the figure across datasets; returns the table.
pub fn run(datasets: &[&str], scale: f64) -> Table {
    let k = 10;
    let mut table = Table::new(
        "Fig 11: QPS vs recall (native software, this machine)",
        &["dataset", "algo", "knob", "recall@10", "QPS"],
    );
    for name in datasets {
        let w = Workbench::get(name, scale, k);
        for p in sweep(&w, k, &[20, 50, 100, 150]) {
            table.row(vec![
                p.dataset.clone(),
                p.algo.to_string(),
                p.knob.to_string(),
                format!("{:.4}", p.recall),
                Table::fmt(p.qps),
            ]);
        }
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig11_shape_holds_on_tiny_scale() {
        let w = Workbench::get("sift-s", 0.012, 10);
        let points = sweep(&w, 10, &[50, 100]);
        // Graph methods reach high recall.
        let best_graph = points
            .iter()
            .filter(|p| p.algo == "Proxima")
            .map(|p| p.recall)
            .fold(0.0, f64::max);
        assert!(best_graph > 0.85, "proxima best recall {best_graph}");
        // Proxima >= DiskANN-PQ recall at matched L (the β-rerank gain).
        for l in [50usize, 100] {
            let prox = points
                .iter()
                .find(|p| p.algo == "Proxima" && p.knob == l)
                .unwrap();
            let dpq = points
                .iter()
                .find(|p| p.algo == "DiskANN-PQ" && p.knob == l)
                .unwrap();
            assert!(
                prox.recall >= dpq.recall - 0.03,
                "L={l}: proxima {} vs diskann {}",
                prox.recall,
                dpq.recall
            );
        }
        // IVF exists and saturates below the graph methods' best.
        let best_ivf = points
            .iter()
            .filter(|p| p.algo == "FAISS-IVF")
            .map(|p| p.recall)
            .fold(0.0, f64::max);
        assert!(best_ivf < 1.0);
    }
}
