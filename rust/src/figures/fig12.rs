//! Fig 12: throughput and energy efficiency — CPU (HNSW) vs GPU (GGNN) vs
//! ANNA (IVF-PQ ASIC) vs Proxima (this accelerator, DES-simulated).
//!
//! Expected shape: Proxima highest QPS, GGNN second; Proxima 6.6–13× over
//! ANNA; energy efficiency ≈3 orders over CPU, ≥17× over ANNA.

use super::{collect_traces, default_mapping, per_query, Algo, Workbench};
use crate::accel::models::{AnnaModel, CpuModel, GpuModel};
use crate::engine::{sim, EngineConfig};
use crate::search::ivf::IvfPq;
use crate::util::bench::Table;

pub struct PlatformRow {
    pub platform: &'static str,
    pub qps: f64,
    pub qps_per_watt: f64,
}

/// Evaluate all four platforms on one dataset.
pub fn compare(w: &Workbench, l: usize) -> Vec<PlatformRow> {
    let k = 10;
    // Software stats feed the analytic baselines.
    let (_tr_hnsw, s_hnsw) = collect_traces(w, Algo::Hnsw, l, k);
    let hnsw_pq = per_query(&s_hnsw, w.ds.n_queries());
    let cpu = CpuModel::default().perf(&hnsw_pq, w.ds.dim());
    let gpu = GpuModel::default().perf(&hnsw_pq);

    // ANNA runs IVF-PQ.
    let ivf = IvfPq::build(
        &w.ds.base,
        w.ds.metric,
        (w.ds.n_base() as f64).sqrt() as usize,
        w.codebook.m,
        w.codebook.c,
        3,
    );
    let mut ivf_stats = crate::search::SearchStats::default();
    for qi in 0..w.ds.n_queries() {
        let out = ivf.search(&w.ds.base, w.ds.queries.row(qi), k, 8, 4 * k);
        ivf_stats.add(&out.stats);
    }
    let anna = AnnaModel::default().perf(&per_query(&ivf_stats, w.ds.n_queries()));

    // Proxima on the DES.
    let (traces, _s) = collect_traces(w, Algo::Proxima, l, k);
    let mapping = default_mapping(w, 0.03);
    let cfg = EngineConfig::paper(w.ds.dim(), w.codebook.m);
    let r = sim::simulate(&cfg, &mapping, &traces);

    vec![
        PlatformRow {
            platform: "CPU(HNSW)",
            qps: cpu.qps,
            qps_per_watt: cpu.qps_per_watt(),
        },
        PlatformRow {
            platform: "GPU(GGNN)",
            qps: gpu.qps,
            qps_per_watt: gpu.qps_per_watt(),
        },
        PlatformRow {
            platform: "ANNA",
            qps: anna.qps,
            qps_per_watt: anna.qps_per_watt(),
        },
        PlatformRow {
            platform: "Proxima",
            qps: r.qps,
            qps_per_watt: r.qps_per_watt,
        },
    ]
}

pub fn run(datasets: &[&str], scale: f64) -> Table {
    let mut table = Table::new(
        "Fig 12: throughput + energy efficiency across platforms",
        &["dataset", "platform", "QPS", "QPS/W"],
    );
    for name in datasets {
        let w = Workbench::get(name, scale, 10);
        for row in compare(&w, 100) {
            table.row(vec![
                w.ds.name.clone(),
                row.platform.to_string(),
                Table::fmt(row.qps),
                Table::fmt(row.qps_per_watt),
            ]);
        }
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig12_ordering_holds() {
        let w = Workbench::get("sift-s", 0.012, 10);
        let rows = compare(&w, 100);
        let get = |p: &str| rows.iter().find(|r| r.platform == p).unwrap();
        let (cpu, gpu, anna, prox) = (
            get("CPU(HNSW)"),
            get("GPU(GGNN)"),
            get("ANNA"),
            get("Proxima"),
        );
        // Paper ordering: Proxima > GGNN > CPU in QPS. (The 6.6-13x gap
        // over ANNA needs paper-scale IVF scan traffic — ANNA's scan over
        // a few thousand points is unrealistically cheap at quick scale,
        // so that ratio is asserted in the full-scale bench record, not
        // here.)
        assert!(prox.qps > gpu.qps, "prox {} vs gpu {}", prox.qps, gpu.qps);
        assert!(gpu.qps > cpu.qps, "gpu {} vs cpu {}", gpu.qps, cpu.qps);
        // Energy efficiency: orders of magnitude over CPU, above GPU too.
        assert!(
            prox.qps_per_watt > 50.0 * cpu.qps_per_watt,
            "prox {} vs cpu {} QPS/W",
            prox.qps_per_watt,
            cpu.qps_per_watt
        );
        assert!(prox.qps_per_watt > gpu.qps_per_watt);
        let _ = anna;
    }
}
