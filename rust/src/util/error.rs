//! Minimal `anyhow`-flavored error plumbing (`Error`, `Result`, `Context`,
//! plus the crate-root `anyhow!` / `bail!` macros) so the crate builds
//! offline with zero external dependencies. Only the surface this repo
//! actually uses is implemented: string-backed errors, context chaining,
//! and `?` conversion from any `std::error::Error`.

use std::fmt;

/// String-backed error with `anyhow`-style context prefixes.
pub struct Error(String);

impl Error {
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error(msg.to_string())
    }

    /// Prefix the error with additional context (`"{context}: {inner}"`).
    pub fn context(self, c: impl fmt::Display) -> Error {
        Error(format!("{c}: {}", self.0))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

// anyhow prints the chain for `{:?}`/`{:#}`; a flat string does the same.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`, so
// this blanket conversion cannot collide with `From<Error> for Error`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error(e.to_string())
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(...)` / `.with_context(...)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error(format!("{c}: {e}")))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error(c.to_string()))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// `anyhow!`-compatible constructor: `anyhow!("x {y}")` → [`Error`].
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::util::error::Error::msg(format!($($arg)*)) };
}

/// `bail!`-compatible early return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/xyz")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_prefixes() {
        let e: Result<()> = Err(Error::msg("inner"));
        let e = e.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let n: Option<u32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
        let n: Option<u32> = None;
        assert_eq!(
            n.with_context(|| format!("missing {}", 3)).unwrap_err().to_string(),
            "missing 3"
        );
    }

    #[test]
    fn macros_format() {
        let x = 7;
        let e = crate::anyhow!("bad value {x}");
        assert_eq!(e.to_string(), "bad value 7");
        fn bailer() -> Result<u32> {
            crate::bail!("nope {}", 1);
        }
        assert_eq!(bailer().unwrap_err().to_string(), "nope 1");
    }
}
