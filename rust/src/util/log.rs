//! One stderr log helper for build/open/serve progress, with a quiet
//! mode — so loadgen runs and tests can silence the serving stack's
//! progress chatter instead of interleaving it with their own output.
//!
//! Progress messages go through the crate-root [`logln!`](crate::logln)
//! macro, which drops the line when quiet mode is on. Quiet mode is
//! enabled by [`set_quiet`] (the CLI's `--quiet` flag) or by setting the
//! `PROXIMA_QUIET` environment variable to anything but `0`/empty.
//! Errors that callers must see (panics, typed API errors) do NOT go
//! through this: it is for progress noise only.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

static QUIET: OnceLock<AtomicBool> = OnceLock::new();

fn cell() -> &'static AtomicBool {
    QUIET.get_or_init(|| {
        let env_quiet = std::env::var("PROXIMA_QUIET")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false);
        AtomicBool::new(env_quiet)
    })
}

/// Enable/disable quiet mode process-wide (overrides `PROXIMA_QUIET`).
pub fn set_quiet(quiet: bool) {
    cell().store(quiet, Ordering::Relaxed);
}

/// Is progress logging currently suppressed?
pub fn is_quiet() -> bool {
    cell().load(Ordering::Relaxed)
}

/// Progress log line to stderr, suppressed in quiet mode. `eprintln!`
/// semantics otherwise.
#[macro_export]
macro_rules! logln {
    ($($arg:tt)*) => {
        if !$crate::util::log::is_quiet() {
            eprintln!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quiet_mode_toggles() {
        let before = is_quiet();
        set_quiet(true);
        assert!(is_quiet());
        crate::logln!("this line must be suppressed");
        set_quiet(false);
        assert!(!is_quiet());
        set_quiet(before);
    }
}
