//! Leveled stderr logging for build/open/serve progress.
//!
//! Four severities ([`Level`]): `error` > `warn` > `info` > `debug` in
//! urgency, `error` < `warn` < `info` < `debug` in verbosity. The
//! process-wide maximum defaults to `info` and is set by the
//! `PROXIMA_LOG` environment variable (`error|warn|info|debug`) or
//! programmatically via [`set_level`] (the CLI's `--quiet` flag maps to
//! `error` through the [`set_quiet`] shim, as does the legacy
//! `PROXIMA_QUIET` variable). Lines render as `[level target] message`
//! where `target` is the emitting module (`module_path!`), so an
//! operator can grep one subsystem out of the interleaved stream.
//!
//! Emit through the crate-root macros: [`log_error!`], [`log_warn!`],
//! [`log_info!`], [`log_debug!`] — or [`logln!`], the historical
//! progress macro, which is `info`-level. Errors that callers must see
//! programmatically (panics, typed API errors) do NOT go through this:
//! it is for human-facing progress and diagnostics only.
//!
//! [`log_error!`]: crate::log_error
//! [`log_warn!`]: crate::log_warn
//! [`log_info!`]: crate::log_info
//! [`log_debug!`]: crate::log_debug
//! [`logln!`]: crate::logln

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Log severity. Ordered by verbosity: a message is emitted when its
/// level is at or below the process maximum ([`max_level`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    /// Parse a `PROXIMA_LOG` value (case-insensitive).
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Error,
            1 => Level::Warn,
            3 => Level::Debug,
            _ => Level::Info,
        }
    }
}

static MAX: OnceLock<AtomicU8> = OnceLock::new();

fn cell() -> &'static AtomicU8 {
    MAX.get_or_init(|| {
        // `PROXIMA_LOG` wins; the legacy `PROXIMA_QUIET` (anything but
        // empty/`0`) degrades to errors-only, matching what the old
        // binary quiet mode suppressed.
        let level = std::env::var("PROXIMA_LOG")
            .ok()
            .and_then(|v| Level::parse(&v))
            .unwrap_or_else(|| {
                let quiet = std::env::var("PROXIMA_QUIET")
                    .map(|v| !v.is_empty() && v != "0")
                    .unwrap_or(false);
                if quiet {
                    Level::Error
                } else {
                    Level::Info
                }
            });
        AtomicU8::new(level as u8)
    })
}

/// Set the process-wide maximum level (overrides the environment).
pub fn set_level(level: Level) {
    cell().store(level as u8, Ordering::Relaxed);
}

/// The current process-wide maximum level.
pub fn max_level() -> Level {
    Level::from_u8(cell().load(Ordering::Relaxed))
}

/// Would a message at `level` be emitted right now?
pub fn enabled(level: Level) -> bool {
    level <= max_level()
}

/// Legacy shim for the old binary quiet mode (the CLI `--quiet` flag):
/// `true` = errors only, `false` = back to the `info` default.
pub fn set_quiet(quiet: bool) {
    set_level(if quiet { Level::Error } else { Level::Info });
}

/// Is progress logging (info and below) currently suppressed?
pub fn is_quiet() -> bool {
    !enabled(Level::Info)
}

/// Emit one line as `[level target] message` if `level` is enabled.
/// The macros below pass `module_path!()` as `target`.
pub fn write(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{} {}] {}", level.name(), target, args);
    }
}

/// Emit at an explicit [`Level`] with the calling module as target.
#[macro_export]
macro_rules! log_at {
    ($level:expr, $($arg:tt)*) => {
        $crate::util::log::write($level, module_path!(), format_args!($($arg)*))
    };
}

/// Error-level log line (never suppressed by `--quiet`).
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::log::Level::Error, $($arg)*) };
}

/// Warn-level log line.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::log::Level::Warn, $($arg)*) };
}

/// Info-level log line.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::log::Level::Info, $($arg)*) };
}

/// Debug-level log line (off by default; `PROXIMA_LOG=debug`).
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::log::Level::Debug, $($arg)*) };
}

/// Progress log line (the historical macro): `info`-level.
#[macro_export]
macro_rules! logln {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::log::Level::Info, $($arg)*) };
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test owns the global level: these cases run sequentially
    // inside it so a parallel test runner cannot interleave them.
    #[test]
    fn levels_parse_order_and_gate() {
        assert_eq!(Level::parse("DEBUG"), Some(Level::Debug));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
        assert!(Level::Error < Level::Debug, "ordered by verbosity");

        let before = max_level();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        assert!(is_quiet(), "info suppressed under warn");
        crate::log_debug!("this line must be suppressed");

        // The quiet shim maps onto levels.
        set_quiet(true);
        assert_eq!(max_level(), Level::Error);
        set_quiet(false);
        assert_eq!(max_level(), Level::Info);
        assert!(!is_quiet());
        set_level(before);
    }
}
