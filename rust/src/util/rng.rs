//! Deterministic pseudo-random number generation.
//!
//! The offline build environment has no `rand` crate, so we implement the
//! two standard small generators used throughout the repo:
//!
//! * [`SplitMix64`] — used for seeding (passes BigCrush for its purpose).
//! * [`Xoshiro256pp`] — the general-purpose generator (xoshiro256++ 1.0,
//!   Blackman & Vigna), used for dataset synthesis, k-means init, graph
//!   construction and the property-test harness.
//!
//! All experiment entry points take explicit seeds so every figure in
//! EXPERIMENTS.md is exactly reproducible.

/// SplitMix64: a fast, well-distributed 64-bit generator used to expand a
/// single `u64` seed into the 256-bit state of [`Xoshiro256pp`].
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 — the repo's workhorse PRNG.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

#[inline(always)]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 per the reference implementation's guidance.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` (f32).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` using Lemire's multiply-shift rejection.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (we only need throughput, not tails).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > f64::EPSILON {
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k << n assumed; falls back
    /// to shuffle when k is a large fraction of n).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 3 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all.sort_unstable();
            return all;
        }
        let mut seen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let x = self.gen_range(n);
            if seen.insert(x) {
                out.push(x);
            }
        }
        out.sort_unstable();
        out
    }

    /// Bernoulli draw.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed=1234567 from the public SplitMix64 impl.
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(a, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic() {
        let mut a = Xoshiro256pp::seed_from_u64(42);
        let mut b = Xoshiro256pp::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xoshiro_different_seeds_diverge() {
        let mut a = Xoshiro256pp::seed_from_u64(1);
        let mut b = Xoshiro256pp::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256pp::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Xoshiro256pp::seed_from_u64(9);
        let mut hit = [false; 10];
        for _ in 0..1000 {
            let x = r.gen_range(10);
            assert!(x < 10);
            hit[x] = true;
        }
        assert!(hit.iter().all(|&h| h));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256pp::seed_from_u64(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_distinct_is_distinct_and_sorted() {
        let mut r = Xoshiro256pp::seed_from_u64(13);
        for (n, k) in [(100, 10), (50, 40), (1000, 999), (5, 5)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            for w in s.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::seed_from_u64(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
