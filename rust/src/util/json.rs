//! Minimal JSON value model, parser and pretty-printer.
//!
//! Used for the AOT `artifacts/manifest.json` handshake with the Python
//! compile path and for machine-readable experiment outputs under
//! `results/`. The offline environment has no `serde`/`serde_json`; this is
//! a small, strict (RFC 8259 subset) implementation:
//!
//! * numbers are parsed as `f64` (the manifest only carries shapes and
//!   scalar config — well inside the exact-integer range of f64);
//! * no `\u` surrogate-pair handling beyond the BMP (manifest is ASCII);
//! * object key order is preserved (Vec of pairs) so output is stable.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with preserved insertion order.
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }
    pub fn arr_num<I: IntoIterator<Item = f64>>(xs: I) -> Json {
        Json::Arr(xs.into_iter().map(Json::Num).collect())
    }
    pub fn arr_str<I: IntoIterator<Item = String>>(xs: I) -> Json {
        Json::Arr(xs.into_iter().map(Json::Str).collect())
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    it.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !kvs.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}
impl std::error::Error for ParseError {}

/// Parse a JSON document (must consume the full input up to trailing ws).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            kvs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (utf-8 passes through)
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Convenience: turn a flat `key -> f64` map into a stable-ordered object.
pub fn obj_from_map(m: &BTreeMap<String, f64>) -> Json {
    Json::Obj(m.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.25", "1e3"] {
            let v = parse(s).unwrap();
            let back = parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, back);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2,{"b":"x\ny","c":null}],"d":true,"e":-2.5e-2}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("d"), Some(&Json::Bool(true)));
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x\ny")
        );
        let back = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\"}").is_err());
        assert!(parse("\"open").is_err());
    }

    #[test]
    fn integer_formatting_is_exact() {
        let v = Json::Num(1_000_000.0);
        assert_eq!(v.to_string_compact(), "1000000");
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        if let Json::Obj(kvs) = &v {
            let keys: Vec<_> = kvs.iter().map(|(k, _)| k.as_str()).collect();
            assert_eq!(keys, vec!["z", "a", "m"]);
        } else {
            panic!("not an object");
        }
    }
}
