//! Tiny command-line argument parser (no `clap` offline).
//!
//! Supports the subset we use everywhere:
//! `prog SUBCOMMAND [--flag] [--key value] [--key=value] [positional...]`.

use std::collections::BTreeMap;

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `std::env::args().skip(1)`-style iterator. The first token not
    /// starting with `-` becomes the subcommand (when `with_subcommand`).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, with_subcommand: bool) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        if with_subcommand {
            if let Some(tok) = it.peek() {
                if !tok.starts_with('-') {
                    out.subcommand = it.next();
                }
            }
        }
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some(eq) = rest.find('=') {
                    out.options
                        .insert(rest[..eq].to_string(), rest[eq + 1..].to_string());
                } else if it
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let val = it.next().unwrap();
                    out.options.insert(rest.to_string(), val);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// From the process environment.
    pub fn from_env(with_subcommand: bool) -> Args {
        Self::parse(std::env::args().skip(1), with_subcommand)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|s| {
                s.parse::<usize>()
                    .unwrap_or_else(|_| panic!("--{key} expects an integer, got '{s}'"))
            })
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|s| {
                s.parse::<u64>()
                    .unwrap_or_else(|_| panic!("--{key} expects an integer, got '{s}'"))
            })
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|s| {
                s.parse::<f64>()
                    .unwrap_or_else(|_| panic!("--{key} expects a number, got '{s}'"))
            })
            .unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Comma-separated list of usize, e.g. `--sweep 32,64,128`.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse::<usize>()
                        .unwrap_or_else(|_| panic!("--{key} expects ints, got '{t}'"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        // Note the documented greedy-value rule: `--name tok` consumes tok
        // as the value, so boolean flags go last or use `--name=`.
        let a = Args::parse(argv("search --dataset sift-s --k=10 data.bin --verbose"), true);
        assert_eq!(a.subcommand.as_deref(), Some("search"));
        assert_eq!(a.get("dataset"), Some("sift-s"));
        assert_eq!(a.get_usize("k", 0), 10);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["data.bin"]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse(argv("--fast --check"), false);
        assert!(a.has_flag("fast") && a.has_flag("check"));
        assert!(a.options.is_empty());
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(argv("--sweep 32,64,256"), false);
        assert_eq!(a.get_usize_list("sweep", &[]), vec![32, 64, 256]);
        assert_eq!(a.get_usize_list("absent", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn defaults() {
        let a = Args::parse(argv(""), true);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_f64("beta", 1.06), 1.06);
    }
}
