//! Miniature property-based testing harness (no `proptest` offline).
//!
//! Usage pattern mirrors the subset of proptest we need: generate random
//! cases from a seeded [`Xoshiro256pp`], run an assertion-style predicate,
//! and on failure report the case index and seed so it replays exactly.
//! There is no shrinking; generators are asked to keep cases readable.

use super::rng::Xoshiro256pp;

/// Default number of cases per property (override with `PROXIMA_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("PROXIMA_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `cases` random checks. `gen` builds a case from the RNG; `check`
/// returns `Err(description)` on violation. Panics with a reproducible
/// report on the first failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Xoshiro256pp) -> T,
    mut check: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    for i in 0..cases {
        let case = gen(&mut rng);
        if let Err(msg) = check(&case) {
            panic!(
                "property '{name}' failed at case {i}/{cases} (seed {seed}):\n  {msg}\n  case: {case:?}"
            );
        }
    }
}

/// Like [`check`] but with the default case count.
pub fn check_default<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    gen: impl FnMut(&mut Xoshiro256pp) -> T,
    check_fn: impl FnMut(&T) -> Result<(), String>,
) {
    check(name, seed, default_cases(), gen, check_fn)
}

/// Generator helpers.
pub mod gen {
    use super::Xoshiro256pp;

    /// Vector of `len` f32 in [lo, hi).
    pub fn vec_f32(rng: &mut Xoshiro256pp, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| lo + rng.next_f32() * (hi - lo)).collect()
    }

    /// Vector of `len` u32 below bound.
    pub fn vec_u32(rng: &mut Xoshiro256pp, len: usize, bound: u32) -> Vec<u32> {
        (0..len).map(|_| rng.gen_range(bound as usize) as u32).collect()
    }

    /// Sorted vector of distinct u32s.
    pub fn sorted_distinct_u32(rng: &mut Xoshiro256pp, len: usize, bound: usize) -> Vec<u32> {
        rng.sample_distinct(bound.max(len), len)
            .into_iter()
            .map(|x| x as u32)
            .collect()
    }

    /// Length in [1, max].
    pub fn len(rng: &mut Xoshiro256pp, max: usize) -> usize {
        1 + rng.gen_range(max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check(
            "sum-commutative",
            1,
            32,
            |r| (r.next_f32(), r.next_f32()),
            |(a, b)| {
                if (a + b - (b + a)).abs() < 1e-9 {
                    Ok(())
                } else {
                    Err("not commutative".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_context() {
        check(
            "always-fails",
            2,
            4,
            |r| r.next_u64(),
            |_| Err("boom".into()),
        );
    }

    #[test]
    fn generators_respect_bounds() {
        let mut r = Xoshiro256pp::seed_from_u64(3);
        let v = gen::vec_f32(&mut r, 100, -2.0, 3.0);
        assert!(v.iter().all(|&x| (-2.0..3.0).contains(&x)));
        let u = gen::vec_u32(&mut r, 100, 17);
        assert!(u.iter().all(|&x| x < 17));
        let s = gen::sorted_distinct_u32(&mut r, 10, 100);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
    }
}
