//! Foundational substrates built in-repo because the offline image only
//! vendors the `xla` dependency tree (no rand/serde/clap/proptest/criterion,
//! and no `anyhow` — see [`error`]).

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;

/// Percentile over an unsorted slice (p in [0,100]); copies + sorts.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[idx.min(v.len() - 1)]
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        f64::NAN
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn mean_basics() {
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!(mean(&[]).is_nan());
    }
}
