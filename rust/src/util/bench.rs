//! Micro/figure benchmark harness (criterion substitute for the offline
//! environment). `cargo bench` targets use `harness = false` and call into
//! this module: it warms up, runs timed iterations until a time budget or
//! iteration cap is reached, and reports mean / p50 / p95 wall-clock plus
//! a stable machine-readable line for EXPERIMENTS.md extraction.
//!
//! Figure benches additionally use [`Table`] to print the paper-shaped rows
//! and write a CSV under `results/`.

use std::time::{Duration, Instant};

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<44} iters={:<6} mean={:>12?} p50={:>12?} p95={:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p95
        );
    }
    /// Throughput helper: items per second given items-per-iteration.
    pub fn per_sec(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean.as_secs_f64()
    }
}

/// Time budget per bench (seconds), override with `PROXIMA_BENCH_SECS`.
fn budget() -> Duration {
    let secs = std::env::var("PROXIMA_BENCH_SECS")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .unwrap_or(1.0);
    Duration::from_secs_f64(secs)
}

/// Run `f` repeatedly; returns timing stats. `f` should perform one logical
/// iteration and return a value which is black-boxed to prevent DCE.
pub fn bench<T>(name: &str, mut f: impl FnMut() -> T) -> BenchResult {
    // Warmup: at least 3 runs or 10% of budget.
    let warm_budget = budget().mul_f64(0.1);
    let t0 = Instant::now();
    let mut warm = 0;
    while warm < 3 || (t0.elapsed() < warm_budget && warm < 1000) {
        black_box(f());
        warm += 1;
    }
    let mut samples: Vec<Duration> = Vec::new();
    let t0 = Instant::now();
    while t0.elapsed() < budget() && samples.len() < 10_000 {
        let s = Instant::now();
        black_box(f());
        samples.push(s.elapsed());
    }
    samples.sort_unstable();
    let iters = samples.len();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    let p50 = samples[iters / 2];
    let p95 = samples[(iters * 95 / 100).min(iters - 1)];
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean,
        p50,
        p95,
    };
    r.report();
    r
}

/// Opaque value barrier (std::hint::black_box exists on this toolchain).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Paper-style table printer + CSV writer.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Cell accessor for assertions: (row, col).
    pub fn cell(&self, row: usize, col: usize) -> &str {
        &self.rows[row][col]
    }

    /// Find the first row whose first cell matches.
    pub fn find_row(&self, first_cell: &str) -> Option<&[String]> {
        self.rows
            .iter()
            .find(|r| r[0] == first_cell)
            .map(|r| r.as_slice())
    }

    /// Format helper for numeric cells.
    pub fn fmt(x: f64) -> String {
        if x == 0.0 {
            "0".into()
        } else if x.abs() >= 1000.0 {
            format!("{x:.0}")
        } else if x.abs() >= 10.0 {
            format!("{x:.1}")
        } else if x.abs() >= 0.01 {
            format!("{x:.3}")
        } else {
            format!("{x:.3e}")
        }
    }

    /// Print aligned to stdout.
    pub fn print(&self) {
        println!("\n=== {} ===", self.title);
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            line(row);
        }
    }

    /// Write CSV under `results/` (created if needed). Returns path.
    pub fn write_csv(&self, stem: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{stem}.csv"));
        let mut s = String::new();
        s.push_str(&self.headers.join(","));
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join(","));
            s.push('\n');
        }
        std::fs::write(&path, s)?;
        println!("[csv] wrote {}", path.display());
        Ok(path)
    }
}

/// Scale knob for figure benches: "quick" (default under cargo bench) or
/// "full" via `PROXIMA_SCALE=full` for larger datasets / more queries.
pub fn full_scale() -> bool {
    std::env::var("PROXIMA_SCALE").map(|v| v == "full").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        std::env::set_var("PROXIMA_BENCH_SECS", "0.05");
        let r = bench("noop-ish", || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.iters >= 3);
        assert!(r.p50 <= r.p95);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.rows.len(), 1);
        assert_eq!(Table::fmt(12345.6), "12346");
        assert_eq!(Table::fmt(0.5), "0.500");
    }
}
