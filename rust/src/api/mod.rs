//! The typed, versioned query API: ONE request/response contract shared
//! by every entry point into the search stack — in-process calls
//! ([`crate::coordinator::SearchService::query`]), the dynamic batcher,
//! the sharded fan-out, and the TCP wire (v2 of the line protocol in
//! [`crate::coordinator::server`]; codecs in [`wire`]).
//!
//! The contract exists so the serving layer can evolve without signature
//! churn — and it has: every batch now executes on the persistent
//! work-stealing pool ([`crate::exec::ExecPool`]) behind this same
//! surface, with the staged GEMM-shaped batch ADT build in front of the
//! walks. Callers construct a [`QueryRequest`] carrying N query vectors,
//! `k`, and per-request [`QueryOptions`], and get back a [`QueryResponse`]
//! with one [`NeighborList`] per query — or a structured [`ApiError`]
//! (whole-request failures); per-query failures (e.g. a contained worker
//! panic) ride in [`QueryResponse::errors`].
//!
//! Alongside the query plane, the v2 wire carries an **admin plane** for
//! the index lifecycle (`status`, `reload` — codecs in [`wire`], spec
//! types in [`crate::artifact`]); artifact failures convert into
//! [`ApiError`]s so bad bytes surface as structured error lines, never
//! as torn connections.
//!
//! # `QueryOptions` defaults
//!
//! Every option defaults to "whatever the service was configured with",
//! so `QueryOptions::default()` reproduces the pre-API behavior exactly:
//!
//! | field            | default  | meaning                                             |
//! |------------------|----------|-----------------------------------------------------|
//! | `mode`           | `Hybrid` | Proxima Alg. 1 (PQ guide + cached exact rerank);    |
//! |                  |          | `PqAdt` = DiskANN-PQ, `Accurate` = HNSW-like        |
//! | `l_override`     | `None`   | candidate-list capacity L (service `SearchParams.l`)|
//! | `early_term_tau` | `None`   | early-termination stability threshold r (τ);        |
//! |                  |          | `Some(0)` disables early termination                |
//! | `rerank`         | `None`   | `PqAdt`: final rerank depth (default L);            |
//! |                  |          | `Hybrid`: `Some(0)` disables the β-rerank           |
//! | `want_stats`     | `false`  | aggregate [`SearchStats`] into the response         |

pub mod wire;

use crate::config::Config;
use crate::search::{SearchOutput, SearchStats};

/// Hard cap on queries per request: bounds what a single wire line (or
/// in-process call) can demand from the decoder and the worker pool.
/// Enforced both at wire decode (before vectors are materialized) and in
/// `SearchService::validate`.
pub const MAX_BATCH_QUERIES: usize = 4096;

/// Which search algorithm answers the request (all three are policies
/// over the unified kernel in [`crate::search::kernel`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SearchMode {
    /// Full-precision traversal (the HNSW-like baseline).
    Accurate,
    /// PQ-guided traversal with a one-shot final rerank (DiskANN-PQ).
    PqAdt,
    /// Proxima Algorithm 1: PQ guide, dynamic list, early termination,
    /// β-rerank through the exact-distance cache.
    #[default]
    Hybrid,
}

impl SearchMode {
    /// Stable wire name.
    pub fn name(self) -> &'static str {
        match self {
            SearchMode::Accurate => "accurate",
            SearchMode::PqAdt => "pq_adt",
            SearchMode::Hybrid => "hybrid",
        }
    }

    /// Parse a wire/config name (accepts a few aliases).
    pub fn parse(s: &str) -> Option<SearchMode> {
        match s {
            "accurate" | "exact" | "hnsw" => Some(SearchMode::Accurate),
            "pq_adt" | "pq" | "pqadt" | "diskann" => Some(SearchMode::PqAdt),
            "hybrid" | "proxima" => Some(SearchMode::Hybrid),
            _ => None,
        }
    }
}

/// Per-request knobs riding along with every query (see the module docs
/// for the default/None semantics).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct QueryOptions {
    pub mode: SearchMode,
    /// Candidate-list capacity L; `None` = service default.
    pub l_override: Option<usize>,
    /// Early-termination stability threshold r (τ); `Some(0)` disables
    /// early termination, `None` = service default.
    pub early_term_tau: Option<usize>,
    /// `PqAdt`: final rerank depth (default L). `Hybrid`: `Some(0)`
    /// disables the β-rerank. Ignored by `Accurate`.
    pub rerank: Option<usize>,
    /// Aggregate per-query [`SearchStats`] into the response.
    pub want_stats: bool,
}

impl QueryOptions {
    /// Read defaults from the `[api]` config section (`api.mode`,
    /// `api.l_override`, `api.early_term_tau`, `api.rerank`,
    /// `api.want_stats`); absent keys keep the `Default` semantics.
    pub fn from_config(cfg: &Config) -> QueryOptions {
        let mode = match cfg.get_str("api.mode") {
            None => SearchMode::default(),
            Some(s) => SearchMode::parse(s)
                .unwrap_or_else(|| panic!("config api.mode: unknown mode '{s}'")),
        };
        QueryOptions {
            mode,
            l_override: cfg.get_opt_usize("api.l_override"),
            early_term_tau: cfg.get_opt_usize("api.early_term_tau"),
            rerank: cfg.get_opt_usize("api.rerank"),
            want_stats: cfg.get_bool("api.want_stats", false),
        }
    }
}

/// A batch of queries answered in one call / one wire round-trip.
#[derive(Clone, Debug, PartialEq)]
pub struct QueryRequest {
    /// Row-major query vectors; every row must match the index dimension.
    pub vectors: Vec<Vec<f32>>,
    /// Neighbors to return per query (clamped to the effective L).
    pub k: usize,
    pub options: QueryOptions,
}

impl QueryRequest {
    /// One-query request with default options.
    pub fn single(q: &[f32], k: usize) -> QueryRequest {
        QueryRequest {
            vectors: vec![q.to_vec()],
            k,
            options: QueryOptions::default(),
        }
    }

    /// Multi-query request with default options.
    pub fn batch(queries: &[&[f32]], k: usize) -> QueryRequest {
        QueryRequest {
            vectors: queries.iter().map(|q| q.to_vec()).collect(),
            k,
            options: QueryOptions::default(),
        }
    }

    /// Builder-style options override.
    pub fn with_options(mut self, options: QueryOptions) -> QueryRequest {
        self.options = options;
        self
    }
}

/// Top-k result of one query: ids ascending by distance.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct NeighborList {
    pub ids: Vec<u32>,
    pub dists: Vec<f32>,
}

/// Answer to a [`QueryRequest`]: `results[i]` answers `vectors[i]`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct QueryResponse {
    pub results: Vec<NeighborList>,
    /// Per-query failures. Empty when every query succeeded (the common
    /// case, kept allocation-free); otherwise `errors[i]` is `Some` for
    /// each query that failed — its `results[i]` entry is empty. A
    /// worker panic surfaces here as [`ApiErrorCode::Internal`] for that
    /// query only; its batch-mates are answered normally.
    pub errors: Vec<Option<ApiError>>,
    /// Aggregated over the batch when the request set `want_stats`
    /// (includes `queue_wait_us` — time queries sat in the exec-pool
    /// queue — and `adt_builds` — distinct ADT tables the staged batch
    /// build produced).
    pub stats: Option<SearchStats>,
    /// Service-side wall time for the whole batch.
    pub server_latency_us: u64,
}

impl QueryResponse {
    /// Assemble a response from per-query search outputs (moves the
    /// output buffers; aggregates stats only when asked).
    pub fn from_outputs(
        outputs: Vec<SearchOutput>,
        want_stats: bool,
        server_latency_us: u64,
    ) -> QueryResponse {
        Self::from_results(outputs.into_iter().map(Ok).collect(), want_stats, server_latency_us)
    }

    /// Assemble a response from fallible per-query results: failed
    /// queries contribute an empty [`NeighborList`] plus their error in
    /// [`Self::errors`]; stats aggregate over the successful ones.
    pub fn from_results(
        outcomes: Vec<Result<SearchOutput, ApiError>>,
        want_stats: bool,
        server_latency_us: u64,
    ) -> QueryResponse {
        let any_err = outcomes.iter().any(|o| o.is_err());
        let mut results = Vec::with_capacity(outcomes.len());
        let mut errors = Vec::with_capacity(if any_err { outcomes.len() } else { 0 });
        let mut stats = want_stats.then(SearchStats::default);
        for o in outcomes {
            match o {
                Ok(out) => {
                    if let Some(s) = stats.as_mut() {
                        s.add(&out.stats);
                    }
                    results.push(NeighborList {
                        ids: out.ids,
                        dists: out.dists,
                    });
                    if any_err {
                        errors.push(None);
                    }
                }
                Err(e) => {
                    results.push(NeighborList::default());
                    errors.push(Some(e));
                }
            }
        }
        QueryResponse {
            results,
            errors,
            stats,
            server_latency_us,
        }
    }

    /// The failure of query `i`, if any.
    pub fn error_for(&self, i: usize) -> Option<&ApiError> {
        self.errors.get(i).and_then(|e| e.as_ref())
    }

    /// Whether any query in the batch failed.
    pub fn has_errors(&self) -> bool {
        self.errors.iter().any(|e| e.is_some())
    }
}

/// Machine-readable error class (stable wire names in parentheses).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ApiErrorCode {
    /// Malformed or semantically invalid request (`bad_request`).
    BadRequest,
    /// Query vector length differs from the index dim (`dim_mismatch`).
    DimMismatch,
    /// The service is shutting down / the batcher is gone (`closed`).
    Closed,
    /// Unexpected server-side failure (`internal`).
    Internal,
    /// Admission control shed the request — over the in-flight budget,
    /// past its deadline, or queued beyond the shed threshold
    /// (`overloaded`). Retryable by the client after backoff.
    Overloaded,
}

impl ApiErrorCode {
    pub fn name(self) -> &'static str {
        match self {
            ApiErrorCode::BadRequest => "bad_request",
            ApiErrorCode::DimMismatch => "dim_mismatch",
            ApiErrorCode::Closed => "closed",
            ApiErrorCode::Internal => "internal",
            ApiErrorCode::Overloaded => "overloaded",
        }
    }

    pub fn parse(s: &str) -> Option<ApiErrorCode> {
        match s {
            "bad_request" => Some(ApiErrorCode::BadRequest),
            "dim_mismatch" => Some(ApiErrorCode::DimMismatch),
            "closed" => Some(ApiErrorCode::Closed),
            "internal" => Some(ApiErrorCode::Internal),
            "overloaded" => Some(ApiErrorCode::Overloaded),
            _ => None,
        }
    }
}

/// Structured API failure: a stable code plus a human-readable message.
#[derive(Clone, Debug, PartialEq)]
pub struct ApiError {
    pub code: ApiErrorCode,
    pub message: String,
}

impl ApiError {
    pub fn new(code: ApiErrorCode, message: impl Into<String>) -> ApiError {
        ApiError {
            code,
            message: message.into(),
        }
    }
    pub fn bad_request(message: impl Into<String>) -> ApiError {
        Self::new(ApiErrorCode::BadRequest, message)
    }
    pub fn dim_mismatch(message: impl Into<String>) -> ApiError {
        Self::new(ApiErrorCode::DimMismatch, message)
    }
    pub fn closed(message: impl Into<String>) -> ApiError {
        Self::new(ApiErrorCode::Closed, message)
    }
    pub fn internal(message: impl Into<String>) -> ApiError {
        Self::new(ApiErrorCode::Internal, message)
    }
    pub fn overloaded(message: impl Into<String>) -> ApiError {
        Self::new(ApiErrorCode::Overloaded, message)
    }
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.code.name(), self.message)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_options_reproduce_service_defaults() {
        let o = QueryOptions::default();
        assert_eq!(o.mode, SearchMode::Hybrid);
        assert_eq!(o.l_override, None);
        assert_eq!(o.early_term_tau, None);
        assert_eq!(o.rerank, None);
        assert!(!o.want_stats);
    }

    #[test]
    fn mode_names_roundtrip() {
        for m in [SearchMode::Accurate, SearchMode::PqAdt, SearchMode::Hybrid] {
            assert_eq!(SearchMode::parse(m.name()), Some(m));
        }
        assert_eq!(SearchMode::parse("nonsense"), None);
    }

    #[test]
    fn error_codes_roundtrip() {
        for c in [
            ApiErrorCode::BadRequest,
            ApiErrorCode::DimMismatch,
            ApiErrorCode::Closed,
            ApiErrorCode::Internal,
            ApiErrorCode::Overloaded,
        ] {
            assert_eq!(ApiErrorCode::parse(c.name()), Some(c));
        }
        assert_eq!(ApiErrorCode::parse("teapot"), None);
    }

    #[test]
    fn request_builders() {
        let q = vec![1.0f32, 2.0];
        let req = QueryRequest::single(&q, 5);
        assert_eq!(req.vectors.len(), 1);
        assert_eq!(req.k, 5);
        let req = QueryRequest::batch(&[&q, &q, &q], 7).with_options(QueryOptions {
            l_override: Some(99),
            ..Default::default()
        });
        assert_eq!(req.vectors.len(), 3);
        assert_eq!(req.options.l_override, Some(99));
    }

    #[test]
    fn response_from_outputs_aggregates_stats_on_demand() {
        let mk = |pq: usize| SearchOutput {
            ids: vec![1, 2],
            dists: vec![0.1, 0.2],
            stats: SearchStats {
                pq_dists: pq,
                ..Default::default()
            },
            trace: None,
            spans: Default::default(),
        };
        let r = QueryResponse::from_outputs(vec![mk(3), mk(4)], true, 42);
        assert_eq!(r.results.len(), 2);
        assert_eq!(r.results[0].ids, vec![1, 2]);
        assert_eq!(r.stats.as_ref().unwrap().pq_dists, 7);
        assert_eq!(r.server_latency_us, 42);
        let r = QueryResponse::from_outputs(vec![mk(3)], false, 1);
        assert!(r.stats.is_none());
        assert!(r.errors.is_empty(), "all-ok responses carry no error vec");
    }

    #[test]
    fn response_from_results_contains_per_query_failures() {
        let ok = SearchOutput {
            ids: vec![7],
            dists: vec![0.5],
            stats: SearchStats {
                pq_dists: 2,
                ..Default::default()
            },
            trace: None,
            spans: Default::default(),
        };
        let r = QueryResponse::from_results(
            vec![
                Ok(ok.clone()),
                Err(ApiError::internal("worker panicked")),
                Ok(ok),
            ],
            true,
            5,
        );
        assert_eq!(r.results.len(), 3);
        assert!(r.has_errors());
        assert!(r.error_for(0).is_none());
        assert_eq!(r.error_for(1).unwrap().code, ApiErrorCode::Internal);
        assert!(r.results[1].ids.is_empty());
        assert_eq!(r.results[2].ids, vec![7]);
        // Stats aggregate over the successes only.
        assert_eq!(r.stats.unwrap().pq_dists, 4);
    }

    #[test]
    fn options_from_config() {
        let cfg = Config::parse(
            "[api]\nmode = pq_adt\nl_override = 200\nearly_term_tau = 5\nwant_stats = true\n",
        )
        .unwrap();
        let o = QueryOptions::from_config(&cfg);
        assert_eq!(o.mode, SearchMode::PqAdt);
        assert_eq!(o.l_override, Some(200));
        assert_eq!(o.early_term_tau, Some(5));
        assert_eq!(o.rerank, None);
        assert!(o.want_stats);
        let o = QueryOptions::from_config(&Config::new());
        assert_eq!(o, QueryOptions::default());
    }

    #[test]
    fn error_display_includes_code() {
        let e = ApiError::dim_mismatch("query 0: expected dim 16, got 3");
        assert_eq!(e.to_string(), "dim_mismatch: query 0: expected dim 16, got 3");
    }
}
