//! JSON wire codecs for the typed query API — the single
//! encode/decode surface shared by the TCP server and [`Client`]
//! (`crate::coordinator::server`), so the two sides can never drift.
//!
//! Versioning: requests carry `"v":2`; a missing `v` means a v1 request
//! (`{"op":"search","query":[...],"k":..}`), which decodes to the same
//! [`QueryRequest`] with one vector and default options — the server
//! answers it in the v1 response shape. Errors are always the structured
//! `{"error":{"code":...,"message":...}}` line; [`decode_error`] also
//! accepts the legacy `{"error":"..."}` string shape.
//!
//! v2 protocol extension (per-query failures, no version bump): a v2
//! response's `results[i]` slot is EITHER `{ids,dists}` or an inline
//! `{"error":{code,message}}` object when query `i` alone failed (e.g. a
//! contained worker panic). Decoders must dispatch on the `"error"` key
//! per entry. This replaces pre-extension behavior where such a failure
//! tore down the whole connection, so no working decoder ever received
//! these bytes before; a version bump was deliberately avoided because
//! it would make NEW clients unintelligible to OLD servers for an
//! error-only path.
//!
//! v3 is NOT a JSON revision: it is the length-prefixed binary frame
//! format in [`crate::net::frame`], sharing this module's typed
//! `QueryRequest`/`QueryResponse`/`ApiError` vocabulary (admin ops ride
//! inside binary frames as v2 JSON lines, so this module stays the
//! single source of truth for op semantics). Both planes share one port:
//! the server sniffs the first byte of a connection — `{` or whitespace
//! selects this JSON plane, the `PXW3` magic selects the binary plane.
//! The `overloaded` error code is emitted by admission control on either
//! plane; decoders predating it degrade it to `internal` (see
//! [`decode_error`]), which is safe because shed requests carry no
//! results.

use super::{
    ApiError, ApiErrorCode, NeighborList, QueryOptions, QueryRequest, QueryResponse, SearchMode,
};
use crate::artifact::IndexSpec;
use crate::distance::Metric;
use crate::search::SearchStats;
use crate::storage::cache::CachePolicy;
use crate::storage::Residency;
use crate::util::json::Json;

/// Highest protocol version this build speaks.
pub const VERSION: u32 = 2;

/// A decoded wire line: an operation the server dispatches on.
#[derive(Clone, Debug)]
pub enum WireRequest {
    /// `op:"search"`; `version` picks the response shape (1 or 2).
    Search { version: u32, request: QueryRequest },
    Stats,
    /// v2 admin plane: spec + provenance + counters of the served index.
    Status,
    /// v2 admin plane: Prometheus text exposition of the lifetime
    /// metrics (`crate::obs`) — counters, gauges, and the log-linear
    /// latency histograms — embedded as the `"exposition"` string field
    /// of the JSON response line (the line protocol carries no raw
    /// multi-line bodies).
    Metrics,
    /// v2 admin plane: the slow-query flight recorder — the N slowest
    /// recent queries with their per-stage span breakdowns and
    /// [`SearchStats`]. Cleared on `reload`/`flush` hot-swaps.
    Slowlog,
    /// v2 admin plane: hot-swap the served index to the artifact at
    /// `path`, optionally switching the vector [`Residency`] (`None`
    /// keeps the currently-served epoch's residency), the row-cache
    /// sizing/policy, and LSH warm starts.
    Reload {
        path: String,
        residency: Option<Residency>,
        /// Row-cache capacity in MiB (sizes `cached`, or layers a cache
        /// under `tiered`); `None` keeps the epoch's capacity.
        cache_mb: Option<u64>,
        /// Eviction policy for the row cache; `None` keeps the epoch's.
        cache_policy: Option<CachePolicy>,
        /// Enable/disable LSH entry-point warm starts; `None` keeps the
        /// epoch's setting.
        lsh_start: Option<bool>,
    },
    /// v2 write plane: insert one vector into the served index.
    Insert { vector: Vec<f32> },
    /// v2 write plane: tombstone one id (original id space).
    Delete { id: u32 },
    /// v2 write plane: compact + re-save the served index and hot-swap
    /// the successor. `None` flushes back to the artifact the index was
    /// opened from.
    Flush { path: Option<String> },
    Shutdown,
}

// ---------------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------------

/// Encode a v2 (multi-query, optioned) search request.
pub fn encode_request_v2(req: &QueryRequest) -> Json {
    Json::obj(vec![
        ("v", Json::num(VERSION as f64)),
        ("op", Json::str("search")),
        (
            "queries",
            Json::Arr(
                req.vectors
                    .iter()
                    .map(|q| Json::arr_num(q.iter().map(|&x| x as f64)))
                    .collect(),
            ),
        ),
        ("k", Json::num(req.k as f64)),
        ("options", encode_options(&req.options)),
    ])
}

/// Encode a legacy v1 single-query request (compat-path clients).
pub fn encode_request_v1(query: &[f32], k: usize) -> Json {
    Json::obj(vec![
        ("op", Json::str("search")),
        ("query", Json::arr_num(query.iter().map(|&x| x as f64))),
        ("k", Json::num(k as f64)),
    ])
}

/// Encode a v2 write-plane insert request.
pub fn encode_insert(vector: &[f32]) -> Json {
    Json::obj(vec![
        ("v", Json::num(VERSION as f64)),
        ("op", Json::str("insert")),
        ("vector", Json::arr_num(vector.iter().map(|&x| x as f64))),
    ])
}

/// Encode a v2 write-plane delete request.
pub fn encode_delete(id: u32) -> Json {
    Json::obj(vec![
        ("v", Json::num(VERSION as f64)),
        ("op", Json::str("delete")),
        ("id", Json::num(id as f64)),
    ])
}

/// Encode a v2 write-plane flush request (`None` = flush back to the
/// artifact the served index was opened from).
pub fn encode_flush(path: Option<&str>) -> Json {
    let mut kvs = vec![
        ("v", Json::num(VERSION as f64)),
        ("op", Json::str("flush")),
    ];
    if let Some(p) = path {
        kvs.push(("path", Json::str(p)));
    }
    Json::obj(kvs)
}

/// Decode one request line (any version) into a [`WireRequest`].
pub fn decode_request(j: &Json) -> Result<WireRequest, ApiError> {
    let version = match j.get("v") {
        None => 1,
        Some(v) => as_index(v, "'v'")? as u32,
    };
    if version == 0 || version > VERSION {
        return Err(ApiError::bad_request(format!(
            "unsupported protocol version {version} (this server speaks up to v{VERSION})"
        )));
    }
    let op = match j.get("op") {
        None => "search",
        Some(o) => o
            .as_str()
            .ok_or_else(|| ApiError::bad_request("'op' must be a string"))?,
    };
    match op {
        "stats" => Ok(WireRequest::Stats),
        // Admin-plane ops (v2): no v1 client ever sent these names, so
        // accepting them regardless of the line's `v` cannot collide
        // with compat behavior; responses are always structured.
        "status" => Ok(WireRequest::Status),
        "metrics" => Ok(WireRequest::Metrics),
        "slowlog" => Ok(WireRequest::Slowlog),
        "reload" => {
            let path = j
                .get("path")
                .and_then(Json::as_str)
                .ok_or_else(|| ApiError::bad_request("reload requires a 'path' string"))?;
            let residency = match j.get("residency") {
                None => None,
                Some(r) => {
                    let s = r.as_str().ok_or_else(|| {
                        ApiError::bad_request("reload 'residency' must be a string")
                    })?;
                    Some(Residency::parse(s).ok_or_else(|| {
                        ApiError::bad_request(format!(
                            "unknown residency '{s}' (resident|cold|tiered|cached)"
                        ))
                    })?)
                }
            };
            let cache_mb = match j.get("cache_mb") {
                None => None,
                Some(v) => Some(as_index(v, "reload 'cache_mb'")? as u64),
            };
            let cache_policy = match j.get("cache_policy") {
                None => None,
                Some(p) => {
                    let s = p.as_str().ok_or_else(|| {
                        ApiError::bad_request("reload 'cache_policy' must be a string")
                    })?;
                    Some(CachePolicy::parse(s).ok_or_else(|| {
                        ApiError::bad_request(format!(
                            "unknown cache_policy '{s}' (s3fifo|clock)"
                        ))
                    })?)
                }
            };
            let lsh_start = match j.get("lsh_start") {
                None => None,
                Some(b) => Some(b.as_bool().ok_or_else(|| {
                    ApiError::bad_request("reload 'lsh_start' must be a bool")
                })?),
            };
            Ok(WireRequest::Reload {
                path: path.to_string(),
                residency,
                cache_mb,
                cache_policy,
                lsh_start,
            })
        }
        // Write-plane ops (v2): new names like the admin ops above, so
        // the same no-collision argument lets them decode regardless of
        // the line's `v`.
        "insert" => {
            let vector = j
                .get("vector")
                .ok_or_else(|| ApiError::bad_request("insert requires a 'vector' array"))?;
            Ok(WireRequest::Insert {
                vector: decode_vector(vector)
                    .map_err(|e| ApiError::bad_request(format!("insert vector: {}", e.message)))?,
            })
        }
        "delete" => {
            let id = j
                .get("id")
                .ok_or_else(|| ApiError::bad_request("delete requires an 'id'"))?;
            Ok(WireRequest::Delete {
                id: as_index(id, "delete 'id'")? as u32,
            })
        }
        "flush" => {
            let path = match j.get("path") {
                None => None,
                Some(p) => Some(
                    p.as_str()
                        .ok_or_else(|| ApiError::bad_request("flush 'path' must be a string"))?
                        .to_string(),
                ),
            };
            Ok(WireRequest::Flush { path })
        }
        "shutdown" => Ok(WireRequest::Shutdown),
        "search" => {
            let vectors = if let Some(qs) = j.get("queries") {
                if version == 1 {
                    // Versionless lines are the v1 compat path, whose
                    // response is the flat single-query shape — a batch
                    // would have to be answered in a shape the client
                    // never asked for.
                    return Err(ApiError::bad_request(
                        "'queries' requires \"v\":2 (v1 takes a single 'query')",
                    ));
                }
                let rows = qs
                    .as_arr()
                    .ok_or_else(|| ApiError::bad_request("'queries' must be an array of arrays"))?;
                if rows.len() > super::MAX_BATCH_QUERIES {
                    return Err(ApiError::bad_request(format!(
                        "batch of {} exceeds the maximum {} queries per request",
                        rows.len(),
                        super::MAX_BATCH_QUERIES
                    )));
                }
                rows.iter()
                    .enumerate()
                    .map(|(i, r)| {
                        decode_vector(r).map_err(|e| {
                            ApiError::bad_request(format!("queries[{i}]: {}", e.message))
                        })
                    })
                    .collect::<Result<Vec<_>, _>>()?
            } else if let Some(q) = j.get("query") {
                vec![decode_vector(q)?]
            } else {
                return Err(ApiError::bad_request("missing 'query' or 'queries'"));
            };
            let k = match j.get("k") {
                None => 10,
                Some(k) => as_index(k, "'k'")?,
            };
            let options = match j.get("options") {
                None => QueryOptions::default(),
                Some(o) => decode_options(o)?,
            };
            Ok(WireRequest::Search {
                version,
                request: QueryRequest { vectors, k, options },
            })
        }
        other => Err(ApiError::bad_request(format!("unknown op '{other}'"))),
    }
}

fn decode_vector(j: &Json) -> Result<Vec<f32>, ApiError> {
    let arr = j
        .as_arr()
        .ok_or_else(|| ApiError::bad_request("query must be an array of numbers"))?;
    arr.iter()
        .map(|x| {
            x.as_f64()
                .map(|v| v as f32)
                .ok_or_else(|| ApiError::bad_request("query element must be a number"))
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Options
// ---------------------------------------------------------------------------

/// Encode options; `None`/default fields are omitted from the wire.
pub fn encode_options(o: &QueryOptions) -> Json {
    let mut kvs: Vec<(&str, Json)> = vec![("mode", Json::str(o.mode.name()))];
    if let Some(l) = o.l_override {
        kvs.push(("l_override", Json::num(l as f64)));
    }
    if let Some(t) = o.early_term_tau {
        kvs.push(("early_term_tau", Json::num(t as f64)));
    }
    if let Some(r) = o.rerank {
        kvs.push(("rerank", Json::num(r as f64)));
    }
    if o.want_stats {
        kvs.push(("want_stats", Json::Bool(true)));
    }
    Json::obj(kvs)
}

pub fn decode_options(j: &Json) -> Result<QueryOptions, ApiError> {
    let mut o = QueryOptions::default();
    if let Some(m) = j.get("mode") {
        let s = m
            .as_str()
            .ok_or_else(|| ApiError::bad_request("options.mode must be a string"))?;
        o.mode = SearchMode::parse(s)
            .ok_or_else(|| ApiError::bad_request(format!("options.mode: unknown mode '{s}'")))?;
    }
    o.l_override = opt_usize(j, "l_override")?;
    o.early_term_tau = opt_usize(j, "early_term_tau")?;
    o.rerank = opt_usize(j, "rerank")?;
    if let Some(w) = j.get("want_stats") {
        o.want_stats = w
            .as_bool()
            .ok_or_else(|| ApiError::bad_request("options.want_stats must be a bool"))?;
    }
    Ok(o)
}

fn opt_usize(j: &Json, key: &str) -> Result<Option<usize>, ApiError> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => as_index(v, &format!("options.{key}")).map(Some),
    }
}

/// Strict non-negative-integer decode: rejects negatives and fractions
/// instead of letting `as usize` saturate/truncate them into different
/// semantics (e.g. `early_term_tau:-5` would otherwise become 0 =
/// "disable early termination").
fn as_index(v: &Json, what: &str) -> Result<usize, ApiError> {
    let x = v
        .as_f64()
        .ok_or_else(|| ApiError::bad_request(format!("{what} must be a number")))?;
    if !(0.0..=u32::MAX as f64).contains(&x) || x.fract() != 0.0 {
        return Err(ApiError::bad_request(format!(
            "{what} must be a non-negative integer, got {x}"
        )));
    }
    Ok(x as usize)
}

// ---------------------------------------------------------------------------
// Responses
// ---------------------------------------------------------------------------

/// Encode a v2 response: one `{ids,dists}` object per query — or, for a
/// query that failed individually (contained worker panic), an inline
/// `{"error":{code,message}}` entry in its slot — plus the aggregated
/// stats when the request asked for them.
pub fn encode_response_v2(resp: &QueryResponse) -> Json {
    let results = resp
        .results
        .iter()
        .enumerate()
        .map(|(i, nl)| match resp.error_for(i) {
            Some(e) => encode_error(e),
            None => encode_neighbor_list(nl),
        })
        .collect();
    let mut kvs: Vec<(&str, Json)> = vec![
        ("v", Json::num(VERSION as f64)),
        ("results", Json::Arr(results)),
        ("server_latency_us", Json::num(resp.server_latency_us as f64)),
    ];
    if let Some(s) = &resp.stats {
        kvs.push(("stats", encode_stats(s)));
    }
    Json::obj(kvs)
}

/// Encode the legacy v1 single-query response shape.
pub fn encode_response_v1(nl: &NeighborList, latency_us: u64) -> Json {
    Json::obj(vec![
        ("ids", Json::arr_num(nl.ids.iter().map(|&i| i as f64))),
        ("dists", Json::arr_num(nl.dists.iter().map(|&d| d as f64))),
        ("latency_us", Json::num(latency_us as f64)),
    ])
}

pub fn decode_response_v2(j: &Json) -> Result<QueryResponse, ApiError> {
    let entries = j
        .get("results")
        .and_then(Json::as_arr)
        .ok_or_else(|| ApiError::bad_request("response missing 'results'"))?;
    let mut results = Vec::with_capacity(entries.len());
    let mut errors = Vec::with_capacity(entries.len());
    let mut any_err = false;
    for entry in entries {
        // A per-query error entry occupies the query's result slot.
        if let Some(e) = decode_error(entry) {
            any_err = true;
            errors.push(Some(e));
            results.push(NeighborList::default());
        } else {
            errors.push(None);
            results.push(decode_neighbor_list(entry)?);
        }
    }
    if !any_err {
        errors.clear(); // all-good batches keep the compact shape
    }
    let stats = match j.get("stats") {
        None => None,
        Some(s) => Some(decode_stats(s)),
    };
    let server_latency_us = j
        .get("server_latency_us")
        .and_then(Json::as_f64)
        .unwrap_or(0.0) as u64;
    Ok(QueryResponse {
        results,
        errors,
        stats,
        server_latency_us,
    })
}

fn encode_neighbor_list(nl: &NeighborList) -> Json {
    Json::obj(vec![
        ("ids", Json::arr_num(nl.ids.iter().map(|&i| i as f64))),
        ("dists", Json::arr_num(nl.dists.iter().map(|&d| d as f64))),
    ])
}

fn decode_neighbor_list(j: &Json) -> Result<NeighborList, ApiError> {
    let ids: Vec<u32> = j
        .get("ids")
        .and_then(Json::as_arr)
        .ok_or_else(|| ApiError::bad_request("result missing 'ids'"))?
        .iter()
        .map(|x| as_index(x, "result id").map(|v| v as u32))
        .collect::<Result<_, _>>()?;
    let dists: Vec<f32> = j
        .get("dists")
        .and_then(Json::as_arr)
        .ok_or_else(|| ApiError::bad_request("result missing 'dists'"))?
        .iter()
        .map(|x| {
            x.as_f64()
                .map(|v| v as f32)
                .ok_or_else(|| ApiError::bad_request("result dist must be a number"))
        })
        .collect::<Result<_, _>>()?;
    if dists.len() != ids.len() {
        return Err(ApiError::bad_request(format!(
            "result carries {} ids but {} dists",
            ids.len(),
            dists.len()
        )));
    }
    Ok(NeighborList { ids, dists })
}

// ---------------------------------------------------------------------------
// IndexSpec (the `status` admin op)
// ---------------------------------------------------------------------------

/// Encode an [`IndexSpec`] for the `status` response.
///
/// `build_seed` crosses the wire as a JSON number: seeds above 2^53
/// would lose precision, but every seed this repo uses (and any a
/// human picks) is far below that.
pub fn encode_spec(s: &IndexSpec) -> Json {
    Json::obj(vec![
        ("dataset", Json::str(s.dataset.clone())),
        ("metric", Json::str(s.metric.name())),
        ("dim", Json::num(s.dim as f64)),
        ("n_base", Json::num(s.n_base as f64)),
        ("graph_r", Json::num(s.graph_r as f64)),
        ("graph_build_l", Json::num(s.graph_build_l as f64)),
        ("graph_alpha", Json::num(s.graph_alpha as f64)),
        ("pq_m", Json::num(s.pq_m as f64)),
        ("pq_c", Json::num(s.pq_c as f64)),
        ("hot_frac", Json::num(s.hot_frac)),
        ("build_seed", Json::num(s.build_seed as f64)),
    ])
}

/// Decode a `status` response's spec object. Integer fields get the
/// same strict non-negative-integer treatment as every other integer on
/// this wire (see [`as_index`]) — saturating `as` casts would turn a
/// malformed line into a silently-garbage spec.
pub fn decode_spec(j: &Json) -> Result<IndexSpec, ApiError> {
    let metric_name = j
        .get("metric")
        .and_then(Json::as_str)
        .ok_or_else(|| ApiError::bad_request("spec missing 'metric'"))?;
    let metric = Metric::parse(metric_name)
        .ok_or_else(|| ApiError::bad_request(format!("spec: unknown metric '{metric_name}'")))?;
    let dataset = j
        .get("dataset")
        .and_then(Json::as_str)
        .ok_or_else(|| ApiError::bad_request("spec missing 'dataset'"))?
        .to_string();
    let num = |key: &str| {
        j.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| ApiError::bad_request(format!("spec missing '{key}'")))
    };
    let idx = |key: &str| -> Result<usize, ApiError> {
        let v = j
            .get(key)
            .ok_or_else(|| ApiError::bad_request(format!("spec missing '{key}'")))?;
        as_index(v, &format!("spec.{key}"))
    };
    // Wide counters (n_base, build_seed) exceed u32 legitimately but
    // must still be non-negative integers within f64's exact range.
    let wide = |key: &str| -> Result<u64, ApiError> {
        let x = num(key)?;
        if !(0.0..=9.007_199_254_740_992e15).contains(&x) || x.fract() != 0.0 {
            return Err(ApiError::bad_request(format!(
                "spec.{key} must be a non-negative integer, got {x}"
            )));
        }
        Ok(x as u64)
    };
    // hot_frac is the one f64 FRACTION on this wire: it crosses as a
    // raw JSON number (shortest-round-trip printing preserves every
    // bit), but a NaN/negative/super-unit value must be rejected here —
    // the tiered open sizes its DRAM hot set from it.
    let hot_frac = num("hot_frac")?;
    if !hot_frac.is_finite() || !(0.0..=1.0).contains(&hot_frac) {
        return Err(ApiError::bad_request(format!(
            "spec.hot_frac must be a fraction in [0, 1], got {hot_frac}"
        )));
    }
    Ok(IndexSpec {
        dataset,
        metric,
        dim: idx("dim")? as u32,
        n_base: wide("n_base")?,
        graph_r: idx("graph_r")? as u32,
        graph_build_l: idx("graph_build_l")? as u32,
        graph_alpha: num("graph_alpha")? as f32,
        pq_m: idx("pq_m")? as u32,
        pq_c: idx("pq_c")? as u32,
        hot_frac,
        build_seed: wide("build_seed")?,
    })
}

// ---------------------------------------------------------------------------
// Stats + errors
// ---------------------------------------------------------------------------

pub fn encode_stats(s: &SearchStats) -> Json {
    Json::obj(vec![
        ("pq_dists", Json::num(s.pq_dists as f64)),
        ("exact_dists", Json::num(s.exact_dists as f64)),
        ("hops", Json::num(s.hops as f64)),
        ("sorts", Json::num(s.sorts as f64)),
        ("bytes_index", Json::num(s.bytes_index as f64)),
        ("bytes_pq", Json::num(s.bytes_pq as f64)),
        ("bytes_raw", Json::num(s.bytes_raw as f64)),
        ("et_iterations", Json::num(s.et_iterations as f64)),
        ("early_terminated", Json::Bool(s.early_terminated)),
        ("adt_builds", Json::num(s.adt_builds as f64)),
        ("queue_wait_us", Json::num(s.queue_wait_us as f64)),
        ("cold_reads", Json::num(s.cold_reads as f64)),
        ("cold_bytes", Json::num(s.cold_bytes as f64)),
        ("cache_hits", Json::num(s.cache_hits as f64)),
        ("cache_misses", Json::num(s.cache_misses as f64)),
        ("lsh_probes", Json::num(s.lsh_probes as f64)),
    ])
}

pub fn decode_stats(j: &Json) -> SearchStats {
    let n = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0);
    SearchStats {
        pq_dists: n("pq_dists") as usize,
        exact_dists: n("exact_dists") as usize,
        hops: n("hops") as usize,
        sorts: n("sorts") as usize,
        bytes_index: n("bytes_index") as u64,
        bytes_pq: n("bytes_pq") as u64,
        bytes_raw: n("bytes_raw") as u64,
        et_iterations: n("et_iterations") as usize,
        early_terminated: j
            .get("early_terminated")
            .and_then(Json::as_bool)
            .unwrap_or(false),
        adt_builds: n("adt_builds") as usize,
        queue_wait_us: n("queue_wait_us") as u64,
        cold_reads: n("cold_reads") as usize,
        cold_bytes: n("cold_bytes") as u64,
        // Added after v2 shipped: absent on lines from older servers, so
        // (like every stats field) they default to 0 rather than erroring.
        cache_hits: n("cache_hits") as usize,
        cache_misses: n("cache_misses") as usize,
        lsh_probes: n("lsh_probes") as usize,
    }
}

// ---------------------------------------------------------------------------
// Storage status block (the `status` admin op)
// ---------------------------------------------------------------------------

/// Typed view of the `status` response's `storage` block. Cache fields
/// are `None` when the served residency carries no row cache — and when
/// talking to an older server that predates them.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StorageStatus {
    pub residency: String,
    pub resident_bytes: u64,
    pub n_hot: usize,
    pub cold_reads: u64,
    pub cold_bytes: u64,
    pub cache: Option<CacheStatusWire>,
}

/// The row-cache sub-block of [`StorageStatus`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CacheStatusWire {
    pub policy: String,
    pub capacity_bytes: u64,
    pub hit_rate: f64,
    pub evictions: u64,
    pub ghost_hits: u64,
}

/// Decode a `status` response's `storage` block. FORWARD-COMPATIBLE by
/// contract: unknown keys are ignored and absent keys default, so an
/// old client reading a new server's block (or vice versa) never
/// errors — the admin plane must stay inspectable across mixed-version
/// fleets. The cache sub-block is recognized by its `cache_policy` key.
pub fn decode_storage_status(j: &Json) -> StorageStatus {
    let n = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0);
    let cache = j
        .get("cache_policy")
        .and_then(Json::as_str)
        .map(|policy| CacheStatusWire {
            policy: policy.to_string(),
            capacity_bytes: n("cache_capacity_bytes") as u64,
            hit_rate: n("cache_hit_rate"),
            evictions: n("cache_evictions") as u64,
            ghost_hits: n("cache_ghost_hits") as u64,
        });
    StorageStatus {
        residency: j
            .get("residency")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string(),
        resident_bytes: n("resident_bytes") as u64,
        n_hot: n("n_hot") as usize,
        cold_reads: n("cold_reads") as u64,
        cold_bytes: n("cold_bytes") as u64,
        cache,
    }
}

/// Encode the structured error line: `{"error":{"code":..,"message":..}}`.
pub fn encode_error(e: &ApiError) -> Json {
    Json::obj(vec![(
        "error",
        Json::obj(vec![
            ("code", Json::str(e.code.name())),
            ("message", Json::str(e.message.clone())),
        ]),
    )])
}

/// Extract an error from a response line, accepting both the structured
/// object shape and the legacy `{"error":"..."}` string shape. Returns
/// `None` when the line carries no error.
pub fn decode_error(j: &Json) -> Option<ApiError> {
    let e = j.get("error")?;
    if let Some(s) = e.as_str() {
        return Some(ApiError::internal(s));
    }
    let code = e
        .get("code")
        .and_then(Json::as_str)
        .and_then(ApiErrorCode::parse)
        .unwrap_or(ApiErrorCode::Internal);
    let message = e
        .get("message")
        .and_then(Json::as_str)
        .unwrap_or("")
        .to_string();
    Some(ApiError::new(code, message))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn reparse(j: &Json) -> Json {
        json::parse(&j.to_string_compact()).expect("wire line must reparse")
    }

    #[test]
    fn v2_request_roundtrip() {
        let req = QueryRequest {
            vectors: vec![vec![1.0, 2.0], vec![3.0, 4.0]],
            k: 7,
            options: QueryOptions {
                mode: SearchMode::PqAdt,
                l_override: Some(120),
                early_term_tau: Some(0),
                rerank: Some(30),
                want_stats: true,
            },
        };
        let line = reparse(&encode_request_v2(&req));
        match decode_request(&line).unwrap() {
            WireRequest::Search { version, request } => {
                assert_eq!(version, 2);
                assert_eq!(request.vectors, req.vectors);
                assert_eq!(request.k, 7);
                assert_eq!(request.options, req.options);
            }
            other => panic!("wrong op: {other:?}"),
        }
    }

    #[test]
    fn v1_request_decodes_with_default_options() {
        let line = reparse(&encode_request_v1(&[0.5, 0.25], 3));
        match decode_request(&line).unwrap() {
            WireRequest::Search { version, request } => {
                assert_eq!(version, 1);
                assert_eq!(request.vectors, vec![vec![0.5, 0.25]]);
                assert_eq!(request.k, 3);
                assert_eq!(request.options, QueryOptions::default());
            }
            other => panic!("wrong op: {other:?}"),
        }
    }

    #[test]
    fn bad_requests_are_rejected_with_codes() {
        let cases = [
            r#"{"v":3,"op":"search","query":[1]}"#,
            r#"{"op":"frobnicate"}"#,
            r#"{"op":"search"}"#,
            r#"{"op":"search","query":"oops"}"#,
            r#"{"v":2,"op":"search","queries":[[1],"oops"]}"#,
            r#"{"v":2,"op":"search","queries":[[1]],"options":{"mode":"bogus"}}"#,
            r#"{"v":2,"op":"search","queries":[[1]],"options":{"early_term_tau":-5}}"#,
            r#"{"v":2,"op":"search","queries":[[1]],"options":{"rerank":-1}}"#,
            r#"{"v":2,"op":"search","queries":[[1]],"k":10.7}"#,
            r#"{"op":"search","queries":[[1],[2]]}"#,
        ];
        for c in cases {
            let j = json::parse(c).unwrap();
            let e = decode_request(&j).expect_err(c);
            assert_eq!(e.code, ApiErrorCode::BadRequest, "{c}");
        }
    }

    #[test]
    fn oversized_batches_are_rejected_at_decode() {
        let req = QueryRequest {
            vectors: vec![vec![0.0]; crate::api::MAX_BATCH_QUERIES + 1],
            k: 1,
            options: QueryOptions::default(),
        };
        let line = reparse(&encode_request_v2(&req));
        let e = decode_request(&line).unwrap_err();
        assert_eq!(e.code, ApiErrorCode::BadRequest);
    }

    #[test]
    fn ops_decode() {
        let cases = [(r#"{"op":"stats"}"#, false), (r#"{"op":"shutdown"}"#, true)];
        for (line, want_shutdown) in cases {
            let j = json::parse(line).unwrap();
            match decode_request(&j).unwrap() {
                WireRequest::Stats => assert!(!want_shutdown),
                WireRequest::Shutdown => assert!(want_shutdown),
                other => panic!("wrong op: {other:?}"),
            }
        }
    }

    #[test]
    fn admin_ops_decode() {
        let j = json::parse(r#"{"v":2,"op":"status"}"#).unwrap();
        assert!(matches!(decode_request(&j).unwrap(), WireRequest::Status));
        let j = json::parse(r#"{"v":2,"op":"metrics"}"#).unwrap();
        assert!(matches!(decode_request(&j).unwrap(), WireRequest::Metrics));
        let j = json::parse(r#"{"v":2,"op":"slowlog"}"#).unwrap();
        assert!(matches!(decode_request(&j).unwrap(), WireRequest::Slowlog));
        // The no-collision argument: observability ops decode on
        // versionless lines too (no v1 client ever sent these names).
        let j = json::parse(r#"{"op":"metrics"}"#).unwrap();
        assert!(matches!(decode_request(&j).unwrap(), WireRequest::Metrics));
        let j = json::parse(r#"{"op":"slowlog"}"#).unwrap();
        assert!(matches!(decode_request(&j).unwrap(), WireRequest::Slowlog));
        let j = json::parse(r#"{"v":2,"op":"reload","path":"/tmp/x.pxa"}"#).unwrap();
        match decode_request(&j).unwrap() {
            WireRequest::Reload {
                path,
                residency,
                cache_mb,
                cache_policy,
                lsh_start,
            } => {
                assert_eq!(path, "/tmp/x.pxa");
                assert_eq!(residency, None, "absent residency keeps the epoch's");
                assert_eq!(cache_mb, None);
                assert_eq!(cache_policy, None);
                assert_eq!(lsh_start, None);
            }
            other => panic!("wrong op: {other:?}"),
        }
        // reload without a path is a bad request, not a panic.
        let j = json::parse(r#"{"v":2,"op":"reload"}"#).unwrap();
        let e = decode_request(&j).unwrap_err();
        assert_eq!(e.code, ApiErrorCode::BadRequest);
        assert!(e.message.contains("path"), "{}", e.message);
        // reload can switch the vector residency of the new epoch.
        let j =
            json::parse(r#"{"v":2,"op":"reload","path":"/tmp/x.pxa","residency":"tiered"}"#)
                .unwrap();
        match decode_request(&j).unwrap() {
            WireRequest::Reload { residency, .. } => {
                assert_eq!(residency, Some(Residency::Tiered));
            }
            other => panic!("wrong op: {other:?}"),
        }
        // ...but only to a known tier.
        let j = json::parse(r#"{"v":2,"op":"reload","path":"/x","residency":"mmap"}"#).unwrap();
        let e = decode_request(&j).unwrap_err();
        assert_eq!(e.code, ApiErrorCode::BadRequest);
        assert!(e.message.contains("residency"), "{}", e.message);
        // The adaptive-cache knobs ride along: residency "cached" plus
        // capacity, policy, and LSH warm-start toggles.
        let j = json::parse(
            r#"{"v":2,"op":"reload","path":"/x","residency":"cached",
                "cache_mb":64,"cache_policy":"clock","lsh_start":true}"#,
        )
        .unwrap();
        match decode_request(&j).unwrap() {
            WireRequest::Reload {
                residency,
                cache_mb,
                cache_policy,
                lsh_start,
                ..
            } => {
                assert!(matches!(residency, Some(Residency::Cached { .. })));
                assert_eq!(cache_mb, Some(64));
                assert_eq!(cache_policy, Some(CachePolicy::Clock));
                assert_eq!(lsh_start, Some(true));
            }
            other => panic!("wrong op: {other:?}"),
        }
        // Malformed cache knobs are typed rejections.
        for bad in [
            r#"{"v":2,"op":"reload","path":"/x","cache_mb":-1}"#,
            r#"{"v":2,"op":"reload","path":"/x","cache_policy":"lru"}"#,
            r#"{"v":2,"op":"reload","path":"/x","lsh_start":"yes"}"#,
        ] {
            let j = json::parse(bad).unwrap();
            assert_eq!(
                decode_request(&j).unwrap_err().code,
                ApiErrorCode::BadRequest,
                "{bad}"
            );
        }
    }

    #[test]
    fn storage_status_block_is_forward_compatible() {
        // New-server block: cache sub-fields present plus a key this
        // client version has never heard of — both must decode cleanly.
        let j = json::parse(
            r#"{"residency":"cached","resident_bytes":4096,"n_hot":0,
                "cold_reads":17,"cold_bytes":1088,
                "cache_policy":"s3fifo","cache_capacity_bytes":4096,
                "cache_hit_rate":0.75,"cache_evictions":3,"cache_ghost_hits":2,
                "some_future_key":{"nested":true}}"#,
        )
        .unwrap();
        let s = decode_storage_status(&j);
        assert_eq!(s.residency, "cached");
        assert_eq!(s.cold_reads, 17);
        let c = s.cache.expect("cache block present");
        assert_eq!(c.policy, "s3fifo");
        assert_eq!(c.capacity_bytes, 4096);
        assert!((c.hit_rate - 0.75).abs() < 1e-12);
        assert_eq!(c.evictions, 3);
        assert_eq!(c.ghost_hits, 2);

        // Old-server block (predates the cache keys entirely): absent
        // keys default instead of erroring.
        let j = json::parse(
            r#"{"residency":"tiered","resident_bytes":128,"n_hot":2,
                "cold_reads":0,"cold_bytes":0}"#,
        )
        .unwrap();
        let s = decode_storage_status(&j);
        assert_eq!(s.residency, "tiered");
        assert_eq!(s.n_hot, 2);
        assert_eq!(s.cache, None, "no cache keys → no cache block");

        // Degenerate/empty block still yields a usable default.
        let s = decode_storage_status(&json::parse("{}").unwrap());
        assert_eq!(s, StorageStatus::default());
    }

    #[test]
    fn write_plane_ops_roundtrip() {
        // insert: encoder → decoder carries the vector bit-exactly.
        let line = reparse(&encode_insert(&[0.5, -2.25, 7.0]));
        match decode_request(&line).unwrap() {
            WireRequest::Insert { vector } => assert_eq!(vector, vec![0.5, -2.25, 7.0]),
            other => panic!("wrong op: {other:?}"),
        }
        // delete carries the id through the strict integer decode.
        let line = reparse(&encode_delete(4_000_000_000));
        match decode_request(&line).unwrap() {
            WireRequest::Delete { id } => assert_eq!(id, 4_000_000_000),
            other => panic!("wrong op: {other:?}"),
        }
        // flush: with and without an explicit path.
        let line = reparse(&encode_flush(Some("/tmp/x.pxa")));
        match decode_request(&line).unwrap() {
            WireRequest::Flush { path } => assert_eq!(path.as_deref(), Some("/tmp/x.pxa")),
            other => panic!("wrong op: {other:?}"),
        }
        let line = reparse(&encode_flush(None));
        match decode_request(&line).unwrap() {
            WireRequest::Flush { path } => assert_eq!(path, None),
            other => panic!("wrong op: {other:?}"),
        }
        // Malformed write-plane lines are typed rejections.
        for bad in [
            r#"{"v":2,"op":"insert"}"#,
            r#"{"v":2,"op":"insert","vector":"oops"}"#,
            r#"{"v":2,"op":"insert","vector":[1,"x"]}"#,
            r#"{"v":2,"op":"delete"}"#,
            r#"{"v":2,"op":"delete","id":-3}"#,
            r#"{"v":2,"op":"delete","id":2.5}"#,
            r#"{"v":2,"op":"flush","path":7}"#,
        ] {
            let j = json::parse(bad).unwrap();
            let e = decode_request(&j).expect_err(bad);
            assert_eq!(e.code, ApiErrorCode::BadRequest, "{bad}");
        }
    }

    #[test]
    fn spec_roundtrips_over_the_wire() {
        let spec = IndexSpec {
            dataset: "sift-s".into(),
            metric: Metric::Angular,
            dim: 100,
            n_base: 123_456,
            graph_r: 32,
            graph_build_l: 64,
            graph_alpha: 1.2,
            pq_m: 25,
            pq_c: 256,
            hot_frac: 0.03,
            build_seed: 0x5EED_0002,
        };
        let line = reparse(&encode_spec(&spec));
        let back = decode_spec(&line).unwrap();
        assert_eq!(back, spec);
        // A spec with a bogus metric is rejected with a typed error.
        let j = json::parse(r#"{"dataset":"x","metric":"manhattan","dim":4}"#).unwrap();
        assert_eq!(decode_spec(&j).unwrap_err().code, ApiErrorCode::BadRequest);
        // Integer fields get the wire's strict decode: negatives and
        // fractions are BadRequest, not saturating casts.
        for bad in [
            r#"{"dataset":"x","metric":"l2","dim":-3}"#,
            r#"{"dataset":"x","metric":"l2","dim":4,"n_base":2.5}"#,
            r#"{"dataset":"x","metric":"l2","dim":4,"n_base":1e300}"#,
        ] {
            let j = json::parse(bad).unwrap();
            assert_eq!(decode_spec(&j).unwrap_err().code, ApiErrorCode::BadRequest, "{bad}");
        }
    }

    #[test]
    fn spec_hot_frac_roundtrips_at_full_f64_precision_and_rejects_garbage() {
        // Awkward fractions (not exactly representable, shortest-print
        // dependent) must survive encode → print → parse → decode with
        // their exact bit pattern: the tiered open sizes its DRAM hot
        // set from this value.
        let mut spec = IndexSpec {
            dataset: "hf".into(),
            metric: Metric::L2,
            dim: 4,
            n_base: 100,
            graph_r: 4,
            graph_build_l: 8,
            graph_alpha: 1.2,
            pq_m: 2,
            pq_c: 4,
            hot_frac: 0.0,
            build_seed: 1,
        };
        for hf in [0.1 + 0.2, 0.03, 1.0 / 3.0, 5e-324_f64, 1.0, 0.0] {
            spec.hot_frac = hf;
            let line = reparse(&encode_spec(&spec));
            let back = decode_spec(&line).unwrap();
            assert_eq!(
                back.hot_frac.to_bits(),
                hf.to_bits(),
                "hot_frac {hf} lost precision over the wire"
            );
        }
        // NaN / negative / super-unit hot_frac is a typed rejection —
        // construct the Json directly (NaN can't round-trip RFC 8259).
        for bad in [f64::NAN, -0.25, 1.5, f64::INFINITY] {
            spec.hot_frac = 0.0;
            let mut j = encode_spec(&spec);
            if let Json::Obj(kvs) = &mut j {
                for (k, v) in kvs.iter_mut() {
                    if k == "hot_frac" {
                        *v = Json::Num(bad);
                    }
                }
            }
            let e = decode_spec(&j).unwrap_err();
            assert_eq!(e.code, ApiErrorCode::BadRequest, "hot_frac {bad}");
            assert!(e.message.contains("hot_frac"), "{}", e.message);
        }
    }

    #[test]
    fn v2_response_roundtrip_with_stats() {
        let resp = QueryResponse {
            results: vec![
                NeighborList {
                    ids: vec![5, 9],
                    dists: vec![0.5, 1.25],
                },
                NeighborList {
                    ids: vec![1],
                    dists: vec![2.0],
                },
            ],
            stats: Some(SearchStats {
                pq_dists: 100,
                exact_dists: 10,
                hops: 7,
                sorts: 7,
                bytes_index: 1000,
                bytes_pq: 800,
                bytes_raw: 640,
                et_iterations: 2,
                early_terminated: true,
                adt_builds: 2,
                queue_wait_us: 57,
                cold_reads: 4,
                cold_bytes: 2048,
                cache_hits: 9,
                cache_misses: 4,
                lsh_probes: 6,
            }),
            errors: Vec::new(),
            server_latency_us: 321,
        };
        let line = reparse(&encode_response_v2(&resp));
        let back = decode_response_v2(&line).unwrap();
        assert_eq!(back.results, resp.results);
        assert!(back.errors.is_empty(), "all-ok batches keep the compact shape");
        assert_eq!(back.server_latency_us, 321);
        let s = back.stats.unwrap();
        assert_eq!(s.pq_dists, 100);
        assert_eq!(s.bytes_raw, 640);
        assert!(s.early_terminated);
        assert_eq!(s.adt_builds, 2, "staged-ADT build count must cross the wire");
        assert_eq!(s.queue_wait_us, 57, "queue-wait must cross the wire");
        assert_eq!(s.cold_reads, 4, "cold-tier reads must cross the wire");
        assert_eq!(s.cold_bytes, 2048, "cold-tier bytes must cross the wire");
        assert_eq!(s.cache_hits, 9, "row-cache hits must cross the wire");
        assert_eq!(s.cache_misses, 4, "row-cache misses must cross the wire");
        assert_eq!(s.lsh_probes, 6, "LSH probes must cross the wire");
    }

    #[test]
    fn per_query_errors_ride_in_their_result_slot() {
        let resp = QueryResponse {
            results: vec![
                NeighborList {
                    ids: vec![4],
                    dists: vec![0.25],
                },
                NeighborList::default(),
                NeighborList {
                    ids: vec![9],
                    dists: vec![1.5],
                },
            ],
            errors: vec![
                None,
                Some(ApiError::internal("search worker panicked on query 1")),
                None,
            ],
            stats: None,
            server_latency_us: 11,
        };
        let line = reparse(&encode_response_v2(&resp));
        // The response line as a whole is NOT an error line.
        assert!(decode_error(&line).is_none());
        let back = decode_response_v2(&line).unwrap();
        assert_eq!(back.results.len(), 3);
        assert!(back.has_errors());
        assert_eq!(back.error_for(0), None);
        let e = back.error_for(1).expect("query 1 failed");
        assert_eq!(e.code, ApiErrorCode::Internal);
        assert!(e.message.contains("panicked"));
        assert!(back.results[1].ids.is_empty());
        assert_eq!(back.results[2].ids, vec![9], "batch-mates are unaffected");
    }

    #[test]
    fn corrupt_response_lines_are_rejected_not_mispaired() {
        // Non-numeric id: must error, not silently drop (which would
        // mispair ids with dists).
        let j = json::parse(r#"{"results":[{"ids":[1,"x",3],"dists":[0.1,0.2,0.3]}]}"#).unwrap();
        assert!(decode_response_v2(&j).is_err());
        // Length mismatch between ids and dists.
        let j = json::parse(r#"{"results":[{"ids":[1,2],"dists":[0.1]}]}"#).unwrap();
        assert!(decode_response_v2(&j).is_err());
        // Missing dists entirely.
        let j = json::parse(r#"{"results":[{"ids":[1,2]}]}"#).unwrap();
        assert!(decode_response_v2(&j).is_err());
    }

    #[test]
    fn error_roundtrip_and_legacy_string() {
        let e = ApiError::dim_mismatch("expected 16, got 3");
        let line = reparse(&encode_error(&e));
        assert_eq!(decode_error(&line), Some(e));
        let legacy = json::parse(r#"{"error":"batcher closed"}"#).unwrap();
        let got = decode_error(&legacy).unwrap();
        assert_eq!(got.code, ApiErrorCode::Internal);
        assert_eq!(got.message, "batcher closed");
        let ok = json::parse(r#"{"ids":[1]}"#).unwrap();
        assert_eq!(decode_error(&ok), None);
    }

    #[test]
    fn overloaded_error_roundtrips_and_degrades_gracefully() {
        // The shed error introduced with the binary plane must survive the
        // JSON compat plane too — same typed code on both wires.
        let e = ApiError::overloaded("queue_wait_us 81000 > shed threshold 50000");
        let line = reparse(&encode_error(&e));
        assert_eq!(decode_error(&line), Some(e));
        // Forward compat: an old client parsing a code it does not know
        // degrades to Internal instead of failing the decode.
        let future = json::parse(r#"{"error":{"code":"quota_exceeded","message":"m"}}"#).unwrap();
        assert_eq!(decode_error(&future).unwrap().code, ApiErrorCode::Internal);
    }
}
