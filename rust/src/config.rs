//! Configuration system: layered defaults → config file → CLI overrides.
//!
//! The config file format is a flat `key = value` / `# comment` text file
//! (a TOML subset; the offline image has no `toml` crate). Keys use dotted
//! sections, e.g. `search.beta = 1.06`, `nand.n_bl = 36864`. Every
//! experiment binary resolves its parameters through [`Config`] so runs are
//! reproducible from a single file + command line.

use crate::util::cli::Args;
use std::collections::BTreeMap;
use std::path::Path;

/// Flat key/value config store with typed getters.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse `key = value` lines; `#` and `;` start comments; section
    /// headers `[sec]` prefix following keys with `sec.`.
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split(&['#', ';'][..]).next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(sec) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = sec.trim().to_string();
                continue;
            }
            let Some(eq) = line.find('=') else {
                return Err(format!("line {}: expected 'key = value'", lineno + 1));
            };
            let key = line[..eq].trim();
            let val = line[eq + 1..].trim().trim_matches('"');
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            values.insert(full, val.to_string());
        }
        Ok(Config { values })
    }

    pub fn load(path: &Path) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        Self::parse(&text)
    }

    /// Overlay CLI options: `--set key=value` entries and direct `--key
    /// value` options (dots allowed in key names).
    pub fn overlay_args(&mut self, args: &Args) {
        for (k, v) in &args.options {
            if k == "set" {
                if let Some(eq) = v.find('=') {
                    self.values
                        .insert(v[..eq].to_string(), v[eq + 1..].to_string());
                }
            } else {
                self.values.insert(k.clone(), v.clone());
            }
        }
    }

    pub fn set(&mut self, key: &str, val: impl ToString) {
        self.values.insert(key.to_string(), val.to_string());
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.typed(key, default)
    }
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.typed(key, default)
    }
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.typed(key, default)
    }
    pub fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.typed(key, default)
    }
    /// Optional integer: `None` when the key is absent (used for
    /// tri-state settings like the `api.*` per-request option defaults,
    /// where "unset" must stay distinguishable from any value).
    pub fn get_opt_usize(&self, key: &str) -> Option<usize> {
        self.get_str(key).map(|s| {
            s.parse::<usize>()
                .unwrap_or_else(|_| panic!("config {key}: cannot parse '{s}'"))
        })
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        match self.get_str(key) {
            Some("true" | "1" | "yes" | "on") => true,
            Some("false" | "0" | "no" | "off") => false,
            Some(other) => panic!("config {key}: expected bool, got '{other}'"),
            None => default,
        }
    }

    fn typed<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.get_str(key) {
            None => default,
            Some(s) => s
                .parse::<T>()
                .unwrap_or_else(|_| panic!("config {key}: cannot parse '{s}'")),
        }
    }

    /// Dump as a config-file string (stable order).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.values {
            out.push_str(&format!("{k} = {v}\n"));
        }
        out
    }
}

/// Search algorithm parameters (paper §III + §V-A defaults).
/// Plain scalars, so `Copy` — the per-query hot path duplicates it with
/// no allocation.
#[derive(Clone, Copy, Debug)]
pub struct SearchParams {
    /// Candidate list capacity L.
    pub l: usize,
    /// Result count k.
    pub k: usize,
    /// PQ error ratio β (§III-C; paper default 1.06 for SIFT).
    pub beta: f32,
    /// Early-termination repetition rate r (§III-D; paper sweeps 1..15).
    pub repetition: usize,
    /// Dynamic-list step T_step (§III-D; paper default 4).
    pub t_step: usize,
    /// Initial working list size T_0.
    pub t_init: usize,
}

impl Default for SearchParams {
    fn default() -> Self {
        SearchParams {
            l: 150,
            k: 10,
            beta: 1.06,
            repetition: 3,
            t_step: 4,
            t_init: 16,
        }
    }
}

impl SearchParams {
    pub fn from_config(cfg: &Config) -> SearchParams {
        let d = SearchParams::default();
        SearchParams {
            l: cfg.get_usize("search.l", d.l),
            k: cfg.get_usize("search.k", d.k),
            beta: cfg.get_f32("search.beta", d.beta),
            repetition: cfg.get_usize("search.repetition", d.repetition),
            t_step: cfg.get_usize("search.t_step", d.t_step),
            t_init: cfg.get_usize("search.t_init", d.t_init),
        }
    }
}

/// PQ parameters (paper §V-A: M=32, C=256; we derive M from D when the
/// dimension is not divisible by 32 — see DESIGN.md).
#[derive(Clone, Debug)]
pub struct PqParams {
    pub m: usize,
    pub c: usize,
    pub train_sample: usize,
    pub kmeans_iters: usize,
}

impl PqParams {
    /// Paper-style default for a given dimension: dsub = 4 → M = D/4,
    /// matching the 32-subvector split at D=128.
    pub fn for_dim(dim: usize) -> PqParams {
        let dsub = [4usize, 2, 5, 3, 1]
            .into_iter()
            .find(|d| dim % d == 0)
            .unwrap_or(1);
        PqParams {
            m: dim / dsub,
            c: 256,
            train_sample: 20_000,
            kmeans_iters: 12,
        }
    }

    pub fn from_config(cfg: &Config, dim: usize) -> PqParams {
        let d = PqParams::for_dim(dim);
        PqParams {
            m: cfg.get_usize("pq.m", d.m),
            c: cfg.get_usize("pq.c", d.c),
            train_sample: cfg.get_usize("pq.train_sample", d.train_sample),
            kmeans_iters: cfg.get_usize("pq.kmeans_iters", d.kmeans_iters),
        }
    }
}

/// Graph-building parameters (paper §V-A: R=64, L=150 DiskANN / 500 HNSW).
#[derive(Clone, Debug)]
pub struct GraphParams {
    pub r: usize,
    pub build_l: usize,
    pub alpha: f32,
    pub seed: u64,
}

impl Default for GraphParams {
    fn default() -> Self {
        GraphParams {
            r: 32,
            build_l: 64,
            alpha: 1.2,
            seed: 42,
        }
    }
}

impl GraphParams {
    pub fn from_config(cfg: &Config) -> GraphParams {
        let d = GraphParams::default();
        GraphParams {
            r: cfg.get_usize("graph.r", d.r),
            build_l: cfg.get_usize("graph.build_l", d.build_l),
            alpha: cfg.get_f32("graph.alpha", d.alpha),
            seed: cfg.get_u64("graph.seed", d.seed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_comments() {
        let cfg = Config::parse(
            "# comment\nscale = 0.5\n[search]\nl = 200\nbeta = 1.08 ; inline\n[nand]\nn_bl = 36864\n",
        )
        .unwrap();
        assert_eq!(cfg.get_f64("scale", 1.0), 0.5);
        assert_eq!(cfg.get_usize("search.l", 0), 200);
        assert_eq!(cfg.get_f32("search.beta", 0.0), 1.08);
        assert_eq!(cfg.get_usize("nand.n_bl", 0), 36864);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("novalue\n").is_err());
        assert!(Config::parse("= 3\n").is_err());
    }

    #[test]
    fn cli_overlay_wins() {
        let mut cfg = Config::parse("search.l = 100\n").unwrap();
        let args = crate::util::cli::Args::parse(
            vec!["--set".to_string(), "search.l=250".to_string()],
            false,
        );
        cfg.overlay_args(&args);
        assert_eq!(cfg.get_usize("search.l", 0), 250);
    }

    #[test]
    fn search_params_defaults_match_paper() {
        let p = SearchParams::default();
        assert_eq!(p.beta, 1.06);
        assert_eq!(p.t_step, 4);
    }

    #[test]
    fn pq_params_dsub() {
        assert_eq!(PqParams::for_dim(128).m, 32);
        assert_eq!(PqParams::for_dim(96).m, 24);
        assert_eq!(PqParams::for_dim(100).m, 25);
    }

    #[test]
    fn opt_usize_distinguishes_absent_from_set() {
        let cfg = Config::parse("api.l_override = 200\n").unwrap();
        assert_eq!(cfg.get_opt_usize("api.l_override"), Some(200));
        assert_eq!(cfg.get_opt_usize("api.rerank"), None);
    }

    #[test]
    fn dump_roundtrip() {
        let mut cfg = Config::new();
        cfg.set("a.b", 3);
        cfg.set("c", "x");
        let re = Config::parse(&cfg.dump()).unwrap();
        assert_eq!(re.get_usize("a.b", 0), 3);
        assert_eq!(re.get_str("c"), Some("x"));
    }
}
