//! Load generators:
//!
//! * [`run`] — open-loop: Poisson arrivals at a target QPS against an
//!   in-process [`SearchService`], measuring the latency distribution
//!   under load.
//! * [`run_rpc`] — closed-loop over the WIRE: N client connections each
//!   driving the v2 batch RPC ([`Client::search_batch`]), so throughput
//!   numbers reflect amortized round-trips (B queries per line turn)
//!   instead of one-query-per-round-trip chatter.
//! * [`run_mixed`] — mixed read/write churn against the online write
//!   plane (`SearchService::{insert, delete}` interleaved with
//!   searches), reporting **recall over time**: recall@k is re-measured
//!   against the exact LIVE ground truth
//!   ([`SearchService::exact_nn_live`]) at checkpoints through the
//!   churn, so index-quality decay under mutation is a first-class
//!   load-test output, not just latency.
//! * [`run_open`] — open-loop over the WIRE: Poisson arrivals pushed
//!   down one pipelined binary-plane connection to a
//!   [`crate::net::NetServer`], send and receive halves on separate
//!   threads, so offered load does NOT back off when the server slows
//!   down (the closed-loop fallacy). Reports completion/shed split and
//!   BOTH latency views — completed-only and all-outcome (see
//!   [`OpenLoadReport`]); [`sweep_open`] + [`knee`] locate the
//!   saturation knee across offered rates.
//!
//! All percentile reporting runs through the shared log-linear
//! [`Histogram`] (`crate::obs`) — the same distribution machinery the
//! serving stack exposes on its metrics plane — so loadgen threads
//! record lock-free into one histogram instead of collecting per-thread
//! latency vectors. Reported percentiles are bucket upper bounds
//! (relative error ≤ 6.25%).

use super::server::Client;
use super::SearchService;
use crate::api::QueryOptions;
use crate::obs::Histogram;
use crate::util::rng::Xoshiro256pp;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Result of one load-generation run. Percentiles are log-linear
/// histogram bucket upper bounds in µs ([`Histogram::percentile`]).
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub offered_qps: f64,
    pub achieved_qps: f64,
    pub completed: usize,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    /// Requests whose start fell behind schedule by > 10 ms (overload).
    pub late_starts: usize,
}

/// Drive `service` at `target_qps` for `duration` with `workers` threads.
/// Queries cycle through `queries` (row-major, dim = service dim).
pub fn run(
    service: Arc<SearchService>,
    queries: &crate::dataset::VectorSet,
    k: usize,
    target_qps: f64,
    duration: Duration,
    workers: usize,
    seed: u64,
) -> LoadReport {
    // Pre-draw the Poisson schedule.
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut schedule: Vec<f64> = Vec::new(); // seconds from start
    let mut t = 0.0f64;
    while t < duration.as_secs_f64() {
        let gap = -rng.next_f64().max(1e-12).ln() / target_qps;
        t += gap;
        schedule.push(t);
    }
    let n = schedule.len();
    let next = AtomicUsize::new(0);
    let late = AtomicUsize::new(0);
    // One shared atomic histogram instead of per-thread latency vectors:
    // workers record lock-free, and the percentiles come from the same
    // log-linear machinery the serving metrics plane exposes.
    let hist = Histogram::new();
    let start = Instant::now();

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..workers.max(1) {
            let svc = service.clone();
            let next = &next;
            let late = &late;
            let schedule = &schedule;
            let hist = &hist;
            handles.push(scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let due = Duration::from_secs_f64(schedule[i]);
                let now = start.elapsed();
                if now < due {
                    std::thread::sleep(due - now);
                } else if now - due > Duration::from_millis(10) {
                    late.fetch_add(1, Ordering::Relaxed);
                }
                let qi = i % queries.len();
                let t0 = Instant::now();
                let _ = svc.search(queries.row(qi), k);
                hist.record(t0.elapsed().as_micros() as u64);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });
    let wall = start.elapsed().as_secs_f64();
    let completed = hist.count() as usize;
    LoadReport {
        offered_qps: target_qps,
        achieved_qps: completed as f64 / wall,
        completed,
        p50_us: hist.percentile(50.0) as f64,
        p95_us: hist.percentile(95.0) as f64,
        p99_us: hist.percentile(99.0) as f64,
        late_starts: late.load(Ordering::Relaxed),
    }
}

/// Result of one closed-loop batch-RPC run ([`run_rpc`]).
#[derive(Debug, Clone)]
pub struct RpcLoadReport {
    /// Wire round-trips completed (each carrying `batch` queries).
    pub round_trips: usize,
    /// Queries answered (`round_trips * batch`).
    pub queries: usize,
    /// Query throughput: queries / wall seconds.
    pub qps: f64,
    /// Per-ROUND-TRIP latency percentiles in µs, histogram bucket upper
    /// bounds (a round-trip amortizes `batch` queries; divide by the
    /// batch size for per-query cost).
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
}

/// Drive a running server's v2 batch RPC closed-loop: `clients`
/// connections each issue `requests_per_client` round-trips of `batch`
/// queries (cycling through `queries`) under the given per-request
/// `options`. Returns per-round-trip latencies and per-query QPS.
pub fn run_rpc(
    addr: std::net::SocketAddr,
    queries: &crate::dataset::VectorSet,
    k: usize,
    options: QueryOptions,
    batch: usize,
    clients: usize,
    requests_per_client: usize,
) -> crate::util::error::Result<RpcLoadReport> {
    let batch = batch.max(1);
    let clients = clients.max(1);
    if queries.is_empty() {
        crate::bail!("run_rpc requires a non-empty query set");
    }
    // Connect every client BEFORE starting the clock, so the reported
    // throughput covers only the measured round-trips (not TCP connect
    // or thread-spawn time — significant for short runs).
    let mut conns = Vec::with_capacity(clients);
    for _ in 0..clients {
        conns.push(Client::connect(addr)?);
    }
    let hist = Histogram::new();
    let start = Instant::now();
    let results: Vec<crate::util::error::Result<()>> = std::thread::scope(|scope| {
        let hist = &hist;
        let handles: Vec<_> = conns
            .into_iter()
            .enumerate()
            .map(|(c, mut client)| {
                scope.spawn(move || {
                    for r in 0..requests_per_client {
                        let base = (c * requests_per_client + r) * batch;
                        let refs: Vec<&[f32]> = (0..batch)
                            .map(|i| queries.row((base + i) % queries.len()))
                            .collect();
                        let t0 = Instant::now();
                        let resp = client.search_batch(&refs, k, &options)?;
                        if resp.results.len() != batch {
                            crate::bail!(
                                "batch RPC returned {} results for {batch} queries",
                                resp.results.len()
                            );
                        }
                        hist.record(t0.elapsed().as_micros() as u64);
                    }
                    Ok(())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall = start.elapsed().as_secs_f64();
    for r in results {
        r?;
    }
    let round_trips = hist.count() as usize;
    Ok(RpcLoadReport {
        round_trips,
        queries: round_trips * batch,
        qps: (round_trips * batch) as f64 / wall,
        p50_us: hist.percentile(50.0) as f64,
        p95_us: hist.percentile(95.0) as f64,
        p99_us: hist.percentile(99.0) as f64,
    })
}

/// Result of one mixed read/write churn run ([`run_mixed`]).
#[derive(Debug, Clone)]
pub struct MixedLoadReport {
    /// Searches issued (across all checkpoints).
    pub queries: usize,
    /// Write ops that succeeded.
    pub inserts: usize,
    pub deletes: usize,
    /// Mean recall@k against the exact live ground truth: entry 0 is
    /// measured before any churn, then one entry per checkpoint. A
    /// healthy write plane keeps this flat; a decaying one trends down.
    pub recall_timeline: Vec<f64>,
    /// Query latency percentiles (µs, histogram bucket upper bounds)
    /// over the whole run.
    pub p50_us: f64,
    pub p95_us: f64,
}

/// Churn `writes` insert+delete pairs through `service`'s write plane,
/// interleaved with searches: each step inserts one synthetic vector
/// (seeded, reproducible) and tombstones one random base id, and at
/// `checkpoints` evenly spaced points the full query sample is searched
/// and scored against [`SearchService::exact_nn_live`] — ground truth
/// that tracks the live id set, so the score isolates GRAPH-quality
/// decay from membership drift. Runs in the calling thread: the
/// concurrency contract is pinned by `tests/online_stress.rs`; this
/// measures quality-over-churn deterministically.
pub fn run_mixed(
    service: &SearchService,
    queries: &crate::dataset::VectorSet,
    k: usize,
    writes: usize,
    checkpoints: usize,
    seed: u64,
) -> MixedLoadReport {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let dim = service.dim();
    let n0 = service.n_base().max(1) as u64;
    let sample = queries.len().min(16).max(1);
    let mut inserts = 0usize;
    let mut deletes = 0usize;
    let mut nq = 0usize;
    let hist = Histogram::new();
    let mut recall_timeline: Vec<f64> = Vec::new();

    let measure = |hist: &Histogram, nq: &mut usize| -> f64 {
        let mut r = 0.0;
        for qi in 0..sample {
            let q = queries.row(qi);
            let gt = service.exact_nn_live(q, k);
            let t0 = Instant::now();
            let out = service.search(q, k);
            hist.record(t0.elapsed().as_micros() as u64);
            *nq += 1;
            r += crate::dataset::recall_at_k(&out.ids, &gt, k);
        }
        r / sample as f64
    };

    recall_timeline.push(measure(&hist, &mut nq)); // pre-churn baseline
    let per_cp = writes.max(1).div_ceil(checkpoints.max(1));
    for w in 0..writes {
        let v: Vec<f32> = (0..dim).map(|_| rng.next_f64() as f32).collect();
        if service.insert(&v).is_ok() {
            inserts += 1;
        }
        // Random victim in the ORIGINAL base id space; an already-
        // tombstoned pick is an idempotent no-op (deleted=false).
        let victim = (rng.next_u64() % n0) as u32;
        if matches!(service.delete(victim), Ok((true, _))) {
            deletes += 1;
        }
        if (w + 1) % per_cp == 0 || w + 1 == writes {
            recall_timeline.push(measure(&hist, &mut nq));
        }
    }
    MixedLoadReport {
        queries: nq,
        inserts,
        deletes,
        recall_timeline,
        p50_us: hist.percentile(50.0) as f64,
        p95_us: hist.percentile(95.0) as f64,
    }
}

/// Result of one open-loop wire run ([`run_open`]).
#[derive(Debug, Clone)]
pub struct OpenLoadReport {
    pub offered_qps: f64,
    /// Requests written to the socket.
    pub sent: usize,
    /// Requests answered with a result set.
    pub completed: usize,
    /// Requests the server shed typed (`overloaded`).
    pub shed: usize,
    /// Requests that failed with any OTHER typed error.
    pub errors: usize,
    /// Completed requests / wall seconds (first send → last response).
    pub achieved_qps: f64,
    /// Wire round-trip latency of COMPLETED requests only, µs
    /// (histogram bucket upper bounds). Shed requests answer fast by
    /// design; mixing them in would flatter the tail exactly when the
    /// server is in trouble — so this stays the headline number.
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    /// Wire round-trip latency over ALL outcomes — completed, shed, and
    /// errors alike, µs. Earlier versions reported ONLY the
    /// completed-only view, silently dropping shed/error responses from
    /// the distribution; under overload the two views diverge (fast
    /// shed answers pull these percentiles DOWN while completed-only
    /// climbs), and reporting both makes that divergence visible.
    pub p50_all_us: f64,
    pub p95_all_us: f64,
    pub p99_all_us: f64,
    /// Sends that fell > 10 ms behind the Poisson schedule — the
    /// GENERATOR saturating, so offered load is below nominal.
    pub late_sends: usize,
}

/// Drive a binary-plane server open-loop: Poisson arrivals at
/// `target_qps` for `duration`, all pushed down ONE pipelined
/// connection (requests don't wait for responses — a sender thread
/// writes on schedule while a reader thread drains responses from a
/// [`TcpStream::try_clone`]'d handle and matches them by request id).
/// Queries cycle through `queries`; requests carry no deadline, so
/// shedding reflects the server's queue-wait policy alone.
pub fn run_open(
    addr: std::net::SocketAddr,
    queries: &crate::dataset::VectorSet,
    k: usize,
    target_qps: f64,
    duration: Duration,
    seed: u64,
) -> crate::util::error::Result<OpenLoadReport> {
    use crate::net::frame::{self, FrameBody};
    use std::io::{Read, Write};

    if queries.is_empty() {
        crate::bail!("run_open requires a non-empty query set");
    }
    // Pre-draw the Poisson schedule (ids are 1-based: id = i + 1).
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut schedule: Vec<f64> = Vec::new();
    let mut t = 0.0f64;
    while t < duration.as_secs_f64() {
        let gap = -rng.next_f64().max(1e-12).ln() / target_qps;
        t += gap;
        schedule.push(t);
    }
    let n = schedule.len();
    let send_stream = std::net::TcpStream::connect(addr)?;
    send_stream.set_nodelay(true)?;
    let mut recv_stream = send_stream.try_clone()?;

    let start = Instant::now();
    let (sent_info, recv_info) = std::thread::scope(|scope| {
        let schedule = &schedule;
        let sender = scope.spawn(move || -> crate::util::error::Result<(Vec<Instant>, usize)> {
            let mut stream = send_stream;
            let mut sent_at: Vec<Instant> = Vec::with_capacity(n);
            let mut late = 0usize;
            let mut buf = Vec::new();
            for (i, due_s) in schedule.iter().enumerate() {
                let due = Duration::from_secs_f64(*due_s);
                let now = start.elapsed();
                if now < due {
                    std::thread::sleep(due - now);
                } else if now - due > Duration::from_millis(10) {
                    late += 1;
                }
                let req = crate::api::QueryRequest::single(queries.row(i % queries.len()), k);
                buf.clear();
                frame::encode_query(&mut buf, (i + 1) as u64, &req, 0);
                stream.write_all(&buf)?;
                sent_at.push(Instant::now());
            }
            Ok((sent_at, late))
        });
        let reader = scope.spawn(move || -> crate::util::error::Result<Vec<(u64, Instant, bool, bool)>> {
            // (id, received_at, completed, shed) per response.
            let mut out = Vec::with_capacity(n);
            let mut inbuf: Vec<u8> = Vec::new();
            let mut chunk = [0u8; 16 * 1024];
            while out.len() < n {
                while inbuf.len() >= frame::HEADER_LEN {
                    let payload_len = match frame::parse_header(&inbuf[..frame::HEADER_LEN]) {
                        Ok(len) => len,
                        Err(e) => crate::bail!("bad response header: {}", e.message),
                    };
                    let total = frame::HEADER_LEN + payload_len;
                    if inbuf.len() < total {
                        break;
                    }
                    let (id, outcome) = match frame::decode_payload(&inbuf[frame::HEADER_LEN..total])
                    {
                        Ok(f) => frame::response_outcome(f),
                        Err((id, e)) => crate::bail!("bad response payload (id {id}): {}", e.message),
                    };
                    inbuf.drain(..total);
                    let at = Instant::now();
                    match outcome {
                        Ok(FrameBody::QueryOk { .. }) => out.push((id, at, true, false)),
                        Ok(_) => crate::bail!("non-query response on the query stream (id {id})"),
                        Err(e) => {
                            let shed = e.code == crate::api::ApiErrorCode::Overloaded;
                            out.push((id, at, false, shed));
                        }
                    }
                }
                if out.len() >= n {
                    break;
                }
                let got = recv_stream.read(&mut chunk)?;
                if got == 0 {
                    crate::bail!("server closed mid-run after {} of {n} responses", out.len());
                }
                inbuf.extend_from_slice(&chunk[..got]);
            }
            Ok(out)
        });
        (sender.join().unwrap(), reader.join().unwrap())
    });
    let (sent_at, late_sends) = sent_info?;
    let responses = recv_info?;
    let wall = start.elapsed().as_secs_f64();

    let mut completed = 0usize;
    let mut shed = 0usize;
    let mut errors = 0usize;
    // Two latency views in one pass: completed-only (the headline
    // percentiles) and all-outcome (every response, shed and errors
    // included — what a CLIENT of this connection actually saw).
    let completed_hist = Histogram::new();
    let all_hist = Histogram::new();
    for (id, at, ok, was_shed) in responses {
        let idx = (id as usize).wrapping_sub(1);
        let lat_us = sent_at
            .get(idx)
            .map(|t0| at.duration_since(*t0).as_micros() as u64);
        if let Some(us) = lat_us {
            all_hist.record(us);
        }
        if ok {
            completed += 1;
            if let Some(us) = lat_us {
                completed_hist.record(us);
            }
        } else if was_shed {
            shed += 1;
        } else {
            errors += 1;
        }
    }
    Ok(OpenLoadReport {
        offered_qps: target_qps,
        sent: sent_at.len(),
        completed,
        shed,
        errors,
        achieved_qps: completed as f64 / wall,
        p50_us: completed_hist.percentile(50.0) as f64,
        p95_us: completed_hist.percentile(95.0) as f64,
        p99_us: completed_hist.percentile(99.0) as f64,
        p50_all_us: all_hist.percentile(50.0) as f64,
        p95_all_us: all_hist.percentile(95.0) as f64,
        p99_all_us: all_hist.percentile(99.0) as f64,
        late_sends,
    })
}

/// [`run_open`] across a ladder of offered rates (one fresh connection
/// per rate), for locating the saturation [`knee`].
pub fn sweep_open(
    addr: std::net::SocketAddr,
    queries: &crate::dataset::VectorSet,
    k: usize,
    rates_qps: &[f64],
    duration: Duration,
    seed: u64,
) -> crate::util::error::Result<Vec<OpenLoadReport>> {
    let mut reports = Vec::with_capacity(rates_qps.len());
    for (i, &qps) in rates_qps.iter().enumerate() {
        reports.push(run_open(addr, queries, k, qps, duration, seed + i as u64)?);
    }
    Ok(reports)
}

/// The saturation knee of a [`sweep_open`] ladder: the highest offered
/// rate the server still KEEPS UP with — achieved ≥ 90% of offered and
/// ≤ 1% of requests shed. `None` if it kept up with nothing.
pub fn knee(reports: &[OpenLoadReport]) -> Option<f64> {
    reports
        .iter()
        .filter(|r| {
            r.sent > 0
                && r.achieved_qps >= 0.9 * r.offered_qps
                && (r.shed as f64) <= 0.01 * r.sent as f64
        })
        .map(|r| r.offered_qps)
        .fold(None, |best, q| Some(best.map_or(q, |b: f64| b.max(q))))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GraphParams, PqParams, SearchParams};
    use crate::dataset::synth::tiny_uniform;
    use crate::distance::Metric;

    #[test]
    fn loadgen_completes_schedule_and_measures() {
        let ds = tiny_uniform(300, 8, Metric::L2, 41);
        let svc = Arc::new(SearchService::build(
            &ds,
            &GraphParams {
                r: 8,
                build_l: 16,
                alpha: 1.2,
                seed: 41,
            },
            &PqParams {
                m: 4,
                c: 16,
                train_sample: 300,
                kmeans_iters: 4,
            },
            SearchParams {
                l: 30,
                k: 5,
                ..Default::default()
            },
            false,
        ));
        let report = run(
            svc,
            &ds.queries,
            5,
            200.0,
            Duration::from_millis(300),
            2,
            1,
        );
        assert!(report.completed > 20, "completed {}", report.completed);
        assert!(report.p50_us > 0.0);
        assert!(report.p99_us >= report.p50_us);
        // Light load on a tiny index: should keep up with the schedule.
        assert!(
            report.achieved_qps > report.offered_qps * 0.5,
            "achieved {} of {}",
            report.achieved_qps,
            report.offered_qps
        );
    }

    #[test]
    fn mixed_loadgen_reports_recall_over_time() {
        let ds = tiny_uniform(300, 8, Metric::L2, 47);
        let svc = SearchService::build(
            &ds,
            &GraphParams {
                r: 8,
                build_l: 16,
                alpha: 1.2,
                seed: 47,
            },
            &PqParams {
                m: 4,
                c: 16,
                train_sample: 300,
                kmeans_iters: 4,
            },
            SearchParams {
                l: 40,
                k: 5,
                ..Default::default()
            },
            false,
        );
        // 10% churn in 3 checkpoints.
        let rep = run_mixed(&svc, &ds.queries, 5, 30, 3, 7);
        assert_eq!(rep.inserts, 30);
        assert!(rep.deletes > 0 && rep.deletes <= 30);
        assert_eq!(
            rep.recall_timeline.len(),
            4,
            "baseline + one entry per checkpoint"
        );
        assert!(rep.queries >= 4 * 16);
        assert!(rep.p95_us >= rep.p50_us);
        // Recall is measured against the LIVE ground truth, so churn
        // must not crater it (tombstones stay traversable).
        for (i, r) in rep.recall_timeline.iter().enumerate() {
            assert!(*r > 0.6, "checkpoint {i}: recall {r}");
        }
    }

    #[test]
    fn rpc_loadgen_amortizes_round_trips() {
        let ds = tiny_uniform(200, 8, Metric::L2, 43);
        let svc = Arc::new(SearchService::build(
            &ds,
            &GraphParams {
                r: 8,
                build_l: 16,
                alpha: 1.2,
                seed: 43,
            },
            &PqParams {
                m: 4,
                c: 16,
                train_sample: 200,
                kmeans_iters: 4,
            },
            SearchParams {
                l: 30,
                k: 5,
                ..Default::default()
            },
            false,
        ));
        let cell = Arc::new(crate::coordinator::ServiceCell::new(svc));
        let (handle, _join) =
            crate::coordinator::batcher::spawn(cell.clone(), Default::default());
        let server = crate::coordinator::server::Server::start(cell, handle, 0).unwrap();
        let rep = run_rpc(
            server.addr,
            &ds.queries,
            5,
            QueryOptions::default(),
            4,
            2,
            5,
        )
        .unwrap();
        assert_eq!(rep.round_trips, 10, "2 clients x 5 requests");
        assert_eq!(rep.queries, 40, "each round-trip carries 4 queries");
        assert!(rep.qps > 0.0);
        assert!(rep.p99_us >= rep.p50_us);
        server.stop();
    }

    #[test]
    fn open_loop_loadgen_keeps_up_under_light_load() {
        let ds = tiny_uniform(200, 8, Metric::L2, 45);
        let svc = Arc::new(SearchService::build(
            &ds,
            &GraphParams {
                r: 8,
                build_l: 16,
                alpha: 1.2,
                seed: 45,
            },
            &PqParams {
                m: 4,
                c: 16,
                train_sample: 200,
                kmeans_iters: 4,
            },
            SearchParams {
                l: 30,
                k: 5,
                ..Default::default()
            },
            false,
        ));
        let cell = Arc::new(crate::coordinator::ServiceCell::new(svc));
        let (handle, _join) =
            crate::coordinator::batcher::spawn(cell.clone(), Default::default());
        let server =
            crate::net::NetServer::start(cell, handle, crate::net::NetConfig::default()).unwrap();
        let rep = run_open(
            server.addr,
            &ds.queries,
            5,
            200.0,
            Duration::from_millis(300),
            9,
        )
        .unwrap();
        assert!(rep.sent > 20, "sent {}", rep.sent);
        // Every request gets exactly one response: completion accounting
        // must balance, and a tiny index under 200 qps sheds nothing.
        assert_eq!(rep.completed + rep.shed + rep.errors, rep.sent);
        assert_eq!(rep.shed, 0, "shed under light load");
        assert_eq!(rep.errors, 0, "errors under light load");
        assert!(rep.p99_us >= rep.p50_us);
        // With nothing shed and no errors, the completed-only and
        // all-outcome distributions saw the same samples — the two
        // views must agree exactly.
        assert_eq!(rep.p50_all_us, rep.p50_us);
        assert_eq!(rep.p99_all_us, rep.p99_us);
        assert!(
            rep.achieved_qps > rep.offered_qps * 0.5,
            "achieved {} of {}",
            rep.achieved_qps,
            rep.offered_qps
        );
        // A one-point "sweep" at a rate the server kept up with must
        // place the knee at that rate.
        assert_eq!(knee(&[rep]), Some(200.0));
        server.stop();
    }
}
