//! Load generators:
//!
//! * [`run`] — open-loop: Poisson arrivals at a target QPS against an
//!   in-process [`SearchService`], measuring the latency distribution
//!   under load.
//! * [`run_rpc`] — closed-loop over the WIRE: N client connections each
//!   driving the v2 batch RPC ([`Client::search_batch`]), so throughput
//!   numbers reflect amortized round-trips (B queries per line turn)
//!   instead of one-query-per-round-trip chatter.
//! * [`run_mixed`] — mixed read/write churn against the online write
//!   plane (`SearchService::{insert, delete}` interleaved with
//!   searches), reporting **recall over time**: recall@k is re-measured
//!   against the exact LIVE ground truth
//!   ([`SearchService::exact_nn_live`]) at checkpoints through the
//!   churn, so index-quality decay under mutation is a first-class
//!   load-test output, not just latency.

use super::server::Client;
use super::SearchService;
use crate::api::QueryOptions;
use crate::util::rng::Xoshiro256pp;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Result of one load-generation run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub offered_qps: f64,
    pub achieved_qps: f64,
    pub completed: usize,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    /// Requests whose start fell behind schedule by > 10 ms (overload).
    pub late_starts: usize,
}

/// Drive `service` at `target_qps` for `duration` with `workers` threads.
/// Queries cycle through `queries` (row-major, dim = service dim).
pub fn run(
    service: Arc<SearchService>,
    queries: &crate::dataset::VectorSet,
    k: usize,
    target_qps: f64,
    duration: Duration,
    workers: usize,
    seed: u64,
) -> LoadReport {
    // Pre-draw the Poisson schedule.
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut schedule: Vec<f64> = Vec::new(); // seconds from start
    let mut t = 0.0f64;
    while t < duration.as_secs_f64() {
        let gap = -rng.next_f64().max(1e-12).ln() / target_qps;
        t += gap;
        schedule.push(t);
    }
    let n = schedule.len();
    let next = AtomicUsize::new(0);
    let late = AtomicUsize::new(0);
    let start = Instant::now();

    let lat_chunks: Vec<Vec<f64>> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..workers.max(1) {
            let svc = service.clone();
            let next = &next;
            let late = &late;
            let schedule = &schedule;
            handles.push(scope.spawn(move || {
                let mut lats = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let due = Duration::from_secs_f64(schedule[i]);
                    let now = start.elapsed();
                    if now < due {
                        std::thread::sleep(due - now);
                    } else if now - due > Duration::from_millis(10) {
                        late.fetch_add(1, Ordering::Relaxed);
                    }
                    let qi = i % queries.len();
                    let t0 = Instant::now();
                    let _ = svc.search(queries.row(qi), k);
                    lats.push(t0.elapsed().as_secs_f64() * 1e6);
                }
                lats
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall = start.elapsed().as_secs_f64();
    let lats: Vec<f64> = lat_chunks.into_iter().flatten().collect();
    LoadReport {
        offered_qps: target_qps,
        achieved_qps: lats.len() as f64 / wall,
        completed: lats.len(),
        p50_us: crate::util::percentile(&lats, 50.0),
        p95_us: crate::util::percentile(&lats, 95.0),
        p99_us: crate::util::percentile(&lats, 99.0),
        late_starts: late.load(Ordering::Relaxed),
    }
}

/// Result of one closed-loop batch-RPC run ([`run_rpc`]).
#[derive(Debug, Clone)]
pub struct RpcLoadReport {
    /// Wire round-trips completed (each carrying `batch` queries).
    pub round_trips: usize,
    /// Queries answered (`round_trips * batch`).
    pub queries: usize,
    /// Query throughput: queries / wall seconds.
    pub qps: f64,
    /// Per-ROUND-TRIP latency percentiles in µs (a round-trip amortizes
    /// `batch` queries; divide by the batch size for per-query cost).
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
}

/// Drive a running server's v2 batch RPC closed-loop: `clients`
/// connections each issue `requests_per_client` round-trips of `batch`
/// queries (cycling through `queries`) under the given per-request
/// `options`. Returns per-round-trip latencies and per-query QPS.
pub fn run_rpc(
    addr: std::net::SocketAddr,
    queries: &crate::dataset::VectorSet,
    k: usize,
    options: QueryOptions,
    batch: usize,
    clients: usize,
    requests_per_client: usize,
) -> crate::util::error::Result<RpcLoadReport> {
    let batch = batch.max(1);
    let clients = clients.max(1);
    if queries.is_empty() {
        crate::bail!("run_rpc requires a non-empty query set");
    }
    // Connect every client BEFORE starting the clock, so the reported
    // throughput covers only the measured round-trips (not TCP connect
    // or thread-spawn time — significant for short runs).
    let mut conns = Vec::with_capacity(clients);
    for _ in 0..clients {
        conns.push(Client::connect(addr)?);
    }
    let start = Instant::now();
    let lat_chunks: Vec<crate::util::error::Result<Vec<f64>>> = std::thread::scope(|scope| {
        let handles: Vec<_> = conns
            .into_iter()
            .enumerate()
            .map(|(c, mut client)| {
                scope.spawn(move || {
                    let mut lats = Vec::with_capacity(requests_per_client);
                    for r in 0..requests_per_client {
                        let base = (c * requests_per_client + r) * batch;
                        let refs: Vec<&[f32]> = (0..batch)
                            .map(|i| queries.row((base + i) % queries.len()))
                            .collect();
                        let t0 = Instant::now();
                        let resp = client.search_batch(&refs, k, &options)?;
                        if resp.results.len() != batch {
                            crate::bail!(
                                "batch RPC returned {} results for {batch} queries",
                                resp.results.len()
                            );
                        }
                        lats.push(t0.elapsed().as_secs_f64() * 1e6);
                    }
                    Ok(lats)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall = start.elapsed().as_secs_f64();
    let mut lats = Vec::new();
    for chunk in lat_chunks {
        lats.extend(chunk?);
    }
    let round_trips = lats.len();
    Ok(RpcLoadReport {
        round_trips,
        queries: round_trips * batch,
        qps: (round_trips * batch) as f64 / wall,
        p50_us: crate::util::percentile(&lats, 50.0),
        p95_us: crate::util::percentile(&lats, 95.0),
        p99_us: crate::util::percentile(&lats, 99.0),
    })
}

/// Result of one mixed read/write churn run ([`run_mixed`]).
#[derive(Debug, Clone)]
pub struct MixedLoadReport {
    /// Searches issued (across all checkpoints).
    pub queries: usize,
    /// Write ops that succeeded.
    pub inserts: usize,
    pub deletes: usize,
    /// Mean recall@k against the exact live ground truth: entry 0 is
    /// measured before any churn, then one entry per checkpoint. A
    /// healthy write plane keeps this flat; a decaying one trends down.
    pub recall_timeline: Vec<f64>,
    /// Query latency percentiles (µs) over the whole run.
    pub p50_us: f64,
    pub p95_us: f64,
}

/// Churn `writes` insert+delete pairs through `service`'s write plane,
/// interleaved with searches: each step inserts one synthetic vector
/// (seeded, reproducible) and tombstones one random base id, and at
/// `checkpoints` evenly spaced points the full query sample is searched
/// and scored against [`SearchService::exact_nn_live`] — ground truth
/// that tracks the live id set, so the score isolates GRAPH-quality
/// decay from membership drift. Runs in the calling thread: the
/// concurrency contract is pinned by `tests/online_stress.rs`; this
/// measures quality-over-churn deterministically.
pub fn run_mixed(
    service: &SearchService,
    queries: &crate::dataset::VectorSet,
    k: usize,
    writes: usize,
    checkpoints: usize,
    seed: u64,
) -> MixedLoadReport {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let dim = service.dim();
    let n0 = service.n_base().max(1) as u64;
    let sample = queries.len().min(16).max(1);
    let mut inserts = 0usize;
    let mut deletes = 0usize;
    let mut nq = 0usize;
    let mut lats: Vec<f64> = Vec::new();
    let mut recall_timeline: Vec<f64> = Vec::new();

    let measure = |lats: &mut Vec<f64>, nq: &mut usize| -> f64 {
        let mut r = 0.0;
        for qi in 0..sample {
            let q = queries.row(qi);
            let gt = service.exact_nn_live(q, k);
            let t0 = Instant::now();
            let out = service.search(q, k);
            lats.push(t0.elapsed().as_secs_f64() * 1e6);
            *nq += 1;
            r += crate::dataset::recall_at_k(&out.ids, &gt, k);
        }
        r / sample as f64
    };

    recall_timeline.push(measure(&mut lats, &mut nq)); // pre-churn baseline
    let per_cp = writes.max(1).div_ceil(checkpoints.max(1));
    for w in 0..writes {
        let v: Vec<f32> = (0..dim).map(|_| rng.next_f64() as f32).collect();
        if service.insert(&v).is_ok() {
            inserts += 1;
        }
        // Random victim in the ORIGINAL base id space; an already-
        // tombstoned pick is an idempotent no-op (deleted=false).
        let victim = (rng.next_u64() % n0) as u32;
        if matches!(service.delete(victim), Ok((true, _))) {
            deletes += 1;
        }
        if (w + 1) % per_cp == 0 || w + 1 == writes {
            recall_timeline.push(measure(&mut lats, &mut nq));
        }
    }
    MixedLoadReport {
        queries: nq,
        inserts,
        deletes,
        recall_timeline,
        p50_us: crate::util::percentile(&lats, 50.0),
        p95_us: crate::util::percentile(&lats, 95.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GraphParams, PqParams, SearchParams};
    use crate::dataset::synth::tiny_uniform;
    use crate::distance::Metric;

    #[test]
    fn loadgen_completes_schedule_and_measures() {
        let ds = tiny_uniform(300, 8, Metric::L2, 41);
        let svc = Arc::new(SearchService::build(
            &ds,
            &GraphParams {
                r: 8,
                build_l: 16,
                alpha: 1.2,
                seed: 41,
            },
            &PqParams {
                m: 4,
                c: 16,
                train_sample: 300,
                kmeans_iters: 4,
            },
            SearchParams {
                l: 30,
                k: 5,
                ..Default::default()
            },
            false,
        ));
        let report = run(
            svc,
            &ds.queries,
            5,
            200.0,
            Duration::from_millis(300),
            2,
            1,
        );
        assert!(report.completed > 20, "completed {}", report.completed);
        assert!(report.p50_us > 0.0);
        assert!(report.p99_us >= report.p50_us);
        // Light load on a tiny index: should keep up with the schedule.
        assert!(
            report.achieved_qps > report.offered_qps * 0.5,
            "achieved {} of {}",
            report.achieved_qps,
            report.offered_qps
        );
    }

    #[test]
    fn mixed_loadgen_reports_recall_over_time() {
        let ds = tiny_uniform(300, 8, Metric::L2, 47);
        let svc = SearchService::build(
            &ds,
            &GraphParams {
                r: 8,
                build_l: 16,
                alpha: 1.2,
                seed: 47,
            },
            &PqParams {
                m: 4,
                c: 16,
                train_sample: 300,
                kmeans_iters: 4,
            },
            SearchParams {
                l: 40,
                k: 5,
                ..Default::default()
            },
            false,
        );
        // 10% churn in 3 checkpoints.
        let rep = run_mixed(&svc, &ds.queries, 5, 30, 3, 7);
        assert_eq!(rep.inserts, 30);
        assert!(rep.deletes > 0 && rep.deletes <= 30);
        assert_eq!(
            rep.recall_timeline.len(),
            4,
            "baseline + one entry per checkpoint"
        );
        assert!(rep.queries >= 4 * 16);
        assert!(rep.p95_us >= rep.p50_us);
        // Recall is measured against the LIVE ground truth, so churn
        // must not crater it (tombstones stay traversable).
        for (i, r) in rep.recall_timeline.iter().enumerate() {
            assert!(*r > 0.6, "checkpoint {i}: recall {r}");
        }
    }

    #[test]
    fn rpc_loadgen_amortizes_round_trips() {
        let ds = tiny_uniform(200, 8, Metric::L2, 43);
        let svc = Arc::new(SearchService::build(
            &ds,
            &GraphParams {
                r: 8,
                build_l: 16,
                alpha: 1.2,
                seed: 43,
            },
            &PqParams {
                m: 4,
                c: 16,
                train_sample: 200,
                kmeans_iters: 4,
            },
            SearchParams {
                l: 30,
                k: 5,
                ..Default::default()
            },
            false,
        ));
        let cell = Arc::new(crate::coordinator::ServiceCell::new(svc));
        let (handle, _join) =
            crate::coordinator::batcher::spawn(cell.clone(), Default::default());
        let server = crate::coordinator::server::Server::start(cell, handle, 0).unwrap();
        let rep = run_rpc(
            server.addr,
            &ds.queries,
            5,
            QueryOptions::default(),
            4,
            2,
            5,
        )
        .unwrap();
        assert_eq!(rep.round_trips, 10, "2 clients x 5 requests");
        assert_eq!(rep.queries, 40, "each round-trip carries 4 queries");
        assert!(rep.qps > 0.0);
        assert!(rep.p99_us >= rep.p50_us);
        server.stop();
    }
}
