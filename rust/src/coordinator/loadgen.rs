//! Open-loop load generator: Poisson arrivals at a target QPS against a
//! [`SearchService`], measuring the latency distribution under load — the
//! serving-side complement to the closed-loop clients in the examples.

use super::SearchService;
use crate::util::rng::Xoshiro256pp;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Result of one load-generation run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub offered_qps: f64,
    pub achieved_qps: f64,
    pub completed: usize,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    /// Requests whose start fell behind schedule by > 10 ms (overload).
    pub late_starts: usize,
}

/// Drive `service` at `target_qps` for `duration` with `workers` threads.
/// Queries cycle through `queries` (row-major, dim = service dim).
pub fn run(
    service: Arc<SearchService>,
    queries: &crate::dataset::VectorSet,
    k: usize,
    target_qps: f64,
    duration: Duration,
    workers: usize,
    seed: u64,
) -> LoadReport {
    // Pre-draw the Poisson schedule.
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut schedule: Vec<f64> = Vec::new(); // seconds from start
    let mut t = 0.0f64;
    while t < duration.as_secs_f64() {
        let gap = -rng.next_f64().max(1e-12).ln() / target_qps;
        t += gap;
        schedule.push(t);
    }
    let n = schedule.len();
    let next = AtomicUsize::new(0);
    let late = AtomicUsize::new(0);
    let start = Instant::now();

    let lat_chunks: Vec<Vec<f64>> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..workers.max(1) {
            let svc = service.clone();
            let next = &next;
            let late = &late;
            let schedule = &schedule;
            handles.push(scope.spawn(move || {
                let mut lats = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let due = Duration::from_secs_f64(schedule[i]);
                    let now = start.elapsed();
                    if now < due {
                        std::thread::sleep(due - now);
                    } else if now - due > Duration::from_millis(10) {
                        late.fetch_add(1, Ordering::Relaxed);
                    }
                    let qi = i % queries.len();
                    let t0 = Instant::now();
                    let _ = svc.search(queries.row(qi), k);
                    lats.push(t0.elapsed().as_secs_f64() * 1e6);
                }
                lats
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall = start.elapsed().as_secs_f64();
    let lats: Vec<f64> = lat_chunks.into_iter().flatten().collect();
    LoadReport {
        offered_qps: target_qps,
        achieved_qps: lats.len() as f64 / wall,
        completed: lats.len(),
        p50_us: crate::util::percentile(&lats, 50.0),
        p95_us: crate::util::percentile(&lats, 95.0),
        p99_us: crate::util::percentile(&lats, 99.0),
        late_starts: late.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GraphParams, PqParams, SearchParams};
    use crate::dataset::synth::tiny_uniform;
    use crate::distance::Metric;

    #[test]
    fn loadgen_completes_schedule_and_measures() {
        let ds = tiny_uniform(300, 8, Metric::L2, 41);
        let svc = Arc::new(SearchService::build(
            &ds,
            &GraphParams {
                r: 8,
                build_l: 16,
                alpha: 1.2,
                seed: 41,
            },
            &PqParams {
                m: 4,
                c: 16,
                train_sample: 300,
                kmeans_iters: 4,
            },
            SearchParams {
                l: 30,
                k: 5,
                ..Default::default()
            },
            false,
        ));
        let report = run(
            svc,
            &ds.queries,
            5,
            200.0,
            Duration::from_millis(300),
            2,
            1,
        );
        assert!(report.completed > 20, "completed {}", report.completed);
        assert!(report.p50_us > 0.0);
        assert!(report.p99_us >= report.p50_us);
        // Light load on a tiny index: should keep up with the schedule.
        assert!(
            report.achieved_qps > report.offered_qps * 0.5,
            "achieved {} of {}",
            report.achieved_qps,
            report.offered_qps
        );
    }
}
