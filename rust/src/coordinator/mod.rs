//! L3 coordinator: the serving layer around the Proxima search algorithm.
//!
//! * [`SearchService`] — owns one loaded index (base vectors, graph, PQ,
//!   gap encoding) and answers queries through the typed query API
//!   ([`SearchService::query`] takes a [`QueryRequest`] — N vectors, `k`,
//!   per-request [`QueryOptions`] — and returns a [`QueryResponse`] or a
//!   structured [`ApiError`]); the per-query ADT is built through
//!   the AOT/XLA artifact when a [`Runtime`](crate::runtime::Runtime) is
//!   attached (Python never runs here), with a native fallback. Per-query
//!   scratch (visited set, candidate list, exact cache, ADT table) comes
//!   from an internal [`ScratchPool`], so the steady-state request path is
//!   allocation-free; multi-query requests fan across a fixed pool of
//!   worker threads, one scratch per worker.
//! * [`batcher`] — dynamic batching (size- or deadline-triggered), each
//!   queued request carrying its own [`QueryOptions`], workers holding
//!   pooled scratch for their batch slice.
//! * [`shard`] — partitioned scale-out with parallel fan-out, speaking the
//!   same [`QueryRequest`]/[`QueryResponse`] contract.
//! * [`server`] — a TCP line-protocol front end + client (versioned wire
//!   protocol, multi-query v2 batches + v1 compat), on std threads
//!   (the offline image has no tokio; see DESIGN.md §1).

pub mod batcher;
pub mod loadgen;
pub mod shard;
pub mod server;

use crate::api::{ApiError, QueryOptions, QueryRequest, QueryResponse, SearchMode};
use crate::config::{GraphParams, PqParams, SearchParams};
use crate::dataset::{Dataset, VectorSet};
use crate::distance::Metric;
use crate::gap::GapGraph;
use crate::graph::{vamana, Graph};
use crate::pq::{Adt, PqCodebook, PqCodes};
use crate::runtime::service::RuntimeHandle;
use crate::search::beam::{accurate_beam_search_into, pq_beam_search_into, SearchContext};
use crate::search::kernel::{Pooled, QueryScratch, ScratchPool};
use crate::search::proxima::{proxima_search_into, ProximaFeatures};
use crate::search::{SearchOutput, SearchStats};
use std::sync::atomic::{AtomicU64, Ordering};

/// Aggregated service counters (exported by the `stats` RPC).
#[derive(Debug, Default)]
pub struct ServiceStats {
    pub queries: AtomicU64,
    pub early_terminated: AtomicU64,
    pub pq_dists: AtomicU64,
    pub exact_dists: AtomicU64,
    pub total_latency_us: AtomicU64,
}

/// Per-query scratch a service worker checks out: the walk state plus a
/// reusable ADT table (the two per-query allocations the seed paid).
#[derive(Default)]
pub struct ServiceScratch {
    pub adt: Adt,
    pub walk: QueryScratch,
}

/// One loaded, queryable index.
pub struct SearchService {
    pub name: String,
    pub metric: Metric,
    pub base: VectorSet,
    pub graph: Graph,
    pub codebook: PqCodebook,
    pub codes: PqCodes,
    pub gap: Option<GapGraph>,
    pub params: SearchParams,
    pub features: ProximaFeatures,
    /// AOT runtime service thread; when present the per-query ADT (and
    /// batch APIs) run through the compiled XLA artifacts. The PJRT
    /// handles are pinned to that thread (they are not `Send`).
    pub runtime: Option<RuntimeHandle>,
    pub stats: ServiceStats,
    /// Fixed worker-pool width for [`Self::search_batch`].
    pub workers: usize,
    scratch: ScratchPool<ServiceScratch>,
}

impl SearchService {
    /// Build the full index stack from a dataset (train PQ, build Vamana,
    /// gap-encode). This is the "index build" phase, not the request path.
    pub fn build(
        ds: &Dataset,
        gp: &GraphParams,
        pq: &PqParams,
        params: SearchParams,
        use_xla: bool,
    ) -> SearchService {
        let graph = vamana::build(&ds.base, ds.metric, gp);
        let codebook = PqCodebook::train(
            &ds.base,
            ds.metric,
            pq.m,
            pq.c,
            pq.train_sample,
            pq.kmeans_iters,
            gp.seed ^ 0xC0DE,
        );
        let codes = codebook.encode(&ds.base);
        let gap = Some(GapGraph::encode(&graph.to_lists()));
        let runtime = if use_xla {
            RuntimeHandle::spawn_default(&codebook)
        } else {
            None
        };
        SearchService {
            name: ds.name.clone(),
            metric: ds.metric,
            base: ds.base.clone(),
            graph,
            codebook,
            codes,
            gap,
            params,
            features: ProximaFeatures::default(),
            runtime,
            stats: ServiceStats::default(),
            workers: default_workers(),
            scratch: ScratchPool::new(),
        }
    }

    /// Override the fixed worker-pool width used by [`Self::search_batch`].
    pub fn with_workers(mut self, workers: usize) -> SearchService {
        self.workers = workers.max(1);
        self
    }

    /// Check out per-query scratch (workers hold one for their lifetime).
    pub fn checkout_scratch(&self) -> Pooled<'_, ServiceScratch> {
        self.scratch.checkout()
    }

    fn context(&self) -> SearchContext<'_> {
        SearchContext {
            base: &self.base,
            metric: self.metric,
            graph: &self.graph,
            codes: Some(&self.codes),
            gap: self.gap.as_ref(),
        }
    }

    /// Build the query's ADT — through XLA when attached, else natively.
    pub fn build_adt(&self, q: &[f32]) -> Adt {
        let mut adt = Adt::default();
        self.build_adt_into(q, &mut adt);
        adt
    }

    /// [`Self::build_adt`] into a reusable table (the scratch path).
    pub fn build_adt_into(&self, q: &[f32], adt: &mut Adt) {
        if let Some(rt) = &self.runtime {
            match rt.build_adt(q) {
                Ok(a) => {
                    // Copy into the pooled table rather than replacing it,
                    // so the scratch allocation survives the XLA path too.
                    adt.m = a.m;
                    adt.c = a.c;
                    adt.table.clear();
                    adt.table.extend_from_slice(&a.table);
                    return;
                }
                Err(e) => {
                    // Fall back but surface the problem.
                    eprintln!("[service] XLA ADT failed ({e:#}); using native path");
                }
            }
        }
        self.codebook.build_adt_into(q, adt);
    }

    /// Index dimensionality (the API boundary validates queries against
    /// this).
    pub fn dim(&self) -> usize {
        self.base.dim
    }

    /// Validate a request against this index: non-empty batch, sane `k`
    /// and `l_override`, and every vector's length equal to the index
    /// dimension (a wrong-length vector would otherwise reach
    /// `Metric::distance` and panic or return garbage).
    pub fn validate(&self, req: &QueryRequest) -> Result<(), ApiError> {
        if req.vectors.is_empty() {
            return Err(ApiError::bad_request("empty query batch"));
        }
        if req.vectors.len() > crate::api::MAX_BATCH_QUERIES {
            return Err(ApiError::bad_request(format!(
                "batch of {} exceeds the maximum {} queries per request",
                req.vectors.len(),
                crate::api::MAX_BATCH_QUERIES
            )));
        }
        if req.k == 0 {
            return Err(ApiError::bad_request("k must be >= 1"));
        }
        if let Some(l) = req.options.l_override {
            if l == 0 {
                return Err(ApiError::bad_request("l_override must be >= 1"));
            }
            // The list buffer reserves L slots up front — an unbounded
            // value would let one request demand a huge allocation. The
            // cap is a request-size constant (not the index size) so
            // every shard of a sharded service accepts or rejects a
            // request identically; `effective()` additionally clamps L
            // to the local index size.
            if l > MAX_L_OVERRIDE {
                return Err(ApiError::bad_request(format!(
                    "l_override {l} exceeds the maximum {MAX_L_OVERRIDE}"
                )));
            }
        }
        let dim = self.base.dim;
        for (i, v) in req.vectors.iter().enumerate() {
            if v.len() != dim {
                return Err(ApiError::dim_mismatch(format!(
                    "query {i}: expected dim {dim}, got {}",
                    v.len()
                )));
            }
            // Non-finite values produce NaN distances, which panic the
            // rerank sorts deep in a worker thread — reject them here so
            // a bad request cannot tear down the serving path.
            if let Some(x) = v.iter().find(|x| !x.is_finite()) {
                return Err(ApiError::bad_request(format!(
                    "query {i}: non-finite value {x}"
                )));
            }
        }
        Ok(())
    }

    /// Resolve per-request options against the service defaults into the
    /// effective search parameters + feature switches.
    fn effective(&self, k: usize, o: &QueryOptions) -> (SearchParams, ProximaFeatures) {
        let mut params = self.params;
        if let Some(l) = o.l_override {
            // Clamp to the local index size: a candidate list longer
            // than the index (or this shard of it) buys nothing but a
            // bigger up-front reserve.
            params.l = l.min(self.base.len().max(1));
        }
        params.k = k.min(params.l);
        let mut features = self.features;
        match o.early_term_tau {
            None => {}
            Some(0) => features.early_termination = false,
            Some(tau) => {
                features.early_termination = true;
                params.repetition = tau;
            }
        }
        if o.mode == SearchMode::Hybrid && o.rerank == Some(0) {
            features.beta_rerank = false;
        }
        (params, features)
    }

    /// THE typed entry point: validate, dispatch every query in the
    /// request (fanning multi-query batches across the worker pool), and
    /// assemble the response. All other search methods are conveniences
    /// over the same machinery.
    pub fn query(&self, req: &QueryRequest) -> Result<QueryResponse, ApiError> {
        self.validate(req)?;
        Ok(self.query_prevalidated(req))
    }

    /// [`Self::query`] minus the boundary checks — for internal callers
    /// (the shard fan-out) that already validated the FULL request
    /// exactly once and must not rescan every vector per shard.
    pub(crate) fn query_prevalidated(&self, req: &QueryRequest) -> QueryResponse {
        let t0 = std::time::Instant::now();
        let refs: Vec<&[f32]> = req.vectors.iter().map(|v| v.as_slice()).collect();
        let outs = self.search_batch_with_options(&refs, req.k, &req.options);
        QueryResponse::from_outputs(
            outs,
            req.options.want_stats,
            t0.elapsed().as_micros() as u64,
        )
    }

    /// Answer one query (Algorithm 1 with service-default options).
    pub fn search(&self, q: &[f32], k: usize) -> SearchOutput {
        let mut scratch = self.scratch.checkout();
        self.search_with_scratch(q, k, &mut scratch)
    }

    /// Answer one query using caller-held scratch (the worker hot path:
    /// zero heap allocations in steady state apart from the output
    /// buffers).
    pub fn search_with_scratch(
        &self,
        q: &[f32],
        k: usize,
        scratch: &mut ServiceScratch,
    ) -> SearchOutput {
        self.search_with_options(q, k, &QueryOptions::default(), scratch)
    }

    /// Answer one query under per-request [`QueryOptions`]: the mode
    /// selects which policy runs over the unified kernel, the remaining
    /// fields override the service's `SearchParams`/`ProximaFeatures`
    /// for this request only. Defaults reproduce [`Self::search`] exactly.
    pub fn search_with_options(
        &self,
        q: &[f32],
        k: usize,
        options: &QueryOptions,
        scratch: &mut ServiceScratch,
    ) -> SearchOutput {
        let t0 = std::time::Instant::now();
        let (params, features) = self.effective(k, options);
        let ServiceScratch { adt, walk } = scratch;
        let mut out = SearchOutput::default();
        match options.mode {
            SearchMode::Accurate => {
                accurate_beam_search_into(
                    &self.context(),
                    q,
                    params.k,
                    params.l,
                    false,
                    walk,
                    &mut out,
                );
            }
            SearchMode::PqAdt => {
                self.build_adt_into(q, adt);
                let rerank = options.rerank.unwrap_or(params.l);
                pq_beam_search_into(
                    &self.context(),
                    adt,
                    q,
                    params.k,
                    params.l,
                    rerank,
                    false,
                    walk,
                    &mut out,
                );
            }
            SearchMode::Hybrid => {
                self.build_adt_into(q, adt);
                proxima_search_into(
                    &self.context(),
                    adt,
                    q,
                    &params,
                    features,
                    false,
                    walk,
                    &mut out,
                );
            }
        }
        self.record(&out.stats, t0.elapsed());
        out
    }

    /// Answer one query with an externally provided ADT (the batcher's
    /// path: ADTs built in a batch up front).
    pub fn search_with_adt(&self, q: &[f32], adt: &Adt, k: usize) -> SearchOutput {
        let t0 = std::time::Instant::now();
        let mut params = self.params;
        params.k = k.min(params.l);
        let mut scratch = self.scratch.checkout();
        let mut out = SearchOutput::default();
        proxima_search_into(
            &self.context(),
            adt,
            q,
            &params,
            self.features,
            false,
            &mut scratch.walk,
            &mut out,
        );
        self.record(&out.stats, t0.elapsed());
        out
    }

    /// Answer a whole batch with service-default options (see
    /// [`Self::search_batch_with_options`]).
    pub fn search_batch(&self, queries: &[&[f32]], k: usize) -> Vec<SearchOutput> {
        self.search_batch_with_options(queries, k, &QueryOptions::default())
    }

    /// Answer a whole batch by fanning the queries across a fixed pool of
    /// [`Self::workers`] threads, each holding its own pooled scratch for
    /// the duration (per-worker scratch, per-query zero-alloc). All
    /// queries share the request's [`QueryOptions`]; results come back in
    /// input order.
    pub fn search_batch_with_options(
        &self,
        queries: &[&[f32]],
        k: usize,
        options: &QueryOptions,
    ) -> Vec<SearchOutput> {
        if queries.is_empty() {
            return Vec::new();
        }
        let workers = self.workers.max(1).min(queries.len());
        if workers == 1 {
            let mut scratch = self.scratch.checkout();
            return queries
                .iter()
                .map(|q| self.search_with_options(q, k, options, &mut scratch))
                .collect();
        }
        let chunk = queries.len().div_ceil(workers);
        std::thread::scope(|scope| {
            let handles: Vec<_> = queries
                .chunks(chunk)
                .map(|part| {
                    scope.spawn(move || {
                        let mut scratch = self.scratch.checkout();
                        part.iter()
                            .map(|q| self.search_with_options(q, k, options, &mut scratch))
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            let mut out = Vec::with_capacity(queries.len());
            for h in handles {
                out.extend(h.join().expect("search worker panicked"));
            }
            out
        })
    }

    fn record(&self, s: &SearchStats, elapsed: std::time::Duration) {
        self.stats.queries.fetch_add(1, Ordering::Relaxed);
        self.stats
            .pq_dists
            .fetch_add(s.pq_dists as u64, Ordering::Relaxed);
        self.stats
            .exact_dists
            .fetch_add(s.exact_dists as u64, Ordering::Relaxed);
        if s.early_terminated {
            self.stats.early_terminated.fetch_add(1, Ordering::Relaxed);
        }
        self.stats
            .total_latency_us
            .fetch_add(elapsed.as_micros() as u64, Ordering::Relaxed);
    }

    /// Mean service latency in microseconds.
    pub fn mean_latency_us(&self) -> f64 {
        let q = self.stats.queries.load(Ordering::Relaxed);
        if q == 0 {
            0.0
        } else {
            self.stats.total_latency_us.load(Ordering::Relaxed) as f64 / q as f64
        }
    }
}

/// Hard cap on per-request candidate-list capacity (`l_override`): the
/// list reserves L slots up front, so this bounds the scratch allocation
/// one request can demand. Beam widths beyond this are never useful.
pub const MAX_L_OVERRIDE: usize = 1 << 20;

/// Default `search_batch` width: one worker per available core.
fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::ground_truth::brute_force;
    use crate::dataset::synth::tiny_uniform;

    fn service() -> (Dataset, SearchService) {
        let ds = tiny_uniform(600, 16, Metric::L2, 81);
        let svc = SearchService::build(
            &ds,
            &GraphParams {
                r: 16,
                build_l: 32,
                alpha: 1.2,
                seed: 81,
            },
            &PqParams {
                m: 8,
                c: 32,
                train_sample: 600,
                kmeans_iters: 8,
            },
            SearchParams {
                l: 80,
                k: 10,
                ..Default::default()
            },
            false,
        );
        (ds, svc)
    }

    #[test]
    fn service_end_to_end_recall() {
        let (ds, svc) = service();
        let gt = brute_force(&ds, 10);
        let mut recall = 0.0;
        for q in 0..ds.n_queries() {
            let out = svc.search(ds.queries.row(q), 10);
            recall += crate::dataset::recall_at_k(&out.ids, gt.row(q), 10);
        }
        recall /= ds.n_queries() as f64;
        assert!(recall > 0.8, "recall {recall}");
        assert_eq!(
            svc.stats.queries.load(Ordering::Relaxed),
            ds.n_queries() as u64
        );
        assert!(svc.mean_latency_us() > 0.0);
    }

    #[test]
    fn search_respects_requested_k() {
        let (ds, svc) = service();
        let out = svc.search(ds.queries.row(0), 3);
        assert_eq!(out.ids.len(), 3);
    }

    #[test]
    fn native_adt_matches_service_adt_without_runtime() {
        let (ds, svc) = service();
        let q = ds.queries.row(0);
        let a = svc.build_adt(q);
        let b = svc.codebook.build_adt(q);
        assert_eq!(a.table, b.table);
    }

    #[test]
    fn search_batch_matches_serial_in_order() {
        let (ds, svc) = service();
        let svc = svc.with_workers(4);
        let queries: Vec<&[f32]> = (0..ds.n_queries()).map(|i| ds.queries.row(i)).collect();
        let serial: Vec<_> = queries.iter().map(|q| svc.search(q, 10)).collect();
        let batch = svc.search_batch(&queries, 10);
        assert_eq!(batch.len(), serial.len());
        for (b, s) in batch.iter().zip(&serial) {
            assert_eq!(b.ids, s.ids, "batch results must match serial, in order");
        }
        assert_eq!(
            svc.stats.queries.load(Ordering::Relaxed),
            2 * ds.n_queries() as u64
        );
    }

    #[test]
    fn query_contract_matches_search() {
        let (ds, svc) = service();
        let q = ds.queries.row(0);
        let direct = svc.search(q, 10);
        let resp = svc.query(&QueryRequest::single(q, 10)).unwrap();
        assert_eq!(resp.results.len(), 1);
        assert_eq!(resp.results[0].ids, direct.ids);
        assert_eq!(resp.results[0].dists, direct.dists);
        assert!(resp.stats.is_none(), "stats are opt-in");

        let queries: Vec<&[f32]> = (0..ds.n_queries()).map(|i| ds.queries.row(i)).collect();
        let batch = svc.query(&QueryRequest::batch(&queries, 10)).unwrap();
        let serial = svc.search_batch(&queries, 10);
        assert_eq!(batch.results.len(), serial.len());
        for (b, s) in batch.results.iter().zip(&serial) {
            assert_eq!(b.ids, s.ids);
        }
    }

    #[test]
    fn query_validates_at_the_boundary() {
        let (ds, svc) = service();
        let q = ds.queries.row(0);

        let wrong_dim = vec![1.0f32; ds.dim() + 3];
        let e = svc
            .query(&QueryRequest::batch(&[q, &wrong_dim], 10))
            .unwrap_err();
        assert_eq!(e.code, crate::api::ApiErrorCode::DimMismatch);
        assert!(e.message.contains("query 1"), "{}", e.message);

        let e = svc
            .query(&QueryRequest {
                vectors: vec![],
                k: 10,
                options: QueryOptions::default(),
            })
            .unwrap_err();
        assert_eq!(e.code, crate::api::ApiErrorCode::BadRequest);

        let e = svc.query(&QueryRequest::single(q, 0)).unwrap_err();
        assert_eq!(e.code, crate::api::ApiErrorCode::BadRequest);

        // An oversized batch is rejected before any search work.
        let big = QueryRequest {
            vectors: vec![vec![0.0f32; ds.dim()]; crate::api::MAX_BATCH_QUERIES + 1],
            k: 10,
            options: QueryOptions::default(),
        };
        let e = svc.query(&big).unwrap_err();
        assert_eq!(e.code, crate::api::ApiErrorCode::BadRequest);

        // An absurd l_override cannot reach the list allocator.
        let e = svc
            .query(&QueryRequest::single(q, 10).with_options(QueryOptions {
                l_override: Some(4_000_000_000),
                ..Default::default()
            }))
            .unwrap_err();
        assert_eq!(e.code, crate::api::ApiErrorCode::BadRequest);

        // Non-finite values cannot reach the distance kernels.
        let mut nan_q = q.to_vec();
        nan_q[0] = f32::NAN;
        let e = svc.query(&QueryRequest::single(&nan_q, 10)).unwrap_err();
        assert_eq!(e.code, crate::api::ApiErrorCode::BadRequest);
        let mut inf_q = q.to_vec();
        inf_q[1] = f32::INFINITY;
        let e = svc.query(&QueryRequest::single(&inf_q, 10)).unwrap_err();
        assert_eq!(e.code, crate::api::ApiErrorCode::BadRequest);
    }

    #[test]
    fn options_change_search_behavior() {
        let (ds, svc) = service();
        let q = ds.queries.row(0);
        let stats_for = |options: QueryOptions| {
            let req = QueryRequest::single(q, 10).with_options(QueryOptions {
                want_stats: true,
                ..options
            });
            svc.query(&req).unwrap().stats.unwrap()
        };

        // Accurate mode never touches PQ; the default (Hybrid) lives on it.
        let acc = stats_for(QueryOptions {
            mode: SearchMode::Accurate,
            ..Default::default()
        });
        assert_eq!(acc.pq_dists, 0);
        assert!(acc.exact_dists > 0);
        let hyb = stats_for(QueryOptions::default());
        assert!(hyb.pq_dists > 0);

        // A larger candidate list does strictly more PQ work.
        let small = stats_for(QueryOptions {
            l_override: Some(20),
            ..Default::default()
        });
        let large = stats_for(QueryOptions {
            l_override: Some(80),
            ..Default::default()
        });
        assert!(
            large.pq_dists > small.pq_dists,
            "l=80 pq {} vs l=20 pq {}",
            large.pq_dists,
            small.pq_dists
        );

        // Disabling early termination via tau=0 never terminates early.
        let noet = stats_for(QueryOptions {
            early_term_tau: Some(0),
            ..Default::default()
        });
        assert!(!noet.early_terminated);

        // PqAdt honors the rerank depth knob.
        let shallow = stats_for(QueryOptions {
            mode: SearchMode::PqAdt,
            rerank: Some(10),
            ..Default::default()
        });
        let deep = stats_for(QueryOptions {
            mode: SearchMode::PqAdt,
            rerank: Some(60),
            ..Default::default()
        });
        assert!(
            deep.exact_dists > shallow.exact_dists,
            "rerank=60 exact {} vs rerank=10 exact {}",
            deep.exact_dists,
            shallow.exact_dists
        );
    }

    #[test]
    fn scratch_reuse_is_deterministic() {
        let (ds, svc) = service();
        let mut scratch = svc.checkout_scratch();
        let fresh: Vec<_> = (0..ds.n_queries())
            .map(|i| svc.search(ds.queries.row(i), 10))
            .collect();
        for (i, f) in fresh.iter().enumerate() {
            let r = svc.search_with_scratch(ds.queries.row(i), 10, &mut scratch);
            assert_eq!(r.ids, f.ids, "query {i}: reused scratch changed results");
            assert_eq!(r.dists, f.dists);
        }
    }
}
