//! L3 coordinator: the serving layer around the Proxima search algorithm.
//!
//! * [`SearchService`] — owns one loaded index (base vectors, graph, PQ,
//!   gap encoding) and answers queries; the per-query ADT is built through
//!   the AOT/XLA artifact when a [`Runtime`](crate::runtime::Runtime) is
//!   attached (Python never runs here), with a native fallback.
//! * [`batcher`] — dynamic batching (size- or deadline-triggered).
//! * [`server`] — a TCP line-protocol front end + client, on std threads
//!   (the offline image has no tokio; see DESIGN.md §1).

pub mod batcher;
pub mod loadgen;
pub mod shard;
pub mod server;

use crate::config::{GraphParams, PqParams, SearchParams};
use crate::dataset::{Dataset, VectorSet};
use crate::distance::Metric;
use crate::gap::GapGraph;
use crate::graph::{vamana, Graph};
use crate::pq::{Adt, PqCodebook, PqCodes};
use crate::runtime::service::RuntimeHandle;
use crate::search::beam::SearchContext;
use crate::search::proxima::{proxima_search, ProximaFeatures};
use crate::search::{SearchOutput, SearchStats};
use std::sync::atomic::{AtomicU64, Ordering};

/// Aggregated service counters (exported by the `stats` RPC).
#[derive(Debug, Default)]
pub struct ServiceStats {
    pub queries: AtomicU64,
    pub early_terminated: AtomicU64,
    pub pq_dists: AtomicU64,
    pub exact_dists: AtomicU64,
    pub total_latency_us: AtomicU64,
}

/// One loaded, queryable index.
pub struct SearchService {
    pub name: String,
    pub metric: Metric,
    pub base: VectorSet,
    pub graph: Graph,
    pub codebook: PqCodebook,
    pub codes: PqCodes,
    pub gap: Option<GapGraph>,
    pub params: SearchParams,
    pub features: ProximaFeatures,
    /// AOT runtime service thread; when present the per-query ADT (and
    /// batch APIs) run through the compiled XLA artifacts. The PJRT
    /// handles are pinned to that thread (they are not `Send`).
    pub runtime: Option<RuntimeHandle>,
    pub stats: ServiceStats,
}

impl SearchService {
    /// Build the full index stack from a dataset (train PQ, build Vamana,
    /// gap-encode). This is the "index build" phase, not the request path.
    pub fn build(
        ds: &Dataset,
        gp: &GraphParams,
        pq: &PqParams,
        params: SearchParams,
        use_xla: bool,
    ) -> SearchService {
        let graph = vamana::build(&ds.base, ds.metric, gp);
        let codebook = PqCodebook::train(
            &ds.base,
            ds.metric,
            pq.m,
            pq.c,
            pq.train_sample,
            pq.kmeans_iters,
            gp.seed ^ 0xC0DE,
        );
        let codes = codebook.encode(&ds.base);
        let gap = Some(GapGraph::encode(&graph.to_lists()));
        let runtime = if use_xla {
            RuntimeHandle::spawn_default(&codebook)
        } else {
            None
        };
        SearchService {
            name: ds.name.clone(),
            metric: ds.metric,
            base: ds.base.clone(),
            graph,
            codebook,
            codes,
            gap,
            params,
            features: ProximaFeatures::default(),
            runtime,
            stats: ServiceStats::default(),
        }
    }

    fn context(&self) -> SearchContext<'_> {
        SearchContext {
            base: &self.base,
            metric: self.metric,
            graph: &self.graph,
            codes: Some(&self.codes),
            gap: self.gap.as_ref(),
        }
    }

    /// Build the query's ADT — through XLA when attached, else natively.
    pub fn build_adt(&self, q: &[f32]) -> Adt {
        if let Some(rt) = &self.runtime {
            match rt.build_adt(q) {
                Ok(adt) => return adt,
                Err(e) => {
                    // Fall back but surface the problem.
                    eprintln!("[service] XLA ADT failed ({e:#}); using native path");
                }
            }
        }
        self.codebook.build_adt(q)
    }

    /// Answer one query (Algorithm 1).
    pub fn search(&self, q: &[f32], k: usize) -> SearchOutput {
        let t0 = std::time::Instant::now();
        let mut params = self.params.clone();
        params.k = k.min(params.l);
        let adt = self.build_adt(q);
        let out = proxima_search(&self.context(), &adt, q, &params, self.features, false);
        self.record(&out.stats, t0.elapsed());
        out
    }

    /// Answer one query with an externally provided ADT (the batcher's
    /// path: ADTs built in a batch up front).
    pub fn search_with_adt(&self, q: &[f32], adt: &Adt, k: usize) -> SearchOutput {
        let t0 = std::time::Instant::now();
        let mut params = self.params.clone();
        params.k = k.min(params.l);
        let out = proxima_search(&self.context(), adt, q, &params, self.features, false);
        self.record(&out.stats, t0.elapsed());
        out
    }

    fn record(&self, s: &SearchStats, elapsed: std::time::Duration) {
        self.stats.queries.fetch_add(1, Ordering::Relaxed);
        self.stats
            .pq_dists
            .fetch_add(s.pq_dists as u64, Ordering::Relaxed);
        self.stats
            .exact_dists
            .fetch_add(s.exact_dists as u64, Ordering::Relaxed);
        if s.early_terminated {
            self.stats.early_terminated.fetch_add(1, Ordering::Relaxed);
        }
        self.stats
            .total_latency_us
            .fetch_add(elapsed.as_micros() as u64, Ordering::Relaxed);
    }

    /// Mean service latency in microseconds.
    pub fn mean_latency_us(&self) -> f64 {
        let q = self.stats.queries.load(Ordering::Relaxed);
        if q == 0 {
            0.0
        } else {
            self.stats.total_latency_us.load(Ordering::Relaxed) as f64 / q as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::ground_truth::brute_force;
    use crate::dataset::synth::tiny_uniform;

    fn service() -> (Dataset, SearchService) {
        let ds = tiny_uniform(600, 16, Metric::L2, 81);
        let svc = SearchService::build(
            &ds,
            &GraphParams {
                r: 16,
                build_l: 32,
                alpha: 1.2,
                seed: 81,
            },
            &PqParams {
                m: 8,
                c: 32,
                train_sample: 600,
                kmeans_iters: 8,
            },
            SearchParams {
                l: 80,
                k: 10,
                ..Default::default()
            },
            false,
        );
        (ds, svc)
    }

    #[test]
    fn service_end_to_end_recall() {
        let (ds, svc) = service();
        let gt = brute_force(&ds, 10);
        let mut recall = 0.0;
        for q in 0..ds.n_queries() {
            let out = svc.search(ds.queries.row(q), 10);
            recall += crate::dataset::recall_at_k(&out.ids, gt.row(q), 10);
        }
        recall /= ds.n_queries() as f64;
        assert!(recall > 0.8, "recall {recall}");
        assert_eq!(
            svc.stats.queries.load(Ordering::Relaxed),
            ds.n_queries() as u64
        );
        assert!(svc.mean_latency_us() > 0.0);
    }

    #[test]
    fn search_respects_requested_k() {
        let (ds, svc) = service();
        let out = svc.search(ds.queries.row(0), 3);
        assert_eq!(out.ids.len(), 3);
    }

    #[test]
    fn native_adt_matches_service_adt_without_runtime() {
        let (ds, svc) = service();
        let q = ds.queries.row(0);
        let a = svc.build_adt(q);
        let b = svc.codebook.build_adt(q);
        assert_eq!(a.table, b.table);
    }
}
