//! L3 coordinator: the serving layer around the Proxima search algorithm.
//!
//! # Execution model
//!
//! Every parallel stage in this module rides ONE substrate: the
//! persistent work-stealing [`ExecPool`](crate::exec::ExecPool) (shared
//! process-wide by default; [`SearchService::with_workers`] swaps in a
//! dedicated pool). There is no per-batch thread spawning anywhere in
//! the serving stack. A multi-query request executes as a staged batch
//! pipeline, mirroring the paper's dataflow that overlaps ADT
//! preparation with graph traversal:
//!
//! 1. **Staged batch ADT build** — the batch's PQ-guided queries
//!    (`PqAdt`/`Hybrid`) are deduplicated (bitwise vector equality) and
//!    ONE blocked, GEMM-shaped sweep
//!    ([`PqCodebook::build_adt_batch`]) fills a pooled table per
//!    DISTINCT query — on the exec pool for large batches — so no walk
//!    ever pays per-query ADT latency mid-batch, and duplicate-heavy
//!    batches build fewer tables than they have queries (visible as
//!    `SearchStats::adt_builds`).
//! 2. **Per-query walk tasks** — each query is ONE task in the pool's
//!    injector; idle workers steal at per-query granularity, so a slow
//!    query (huge `l_override`, hybrid rerank) no longer idles a chunk
//!    of batch-mates the way contiguous chunking did. Results return in
//!    input order.
//!
//! Each pool worker pins its own [`ServiceScratch`] in a thread-local,
//! persisting across batches — the steady-state walk performs zero heap
//! allocations (`tests/zero_alloc.rs`). Every task's submission→start
//! time is metered and surfaced as `SearchStats::queue_wait_us`. A
//! panicking query task is contained by the pool and answered as
//! [`ApiErrorCode::Internal`](crate::api::ApiErrorCode) for that query
//! only; batch-mates are unaffected.
//!
//! # Components
//!
//! * [`SearchService`] — owns one loaded index (base vectors behind the
//!   tiered [`VectorStore`] — fully resident by default, served in
//!   place from the artifact file or hot_frac-pinned under
//!   [`open_with`](SearchService::open_with) — plus graph, PQ,
//!   gap encoding) and answers queries through the typed query API
//!   ([`SearchService::query`] takes a [`QueryRequest`] — N vectors, `k`,
//!   per-request [`QueryOptions`] — and returns a [`QueryResponse`] or a
//!   structured [`ApiError`]); the per-query ADT is built through
//!   the AOT/XLA artifact when a [`Runtime`](crate::runtime::Runtime) is
//!   attached (Python never runs here), with a native fallback.
//!   Heterogeneous batches (per-query options) go through
//!   [`SearchService::search_batch_mixed`].
//! * [`batcher`] — dynamic batching (size- or deadline-triggered), each
//!   queued request carrying its own [`QueryOptions`]; a flushed batch
//!   executes as one staged pipeline on the shared pool, so coalesced
//!   duplicate queries share ADT builds.
//! * [`shard`] — partitioned scale-out, fanning shard queries out as
//!   pool tasks (which themselves submit per-query walks — nested
//!   submission is deadlock-free because waiting submitters help
//!   execute), speaking the same [`QueryRequest`]/[`QueryResponse`]
//!   contract.
//! * [`server`] — a TCP line-protocol front end + client (versioned wire
//!   protocol, multi-query v2 batches + v1 compat), on std threads
//!   (the offline image has no tokio; see DESIGN.md §1). The v2
//!   multi-query path rides the same pool, so `queue_wait_us` is
//!   measurable per response via `want_stats`. This is the JSON-only
//!   debug/compat front end; the throughput path is
//!   [`crate::net::NetServer`], which serves the v3 binary frame plane
//!   AND these same JSON ops on one port (first-byte sniff), routing
//!   every JSON line through the shared
//!   [`server::respond_json_line`](server) dispatch so op semantics
//!   cannot drift between the two servers.
//! * [`loadgen`] — closed-loop, mixed-churn, and open-loop (Poisson
//!   arrivals over the binary wire, [`loadgen::run_open`]) load
//!   generators.

pub mod batcher;
pub mod loadgen;
pub mod shard;
pub mod server;

use crate::api::{ApiError, QueryOptions, QueryRequest, QueryResponse, SearchMode};
use crate::artifact::{
    ArtifactError, ArtifactParts, ColdArtifact, IndexArtifact, IndexProvenance, IndexSpec,
};
use crate::config::{GraphParams, PqParams, SearchParams};
use crate::dataset::{Dataset, VectorSet};
use crate::distance::Metric;
use crate::engine::mapping::DataMapping;
use crate::exec::ExecPool;
use crate::gap::GapGraph;
use crate::graph::{vamana, Graph};
use crate::nand::NandConfig;
use crate::online::{compact, IndexRefs, OnlineSnapshot, OnlineState};
use crate::pq::{Adt, AdtBatch, PqCodebook, PqCodes};
use crate::runtime::service::RuntimeHandle;
use crate::search::beam::{accurate_beam_search_into, pq_beam_search_into, SearchContext};
use crate::search::kernel::{Pooled, QueryScratch, ScratchPool};
use crate::search::lsh_start::LshIndex;
use crate::search::proxima::{proxima_search_into, ProximaFeatures};
use crate::search::{SearchOutput, SearchStats};
use crate::simd::AlignedBuf;
use crate::storage::{ColdVectors, OpenOptions, ReadBuf, Residency, RowSource, VectorStore};
use std::cell::RefCell;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Aggregated service counters (exported by the `stats` RPC).
#[derive(Debug, Default)]
pub struct ServiceStats {
    pub queries: AtomicU64,
    pub early_terminated: AtomicU64,
    pub pq_dists: AtomicU64,
    pub exact_dists: AtomicU64,
    pub total_latency_us: AtomicU64,
    /// Total time queries sat in the exec-pool queue (µs).
    pub queue_wait_us: AtomicU64,
    /// Cold-tier raw-vector fetches this epoch (0 under `Resident`).
    pub cold_reads: AtomicU64,
    /// Bytes those cold fetches read from the artifact file.
    pub cold_bytes: AtomicU64,
    /// Row-cache hits (cold fetches answered from the adaptive hot set
    /// without touching the artifact file; 0 without a cache tier).
    pub cache_hits: AtomicU64,
    /// Row-cache misses (cold fetches that went to the file and were
    /// then admitted under the cache policy).
    pub cache_misses: AtomicU64,
    /// LSH entry-point buckets examined across queries (0 unless the
    /// service was opened with `lsh_start`).
    pub lsh_probes: AtomicU64,
}

/// Per-query scratch a service worker checks out: the walk state plus a
/// reusable ADT table (the two per-query allocations the seed paid).
#[derive(Default)]
pub struct ServiceScratch {
    pub adt: Adt,
    pub walk: QueryScratch,
}

thread_local! {
    /// Per-worker pinned scratch for batch tasks on the exec pool: every
    /// pool worker (and every helping submitter) owns one for its thread
    /// lifetime, so it persists across batches — no checkout traffic, no
    /// contention, zero steady-state allocations on the walk path.
    ///
    /// Retention trade-off: the shared pool outlives any one service, so
    /// this scratch (visited stamps sized to the largest index served,
    /// the exact cache, the Bloom filter) stays resident per worker for
    /// the process lifetime — that is the price of a warm hot path.
    /// What must NOT stay resident is a one-off spike: see
    /// [`trim_worker_scratch`], which releases outsized candidate-list /
    /// rerank buffers (a single `l_override` near [`MAX_L_OVERRIDE`]
    /// would otherwise pin megabytes per worker forever).
    static WORKER_SCRATCH: RefCell<ServiceScratch> = RefCell::new(ServiceScratch::default());
}

/// Largest candidate-list / rerank capacity (entries) a pinned worker
/// scratch keeps between batches. Normal serving lists (L up to a few
/// thousand) sit far below this; one outlier request above it pays its
/// re-allocation again instead of pinning the memory on an immortal
/// worker.
const SCRATCH_RETAIN_CAP: usize = 1 << 16;

/// Bound the pinned scratch after a pool task (see [`WORKER_SCRATCH`]).
fn trim_worker_scratch(scratch: &mut ServiceScratch) {
    let list = &mut scratch.walk.list;
    if list.items.capacity() > SCRATCH_RETAIN_CAP {
        list.items = Vec::new();
    }
    if scratch.walk.rerank.capacity() > SCRATCH_RETAIN_CAP {
        scratch.walk.rerank = Vec::new();
    }
}

/// One query of a heterogeneous batch: its vector, `k`, and the options
/// it must be answered under ([`SearchService::search_batch_mixed`]).
#[derive(Clone, Copy)]
pub struct BatchQuery<'a> {
    pub q: &'a [f32],
    pub k: usize,
    pub options: QueryOptions,
}

/// One loaded, queryable index.
pub struct SearchService {
    pub name: String,
    /// Identity card of the index: what was built and how. Persisted in
    /// the artifact header and reported by the wire `status` op.
    pub spec: IndexSpec,
    /// Whether this index was built in-process or opened from an
    /// artifact (and from which path).
    pub provenance: IndexProvenance,
    pub metric: Metric,
    /// Raw base vectors behind the tiered storage layer: fully resident
    /// by default; [`Self::open_with`] can leave them on disk (`Cold`)
    /// or pin only the §IV-E hot fraction (`Tiered`). Traversal
    /// metadata (graph, codes, gap) is always resident.
    pub storage: VectorStore,
    pub graph: Graph,
    pub codebook: PqCodebook,
    pub codes: PqCodes,
    pub gap: Option<GapGraph>,
    /// §IV-E reorder permutation (`perm[old] = new`) when this index was
    /// opened from a reordered artifact; persisted back by [`Self::save`].
    pub reorder: Option<Vec<u32>>,
    /// Inverse of `reorder` (`id_map[stored] = original`): applied to
    /// every result list, so clients see ORIGINAL ids no matter how the
    /// stored layout was permuted for NAND locality.
    id_map: Option<Vec<u32>>,
    /// The §IV-E layout this index was opened with. [`Self::save`]
    /// persists it VERBATIM (the contract with the NAND engine/sim);
    /// only when absent (a freshly built index) does `save` compute
    /// [`Self::default_mapping`].
    pub mapping: Option<DataMapping>,
    /// LSH entry-point index (persisted as the optional `SEC_LSH`
    /// artifact section). Carried even when warm starts are off so
    /// `save` round-trips it; [`Self::use_lsh`] gates query use.
    pub lsh: Option<LshIndex>,
    /// Whether queries seed from LSH warm starts (`--lsh_start` /
    /// `OpenOptions::lsh_start`). Off by default: extra seeds change
    /// traversal order, and the default path stays bitwise-compatible
    /// with the fixed-entry oracles.
    use_lsh: bool,
    pub params: SearchParams,
    pub features: ProximaFeatures,
    /// Graph-build parameters (degree bound R, prune slack α, build-time
    /// search width) — the write plane reuses them for online inserts,
    /// repair re-pruning, and flush compaction.
    pub graph_params: GraphParams,
    /// The online write plane: epoch-published mutation snapshots plus
    /// the single-writer queue (`SearchService::{insert, delete, flush}`).
    pub online: OnlineState,
    /// AOT runtime service thread; when present the per-query ADT (and
    /// batch APIs) run through the compiled XLA artifacts. The PJRT
    /// handles are pinned to that thread (they are not `Send`).
    pub runtime: Option<RuntimeHandle>,
    /// The XLA *preference* this service was created with — distinct
    /// from `runtime.is_some()` (the attach *outcome*): a reload must
    /// retry the preference, not inherit a transient attach failure.
    xla_preferred: bool,
    pub stats: ServiceStats,
    /// The observability plane (`crate::obs`): latency histograms,
    /// stage breakdowns, gauges, and the slow-query flight recorder.
    /// Unlike `stats` (per-epoch), this handle is ADOPTED by the
    /// successor service on `reload`/`flush` hot-swaps — histogram
    /// series are lifetime series — while its slowlog is cleared
    /// (cross-epoch spans are not comparable).
    pub obs: Arc<crate::obs::Metrics>,
    /// Parallelism width for batch execution: the exec pool's worker
    /// threads plus the submitting thread, which helps execute while it
    /// waits. `1` = serial inline execution.
    pub workers: usize,
    /// The execution substrate every batch stage submits to — the
    /// process-wide shared pool unless [`Self::with_workers`] swapped in
    /// a dedicated one.
    exec: Arc<ExecPool>,
    scratch: ScratchPool<ServiceScratch>,
    /// Pooled staged-ADT-build state (tables + dedup plan), reused
    /// across batches.
    adt_batches: ScratchPool<AdtBatch>,
}

impl SearchService {
    /// Build the full index stack from a dataset (train PQ, build Vamana,
    /// gap-encode). This is the "index build" phase, not the request path.
    pub fn build(
        ds: &Dataset,
        gp: &GraphParams,
        pq: &PqParams,
        params: SearchParams,
        use_xla: bool,
    ) -> SearchService {
        let graph = vamana::build(&ds.base, ds.metric, gp);
        let codebook = PqCodebook::train(
            &ds.base,
            ds.metric,
            pq.m,
            pq.c,
            pq.train_sample,
            pq.kmeans_iters,
            gp.seed ^ 0xC0DE,
        );
        let codes = codebook.encode(&ds.base);
        let gap = Some(GapGraph::encode(&graph.to_lists()));
        let runtime = if use_xla {
            RuntimeHandle::spawn_default(&codebook)
        } else {
            None
        };
        let spec = IndexSpec {
            dataset: ds.name.clone(),
            metric: ds.metric,
            dim: ds.dim() as u32,
            n_base: ds.n_base() as u64,
            graph_r: gp.r as u32,
            graph_build_l: gp.build_l as u32,
            graph_alpha: gp.alpha,
            pq_m: pq.m as u32,
            pq_c: pq.c as u32,
            hot_frac: 0.0,
            build_seed: gp.seed,
        };
        SearchService {
            name: ds.name.clone(),
            spec,
            provenance: IndexProvenance::Built,
            metric: ds.metric,
            storage: VectorStore::resident(&ds.base),
            graph,
            codebook,
            codes,
            gap,
            reorder: None,
            id_map: None,
            mapping: None,
            lsh: None,
            use_lsh: false,
            params,
            features: ProximaFeatures::default(),
            graph_params: gp.clone(),
            online: OnlineState::new(ds.n_base(), ds.dim(), pq.m),
            runtime,
            xla_preferred: use_xla,
            stats: ServiceStats::default(),
            obs: Arc::new(crate::obs::Metrics::new()),
            workers: default_workers(),
            exec: ExecPool::shared().clone(),
            scratch: ScratchPool::new(),
            adt_batches: ScratchPool::new(),
        }
    }

    /// Persist this index as a versioned, checksummed artifact — the
    /// deployment unit [`Self::open`] (and `serve --index`) restarts
    /// from without touching the raw dataset. Alongside the search
    /// structures it stores the §IV-E [`DataMapping`] layout computed
    /// for the paper's accelerator geometry, so the NAND engine/sim can
    /// open the same file.
    pub fn save(&self, path: &Path) -> Result<(), ArtifactError> {
        // An index opened from an artifact carries that artifact's
        // layout and must persist it VERBATIM — recomputing would
        // silently rewrite the physical addresses the engine/sim
        // resolves. Only a freshly built index derives the default.
        let mapping = self
            .mapping
            .clone()
            .unwrap_or_else(|| self.default_mapping());
        // The artifact stores the LOGICAL (unpadded) vectors; resident
        // tiers strip their SIMD padding here, and a cold/tiered-opened
        // service re-reads its cold tier once — save is an offline path,
        // and I/O failures are typed here.
        let materialized = self
            .storage
            .materialize()
            .map_err(|e| ArtifactError::io(format!("reading cold vectors for save: {e}")))?;
        ArtifactParts {
            spec: &self.spec,
            base: &materialized,
            graph: &self.graph,
            gap: self.gap.as_ref(),
            codebook: &self.codebook,
            codes: &self.codes,
            reorder: self.reorder.as_deref(),
            mapping: Some(&mapping),
            lsh: self.lsh.as_ref(),
        }
        .write(path)
    }

    /// Build (or rebuild) the LSH entry-point index over the resident
    /// base — the index-construction half of `--lsh_start` (persisted by
    /// [`Self::save`] as `SEC_LSH`). Returns false under `Cold`/`Tiered`
    /// residency, where the base is not materialized.
    pub fn build_lsh(&mut self, n_bits: u32) -> bool {
        let Some(base) = self.resident_base() else {
            return false;
        };
        // Derive the hash seed from the build seed so rebuilds of the
        // same index draw the same hyperplanes.
        self.lsh = Some(LshIndex::build(&base, n_bits, self.spec.build_seed ^ 0x15A8));
        true
    }

    /// Toggle LSH warm starts at query time (no-op signal when no LSH
    /// index is loaded — [`Self::lsh_active`] reports the outcome).
    pub fn set_use_lsh(&mut self, on: bool) {
        self.use_lsh = on;
    }

    /// Whether queries currently seed from LSH warm starts.
    pub fn lsh_active(&self) -> bool {
        self.use_lsh && self.lsh.is_some()
    }

    /// The §IV-E layout for this index on the paper's accelerator
    /// geometry: gap-encoded index width, coupled PQ frames, raw-vector
    /// region (persisted by [`Self::save`]).
    pub fn default_mapping(&self) -> DataMapping {
        let b_index = self
            .gap
            .as_ref()
            .map(|g| g.mean_bits_per_edge(self.graph.n_edges().max(1)).ceil() as u32)
            .unwrap_or(32)
            .clamp(1, 32);
        DataMapping::new(
            &NandConfig::proxima(),
            self.n_base() as u32,
            self.graph.max_degree.max(1) as u32,
            b_index,
            (self.codebook.m * 8) as u32,
            self.dim() as u32,
            32,
            self.spec.hot_frac,
        )
    }

    /// Open a serialized index artifact — the fast restart path: no
    /// dataset, no graph build, no PQ training. The artifact is
    /// checksum-verified and structurally validated ([`IndexArtifact`]);
    /// every failure is a typed [`ArtifactError`], never a panic.
    /// Vectors are fully resident; [`Self::open_with`] picks a tiered
    /// [`Residency`] instead.
    pub fn open(
        path: &Path,
        params: SearchParams,
        use_xla: bool,
    ) -> Result<SearchService, ArtifactError> {
        Self::open_with(path, params, use_xla, &OpenOptions::default())
    }

    /// [`Self::open`] with an explicit vector [`Residency`]:
    ///
    /// * `Resident` — every section materialized into owned buffers
    ///   (the default);
    /// * `Cold` — the BASE payload is validated by one streaming CRC
    ///   pass and then **served in place** from the artifact file
    ///   ([`ColdArtifact`]): serving DRAM stops scaling with `n_base`;
    /// * `Tiered` — additionally pins the `spec.hot_frac` hot prefix
    ///   (ids `0..n_hot` after the §IV-E REORDER permutation) in DRAM,
    ///   so only cold MISSES touch the file.
    ///
    /// Search results are bitwise-identical across all three (pinned by
    /// `tests/storage_parity.rs`), and so is open-time validation: both
    /// decode paths CRC every section and re-prove the same structural
    /// invariants.
    pub fn open_with(
        path: &Path,
        params: SearchParams,
        use_xla: bool,
        opts: &OpenOptions,
    ) -> Result<SearchService, ArtifactError> {
        // Residency decides only HOW the BASE payload is materialized;
        // everything downstream of (spec, storage, sections) is one
        // shared construction path.
        let (spec, storage, graph, codebook, codes, gap, reorder, mapping, lsh) =
            match opts.residency {
                Residency::Resident => {
                    let art = IndexArtifact::open(path)?;
                    (
                        art.spec,
                        VectorStore::resident(&art.base),
                        art.graph,
                        art.codebook,
                        art.codes,
                        art.gap,
                        art.reorder,
                        art.mapping,
                        art.lsh,
                    )
                }
                residency => {
                    let art = ColdArtifact::open(path, residency == Residency::Tiered)?;
                    let cold =
                        ColdVectors::new(art.file, art.base_data_offset, art.n_base, art.dim, path);
                    let storage = match residency {
                        Residency::Cold => VectorStore::cold(cold),
                        Residency::Tiered => match opts.tiered_cache_bytes {
                            // A cache layer under the static hot prefix:
                            // the prefix becomes the warm-start set, the
                            // cache adapts to the query-time tail.
                            Some(bytes) => VectorStore::tiered_cached(
                                &art.hot,
                                cold,
                                bytes,
                                opts.cache_policy,
                            ),
                            None => VectorStore::tiered(&art.hot, cold),
                        },
                        Residency::Cached { capacity_bytes } => {
                            VectorStore::cached(cold, capacity_bytes, opts.cache_policy)
                        }
                        Residency::Resident => unreachable!("matched above"),
                    };
                    (
                        art.spec, storage, art.graph, art.codebook, art.codes, art.gap,
                        art.reorder, art.mapping, art.lsh,
                    )
                }
            };
        let gap = match gap {
            Some(g) => g,
            // Minimal artifacts may omit the packed stream; re-encode
            // (cheap relative to a graph build).
            None => GapGraph::encode(&graph.to_lists()),
        };
        let runtime = if use_xla {
            RuntimeHandle::spawn_default(&codebook)
        } else {
            None
        };
        // A reordered artifact stores everything in the permuted (NAND
        // layout) space; results must still name ORIGINAL ids. Invert
        // the stored `perm[old] = new` once, map every output through it
        // (decode already proved it a bijection).
        let id_map = reorder
            .as_ref()
            .map(|perm| crate::reorder::invert_permutation(perm));
        let graph_params = GraphParams {
            r: spec.graph_r as usize,
            build_l: spec.graph_build_l as usize,
            alpha: spec.graph_alpha,
            seed: spec.build_seed,
        };
        let online = OnlineState::new(storage.len(), storage.dim(), spec.pq_m as usize);
        if opts.lsh_start && lsh.is_none() {
            crate::log_warn!(
                "--lsh_start requested but {} carries no LSH section; \
                 rebuild with --lsh_bits to enable warm starts",
                path.display()
            );
        }
        Ok(SearchService {
            name: spec.dataset.clone(),
            provenance: IndexProvenance::Artifact {
                path: path.display().to_string(),
            },
            metric: spec.metric,
            storage,
            graph,
            codebook,
            codes,
            gap: Some(gap),
            reorder,
            id_map,
            mapping,
            use_lsh: opts.lsh_start && lsh.is_some(),
            lsh,
            params,
            features: ProximaFeatures::default(),
            graph_params,
            online,
            runtime,
            xla_preferred: use_xla,
            stats: ServiceStats::default(),
            obs: Arc::new(crate::obs::Metrics::new()),
            workers: default_workers(),
            exec: ExecPool::shared().clone(),
            scratch: ScratchPool::new(),
            adt_batches: ScratchPool::new(),
            spec,
        })
    }

    /// The XLA preference this service was created with (what a hot
    /// reload should retry — not the attach outcome).
    pub fn xla_preferred(&self) -> bool {
        self.xla_preferred
    }

    /// Override the batch-execution width: swaps in a DEDICATED exec
    /// pool of `workers - 1` threads (the submitting thread is the extra
    /// lane). `workers == 1` executes batches serially inline. The
    /// previous pool (if dedicated) shuts down gracefully on drop.
    pub fn with_workers(mut self, workers: usize) -> SearchService {
        self.workers = workers.max(1);
        self.exec = Arc::new(ExecPool::new(self.workers - 1));
        self
    }

    /// Whether batches run on the process-wide shared pool (vs a
    /// dedicated pool installed by [`Self::with_workers`]). The wire
    /// `reload` op uses this to carry a serve-time `--workers` override
    /// across hot swaps.
    pub fn uses_shared_pool(&self) -> bool {
        Arc::ptr_eq(&self.exec, ExecPool::shared())
    }

    /// Check out per-query scratch (workers hold one for their lifetime).
    pub fn checkout_scratch(&self) -> Pooled<'_, ServiceScratch> {
        self.scratch.checkout()
    }

    fn context(&self) -> SearchContext<'_> {
        // Every residency routes raw-vector fetches through the store,
        // whose rows are SIMD-padded and 64-byte aligned (`base` is only
        // the dim-carrying stub). Searches pad the query to the same
        // stride, so service distances are evaluated entirely in the
        // padded layout regardless of tier.
        SearchContext {
            base: self.storage.base_stub(),
            metric: self.metric,
            graph: &self.graph,
            codes: Some(&self.codes),
            gap: self.gap.as_ref(),
            storage: Some(&self.storage),
            online: None,
            lsh: if self.use_lsh { self.lsh.as_ref() } else { None },
        }
    }

    /// [`Self::context`] pinned to one write-plane snapshot. A clean
    /// snapshot (no mutation ever applied) degrades to the frozen
    /// context, so unmutated serving pays zero overlay overhead and
    /// stays byte-for-byte identical to pre-write-plane behavior.
    fn context_at<'s>(&'s self, snap: &'s OnlineSnapshot) -> SearchContext<'s> {
        SearchContext {
            online: (!snap.is_clean()).then_some(snap),
            ..self.context()
        }
    }

    /// Borrowed index pieces the write plane operates on.
    fn index_refs(&self) -> IndexRefs<'_> {
        IndexRefs {
            graph: &self.graph,
            storage: &self.storage,
            base_stub: self.storage.base_stub(),
            metric: self.metric,
            codes: Some(&self.codes),
            gap: self.gap.as_ref(),
            codebook: Some(&self.codebook),
            params: &self.graph_params,
        }
    }

    /// Number of indexed base vectors (tier-independent).
    pub fn n_base(&self) -> usize {
        self.storage.len()
    }

    /// The full base vectors, when fully DRAM-resident (`None` under
    /// `Cold`/`Tiered` residency — that is the point of those modes).
    /// Returns an owned, LOGICALLY-shaped copy: the resident tier stores
    /// rows SIMD-padded, so callers get the padding stripped back out.
    pub fn resident_base(&self) -> Option<VectorSet> {
        match self.storage.residency() {
            Residency::Resident => self.storage.materialize().ok(),
            _ => None,
        }
    }

    /// Build the query's ADT — through XLA when attached, else natively.
    pub fn build_adt(&self, q: &[f32]) -> Adt {
        let mut adt = Adt::default();
        self.build_adt_into(q, &mut adt);
        adt
    }

    /// [`Self::build_adt`] into a reusable table (the scratch path).
    pub fn build_adt_into(&self, q: &[f32], adt: &mut Adt) {
        if let Some(rt) = &self.runtime {
            match rt.build_adt(q) {
                Ok(a) => {
                    // Copy into the pooled table rather than replacing it,
                    // so the scratch allocation survives the XLA path too.
                    adt.m = a.m;
                    adt.c = a.c;
                    adt.table.clear();
                    adt.table.extend_from_slice(&a.table);
                    return;
                }
                Err(e) => {
                    // Fall back but surface the problem (suppressed in
                    // quiet mode like all progress/diagnostic chatter).
                    crate::log_warn!("XLA ADT failed ({e:#}); using native path");
                }
            }
        }
        self.codebook.build_adt_into(q, adt);
    }

    /// Index dimensionality (the API boundary validates queries against
    /// this).
    pub fn dim(&self) -> usize {
        self.storage.dim()
    }

    /// Validate a request against this index: non-empty batch, sane `k`
    /// and `l_override`, and every vector's length equal to the index
    /// dimension (a wrong-length vector would otherwise reach
    /// `Metric::distance` and panic or return garbage).
    pub fn validate(&self, req: &QueryRequest) -> Result<(), ApiError> {
        if req.vectors.is_empty() {
            return Err(ApiError::bad_request("empty query batch"));
        }
        if req.vectors.len() > crate::api::MAX_BATCH_QUERIES {
            return Err(ApiError::bad_request(format!(
                "batch of {} exceeds the maximum {} queries per request",
                req.vectors.len(),
                crate::api::MAX_BATCH_QUERIES
            )));
        }
        if req.k == 0 {
            return Err(ApiError::bad_request("k must be >= 1"));
        }
        if let Some(l) = req.options.l_override {
            if l == 0 {
                return Err(ApiError::bad_request("l_override must be >= 1"));
            }
            // The list buffer reserves L slots up front — an unbounded
            // value would let one request demand a huge allocation. The
            // cap is a request-size constant (not the index size) so
            // every shard of a sharded service accepts or rejects a
            // request identically; `effective()` additionally clamps L
            // to the local index size.
            if l > MAX_L_OVERRIDE {
                return Err(ApiError::bad_request(format!(
                    "l_override {l} exceeds the maximum {MAX_L_OVERRIDE}"
                )));
            }
        }
        let dim = self.dim();
        for (i, v) in req.vectors.iter().enumerate() {
            if v.len() != dim {
                return Err(ApiError::dim_mismatch(format!(
                    "query {i}: expected dim {dim}, got {}",
                    v.len()
                )));
            }
            // Non-finite values produce NaN distances, which panic the
            // rerank sorts deep in a worker thread — reject them here so
            // a bad request cannot tear down the serving path.
            if let Some(x) = v.iter().find(|x| !x.is_finite()) {
                return Err(ApiError::bad_request(format!(
                    "query {i}: non-finite value {x}"
                )));
            }
        }
        Ok(())
    }

    /// Resolve per-request options against the service defaults into the
    /// effective search parameters + feature switches.
    fn effective(&self, k: usize, o: &QueryOptions) -> (SearchParams, ProximaFeatures) {
        let mut params = self.params;
        if let Some(l) = o.l_override {
            // Clamp to the local index size: a candidate list longer
            // than the index (or this shard of it) buys nothing but a
            // bigger up-front reserve.
            params.l = l.min(self.n_base().max(1));
        }
        params.k = k.min(params.l);
        let mut features = self.features;
        match o.early_term_tau {
            None => {}
            Some(0) => features.early_termination = false,
            Some(tau) => {
                features.early_termination = true;
                params.repetition = tau;
            }
        }
        if o.mode == SearchMode::Hybrid && o.rerank == Some(0) {
            features.beta_rerank = false;
        }
        (params, features)
    }

    /// THE typed entry point: validate, dispatch every query in the
    /// request (fanning multi-query batches across the worker pool), and
    /// assemble the response. All other search methods are conveniences
    /// over the same machinery.
    pub fn query(&self, req: &QueryRequest) -> Result<QueryResponse, ApiError> {
        self.validate(req)?;
        Ok(self.query_prevalidated(req))
    }

    /// [`Self::query`] minus the boundary checks — for internal callers
    /// (the shard fan-out) that already validated the FULL request
    /// exactly once and must not rescan every vector per shard. A query
    /// whose worker task panics is answered as `Internal` in
    /// [`QueryResponse::errors`]; its batch-mates are unaffected.
    pub(crate) fn query_prevalidated(&self, req: &QueryRequest) -> QueryResponse {
        let t0 = std::time::Instant::now();
        let items: Vec<BatchQuery> = req
            .vectors
            .iter()
            .map(|v| BatchQuery {
                q: v.as_slice(),
                k: req.k,
                options: req.options,
            })
            .collect();
        let outcomes = self.search_batch_mixed(&items);
        QueryResponse::from_results(
            outcomes,
            req.options.want_stats,
            t0.elapsed().as_micros() as u64,
        )
    }

    /// Answer one query (Algorithm 1 with service-default options).
    pub fn search(&self, q: &[f32], k: usize) -> SearchOutput {
        let mut scratch = self.scratch.checkout();
        self.search_with_scratch(q, k, &mut scratch)
    }

    /// Answer one query using caller-held scratch (the worker hot path:
    /// zero heap allocations in steady state apart from the output
    /// buffers).
    pub fn search_with_scratch(
        &self,
        q: &[f32],
        k: usize,
        scratch: &mut ServiceScratch,
    ) -> SearchOutput {
        self.search_with_options(q, k, &QueryOptions::default(), scratch)
    }

    /// Answer one query under per-request [`QueryOptions`]: the mode
    /// selects which policy runs over the unified kernel, the remaining
    /// fields override the service's `SearchParams`/`ProximaFeatures`
    /// for this request only. Defaults reproduce [`Self::search`] exactly.
    pub fn search_with_options(
        &self,
        q: &[f32],
        k: usize,
        options: &QueryOptions,
        scratch: &mut ServiceScratch,
    ) -> SearchOutput {
        let ServiceScratch { adt, walk } = scratch;
        let needs_adt = options.mode != SearchMode::Accurate;
        let mut adt_build_us = 0u64;
        if needs_adt {
            let b0 = self.obs.now_us();
            self.build_adt_into(q, adt);
            adt_build_us = self.obs.now_us().saturating_sub(b0);
        }
        self.run_query(
            q,
            k,
            options,
            needs_adt.then_some(&*adt),
            needs_adt,
            adt_build_us,
            walk,
        )
    }

    /// The per-query engine: run one walk over the unified kernel with an
    /// already-staged ADT (`None` for `Accurate` mode). `fresh_adt`
    /// charges `stats.adt_builds` to the query that triggered its
    /// table's build — batch dedup makes the batch aggregate equal the
    /// number of DISTINCT tables built, not the number of queries.
    /// `adt_build_us` is the caller-measured table-build time for THIS
    /// query (0 when the table came staged from a batch — the batch
    /// path charges its build to the stage histogram directly).
    #[allow(clippy::too_many_arguments)]
    fn run_query(
        &self,
        q: &[f32],
        k: usize,
        options: &QueryOptions,
        adt: Option<&Adt>,
        fresh_adt: bool,
        adt_build_us: u64,
        walk: &mut QueryScratch,
    ) -> SearchOutput {
        // Service-level timing runs on the obs clock (wall by default,
        // fake in tests) so end-to-end latency histograms are
        // deterministic under an injected clock; the kernel stages
        // inside `out.spans` stay `Instant`-timed.
        let c0 = self.obs.now_us();
        let (params, features) = self.effective(k, options);
        // Pin ONE write-plane snapshot for the whole walk: the query
        // sees exactly that epoch's inserts/tombstones and never blocks
        // on (or races with) concurrent writers.
        let snap = self.online.load();
        let ctx = self.context_at(&snap);
        let mut out = SearchOutput::default();
        match options.mode {
            SearchMode::Accurate => {
                accurate_beam_search_into(&ctx, q, params.k, params.l, false, walk, &mut out);
            }
            SearchMode::PqAdt => {
                let adt = adt.expect("PqAdt query requires a staged ADT");
                let rerank = options.rerank.unwrap_or(params.l);
                pq_beam_search_into(
                    &ctx, adt, q, params.k, params.l, rerank, false, walk, &mut out,
                );
            }
            SearchMode::Hybrid => {
                let adt = adt.expect("Hybrid query requires a staged ADT");
                proxima_search_into(&ctx, adt, q, &params, features, false, walk, &mut out);
            }
        }
        out.stats.adt_builds = fresh_adt as usize;
        self.map_ids(&mut out);
        out.spans.add(crate::obs::Stage::AdtBuild, adt_build_us);
        // The clock total REPLACES the kernel's Instant-based total:
        // one time source end to end keeps the engine histogram
        // deterministic under an injected fake clock.
        out.spans.total_us = self.obs.now_us().saturating_sub(c0) + adt_build_us;
        self.record(&out.stats, &out.spans);
        out
    }

    /// Translate stored-space result ids back to original ids when this
    /// index was opened from a reordered artifact (k lookups per query —
    /// off the traversal hot loop). Delta ids (online inserts, past the
    /// frozen permutation) are never permuted: they name themselves.
    fn map_ids(&self, out: &mut SearchOutput) {
        if let Some(map) = &self.id_map {
            for id in out.ids.iter_mut() {
                if (*id as usize) < map.len() {
                    *id = map[*id as usize];
                }
            }
        }
    }

    /// Answer one query with an externally provided ADT (the batcher's
    /// path: ADTs built in a batch up front).
    pub fn search_with_adt(&self, q: &[f32], adt: &Adt, k: usize) -> SearchOutput {
        let c0 = self.obs.now_us();
        let mut params = self.params;
        params.k = k.min(params.l);
        let mut scratch = self.scratch.checkout();
        let mut out = SearchOutput::default();
        let snap = self.online.load();
        proxima_search_into(
            &self.context_at(&snap),
            adt,
            q,
            &params,
            self.features,
            false,
            &mut scratch.walk,
            &mut out,
        );
        self.map_ids(&mut out);
        out.spans.total_us = self.obs.now_us().saturating_sub(c0);
        self.record(&out.stats, &out.spans);
        out
    }

    // -----------------------------------------------------------------
    // Write plane: insert / delete / flush (the `online` subsystem,
    // threaded through the typed API). Queries admitted concurrently
    // never block on these — they pin a published snapshot and walk it.
    // -----------------------------------------------------------------

    /// Insert one vector into the served index. Returns `(id, epoch)`:
    /// the id names the vector in results (delta ids start at `n_base`
    /// and are never permuted by a §IV-E reorder — they name
    /// themselves), and any query admitted after this returns can find
    /// it. Under `Metric::Angular` the stored copy is normalized, like
    /// the offline build path.
    pub fn insert(&self, vector: &[f32]) -> Result<(u32, u64), ApiError> {
        if vector.len() != self.dim() {
            return Err(ApiError::dim_mismatch(format!(
                "insert: expected dim {}, got {}",
                self.dim(),
                vector.len()
            )));
        }
        if let Some(x) = vector.iter().find(|x| !x.is_finite()) {
            return Err(ApiError::bad_request(format!(
                "insert: non-finite value {x}"
            )));
        }
        let mut scratch = self.scratch.checkout();
        self.online
            .insert(&self.index_refs(), vector, &mut scratch.walk)
            .map_err(ApiError::internal)
    }

    /// Tombstone `id` (ORIGINAL id space, like every result list).
    /// Returns `(deleted, epoch)` — `deleted` is false when the id was
    /// already tombstoned (idempotent). The vector stops being
    /// returnable the moment this returns but stays traversable until
    /// repair/flush splices it out, so recall survives churn.
    pub fn delete(&self, id: u32) -> Result<(bool, u64), ApiError> {
        // A reordered artifact stores base vectors in the permuted
        // space; clients speak original ids. Delta ids (past the
        // permutation) are identical in both spaces.
        let stored = match &self.reorder {
            Some(perm) if (id as usize) < perm.len() => perm[id as usize],
            _ => id,
        };
        self.online
            .delete(&self.index_refs(), stored)
            .map_err(ApiError::bad_request)
    }

    /// Current write-plane publish epoch (monotonic across flush swaps).
    pub fn online_epoch(&self) -> u64 {
        self.online.epoch()
    }

    /// Compact the live index (tombstones dropped, delta merged,
    /// PQ codes recomputed), re-save it as a versioned artifact, and
    /// open the successor service the caller hot-swaps in (via
    /// [`ServiceCell::swap`] on the serving path).
    ///
    /// `path` defaults to the artifact this service was opened from; a
    /// built (never-saved) index must name one explicitly. The whole
    /// critical section — compact, persist, reopen — runs under the
    /// writer lock ([`OnlineState::run_exclusive`]), so no insert or
    /// delete can land between the compacted image and the swap and be
    /// silently dropped; queries are never blocked (they read published
    /// snapshots only). The successor's write plane starts clean at
    /// `epoch + 1` with the predecessor's lifetime counters and
    /// repair cadence carried over.
    pub fn flush(&self, path: Option<&Path>) -> Result<FlushOutcome, ApiError> {
        let path: PathBuf = match path {
            Some(p) => p.to_path_buf(),
            None => match &self.provenance {
                IndexProvenance::Artifact { path } => PathBuf::from(path),
                IndexProvenance::Built => {
                    return Err(ApiError::bad_request(
                        "flush of a built (unsaved) index requires an explicit path",
                    ));
                }
            },
        };
        let idx = self.index_refs();
        // NOTE: the closure must not call self.online.insert/delete —
        // the writer mutex is not reentrant.
        self.online.run_exclusive(|| {
            let cur = self.online.load();
            let image = compact(&cur, &idx).map_err(ApiError::bad_request)?;
            let n_live = image.base.len();

            // Rebuild the derived structures over the compacted id
            // space: codes are REcomputed (not carried stale), the
            // graph/gap come from the spliced+renumbered lists.
            let codes = self.codebook.encode(&image.base);
            let graph = Graph::from_lists(&image.lists, image.entry_point, self.graph_params.r);
            let gap = GapGraph::encode(&image.lists);

            // Re-stamp the spec for the compacted reality so
            // `check_compatible`/`open` see a consistent artifact.
            let mut spec = self.spec.clone();
            spec.n_base = n_live as u64;

            // Fresh §IV-E layout: the compaction renumbered ids, so the
            // predecessor's physical addresses are meaningless here.
            let b_index = gap
                .mean_bits_per_edge(graph.n_edges().max(1))
                .ceil() as u32;
            let mapping = DataMapping::new(
                &NandConfig::proxima(),
                n_live as u32,
                graph.max_degree.max(1) as u32,
                b_index.clamp(1, 32),
                (self.codebook.m * 8) as u32,
                self.dim() as u32,
                32,
                spec.hot_frac,
            );

            // Compaction renumbered ids and rewrote the base rows, so
            // the persisted LSH signatures must be recomputed (same bit
            // count and seed: the hyperplanes are a function of both).
            let lsh = self
                .lsh
                .as_ref()
                .map(|l| LshIndex::build(&image.base, l.n_bits(), l.seed()));

            ArtifactParts {
                spec: &spec,
                base: &image.base,
                graph: &graph,
                gap: Some(&gap),
                codebook: &self.codebook,
                codes: &codes,
                reorder: None,
                mapping: Some(&mapping),
                lsh: lsh.as_ref(),
            }
            .write(&path)
            .map_err(|e| ApiError::internal(format!("flush write: {e}")))?;

            // The successor inherits the full open configuration, not
            // just the residency: cache layer (policy + capacity) and
            // LSH warm starts survive a flush swap.
            let reopen_opts = OpenOptions {
                residency: self.storage.residency(),
                cache_policy: self
                    .storage
                    .row_cache()
                    .map(|c| c.policy())
                    .unwrap_or_default(),
                tiered_cache_bytes: match self.storage.residency() {
                    Residency::Tiered => self.storage.row_cache().map(|c| c.capacity_bytes()),
                    _ => None,
                },
                lsh_start: self.use_lsh,
            };
            let mut svc = SearchService::open_with(
                &path,
                self.params,
                self.xla_preferred,
                &reopen_opts,
            )
            .map_err(|e| ApiError::internal(format!("flush reopen: {e}")))?;
            if !self.uses_shared_pool() {
                svc = svc.with_workers(self.workers);
            }
            svc.features = self.features;
            // Seed the successor's write plane past this epoch so
            // clients observe monotonic epochs across the swap, and
            // carry the lifetime totals (status reports since-boot
            // numbers, not since-flush).
            self.online
                .counters()
                .flushes_total
                .fetch_add(1, Ordering::Relaxed);
            svc.online =
                OnlineState::with_epoch(svc.n_base(), svc.dim(), svc.codebook.m, cur.epoch + 1);
            svc.online.counters().adopt(self.online.counters());
            svc.online.set_repair_every(self.online.repair_every());
            // The observability plane is lifetime, not per-epoch: the
            // successor adopts the same histogram/counter handle, but
            // the slow-query ring is cleared — its spans were measured
            // against the predecessor's graph and residency.
            svc.obs = self.obs.clone();
            svc.obs.slowlog().clear();
            // Compaction renumbered STORED ids; translate to the
            // client-visible space (delta ids past the permutation are
            // identical in both).
            let new_to_old: Vec<u32> = image
                .new_to_old
                .iter()
                .map(|&old| match &self.id_map {
                    Some(map) if (old as usize) < map.len() => map[old as usize],
                    _ => old,
                })
                .collect();
            Ok(FlushOutcome {
                service: Arc::new(svc),
                path: path.display().to_string(),
                n_live,
                epoch: cur.epoch + 1,
                new_to_old,
            })
        })
    }

    /// Exact (linear-scan) nearest neighbors over the LIVE id set —
    /// base rows minus tombstones plus the delta region — in ORIGINAL
    /// id space. The ground truth for recall-over-time measurement
    /// under churn (`loadgen::run_mixed`); O(n·dim) per call, not a
    /// serving path.
    pub fn exact_nn_live(&self, q: &[f32], k: usize) -> Vec<u32> {
        let snap = self.online.load();
        let src = if snap.delta().is_empty() {
            RowSource::Store(&self.storage)
        } else {
            RowSource::StoreDelta(&self.storage, snap.delta())
        };
        // Pad the query to the stored stride so distances run in the
        // padded layout, exactly like the serving path.
        let mut qbuf = AlignedBuf::new();
        let qp = qbuf.fill_padded(q, self.storage.stride());
        let mut buf = ReadBuf::default();
        let mut stats = SearchStats::default();
        let mut best: Vec<(f32, u32)> = Vec::with_capacity(src.len());
        for id in 0..src.len() as u32 {
            if snap.is_tombstoned(id) {
                continue;
            }
            let row = src.get(id, &mut buf, &mut stats);
            best.push((self.metric.distance(qp, row), id));
        }
        best.sort_by(|a, b| a.partial_cmp(b).unwrap());
        best.truncate(k);
        let mut ids: Vec<u32> = best.into_iter().map(|(_, id)| id).collect();
        if let Some(map) = &self.id_map {
            for id in ids.iter_mut() {
                if (*id as usize) < map.len() {
                    *id = map[*id as usize];
                }
            }
        }
        ids
    }

    /// Answer a whole batch with service-default options (see
    /// [`Self::search_batch_with_options`]).
    pub fn search_batch(&self, queries: &[&[f32]], k: usize) -> Vec<SearchOutput> {
        self.search_batch_with_options(queries, k, &QueryOptions::default())
    }

    /// Answer a whole batch through the staged pipeline (see the module
    /// docs): one batched, deduplicated ADT-build pass, then per-query
    /// walk tasks submitted individually to the exec pool so
    /// work-stealing absorbs skewed per-query cost. All queries share
    /// the request's [`QueryOptions`]; results come back in input order.
    ///
    /// This infallible convenience panics if a query task panics; the
    /// typed path ([`Self::query`]) and [`Self::search_batch_mixed`]
    /// contain such failures per query instead.
    pub fn search_batch_with_options(
        &self,
        queries: &[&[f32]],
        k: usize,
        options: &QueryOptions,
    ) -> Vec<SearchOutput> {
        let items: Vec<BatchQuery> = queries
            .iter()
            .map(|q| BatchQuery {
                q,
                k,
                options: *options,
            })
            .collect();
        self.search_batch_mixed(&items)
            .into_iter()
            .enumerate()
            .map(|(i, r)| r.unwrap_or_else(|e| panic!("batch query {i} failed: {e}")))
            .collect()
    }

    /// Answer a heterogeneous batch — every [`BatchQuery`] carries its
    /// own `k` and [`QueryOptions`] (the dynamic batcher's coalesced
    /// requests take this path) — through the staged pipeline:
    ///
    /// 1. PQ-guided queries are deduplicated and their ADTs built in one
    ///    blocked pass over pooled tables (stage 1);
    /// 2. every query becomes one work-stealing pool task running the
    ///    walk against its staged table (stage 2).
    ///
    /// Results return in input order. A panicking query task yields
    /// `Err(Internal)` for THAT query only — batch-mates complete
    /// normally and the pool survives.
    pub fn search_batch_mixed(
        &self,
        items: &[BatchQuery<'_>],
    ) -> Vec<Result<SearchOutput, ApiError>> {
        if items.is_empty() {
            return Vec::new();
        }

        // ---- Stage 1: staged batch ADT build over distinct queries.
        // Runs for BOTH the serial and the pooled stage-2 below, so the
        // dedup contract (`adt_builds` = distinct tables, not queries)
        // does not depend on the machine's width.
        let mut pq_items: Vec<usize> = Vec::new();
        let mut pq_queries: Vec<&[f32]> = Vec::new();
        for (i, it) in items.iter().enumerate() {
            if it.options.mode != SearchMode::Accurate {
                pq_items.push(i);
                pq_queries.push(it.q);
            }
        }
        let mut batch_guard = (!pq_queries.is_empty()).then(|| self.adt_batches.checkout());
        // (table index, is-the-build-charged-here) per item.
        let mut adt_slot: Vec<Option<(usize, bool)>> = vec![None; items.len()];
        if let Some(batch) = batch_guard.as_mut() {
            // Contain stage-1 panics (e.g. a wrong-dimension vector
            // through this validation-skipping internal path): leave
            // every slot unstaged so stage 2 falls back to per-query
            // builds INSIDE its per-query catch — the malformed query
            // then fails alone instead of killing the caller (the
            // batcher-loop survival contract).
            let b0 = self.obs.now_us();
            let staged_ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                self.stage_adt_batch(&pq_queries, batch)
            }))
            .is_ok();
            // Staged builds are shared across the batch, so their time
            // is charged to the stage histogram ONCE per batch rather
            // than split across per-query spans (which report 0 for
            // staged tables).
            self.obs.record_stage(
                crate::obs::Stage::AdtBuild,
                self.obs.now_us().saturating_sub(b0),
            );
            if staged_ok {
                for (f, &i) in pq_items.iter().enumerate() {
                    adt_slot[i] = Some((batch.table_index(f), batch.is_fresh(f)));
                }
            }
        }
        let staged: Option<&AdtBatch> = batch_guard.as_deref();

        // Per-item execution, shared by the serial and pooled stage 2:
        // staged table when stage 1 produced one, else a per-query build
        // into the worker's own scratch (stage-1 fallback).
        let run_item = |i: usize, scratch: &mut ServiceScratch| -> SearchOutput {
            let it = &items[i];
            let ServiceScratch { adt, walk } = scratch;
            let (adt_ref, fresh, adt_build_us) = match adt_slot[i] {
                Some((d, fresh)) => (Some(staged.expect("staged batch").table(d)), fresh, 0),
                None if it.options.mode != SearchMode::Accurate => {
                    let b0 = self.obs.now_us();
                    self.build_adt_into(it.q, adt);
                    (
                        Some(&*adt),
                        true,
                        self.obs.now_us().saturating_sub(b0),
                    )
                }
                None => (None, false, 0),
            };
            self.run_query(it.q, it.k, &it.options, adt_ref, fresh, adt_build_us, walk)
        };

        if items.len() == 1 || self.workers <= 1 {
            // Serial stage 2: same staged tables, same per-query panic
            // containment (so the batcher loop gets one contract either
            // way), no pool traffic, queue-wait 0 by definition.
            let mut scratch = self.scratch.checkout();
            return items
                .iter()
                .enumerate()
                .map(|(i, _)| {
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        run_item(i, &mut scratch)
                    }))
                    .map_err(|_| {
                        ApiError::internal(format!("search worker panicked on query {i}"))
                    })
                })
                .collect();
        }

        // ---- Stage 2: one pool task per query, per-worker pinned
        // scratch, queue-wait metered.
        let results = self.exec.run_collect(items.len(), |i| {
            WORKER_SCRATCH.with(|cell| {
                let mut scratch = cell.borrow_mut();
                let out = run_item(i, &mut scratch);
                trim_worker_scratch(&mut scratch);
                out
            })
        });

        let mut queue_wait_total = 0u64;
        let outcomes = results
            .into_iter()
            .enumerate()
            .map(|(i, r)| {
                queue_wait_total += r.queue_wait_us;
                // Queue wait is only knowable here (after the pool ran
                // the task), so it reaches the stage histogram and the
                // output spans but NOT the slowlog entry recorded
                // inside `run_query`.
                self.obs
                    .record_stage(crate::obs::Stage::QueueWait, r.queue_wait_us);
                match r.value {
                    Some(mut out) => {
                        out.stats.queue_wait_us = r.queue_wait_us;
                        out.spans.add(crate::obs::Stage::QueueWait, r.queue_wait_us);
                        Ok(out)
                    }
                    None => Err(ApiError::internal(format!(
                        "search worker panicked on query {i}"
                    ))),
                }
            })
            .collect();
        self.stats
            .queue_wait_us
            .fetch_add(queue_wait_total, Ordering::Relaxed);
        outcomes
    }

    /// Stage 1 of the batch pipeline: plan the dedup, then fill one
    /// pooled table per distinct query — through the AOT/XLA runtime
    /// when attached (serialized on its submission thread; dedup is
    /// still the win), natively in parallel groups on the exec pool for
    /// large plans, or in one blocked sweep on the submitting thread.
    fn stage_adt_batch(&self, queries: &[&[f32]], batch: &mut AdtBatch) {
        batch.plan(queries);
        let (rep, tables) = batch.split();
        if let Some(rt) = &self.runtime {
            // ONE runtime submission for the whole distinct set: the
            // distinct queries cross the runtime-thread channel once and
            // the tables come back concatenated — the per-distinct
            // round-trips (send, device dispatch, recv per table) were
            // the staged path's XLA overhead. Any failure falls back to
            // the native blocked sweep below, exactly like the
            // single-query path does.
            let dim = self.dim();
            let mut flat: Vec<f32> = Vec::with_capacity(tables.len() * dim);
            for &r in rep.iter() {
                flat.extend_from_slice(queries[r as usize]);
            }
            match rt.build_adt_batch(&flat, tables.len()) {
                Ok(out) => {
                    let stride = self.codebook.m * self.codebook.c;
                    debug_assert_eq!(out.len(), tables.len() * stride);
                    for (di, table) in tables.iter_mut().enumerate() {
                        table.m = self.codebook.m;
                        table.c = self.codebook.c;
                        table.table.clear();
                        table
                            .table
                            .extend_from_slice(&out[di * stride..(di + 1) * stride]);
                    }
                    return;
                }
                Err(e) => {
                    crate::log_warn!("XLA batch ADT failed ({e:#}); using native path");
                }
            }
        }
        const PAR_GROUP: usize = 8;
        if tables.len() >= 2 * PAR_GROUP {
            let mut groups: Vec<&mut [Adt]> = tables.chunks_mut(PAR_GROUP).collect();
            let metas = self.exec.run_on_slice(&mut groups, |g, chunk| {
                let start = g * PAR_GROUP;
                let reps = &rep[start..start + chunk.len()];
                self.codebook.build_adt_for(queries, reps, chunk);
            });
            drop(groups);
            if metas.iter().any(|m| m.panicked) {
                // The sweep has no data-dependent panics, so this can
                // only be a logic bug; rebuild serially rather than let
                // walks run against a partially-built table, so the
                // failure reproduces deterministically on this thread.
                self.codebook.build_adt_for(queries, rep, tables);
            }
        } else {
            self.codebook.build_adt_for(queries, rep, tables);
        }
    }

    /// Record one finished query into BOTH planes: the per-epoch
    /// `ServiceStats` counters and the lifetime `obs` histograms +
    /// slowlog (`spans.total_us` is the query's end-to-end latency).
    fn record(&self, s: &SearchStats, spans: &crate::obs::StageSpans) {
        self.stats.queries.fetch_add(1, Ordering::Relaxed);
        self.stats
            .pq_dists
            .fetch_add(s.pq_dists as u64, Ordering::Relaxed);
        self.stats
            .exact_dists
            .fetch_add(s.exact_dists as u64, Ordering::Relaxed);
        if s.cold_reads > 0 {
            self.stats
                .cold_reads
                .fetch_add(s.cold_reads as u64, Ordering::Relaxed);
            self.stats
                .cold_bytes
                .fetch_add(s.cold_bytes, Ordering::Relaxed);
        }
        if s.cache_hits > 0 {
            self.stats
                .cache_hits
                .fetch_add(s.cache_hits as u64, Ordering::Relaxed);
        }
        if s.cache_misses > 0 {
            self.stats
                .cache_misses
                .fetch_add(s.cache_misses as u64, Ordering::Relaxed);
        }
        if s.lsh_probes > 0 {
            self.stats
                .lsh_probes
                .fetch_add(s.lsh_probes as u64, Ordering::Relaxed);
        }
        if s.early_terminated {
            self.stats.early_terminated.fetch_add(1, Ordering::Relaxed);
        }
        self.stats
            .total_latency_us
            .fetch_add(spans.total_us, Ordering::Relaxed);
        self.obs.record_query(spans, s);
    }

    /// Tasks currently queued or executing on this service's exec pool
    /// (the shed signal; exported as the `proxima_exec_pending` gauge
    /// and the status op's `admission.exec_pending` field).
    pub fn exec_pending(&self) -> usize {
        self.exec.pending()
    }

    /// Mean service latency in microseconds.
    pub fn mean_latency_us(&self) -> f64 {
        let q = self.stats.queries.load(Ordering::Relaxed);
        if q == 0 {
            0.0
        } else {
            self.stats.total_latency_us.load(Ordering::Relaxed) as f64 / q as f64
        }
    }
}

/// Hard cap on per-request candidate-list capacity (`l_override`): the
/// list reserves L slots up front, so this bounds the scratch allocation
/// one request can demand. Beam widths beyond this are never useful.
pub const MAX_L_OVERRIDE: usize = 1 << 20;

/// Everything one [`SearchService::flush`] produced: the successor
/// service (already opened from the compacted artifact, write plane
/// seeded past the predecessor's epoch) plus the numbers the wire
/// response reports. The caller hot-swaps `service` in (the server's
/// flush op does this through its [`ServiceCell`]).
pub struct FlushOutcome {
    pub service: Arc<SearchService>,
    /// Where the compacted artifact was written.
    pub path: String,
    /// Live vectors in the compacted index (`spec.n_base` of the
    /// successor).
    pub n_live: usize,
    /// The successor's starting epoch (predecessor's last + 1).
    pub epoch: u64,
    /// `new_to_old[new]` = the ORIGINAL (client-visible) id each
    /// compacted id was renumbered from — compaction packs survivors
    /// densely, so pre-flush ids shift whenever a base vector was
    /// tombstoned. Clients that cached pre-flush ids translate through
    /// this; with zero deletions it is the identity.
    pub new_to_old: Vec<u32>,
}

/// The swappable serving handle: an `ArcSwap`-style epoch cell holding
/// the currently served [`SearchService`].
///
/// Every dispatch site ([`server`] per wire line, [`batcher`] per flush)
/// calls [`ServiceCell::load`], which clones the inner `Arc` under a
/// briefly-held read lock and runs the query OUTSIDE the lock. A
/// [`ServiceCell::swap`] (the wire `reload` op) publishes a new index
/// for all FUTURE loads; in-flight queries keep their epoch's `Arc`, so
/// they finish on the old index and the old service (graph, vectors,
/// runtime thread) is dropped only when its last in-flight query
/// completes. The write lock is only ever contended for the duration of
/// an `Arc` clone, so reloads never stall the serving path behind a
/// long-running query.
pub struct ServiceCell {
    inner: RwLock<Arc<SearchService>>,
}

impl ServiceCell {
    pub fn new(service: Arc<SearchService>) -> ServiceCell {
        ServiceCell {
            inner: RwLock::new(service),
        }
    }

    /// The current epoch's service. Hold the returned `Arc` for the
    /// duration of ONE request — re-loading per request is what makes
    /// hot swaps take effect.
    pub fn load(&self) -> Arc<SearchService> {
        self.inner
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    /// Publish `next` as the served index; returns the replaced one
    /// (which in-flight queries may still be using).
    pub fn swap(&self, next: Arc<SearchService>) -> Arc<SearchService> {
        let mut guard = self.inner.write().unwrap_or_else(|e| e.into_inner());
        std::mem::replace(&mut *guard, next)
    }
}

/// Default `search_batch` width: one worker per available core.
fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::ground_truth::brute_force;
    use crate::dataset::synth::tiny_uniform;

    fn service() -> (Dataset, SearchService) {
        let ds = tiny_uniform(600, 16, Metric::L2, 81);
        let svc = SearchService::build(
            &ds,
            &GraphParams {
                r: 16,
                build_l: 32,
                alpha: 1.2,
                seed: 81,
            },
            &PqParams {
                m: 8,
                c: 32,
                train_sample: 600,
                kmeans_iters: 8,
            },
            SearchParams {
                l: 80,
                k: 10,
                ..Default::default()
            },
            false,
        );
        (ds, svc)
    }

    #[test]
    fn service_end_to_end_recall() {
        let (ds, svc) = service();
        let gt = brute_force(&ds, 10);
        let mut recall = 0.0;
        for q in 0..ds.n_queries() {
            let out = svc.search(ds.queries.row(q), 10);
            recall += crate::dataset::recall_at_k(&out.ids, gt.row(q), 10);
        }
        recall /= ds.n_queries() as f64;
        assert!(recall > 0.8, "recall {recall}");
        assert_eq!(
            svc.stats.queries.load(Ordering::Relaxed),
            ds.n_queries() as u64
        );
        assert!(svc.mean_latency_us() > 0.0);
    }

    #[test]
    fn search_respects_requested_k() {
        let (ds, svc) = service();
        let out = svc.search(ds.queries.row(0), 3);
        assert_eq!(out.ids.len(), 3);
    }

    #[test]
    fn native_adt_matches_service_adt_without_runtime() {
        let (ds, svc) = service();
        let q = ds.queries.row(0);
        let a = svc.build_adt(q);
        let b = svc.codebook.build_adt(q);
        assert_eq!(a.table, b.table);
    }

    #[test]
    fn search_batch_matches_serial_in_order() {
        let (ds, svc) = service();
        let svc = svc.with_workers(4);
        let queries: Vec<&[f32]> = (0..ds.n_queries()).map(|i| ds.queries.row(i)).collect();
        let serial: Vec<_> = queries.iter().map(|q| svc.search(q, 10)).collect();
        let batch = svc.search_batch(&queries, 10);
        assert_eq!(batch.len(), serial.len());
        for (b, s) in batch.iter().zip(&serial) {
            assert_eq!(b.ids, s.ids, "batch results must match serial, in order");
        }
        assert_eq!(
            svc.stats.queries.load(Ordering::Relaxed),
            2 * ds.n_queries() as u64
        );
    }

    #[test]
    fn query_contract_matches_search() {
        let (ds, svc) = service();
        let q = ds.queries.row(0);
        let direct = svc.search(q, 10);
        let resp = svc.query(&QueryRequest::single(q, 10)).unwrap();
        assert_eq!(resp.results.len(), 1);
        assert_eq!(resp.results[0].ids, direct.ids);
        assert_eq!(resp.results[0].dists, direct.dists);
        assert!(resp.stats.is_none(), "stats are opt-in");

        let queries: Vec<&[f32]> = (0..ds.n_queries()).map(|i| ds.queries.row(i)).collect();
        let batch = svc.query(&QueryRequest::batch(&queries, 10)).unwrap();
        let serial = svc.search_batch(&queries, 10);
        assert_eq!(batch.results.len(), serial.len());
        for (b, s) in batch.results.iter().zip(&serial) {
            assert_eq!(b.ids, s.ids);
        }
    }

    #[test]
    fn query_validates_at_the_boundary() {
        let (ds, svc) = service();
        let q = ds.queries.row(0);

        let wrong_dim = vec![1.0f32; ds.dim() + 3];
        let e = svc
            .query(&QueryRequest::batch(&[q, &wrong_dim], 10))
            .unwrap_err();
        assert_eq!(e.code, crate::api::ApiErrorCode::DimMismatch);
        assert!(e.message.contains("query 1"), "{}", e.message);

        let e = svc
            .query(&QueryRequest {
                vectors: vec![],
                k: 10,
                options: QueryOptions::default(),
            })
            .unwrap_err();
        assert_eq!(e.code, crate::api::ApiErrorCode::BadRequest);

        let e = svc.query(&QueryRequest::single(q, 0)).unwrap_err();
        assert_eq!(e.code, crate::api::ApiErrorCode::BadRequest);

        // An oversized batch is rejected before any search work.
        let big = QueryRequest {
            vectors: vec![vec![0.0f32; ds.dim()]; crate::api::MAX_BATCH_QUERIES + 1],
            k: 10,
            options: QueryOptions::default(),
        };
        let e = svc.query(&big).unwrap_err();
        assert_eq!(e.code, crate::api::ApiErrorCode::BadRequest);

        // An absurd l_override cannot reach the list allocator.
        let e = svc
            .query(&QueryRequest::single(q, 10).with_options(QueryOptions {
                l_override: Some(4_000_000_000),
                ..Default::default()
            }))
            .unwrap_err();
        assert_eq!(e.code, crate::api::ApiErrorCode::BadRequest);

        // Non-finite values cannot reach the distance kernels.
        let mut nan_q = q.to_vec();
        nan_q[0] = f32::NAN;
        let e = svc.query(&QueryRequest::single(&nan_q, 10)).unwrap_err();
        assert_eq!(e.code, crate::api::ApiErrorCode::BadRequest);
        let mut inf_q = q.to_vec();
        inf_q[1] = f32::INFINITY;
        let e = svc.query(&QueryRequest::single(&inf_q, 10)).unwrap_err();
        assert_eq!(e.code, crate::api::ApiErrorCode::BadRequest);
    }

    #[test]
    fn options_change_search_behavior() {
        let (ds, svc) = service();
        let q = ds.queries.row(0);
        let stats_for = |options: QueryOptions| {
            let req = QueryRequest::single(q, 10).with_options(QueryOptions {
                want_stats: true,
                ..options
            });
            svc.query(&req).unwrap().stats.unwrap()
        };

        // Accurate mode never touches PQ; the default (Hybrid) lives on it.
        let acc = stats_for(QueryOptions {
            mode: SearchMode::Accurate,
            ..Default::default()
        });
        assert_eq!(acc.pq_dists, 0);
        assert!(acc.exact_dists > 0);
        let hyb = stats_for(QueryOptions::default());
        assert!(hyb.pq_dists > 0);

        // A larger candidate list does strictly more PQ work.
        let small = stats_for(QueryOptions {
            l_override: Some(20),
            ..Default::default()
        });
        let large = stats_for(QueryOptions {
            l_override: Some(80),
            ..Default::default()
        });
        assert!(
            large.pq_dists > small.pq_dists,
            "l=80 pq {} vs l=20 pq {}",
            large.pq_dists,
            small.pq_dists
        );

        // Disabling early termination via tau=0 never terminates early.
        let noet = stats_for(QueryOptions {
            early_term_tau: Some(0),
            ..Default::default()
        });
        assert!(!noet.early_terminated);

        // PqAdt honors the rerank depth knob.
        let shallow = stats_for(QueryOptions {
            mode: SearchMode::PqAdt,
            rerank: Some(10),
            ..Default::default()
        });
        let deep = stats_for(QueryOptions {
            mode: SearchMode::PqAdt,
            rerank: Some(60),
            ..Default::default()
        });
        assert!(
            deep.exact_dists > shallow.exact_dists,
            "rerank=60 exact {} vs rerank=10 exact {}",
            deep.exact_dists,
            shallow.exact_dists
        );
    }

    #[test]
    fn skewed_mixed_batch_matches_serial_in_order() {
        use crate::api::SearchMode;
        // A batch mixing tiny-L and huge-l_override queries (plus mode
        // skew) must return results identical to serial execution,
        // order-stable by input index, under the work-stealing pool.
        let (ds, svc) = service();
        let svc = svc.with_workers(4);
        let items: Vec<BatchQuery> = (0..ds.n_queries())
            .map(|i| BatchQuery {
                q: ds.queries.row(i),
                k: 10,
                options: match i % 4 {
                    // Adversarial placement: the heavy queries cluster at
                    // the front, where contiguous chunking would pile
                    // them onto one worker.
                    0 => QueryOptions {
                        l_override: Some(400),
                        early_term_tau: Some(0),
                        ..Default::default()
                    },
                    1 => QueryOptions {
                        l_override: Some(12),
                        ..Default::default()
                    },
                    2 => QueryOptions {
                        mode: SearchMode::Accurate,
                        ..Default::default()
                    },
                    _ => QueryOptions::default(),
                },
            })
            .collect();
        let serial: Vec<SearchOutput> = {
            let mut scratch = svc.checkout_scratch();
            items
                .iter()
                .map(|it| svc.search_with_options(it.q, it.k, &it.options, &mut scratch))
                .collect()
        };
        let batch = svc.search_batch_mixed(&items);
        assert_eq!(batch.len(), serial.len());
        for (i, (b, s)) in batch.iter().zip(&serial).enumerate() {
            let b = b.as_ref().expect("no query may fail");
            assert_eq!(b.ids, s.ids, "query {i}: pooled batch vs serial ids");
            assert_eq!(b.dists, s.dists, "query {i}: pooled batch vs serial dists");
        }
    }

    #[test]
    fn batch_stats_report_queue_wait_and_deduped_adt_builds() {
        let (ds, svc) = service();
        let svc = svc.with_workers(2);
        // Duplicate-heavy batch: 4 copies of each of 8 distinct queries.
        let vectors: Vec<Vec<f32>> = (0..32).map(|i| ds.queries.row(i % 8).to_vec()).collect();
        let req = QueryRequest {
            vectors,
            k: 10,
            options: QueryOptions {
                want_stats: true,
                ..Default::default()
            },
        };
        let resp = svc.query(&req).unwrap();
        assert!(!resp.has_errors());
        let stats = resp.stats.unwrap();
        assert_eq!(
            stats.adt_builds, 8,
            "32 duplicate-heavy queries must build only 8 ADT tables"
        );
        // 32 queries over ~2 lanes: the later tasks demonstrably queued.
        assert!(
            stats.queue_wait_us > 0,
            "aggregate queue wait must be measurable, got {}",
            stats.queue_wait_us
        );
        assert!(svc.stats.queue_wait_us.load(Ordering::Relaxed) >= stats.queue_wait_us);
        // Duplicates share a table but still get their own answers.
        for (i, nl) in resp.results.iter().enumerate() {
            assert_eq!(nl.ids, resp.results[i % 8].ids);
        }
    }

    #[test]
    fn panicking_query_fails_alone_in_a_batch() {
        use crate::api::ApiErrorCode;
        let (ds, svc) = service();
        let svc = svc.with_workers(4);
        let mut nan_q = ds.queries.row(0).to_vec();
        // No boundary to bypass: search_batch_mixed is the raw internal
        // path, so the NaN reaches a worker and panics its rerank sort.
        nan_q[3] = f32::NAN;
        let items: Vec<BatchQuery> = vec![
            BatchQuery {
                q: ds.queries.row(1),
                k: 5,
                options: QueryOptions::default(),
            },
            BatchQuery {
                q: &nan_q,
                k: 5,
                options: QueryOptions::default(),
            },
            BatchQuery {
                q: ds.queries.row(2),
                k: 5,
                options: QueryOptions::default(),
            },
        ];
        let outcomes = svc.search_batch_mixed(&items);
        assert_eq!(outcomes[0].as_ref().unwrap().ids.len(), 5);
        let e = outcomes[1].as_ref().unwrap_err();
        assert_eq!(e.code, ApiErrorCode::Internal);
        assert!(e.message.contains("query 1"), "{}", e.message);
        assert_eq!(outcomes[2].as_ref().unwrap().ids.len(), 5);
        // The pool survives for the next batch.
        let ok = svc.search_batch(&[ds.queries.row(3)], 5);
        assert_eq!(ok[0].ids.len(), 5);
    }

    #[test]
    fn worker_pool_lifecycle_shutdown_and_resubmit() {
        let (ds, svc) = service();
        let queries: Vec<&[f32]> = (0..ds.n_queries()).map(|i| ds.queries.row(i)).collect();
        let svc = svc.with_workers(4);
        let first = svc.search_batch(&queries, 10);
        // Swapping widths drops the old dedicated pool (graceful join)
        // and re-submits onto a fresh one; results must be unchanged.
        let svc = svc.with_workers(2);
        let second = svc.search_batch(&queries, 10);
        let svc = svc.with_workers(1); // serial inline
        let third = svc.search_batch(&queries, 10);
        for ((a, b), c) in first.iter().zip(&second).zip(&third) {
            assert_eq!(a.ids, b.ids);
            assert_eq!(a.ids, c.ids);
        }
    }

    #[test]
    fn write_plane_insert_delete_flush_round_trip() {
        let (ds, svc) = service();
        let q = ds.queries.row(0);

        // Boundary validation mirrors the query path.
        let e = svc.insert(&vec![1.0f32; ds.dim() + 1]).unwrap_err();
        assert_eq!(e.code, crate::api::ApiErrorCode::DimMismatch);
        let mut bad = q.to_vec();
        bad[0] = f32::NAN;
        let e = svc.insert(&bad).unwrap_err();
        assert_eq!(e.code, crate::api::ApiErrorCode::BadRequest);

        // An inserted vector is its own nearest neighbor immediately.
        let (id, e1) = svc.insert(q).unwrap();
        assert_eq!(id as usize, ds.n_base());
        let out = svc.search(q, 1);
        assert_eq!(out.ids, vec![id]);

        // Delete excludes it from results at once (idempotently).
        let (deleted, e2) = svc.delete(id).unwrap();
        assert!(deleted && e2 > e1);
        assert!(!svc.delete(id).unwrap().0, "re-delete is a no-op");
        let out = svc.search(q, 5);
        assert!(!out.ids.contains(&id));

        // A built index refuses a pathless flush; with a path it
        // compacts, persists, and hands back a swappable successor.
        let e = svc.flush(None).unwrap_err();
        assert_eq!(e.code, crate::api::ApiErrorCode::BadRequest);
        let path = std::env::temp_dir().join(format!(
            "proxima-coord-flush-{}.pxa",
            std::process::id()
        ));
        let fo = svc.flush(Some(&path)).unwrap();
        assert_eq!(fo.n_live, ds.n_base(), "one insert minus one delete");
        assert_eq!(fo.service.spec.n_base as usize, fo.n_live);
        assert!(fo.epoch > e2, "epochs stay monotonic across the swap");
        assert_eq!(fo.service.online_epoch(), fo.epoch);
        let c = fo.service.online.counters();
        assert_eq!(c.inserts_total.load(Ordering::Relaxed), 1);
        assert_eq!(c.deletes_total.load(Ordering::Relaxed), 1);
        assert_eq!(c.flushes_total.load(Ordering::Relaxed), 1);
        // The successor serves sane results for the surviving ids.
        let out = fo.service.search(ds.queries.row(1), 10);
        assert_eq!(out.ids.len(), 10);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn exact_nn_live_tracks_churn() {
        let (ds, svc) = service();
        let q = ds.queries.row(2);
        let base_gt = svc.exact_nn_live(q, 5);
        assert_eq!(base_gt.len(), 5);
        // Insert the query itself: it becomes the exact top-1.
        let (id, _) = svc.insert(q).unwrap();
        assert_eq!(svc.exact_nn_live(q, 1), vec![id]);
        // Delete it: ground truth reverts to the base answer.
        svc.delete(id).unwrap();
        assert_eq!(svc.exact_nn_live(q, 5), base_gt);
    }

    #[test]
    fn scratch_reuse_is_deterministic() {
        let (ds, svc) = service();
        let mut scratch = svc.checkout_scratch();
        let fresh: Vec<_> = (0..ds.n_queries())
            .map(|i| svc.search(ds.queries.row(i), 10))
            .collect();
        for (i, f) in fresh.iter().enumerate() {
            let r = svc.search_with_scratch(ds.queries.row(i), 10, &mut scratch);
            assert_eq!(r.ids, f.ids, "query {i}: reused scratch changed results");
            assert_eq!(r.dists, f.dists);
        }
    }
}
