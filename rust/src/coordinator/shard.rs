//! Sharded multi-accelerator serving (§IV-E "scalable to support different
//! ANNS dataset scales"): the base set is partitioned across `S` shards,
//! each with its own graph/PQ index (one per simulated accelerator); a
//! request fans out to every shard and the coordinator merges each
//! query's top-k by accurate distance — the standard scale-out pattern
//! for datasets beyond one device's 54 GB.
//!
//! The fan-out speaks the typed query API: [`ShardedService::query`]
//! forwards the whole [`QueryRequest`] (options included) to every shard
//! and merges per query, so per-request knobs behave identically on one
//! shard or fifty.
//!
//! Execution: each shard's sub-query is ONE task on the shared
//! work-stealing pool ([`ExecPool::shared`]); inside its task a shard
//! submits its per-query walks to the SAME pool (nested submission is
//! deadlock-free — waiting submitters help execute). One pool bounds the
//! machine's total compute threads, so there is no per-shard worker
//! budget to split and no thread spawn per request.

use super::SearchService;
use crate::api::{ApiError, NeighborList, QueryRequest, QueryResponse};
use crate::artifact::ArtifactError;
use crate::storage::OpenOptions;
use crate::config::{GraphParams, PqParams, SearchParams};
use crate::dataset::{Dataset, VectorSet};
use crate::exec::ExecPool;
use crate::search::{SearchOutput, SearchStats};
use std::path::{Path, PathBuf};

/// A sharded index: per-shard services plus the id mapping back to the
/// global space.
pub struct ShardedService {
    pub shards: Vec<SearchService>,
    /// global_id = shard_base[s] + local_id ordering is preserved by the
    /// contiguous partitioning.
    pub shard_base: Vec<u32>,
}

impl ShardedService {
    /// Partition `ds` into `n_shards` contiguous slices and build each.
    pub fn build(
        ds: &Dataset,
        n_shards: usize,
        gp: &GraphParams,
        pq: &PqParams,
        params: SearchParams,
    ) -> ShardedService {
        assert!(n_shards >= 1);
        let n = ds.n_base();
        let per = n.div_ceil(n_shards);
        // All shards share the process-wide exec pool (the default for
        // built services), so total compute concurrency is bounded by
        // the pool regardless of shard count — no budget splitting.
        let mut shards = Vec::with_capacity(n_shards);
        let mut shard_base = Vec::with_capacity(n_shards);
        for s in 0..n_shards {
            let lo = s * per;
            let hi = ((s + 1) * per).min(n);
            if lo >= hi {
                break;
            }
            let slice =
                VectorSet::new(ds.dim(), ds.base.data[lo * ds.dim()..hi * ds.dim()].to_vec());
            let sub = Dataset {
                name: format!("{}-shard{s}", ds.name),
                metric: ds.metric,
                base: slice,
                queries: VectorSet::zeros(0, ds.dim()),
            };
            shard_base.push(lo as u32);
            shards.push(SearchService::build(&sub, gp, pq, params, false));
        }
        ShardedService { shards, shard_base }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Persist every shard as its own index artifact under `dir`
    /// (`shard-000.pxa`, `shard-001.pxa`, ...). Returns the written
    /// paths in shard order — the order [`Self::open_shards`] must see
    /// them in, since shard position determines the global-id base.
    pub fn save_shards(&self, dir: &Path) -> Result<Vec<PathBuf>, ArtifactError> {
        std::fs::create_dir_all(dir)
            .map_err(|e| ArtifactError::io(format!("creating {}: {e}", dir.display())))?;
        let paths: Vec<PathBuf> = (0..self.shards.len())
            .map(|s| dir.join(format!("shard-{s:03}.pxa")))
            .collect();
        // Per-shard encode + CRC sweep + atomic write is independent:
        // run the saves in parallel on the shared pool (mirroring
        // [`Self::open_shards`]), then surface the first failure.
        let results = ExecPool::shared()
            .run_collect(self.shards.len(), |s| self.shards[s].save(&paths[s]));
        for (s, r) in results.into_iter().enumerate() {
            r.value.ok_or_else(|| {
                ArtifactError::io(format!("saving shard {s}: worker task panicked"))
            })??;
        }
        Ok(paths)
    }

    /// Open per-shard artifacts as one sharded service — the scale-out
    /// restart path: no dataset, no rebuilds, shards mapped back into
    /// the global id space by their position in `paths` (shard `s`
    /// serves global ids `[sum of earlier shard sizes, +its size)`,
    /// matching how [`Self::build`] partitioned contiguously).
    ///
    /// Every artifact must agree on dimension and metric; a foreign
    /// shard file fails with a typed spec mismatch instead of silently
    /// merging distances from incompatible spaces.
    pub fn open_shards(
        paths: &[PathBuf],
        params: SearchParams,
    ) -> Result<ShardedService, ArtifactError> {
        Self::open_shards_with(paths, params, &OpenOptions::default())
    }

    /// [`Self::open_shards`] with an explicit vector residency — every
    /// shard opens under the same tier (`cold`/`tiered` shards serve
    /// their raw vectors in place from their own artifact file).
    pub fn open_shards_with(
        paths: &[PathBuf],
        params: SearchParams,
        opts: &OpenOptions,
    ) -> Result<ShardedService, ArtifactError> {
        if paths.is_empty() {
            return Err(ArtifactError::spec_mismatch(
                "open_shards requires at least one artifact path",
            ));
        }
        // Open (file read + CRC sweep + structural validation) every
        // shard in parallel on the shared pool — the dominant restart
        // cost is per-file and independent. Ordering/consistency checks
        // run afterwards, in shard order.
        let results = ExecPool::shared().run_collect(paths.len(), |s| {
            SearchService::open_with(&paths[s], params, false, opts)
        });
        let mut opened = Vec::with_capacity(paths.len());
        for (s, r) in results.into_iter().enumerate() {
            let svc = r.value.ok_or_else(|| {
                ArtifactError::io(format!("opening shard {s}: worker task panicked"))
            })??;
            opened.push(svc);
        }
        let mut shards: Vec<SearchService> = Vec::with_capacity(paths.len());
        let mut shard_base = Vec::with_capacity(paths.len());
        let mut next_base = 0u64;
        let mut stem0: Option<String> = None;
        for (s, (path, svc)) in paths.iter().zip(opened).enumerate() {
            // Shard artifacts are named `<dataset>-shard<N>` by
            // [`Self::build`]; global ids are `shard_base[s] + local`,
            // so a path list in the wrong order (e.g. reconstructed
            // from an unsorted readdir) would silently shift every
            // merged id into the wrong shard's range. Enforce that
            // position `s` really holds shard `s` of one dataset.
            let (stem, idx) = svc
                .spec
                .dataset
                .rsplit_once("-shard")
                .and_then(|(stem, idx)| Some((stem.to_string(), idx.parse::<usize>().ok()?)))
                .ok_or_else(|| {
                    ArtifactError::spec_mismatch(format!(
                        "{}: '{}' is not a shard artifact (expected '<dataset>-shard<N>')",
                        path.display(),
                        svc.spec.dataset
                    ))
                })?;
            if idx != s {
                return Err(ArtifactError::spec_mismatch(format!(
                    "{} holds shard {idx} but was passed at position {s} — \
                     pass the paths in shard order (save_shards returns them)",
                    path.display()
                )));
            }
            match &stem0 {
                None => stem0 = Some(stem),
                Some(expect) if *expect != stem => {
                    return Err(ArtifactError::spec_mismatch(format!(
                        "{} belongs to dataset '{stem}', not '{expect}'",
                        path.display()
                    )));
                }
                Some(_) => {}
            }
            if let Some(first) = shards.first() {
                if svc.dim() != first.dim() || svc.metric != first.metric {
                    return Err(ArtifactError::spec_mismatch(format!(
                        "shard {} ({}d, {}) does not match shard 0 ({}d, {})",
                        path.display(),
                        svc.dim(),
                        svc.metric.name(),
                        first.dim(),
                        first.metric.name()
                    )));
                }
            }
            if next_base + svc.n_base() as u64 > u32::MAX as u64 {
                return Err(ArtifactError::spec_mismatch(
                    "combined shards exceed the u32 global-id space",
                ));
            }
            shard_base.push(next_base as u32);
            next_base += svc.n_base() as u64;
            shards.push(svc);
        }
        Ok(ShardedService { shards, shard_base })
    }

    /// Fan a whole [`QueryRequest`] out to all shards — one task per
    /// shard on the shared exec pool, the caller helping while it waits —
    /// then merge each query's top-k by reported (accurate) distance,
    /// mapping local ids back to the global space. A shard task that
    /// panics outside the per-query walks fails the whole request as
    /// `Internal` (a missing shard would silently degrade recall);
    /// per-query walk panics INSIDE a shard are contained per query and
    /// propagate through the merged response's `errors`.
    pub fn query(&self, req: &QueryRequest) -> Result<QueryResponse, ApiError> {
        let t0 = std::time::Instant::now();
        let first = self
            .shards
            .first()
            .ok_or_else(|| ApiError::internal("sharded service has no shards"))?;
        // Validate ONCE at the fan-out (shards share dim, and the
        // request-size caps are constants all shards agree on), then
        // dispatch through the pre-validated entry point so the full
        // per-vector scan is not repeated on every shard.
        first.validate(req)?;

        let responses: Vec<QueryResponse> = if self.shards.len() == 1 {
            vec![first.query_prevalidated(req)]
        } else {
            let fanned = ExecPool::shared()
                .run_collect(self.shards.len(), |s| self.shards[s].query_prevalidated(req));
            let mut responses = Vec::with_capacity(fanned.len());
            for (s, r) in fanned.into_iter().enumerate() {
                match r.value {
                    Some(resp) => responses.push(resp),
                    None => {
                        return Err(ApiError::internal(format!("shard {s} fan-out task panicked")))
                    }
                }
            }
            responses
        };

        let n_queries = req.vectors.len();
        let mut results = Vec::with_capacity(n_queries);
        let mut errors: Vec<Option<ApiError>> = Vec::new();
        let mut merged: Vec<(f32, u32)> = Vec::with_capacity(req.k * self.shards.len());
        for qi in 0..n_queries {
            // A query that failed on ANY shard is reported failed: a
            // partial merge would silently return degraded neighbors.
            if let Some(e) = responses.iter().find_map(|r| r.error_for(qi)) {
                errors.resize(n_queries, None);
                errors[qi] = Some(e.clone());
                results.push(NeighborList::default());
                continue;
            }
            merged.clear();
            for (s, resp) in responses.iter().enumerate() {
                let nl = &resp.results[qi];
                for (d, id) in nl.dists.iter().zip(&nl.ids) {
                    merged.push((*d, self.shard_base[s] + id));
                }
            }
            merged.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then_with(|| a.1.cmp(&b.1)));
            merged.truncate(req.k);
            results.push(NeighborList {
                ids: merged.iter().map(|&(_, v)| v).collect(),
                dists: merged.iter().map(|&(d, _)| d).collect(),
            });
        }

        let stats = req.options.want_stats.then(|| {
            let mut s = SearchStats::default();
            for resp in &responses {
                if let Some(rs) = &resp.stats {
                    s.add(rs);
                }
            }
            s
        });
        Ok(QueryResponse {
            results,
            errors,
            stats,
            server_latency_us: t0.elapsed().as_micros() as u64,
        })
    }

    /// One query with default options (a convenience over
    /// [`Self::query`], kept for the figure harnesses and examples).
    pub fn search(&self, q: &[f32], k: usize) -> SearchOutput {
        let mut req = QueryRequest::single(q, k);
        req.options.want_stats = true;
        let resp = self.query(&req).expect("sharded query failed");
        let nl = resp
            .results
            .into_iter()
            .next()
            .expect("one query, one result");
        SearchOutput {
            ids: nl.ids,
            dists: nl.dists,
            stats: resp.stats.unwrap_or_default(),
            trace: None,
            spans: Default::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::ground_truth::brute_force;
    use crate::dataset::synth::tiny_uniform;
    use crate::distance::Metric;

    fn build_sharded(n_shards: usize) -> (Dataset, ShardedService) {
        let ds = tiny_uniform(600, 12, Metric::L2, 31);
        let sh = ShardedService::build(
            &ds,
            n_shards,
            &GraphParams {
                r: 12,
                build_l: 24,
                alpha: 1.2,
                seed: 31,
            },
            &PqParams {
                m: 6,
                c: 32,
                train_sample: 600,
                kmeans_iters: 5,
            },
            SearchParams {
                l: 60,
                k: 10,
                ..Default::default()
            },
        );
        (ds, sh)
    }

    #[test]
    fn sharded_recall_matches_single_shard() {
        let (ds, sh1) = build_sharded(1);
        let (_, sh4) = build_sharded(4);
        assert_eq!(sh4.n_shards(), 4);
        let gt = brute_force(&ds, 10);
        let recall = |sh: &ShardedService| {
            let mut r = 0.0;
            for qi in 0..ds.n_queries() {
                let out = sh.search(ds.queries.row(qi), 10);
                r += crate::dataset::recall_at_k(&out.ids, gt.row(qi), 10);
            }
            r / ds.n_queries() as f64
        };
        let r1 = recall(&sh1);
        let r4 = recall(&sh4);
        assert!(r1 > 0.75, "single shard recall {r1}");
        // Sharded search evaluates each partition independently — recall
        // should be at least as good (smaller per-shard search spaces).
        assert!(r4 >= r1 - 0.05, "r1={r1} r4={r4}");
    }

    #[test]
    fn global_ids_are_valid_and_sorted() {
        let (ds, sh) = build_sharded(3);
        let out = sh.search(ds.queries.row(0), 10);
        assert_eq!(out.ids.len(), 10);
        assert!(out.ids.iter().all(|&id| (id as usize) < ds.n_base()));
        assert!(out.dists.windows(2).all(|w| w[0] <= w[1]));
        // Distances must be the true global distances.
        for (d, id) in out.dists.iter().zip(&out.ids) {
            let want = ds.metric.distance(ds.queries.row(0), ds.base.row(*id as usize));
            assert!((d - want).abs() < 1e-5);
        }
    }

    #[test]
    fn batched_query_contract_fans_out_with_options() {
        use crate::api::{QueryOptions, QueryRequest, SearchMode};
        let (ds, sh) = build_sharded(3);
        let queries: Vec<&[f32]> = (0..4).map(|qi| ds.queries.row(qi)).collect();
        let req = QueryRequest::batch(&queries, 10).with_options(QueryOptions {
            want_stats: true,
            ..Default::default()
        });
        let resp = sh.query(&req).unwrap();
        assert_eq!(resp.results.len(), 4);
        for (qi, nl) in resp.results.iter().enumerate() {
            let single = sh.search(ds.queries.row(qi), 10);
            assert_eq!(nl.ids, single.ids, "query {qi}: batch vs single fan-out");
        }
        assert!(resp.stats.unwrap().pq_dists > 0);

        // Accurate mode reaches every shard: no PQ work anywhere.
        let req = QueryRequest::batch(&queries, 10).with_options(QueryOptions {
            mode: SearchMode::Accurate,
            want_stats: true,
            ..Default::default()
        });
        let stats = sh.query(&req).unwrap().stats.unwrap();
        assert_eq!(stats.pq_dists, 0);
        assert!(stats.exact_dists > 0);

        // Dimension mismatch is caught at the fan-out boundary.
        let short = vec![0.0f32; ds.dim() - 1];
        let e = sh.query(&QueryRequest::single(&short, 5)).unwrap_err();
        assert_eq!(e.code, crate::api::ApiErrorCode::DimMismatch);
    }

    #[test]
    fn uneven_partition_handled() {
        let (_, sh) = build_sharded(7); // 600 / 7 is uneven
        let total: usize = sh.shards.iter().map(|s| s.n_base()).sum();
        assert_eq!(total, 600);
    }

    #[test]
    fn shards_roundtrip_through_artifacts() {
        let (ds, sh) = build_sharded(3);
        let dir = std::env::temp_dir().join(format!("proxima-shardrt-{}", std::process::id()));
        let paths = sh.save_shards(&dir).unwrap();
        assert_eq!(paths.len(), 3);
        let reopened = ShardedService::open_shards(&paths, sh.shards[0].params).unwrap();
        assert_eq!(reopened.n_shards(), 3);
        assert_eq!(reopened.shard_base, sh.shard_base);
        for qi in 0..4 {
            let a = sh.search(ds.queries.row(qi), 10);
            let b = reopened.search(ds.queries.row(qi), 10);
            assert_eq!(a.ids, b.ids, "query {qi}: reopened shards must answer identically");
            assert_eq!(a.dists, b.dists);
        }
        // Cold-opened shards (each serving raw vectors in place from its
        // own artifact file) answer identically and meter their reads.
        let cold = ShardedService::open_shards_with(
            &paths,
            sh.shards[0].params,
            &crate::storage::OpenOptions::with_residency(crate::storage::Residency::Cold),
        )
        .unwrap();
        for qi in 0..4 {
            let a = sh.search(ds.queries.row(qi), 10);
            let b = cold.search(ds.queries.row(qi), 10);
            assert_eq!(a.ids, b.ids, "query {qi}: cold shards must answer identically");
            assert!(b.stats.cold_reads > 0, "query {qi}: cold shards must meter reads");
        }
        // A wrong-order path list is rejected (global ids would shift
        // into the wrong shard's range).
        let mut reversed = paths.clone();
        reversed.reverse();
        let e = ShardedService::open_shards(&reversed, sh.shards[0].params).unwrap_err();
        assert_eq!(e.kind, crate::artifact::ArtifactErrorKind::SpecMismatch);
        assert!(e.message.contains("position"), "{e}");
        // A mixed-dimension shard set is rejected at open.
        let foreign = tiny_uniform(100, 8, Metric::L2, 5);
        let fsvc = SearchService::build(
            &foreign,
            &GraphParams {
                r: 8,
                build_l: 16,
                alpha: 1.2,
                seed: 5,
            },
            &PqParams {
                m: 4,
                c: 16,
                train_sample: 100,
                kmeans_iters: 4,
            },
            SearchParams::default(),
            false,
        );
        let fpath = dir.join("foreign.pxa");
        fsvc.save(&fpath).unwrap();
        let mut mixed = paths.clone();
        mixed.push(fpath);
        let e = ShardedService::open_shards(&mixed, sh.shards[0].params).unwrap_err();
        assert_eq!(e.kind, crate::artifact::ArtifactErrorKind::SpecMismatch);
        std::fs::remove_dir_all(&dir).ok();
    }
}
