//! Sharded multi-accelerator serving (§IV-E "scalable to support different
//! ANNS dataset scales"): the base set is partitioned across `S` shards,
//! each with its own graph/PQ index (one per simulated accelerator); a
//! request fans out to every shard and the coordinator merges each
//! query's top-k by accurate distance — the standard scale-out pattern
//! for datasets beyond one device's 54 GB.
//!
//! The fan-out speaks the typed query API: [`ShardedService::query`]
//! forwards the whole [`QueryRequest`] (options included) to every shard
//! and merges per query, so per-request knobs behave identically on one
//! shard or fifty.
//!
//! Execution: each shard's sub-query is ONE task on the shared
//! work-stealing pool ([`ExecPool::shared`]); inside its task a shard
//! submits its per-query walks to the SAME pool (nested submission is
//! deadlock-free — waiting submitters help execute). One pool bounds the
//! machine's total compute threads, so there is no per-shard worker
//! budget to split and no thread spawn per request.

use super::SearchService;
use crate::api::{ApiError, NeighborList, QueryRequest, QueryResponse};
use crate::config::{GraphParams, PqParams, SearchParams};
use crate::dataset::{Dataset, VectorSet};
use crate::exec::ExecPool;
use crate::search::{SearchOutput, SearchStats};

/// A sharded index: per-shard services plus the id mapping back to the
/// global space.
pub struct ShardedService {
    pub shards: Vec<SearchService>,
    /// global_id = shard_base[s] + local_id ordering is preserved by the
    /// contiguous partitioning.
    pub shard_base: Vec<u32>,
}

impl ShardedService {
    /// Partition `ds` into `n_shards` contiguous slices and build each.
    pub fn build(
        ds: &Dataset,
        n_shards: usize,
        gp: &GraphParams,
        pq: &PqParams,
        params: SearchParams,
    ) -> ShardedService {
        assert!(n_shards >= 1);
        let n = ds.n_base();
        let per = n.div_ceil(n_shards);
        // All shards share the process-wide exec pool (the default for
        // built services), so total compute concurrency is bounded by
        // the pool regardless of shard count — no budget splitting.
        let mut shards = Vec::with_capacity(n_shards);
        let mut shard_base = Vec::with_capacity(n_shards);
        for s in 0..n_shards {
            let lo = s * per;
            let hi = ((s + 1) * per).min(n);
            if lo >= hi {
                break;
            }
            let slice =
                VectorSet::new(ds.dim(), ds.base.data[lo * ds.dim()..hi * ds.dim()].to_vec());
            let sub = Dataset {
                name: format!("{}-shard{s}", ds.name),
                metric: ds.metric,
                base: slice,
                queries: VectorSet::zeros(0, ds.dim()),
            };
            shard_base.push(lo as u32);
            shards.push(SearchService::build(&sub, gp, pq, params, false));
        }
        ShardedService { shards, shard_base }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Fan a whole [`QueryRequest`] out to all shards — one task per
    /// shard on the shared exec pool, the caller helping while it waits —
    /// then merge each query's top-k by reported (accurate) distance,
    /// mapping local ids back to the global space. A shard task that
    /// panics outside the per-query walks fails the whole request as
    /// `Internal` (a missing shard would silently degrade recall);
    /// per-query walk panics INSIDE a shard are contained per query and
    /// propagate through the merged response's `errors`.
    pub fn query(&self, req: &QueryRequest) -> Result<QueryResponse, ApiError> {
        let t0 = std::time::Instant::now();
        let first = self
            .shards
            .first()
            .ok_or_else(|| ApiError::internal("sharded service has no shards"))?;
        // Validate ONCE at the fan-out (shards share dim, and the
        // request-size caps are constants all shards agree on), then
        // dispatch through the pre-validated entry point so the full
        // per-vector scan is not repeated on every shard.
        first.validate(req)?;

        let responses: Vec<QueryResponse> = if self.shards.len() == 1 {
            vec![first.query_prevalidated(req)]
        } else {
            let fanned = ExecPool::shared()
                .run_collect(self.shards.len(), |s| self.shards[s].query_prevalidated(req));
            let mut responses = Vec::with_capacity(fanned.len());
            for (s, r) in fanned.into_iter().enumerate() {
                match r.value {
                    Some(resp) => responses.push(resp),
                    None => {
                        return Err(ApiError::internal(format!("shard {s} fan-out task panicked")))
                    }
                }
            }
            responses
        };

        let n_queries = req.vectors.len();
        let mut results = Vec::with_capacity(n_queries);
        let mut errors: Vec<Option<ApiError>> = Vec::new();
        let mut merged: Vec<(f32, u32)> = Vec::with_capacity(req.k * self.shards.len());
        for qi in 0..n_queries {
            // A query that failed on ANY shard is reported failed: a
            // partial merge would silently return degraded neighbors.
            if let Some(e) = responses.iter().find_map(|r| r.error_for(qi)) {
                errors.resize(n_queries, None);
                errors[qi] = Some(e.clone());
                results.push(NeighborList::default());
                continue;
            }
            merged.clear();
            for (s, resp) in responses.iter().enumerate() {
                let nl = &resp.results[qi];
                for (d, id) in nl.dists.iter().zip(&nl.ids) {
                    merged.push((*d, self.shard_base[s] + id));
                }
            }
            merged.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then_with(|| a.1.cmp(&b.1)));
            merged.truncate(req.k);
            results.push(NeighborList {
                ids: merged.iter().map(|&(_, v)| v).collect(),
                dists: merged.iter().map(|&(d, _)| d).collect(),
            });
        }

        let stats = req.options.want_stats.then(|| {
            let mut s = SearchStats::default();
            for resp in &responses {
                if let Some(rs) = &resp.stats {
                    s.add(rs);
                }
            }
            s
        });
        Ok(QueryResponse {
            results,
            errors,
            stats,
            server_latency_us: t0.elapsed().as_micros() as u64,
        })
    }

    /// One query with default options (a convenience over
    /// [`Self::query`], kept for the figure harnesses and examples).
    pub fn search(&self, q: &[f32], k: usize) -> SearchOutput {
        let mut req = QueryRequest::single(q, k);
        req.options.want_stats = true;
        let resp = self.query(&req).expect("sharded query failed");
        let nl = resp
            .results
            .into_iter()
            .next()
            .expect("one query, one result");
        SearchOutput {
            ids: nl.ids,
            dists: nl.dists,
            stats: resp.stats.unwrap_or_default(),
            trace: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::ground_truth::brute_force;
    use crate::dataset::synth::tiny_uniform;
    use crate::distance::Metric;

    fn build_sharded(n_shards: usize) -> (Dataset, ShardedService) {
        let ds = tiny_uniform(600, 12, Metric::L2, 31);
        let sh = ShardedService::build(
            &ds,
            n_shards,
            &GraphParams {
                r: 12,
                build_l: 24,
                alpha: 1.2,
                seed: 31,
            },
            &PqParams {
                m: 6,
                c: 32,
                train_sample: 600,
                kmeans_iters: 5,
            },
            SearchParams {
                l: 60,
                k: 10,
                ..Default::default()
            },
        );
        (ds, sh)
    }

    #[test]
    fn sharded_recall_matches_single_shard() {
        let (ds, sh1) = build_sharded(1);
        let (_, sh4) = build_sharded(4);
        assert_eq!(sh4.n_shards(), 4);
        let gt = brute_force(&ds, 10);
        let recall = |sh: &ShardedService| {
            let mut r = 0.0;
            for qi in 0..ds.n_queries() {
                let out = sh.search(ds.queries.row(qi), 10);
                r += crate::dataset::recall_at_k(&out.ids, gt.row(qi), 10);
            }
            r / ds.n_queries() as f64
        };
        let r1 = recall(&sh1);
        let r4 = recall(&sh4);
        assert!(r1 > 0.75, "single shard recall {r1}");
        // Sharded search evaluates each partition independently — recall
        // should be at least as good (smaller per-shard search spaces).
        assert!(r4 >= r1 - 0.05, "r1={r1} r4={r4}");
    }

    #[test]
    fn global_ids_are_valid_and_sorted() {
        let (ds, sh) = build_sharded(3);
        let out = sh.search(ds.queries.row(0), 10);
        assert_eq!(out.ids.len(), 10);
        assert!(out.ids.iter().all(|&id| (id as usize) < ds.n_base()));
        assert!(out.dists.windows(2).all(|w| w[0] <= w[1]));
        // Distances must be the true global distances.
        for (d, id) in out.dists.iter().zip(&out.ids) {
            let want = ds.metric.distance(ds.queries.row(0), ds.base.row(*id as usize));
            assert!((d - want).abs() < 1e-5);
        }
    }

    #[test]
    fn batched_query_contract_fans_out_with_options() {
        use crate::api::{QueryOptions, QueryRequest, SearchMode};
        let (ds, sh) = build_sharded(3);
        let queries: Vec<&[f32]> = (0..4).map(|qi| ds.queries.row(qi)).collect();
        let req = QueryRequest::batch(&queries, 10).with_options(QueryOptions {
            want_stats: true,
            ..Default::default()
        });
        let resp = sh.query(&req).unwrap();
        assert_eq!(resp.results.len(), 4);
        for (qi, nl) in resp.results.iter().enumerate() {
            let single = sh.search(ds.queries.row(qi), 10);
            assert_eq!(nl.ids, single.ids, "query {qi}: batch vs single fan-out");
        }
        assert!(resp.stats.unwrap().pq_dists > 0);

        // Accurate mode reaches every shard: no PQ work anywhere.
        let req = QueryRequest::batch(&queries, 10).with_options(QueryOptions {
            mode: SearchMode::Accurate,
            want_stats: true,
            ..Default::default()
        });
        let stats = sh.query(&req).unwrap().stats.unwrap();
        assert_eq!(stats.pq_dists, 0);
        assert!(stats.exact_dists > 0);

        // Dimension mismatch is caught at the fan-out boundary.
        let short = vec![0.0f32; ds.dim() - 1];
        let e = sh.query(&QueryRequest::single(&short, 5)).unwrap_err();
        assert_eq!(e.code, crate::api::ApiErrorCode::DimMismatch);
    }

    #[test]
    fn uneven_partition_handled() {
        let (_, sh) = build_sharded(7); // 600 / 7 is uneven
        let total: usize = sh.shards.iter().map(|s| s.base.len()).sum();
        assert_eq!(total, 600);
    }
}
