//! Dynamic batcher: collects queries and flushes either when the batch is
//! full or when the oldest request exceeds its deadline — the standard
//! serving trade-off (throughput vs tail latency) the paper's scheduler
//! makes in hardware with its N_q queues.
//!
//! Each queued [`Request`] carries its own [`QueryOptions`], so requests
//! with different modes / list sizes coalesce into one batch and still
//! get answered under their own knobs (the typed-API contract reaches
//! through the batching layer untouched). A flushed batch executes as
//! ONE staged pipeline on the shared exec pool
//! ([`SearchService::search_batch_mixed`]): coalesced duplicate queries
//! share a single ADT build, per-query tasks rebalance by work-stealing,
//! and a panicking request is answered `Err(Internal)` for that request
//! only — the loop, the pool, and the batch-mates all survive.
//!
//! Interaction with the online write plane: the batcher loads its
//! [`SearchService`] from the [`ServiceCell`] per FLUSH, and each query
//! in the flushed batch pins one write-plane snapshot for its walk
//! (`crate::online`), so batched queries never block on concurrent
//! `insert`/`delete`/`flush` — a batch dispatched before a mutation
//! publishes simply answers from the pre-mutation epoch, exactly like
//! an un-batched query.

use super::{BatchQuery, ServiceCell};
use crate::api::{ApiError, QueryOptions};
use crate::search::SearchOutput;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// One queued request: a single query vector plus the per-request options
/// it must be answered under, regardless of what it coalesces with.
pub struct Request {
    pub query: Vec<f32>,
    pub k: usize,
    pub options: QueryOptions,
    pub respond: mpsc::Sender<Result<SearchOutput, ApiError>>,
    pub enqueued: Instant,
}

/// Handle for submitting queries to the batching loop.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: mpsc::Sender<Request>,
}

impl BatcherHandle {
    /// Submit with default options and wait for the result.
    pub fn query(&self, query: Vec<f32>, k: usize) -> Result<SearchOutput, ApiError> {
        self.query_with(query, k, QueryOptions::default())
    }

    /// Submit with per-request options and wait for the result.
    /// `Err(Closed)` means the batching loop is gone (service shutting
    /// down); `Err(Internal)` means THIS request's worker task panicked
    /// (its batch-mates were answered normally).
    pub fn query_with(
        &self,
        query: Vec<f32>,
        k: usize,
        options: QueryOptions,
    ) -> Result<SearchOutput, ApiError> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Request {
                query,
                k,
                options,
                respond: tx,
                enqueued: Instant::now(),
            })
            .map_err(|_| ApiError::closed("batcher closed"))?;
        rx.recv().map_err(|_| ApiError::closed("batcher closed"))?
    }
}

/// Spawn the batching loop against a swappable [`ServiceCell`]: each
/// flush loads the cell's CURRENT epoch, so a wire `reload` takes
/// effect on the next batch while the in-flight one finishes on the
/// index it started with. Flushed batches execute on the loaded
/// service's exec pool (the loop thread helps as one more lane).
/// Returns the submit handle; dropping every handle shuts the loop down.
pub fn spawn(
    cell: Arc<ServiceCell>,
    policy: BatchPolicy,
) -> (BatcherHandle, std::thread::JoinHandle<BatchStats>) {
    let (tx, rx) = mpsc::channel::<Request>();
    let handle = BatcherHandle { tx };
    let join = std::thread::spawn(move || run_loop(cell, policy, rx));
    (handle, join)
}

/// Counters the loop returns on shutdown.
#[derive(Debug, Default, Clone)]
pub struct BatchStats {
    pub batches: u64,
    pub queries: u64,
    pub size_triggered: u64,
    pub deadline_triggered: u64,
}

fn run_loop(
    cell: Arc<ServiceCell>,
    policy: BatchPolicy,
    rx: mpsc::Receiver<Request>,
) -> BatchStats {
    let mut stats = BatchStats::default();
    let mut pending: Vec<Request> = Vec::new();
    loop {
        // Block for the first request of a batch.
        if pending.is_empty() {
            match rx.recv() {
                Ok(r) => pending.push(r),
                Err(_) => break, // all senders gone
            }
        }
        // Accumulate until full or deadline.
        let deadline = pending[0].enqueued + policy.max_wait;
        while pending.len() < policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        if pending.len() >= policy.max_batch {
            stats.size_triggered += 1;
        } else {
            stats.deadline_triggered += 1;
        }
        stats.batches += 1;
        stats.queries += pending.len() as u64;

        // Dispatch the coalesced batch as ONE staged pipeline on the
        // exec pool: duplicate queries share an ADT build, per-query
        // tasks rebalance by stealing, and a panicking request comes
        // back as Err(Internal) for that request alone. The epoch is
        // loaded per flush: after a hot reload, the NEXT batch runs on
        // the new index.
        let service = cell.load();
        let batch: Vec<Request> = std::mem::take(&mut pending);
        // Coalesced size distribution: how well arrival bursts fill
        // batches (the `proxima_batch_size` histogram).
        service.obs.record_batch(batch.len());
        // Each request was validated at enqueue against THAT moment's
        // epoch; a hot reload may have swapped in a differently-shaped
        // index since. Re-check the one epoch-dependent precondition
        // (vector length) against the FLUSH epoch, so a racing swap
        // yields a typed error — never a silently truncated distance
        // against mismatched base rows.
        let dim = service.dim();
        let items: Vec<BatchQuery> = batch
            .iter()
            .filter(|r| r.query.len() == dim)
            .map(|r| BatchQuery {
                q: &r.query,
                k: r.k,
                options: r.options,
            })
            .collect();
        let mut outcomes = service.search_batch_mixed(&items).into_iter();
        for req in &batch {
            let outcome = if req.query.len() == dim {
                outcomes.next().expect("one outcome per dispatched item")
            } else {
                // Neutral phrasing: this arm is reached both by a hot
                // swap racing a validated request AND by direct
                // (unvalidated) BatcherHandle submissions.
                Err(ApiError::dim_mismatch(format!(
                    "query dim {} does not match the currently served index dim {dim}",
                    req.query.len()
                )))
            };
            let _ = req.respond.send(outcome);
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GraphParams, PqParams, SearchParams};
    use crate::dataset::synth::tiny_uniform;
    use crate::distance::Metric;

    fn service() -> (crate::dataset::Dataset, Arc<ServiceCell>) {
        let ds = tiny_uniform(300, 12, Metric::L2, 91);
        let svc = crate::coordinator::SearchService::build(
            &ds,
            &GraphParams {
                r: 12,
                build_l: 24,
                alpha: 1.2,
                seed: 91,
            },
            &PqParams {
                m: 6,
                c: 16,
                train_sample: 300,
                kmeans_iters: 5,
            },
            SearchParams {
                l: 50,
                k: 5,
                ..Default::default()
            },
            false,
        );
        (ds, Arc::new(ServiceCell::new(Arc::new(svc))))
    }

    #[test]
    fn batcher_answers_all_queries() {
        let (ds, svc) = service();
        let (handle, join) = spawn(svc, BatchPolicy::default());
        let mut outs = Vec::new();
        for q in 0..ds.n_queries() {
            outs.push(handle.query(ds.queries.row(q).to_vec(), 5).unwrap());
        }
        assert!(outs.iter().all(|o| o.ids.len() == 5));
        drop(handle);
        let stats = join.join().unwrap();
        assert_eq!(stats.queries, ds.n_queries() as u64);
        assert!(stats.batches >= 1);
    }

    #[test]
    fn deadline_triggers_on_single_query() {
        let (ds, svc) = service();
        let (handle, join) = spawn(
            svc,
            BatchPolicy {
                max_batch: 1000,
                max_wait: Duration::from_millis(1),
            },
        );
        let out = handle.query(ds.queries.row(0).to_vec(), 5).unwrap();
        assert_eq!(out.ids.len(), 5);
        drop(handle);
        let stats = join.join().unwrap();
        assert!(stats.deadline_triggered >= 1);
    }

    #[test]
    fn options_survive_coalescing() {
        use crate::api::SearchMode;
        let (ds, svc) = service();
        // A wide deadline so the two concurrent submissions below land in
        // ONE batch (max_batch = 2 forces a size-triggered flush as soon
        // as both are queued).
        let (handle, join) = spawn(
            svc,
            BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_secs(2),
            },
        );
        let q = ds.queries.row(0).to_vec();
        let (accurate, hybrid) = std::thread::scope(|scope| {
            let h1 = handle.clone();
            let q1 = q.clone();
            let a = scope.spawn(move || {
                h1.query_with(
                    q1,
                    5,
                    QueryOptions {
                        mode: SearchMode::Accurate,
                        want_stats: true,
                        ..Default::default()
                    },
                )
                .unwrap()
            });
            let h2 = handle.clone();
            let q2 = q.clone();
            let b = scope.spawn(move || {
                h2.query_with(
                    q2,
                    5,
                    QueryOptions {
                        want_stats: true,
                        ..Default::default()
                    },
                )
                .unwrap()
            });
            (a.join().unwrap(), b.join().unwrap())
        });
        // Each coalesced request was answered under ITS options.
        assert_eq!(accurate.stats.pq_dists, 0, "accurate mode must not touch PQ");
        assert!(accurate.stats.exact_dists > 0);
        assert!(hybrid.stats.pq_dists > 0, "hybrid mode traverses on PQ");
        drop(handle);
        let stats = join.join().unwrap();
        assert_eq!(stats.queries, 2);
        assert_eq!(
            stats.batches, 1,
            "the two optioned requests must coalesce into one batch"
        );
    }

    #[test]
    fn panicking_request_fails_alone_and_the_loop_survives() {
        use crate::api::ApiErrorCode;
        let (ds, svc) = service();
        let (handle, join) = spawn(svc, BatchPolicy::default());
        // The batcher sits BEHIND the API boundary, so a NaN query can
        // reach a worker and panic its rerank sort. It must come back as
        // Err(Internal) for that request only.
        let mut nan_q = ds.queries.row(0).to_vec();
        nan_q[0] = f32::NAN;
        let err = handle.query(nan_q, 5).unwrap_err();
        assert_eq!(err.code, ApiErrorCode::Internal, "{err}");
        assert!(err.message.contains("panicked"), "{err}");
        // The loop, the pool, and subsequent requests all survive.
        let ok = handle.query(ds.queries.row(1).to_vec(), 5).unwrap();
        assert_eq!(ok.ids.len(), 5);
        drop(handle);
        let stats = join.join().unwrap();
        assert_eq!(stats.queries, 2);
    }

    #[test]
    fn concurrent_clients() {
        let (ds, svc) = service();
        let (handle, join) = spawn(svc, BatchPolicy::default());
        std::thread::scope(|scope| {
            for t in 0..4 {
                let h = handle.clone();
                let q = ds.queries.row(t % ds.n_queries()).to_vec();
                scope.spawn(move || {
                    for _ in 0..5 {
                        let out = h.query(q.clone(), 3).unwrap();
                        assert_eq!(out.ids.len(), 3);
                    }
                });
            }
        });
        drop(handle);
        let stats = join.join().unwrap();
        assert_eq!(stats.queries, 20);
    }
}
