//! TCP front end: newline-delimited JSON requests/responses over a local
//! socket, one handler thread per connection. Single-query requests feed
//! the shared dynamic batcher (cross-connection coalescing); multi-query
//! v2 batches go straight to the service's staged batch pipeline on the
//! persistent work-stealing exec pool — one round-trip, N answers, and
//! with `want_stats` the response's stats report `queue_wait_us` (time
//! the queries sat in the pool queue) and `adt_builds` (distinct ADT
//! tables the deduplicated batch build produced). A query whose worker
//! task panics is answered as an inline `{"error":...}` entry in ITS
//! result slot; batch-mates are unaffected.
//!
//! This threaded server is the JSON-only front end; the scalable front
//! door is [`crate::net::NetServer`], whose readiness event loop serves
//! the binary v3 frame plane AND this same JSON protocol on one port
//! (first-byte sniff), with admission control. The per-line dispatch
//! below ([`respond_json_line`]) is shared by both servers, so op
//! semantics cannot drift between them. Connections here idle out after
//! `idle_timeout` ([`Server::start_with`]) instead of pinning their
//! thread forever, and `stop()` drains: in-flight requests finish,
//! handler threads notice the shutdown flag within ~100 ms, and the
//! listener refuses new connections.
//!
//! Protocol v2 (one JSON object per line; codecs in [`crate::api::wire`]):
//! ```text
//! -> {"v":2,"op":"search","queries":[[f32...],[f32...],...],"k":10,
//!     "options":{"mode":"hybrid","l_override":200,"early_term_tau":3,
//!                "rerank":50,"want_stats":true}}
//! <- {"v":2,"results":[{"ids":[...],"dists":[...]},...],
//!     "server_latency_us":123,"stats":{...}}
//! -> {"op":"stats"}
//! <- {"queries":N,"early_terminated":E,"mean_latency_us":...}
//! -> {"op":"shutdown"}
//! <- {"ok":true}
//! ```
//!
//! # v2 admin plane (index lifecycle)
//!
//! The server serves whatever index its [`ServiceCell`] currently
//! holds, and two admin ops manage that cell over the same socket:
//! ```text
//! -> {"v":2,"op":"status"}
//! <- {"v":2,"spec":{...IndexSpec...},
//!     "provenance":{"source":"built"|"artifact","path":...},
//!     "stats":{"queries":...,"early_terminated":...,
//!              "mean_latency_us":...,"queue_wait_us_total":...}}
//! -> {"v":2,"op":"reload","path":"/path/to/index.pxa"}
//! <- {"ok":true,"dataset":...,"n_base":...,"path":...}   (or an error line)
//! ```
//! `reload` opens the artifact (checksum-verified; every failure is a
//! structured error line and the OLD index keeps serving) and swaps it
//! into the cell. Requests dispatched before the swap hold the old
//! epoch's `Arc` and complete on the old index; requests dispatched
//! after it run on the new one. Service counters (`stats`) belong to an
//! index instance and start fresh after a reload.
//!
//! # v2 write plane (online mutation)
//!
//! Three ops mutate the served index in place (`crate::online`; queries
//! concurrent with them never block — they pin epoch-published
//! snapshots):
//! ```text
//! -> {"v":2,"op":"insert","vector":[f32...]}
//! <- {"v":2,"op":"insert","id":N,"epoch":E}
//! -> {"v":2,"op":"delete","id":N}
//! <- {"v":2,"op":"delete","deleted":true|false,"epoch":E}
//! -> {"v":2,"op":"flush","path":"/optional/target.pxa"}
//! <- {"v":2,"op":"flush","ok":true,"path":...,"n_live":N,"epoch":E}
//! ```
//! `flush` compacts tombstones away, re-saves the artifact, and swaps
//! the successor into the cell exactly like `reload`; the `status`
//! response's `"online"` block reports the write plane's live/tombstone
//! census and lifetime op counters.
//! Every `options` field is optional (defaults in [`crate::api`] module
//! docs). A request without `"v"` is a v1 request — the compatibility
//! path, answered in the original single-query shape:
//! ```text
//! -> {"op":"search","query":[f32...],"k":10}
//! <- {"ids":[...],"dists":[...],"latency_us":123}
//! ```
//! Any failure (malformed JSON, unknown op, dimension mismatch, ...)
//! produces an error line and the connection KEEPS SERVING — a bad
//! request never tears down its neighbors on the same socket:
//! ```text
//! <- {"error":{"code":"bad_request"|"dim_mismatch"|"closed"|"internal",
//!              "message":"..."}}
//! ```
//! Failures on the v1 compat path (versionless lines) keep the legacy
//! string shape (`{"error":"..."}`); lines whose version is unknowable
//! (malformed JSON, non-numeric `v`) get the structured shape above.

use super::batcher::BatcherHandle;
use super::{SearchService, ServiceCell};
use crate::anyhow;
use crate::api::wire::{self, WireRequest};
use crate::api::{ApiError, NeighborList, QueryOptions, QueryRequest, QueryResponse};
use crate::artifact::IndexProvenance;
use crate::storage::cache::CachePolicy;
use crate::storage::{OpenOptions, Residency};
use crate::util::error::Result;
use crate::util::json::{self, Json};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Running server handle.
pub struct Server {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind to `127.0.0.1:port` (0 = ephemeral) and serve whatever index
    /// `cell` holds — which the wire `reload` op can hot-swap. Idle
    /// connections are dropped after 5 minutes ([`Server::start_with`]
    /// tunes this).
    pub fn start(
        cell: Arc<ServiceCell>,
        batcher: BatcherHandle,
        port: u16,
    ) -> Result<Server> {
        Self::start_with(cell, batcher, port, std::time::Duration::from_secs(300))
    }

    /// [`Server::start`] with an explicit idle read timeout: a
    /// connection that sends nothing for `idle_timeout` is closed,
    /// releasing its handler thread (an idle connection used to pin one
    /// forever — and made `stop()` wait on it).
    pub fn start_with(
        cell: Arc<ServiceCell>,
        batcher: BatcherHandle,
        port: u16,
        idle_timeout: std::time::Duration,
    ) -> Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = shutdown.clone();
        let accept_thread = std::thread::spawn(move || {
            let mut handlers = Vec::new();
            while !flag.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nonblocking(false).ok();
                        // Small JSON lines + closed-loop clients: Nagle +
                        // delayed-ACK would add ~40 ms per hop.
                        stream.set_nodelay(true).ok();
                        let cell = cell.clone();
                        let bh = batcher.clone();
                        let f = flag.clone();
                        handlers.push(std::thread::spawn(move || {
                            let _ = handle_conn(stream, cell, bh, f, idle_timeout);
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            // Graceful drain: the listener is gone (refusing new
            // connections) and every handler exits after finishing its
            // in-flight request — within one 100 ms poll tick.
            for h in handlers {
                let _ = h.join();
            }
        });
        Ok(Server {
            addr,
            shutdown,
            accept_thread: Some(accept_thread),
        })
    }

    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Serve one connection. Only I/O failures (and the idle timeout) end
/// the loop; every request-level failure is answered with a structured
/// error line so the connection survives bad input (a malformed line
/// used to kill the whole connection silently). The served index is
/// loaded from the epoch cell per line, so a concurrent `reload`
/// applies from the next request on — never mid-request.
///
/// Reads tick every 100 ms so the thread notices both the shutdown flag
/// (graceful drain) and its own idleness; partial lines accumulate
/// across ticks (`read_until` keeps already-received bytes on a
/// timeout), so a slow writer is never corrupted by the timer.
fn handle_conn(
    stream: TcpStream,
    cell: Arc<ServiceCell>,
    batcher: BatcherHandle,
    shutdown: Arc<AtomicBool>,
    idle_timeout: std::time::Duration,
) -> Result<()> {
    // The obs handle is adopted across hot-swaps, so the open/close
    // pair below always hits the same lifetime gauge even if a reload
    // lands mid-connection.
    let obs = cell.load().obs.clone();
    obs.conn_opened();
    let r = conn_loop(stream, cell, batcher, shutdown, idle_timeout);
    obs.conn_closed();
    r
}

fn conn_loop(
    stream: TcpStream,
    cell: Arc<ServiceCell>,
    batcher: BatcherHandle,
    shutdown: Arc<AtomicBool>,
    idle_timeout: std::time::Duration,
) -> Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut raw: Vec<u8> = Vec::new();
    let mut last_activity = Instant::now();
    loop {
        if shutdown.load(Ordering::Relaxed) {
            break;
        }
        let eof = match reader.read_until(b'\n', &mut raw) {
            Ok(0) => true,
            Ok(_) => false,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Timer tick: bytes read so far stay in `raw`.
                if last_activity.elapsed() >= idle_timeout {
                    break;
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        };
        if !eof && raw.last() != Some(&b'\n') {
            continue; // stream ended mid-line; the next read reports EOF
        }
        let line = String::from_utf8_lossy(&raw).trim().to_string();
        raw.clear();
        last_activity = Instant::now();
        if !line.is_empty() {
            let (resp, quit) = respond_json_line(&line, &cell, &batcher, crate::obs::Plane::Json);
            writeln!(writer, "{}", resp.to_string_compact())?;
            if quit {
                shutdown.store(true, Ordering::Relaxed);
                break;
            }
        }
        if eof {
            break;
        }
    }
    Ok(())
}

/// Dispatch one JSON request line against the served cell and shape the
/// response line. Returns `(response, shutdown_requested)`. This is THE
/// op dispatch for the JSON protocol — shared verbatim by this threaded
/// server and by [`crate::net::NetServer`]'s dispatchers (both the JSON
/// compat plane and binary `OP_ADMIN` frames), so the two front ends
/// cannot drift. `plane` tags the per-op latency histogram with the
/// wire plane the line arrived on; the obs clock (wall by default, fake
/// in tests) times the full decode→dispatch→encode span.
pub(crate) fn respond_json_line(
    line: &str,
    cell: &ServiceCell,
    batcher: &BatcherHandle,
    plane: crate::obs::Plane,
) -> (Json, bool) {
    // Adopted across hot-swaps, so a reload racing this request still
    // records into the lifetime series.
    let obs = cell.load().obs.clone();
    let t0 = obs.now_us();
    let mut op_class = crate::obs::OpClass::Admin;
    let resp = match json::parse(line) {
        Err(e) => wire::encode_error(&ApiError::bad_request(format!("malformed JSON: {e}"))),
        Ok(req) => match wire::decode_request(&req) {
            // Shape decode failures for the request's version too: a
            // versionless (or explicit `"v":1`) line with an unknown
            // op used to get the legacy string error, and must
            // still. Any other `v` — including malformed values like
            // 1.5 — gets the structured shape (version 0 here).
            Err(e) => {
                let version = match req.get("v") {
                    None => 1,
                    Some(v) if v.as_f64() == Some(1.0) => 1,
                    Some(_) => 0,
                };
                error_line(version, &e)
            }
            Ok(w) => {
                op_class = match &w {
                    WireRequest::Search { .. } => crate::obs::OpClass::Search,
                    WireRequest::Insert { .. }
                    | WireRequest::Delete { .. }
                    | WireRequest::Flush { .. } => crate::obs::OpClass::Write,
                    _ => crate::obs::OpClass::Admin,
                };
                match w {
                    WireRequest::Stats => stats_response(&cell.load()),
                    WireRequest::Status => status_response(&cell.load()),
                    WireRequest::Metrics => metrics_response(&cell.load()),
                    WireRequest::Slowlog => slowlog_response(&cell.load()),
                    WireRequest::Reload {
                        path,
                        residency,
                        cache_mb,
                        cache_policy,
                        lsh_start,
                    } => reload_response(cell, &path, residency, cache_mb, cache_policy, lsh_start),
                    WireRequest::Insert { vector } => insert_response(&cell.load(), &vector),
                    WireRequest::Delete { id } => delete_response(&cell.load(), id),
                    WireRequest::Flush { path } => flush_response(cell, path.as_deref()),
                    WireRequest::Shutdown => {
                        // Not recorded: the process is going away and a
                        // scrape will never see the point.
                        return (Json::obj(vec![("ok", Json::Bool(true))]), true);
                    }
                    WireRequest::Search { version, request } => {
                        answer_search(&cell.load(), batcher, version, request)
                    }
                }
            }
        },
    };
    // Top-level error lines (decode failures AND op-level failures)
    // share one counter; per-result inline errors inside a v2 batch
    // response are the per-query contract, not a request failure.
    if wire::decode_error(&resp).is_some() {
        obs.inc_errors();
    }
    obs.record_request(op_class, plane, obs.now_us().saturating_sub(t0));
    (resp, false)
}

/// Dispatch one search request: validate at the boundary, route
/// single-query requests through the dynamic batcher (options ride
/// along), hand multi-query batches to the service's worker fan-out, and
/// shape the response for the request's protocol version.
fn answer_search(
    service: &SearchService,
    batcher: &BatcherHandle,
    version: u32,
    request: QueryRequest,
) -> Json {
    let t0 = Instant::now();
    if request.vectors.len() > 1 {
        // Multi-query batch: one round-trip, answered by the worker pool
        // (`service.query` validates internally).
        return match service.query(&request) {
            Ok(resp) => wire::encode_response_v2(&resp),
            Err(e) => error_line(version, &e),
        };
    }
    // Single query: validate here (the batcher has no error channel),
    // then coalesce with other connections.
    if let Err(e) = service.validate(&request) {
        return error_line(version, &e);
    }
    let QueryRequest { vectors, k, options } = request;
    let query = vectors.into_iter().next().expect("validated non-empty");
    match batcher.query_with(query, k, options) {
        // Closed (service shutting down) or Internal (this request's
        // worker task panicked — its coalesced batch-mates were fine).
        Err(e) => error_line(version, &e),
        Ok(out) => {
            let latency_us = t0.elapsed().as_micros() as u64;
            if version == 1 {
                wire::encode_response_v1(
                    &NeighborList {
                        ids: out.ids,
                        dists: out.dists,
                    },
                    latency_us,
                )
            } else {
                wire::encode_response_v2(&QueryResponse::from_outputs(
                    vec![out],
                    options.want_stats,
                    latency_us,
                ))
            }
        }
    }
}

/// Shape an error for the request's protocol version: v1 clients predate
/// the structured object and expect the legacy `{"error":"..."}` string
/// (the compat contract); v2 gets `{"error":{"code":..,"message":..}}`.
/// Lines whose version is unknowable (malformed JSON, non-numeric `v`)
/// are answered structured — the old server killed the connection on
/// those, so no working v1 client depends on their shape.
fn error_line(version: u32, e: &ApiError) -> Json {
    if version == 1 {
        Json::obj(vec![("error", Json::str(e.to_string()))])
    } else {
        wire::encode_error(e)
    }
}

fn stats_response(service: &SearchService) -> Json {
    Json::obj(vec![
        (
            "queries",
            Json::num(service.stats.queries.load(Ordering::Relaxed) as f64),
        ),
        (
            "early_terminated",
            Json::num(service.stats.early_terminated.load(Ordering::Relaxed) as f64),
        ),
        ("mean_latency_us", Json::num(service.mean_latency_us())),
        (
            "queue_wait_us_total",
            Json::num(service.stats.queue_wait_us.load(Ordering::Relaxed) as f64),
        ),
        (
            "cache_hits",
            Json::num(service.stats.cache_hits.load(Ordering::Relaxed) as f64),
        ),
        (
            "cache_misses",
            Json::num(service.stats.cache_misses.load(Ordering::Relaxed) as f64),
        ),
        (
            "lsh_probes",
            Json::num(service.stats.lsh_probes.load(Ordering::Relaxed) as f64),
        ),
        ("dataset", Json::str(service.name.clone())),
    ])
}

/// The admin `status` op: the served index's [`IndexSpec`]
/// (what was built and how), its provenance (fresh build vs opened
/// artifact + path), the vector-storage tier (residency, DRAM
/// `resident_bytes` — scaling with `hot_frac`, not `n_base`, under
/// `tiered` — and this epoch's cold-tier read counters), and the
/// service counters — everything an operator needs to tell replicas
/// apart.
///
/// [`IndexSpec`]: crate::artifact::IndexSpec
fn status_response(service: &SearchService) -> Json {
    let provenance = match &service.provenance {
        IndexProvenance::Built => Json::obj(vec![("source", Json::str("built"))]),
        IndexProvenance::Artifact { path } => Json::obj(vec![
            ("source", Json::str("artifact")),
            ("path", Json::str(path.clone())),
        ]),
    };
    let mut storage_kvs = vec![
        ("residency", Json::str(service.storage.residency().name())),
        (
            "resident_bytes",
            Json::num(service.storage.resident_bytes() as f64),
        ),
        ("n_hot", Json::num(service.storage.n_hot() as f64)),
        (
            "cold_reads",
            Json::num(service.stats.cold_reads.load(Ordering::Relaxed) as f64),
        ),
        (
            "cold_bytes",
            Json::num(service.stats.cold_bytes.load(Ordering::Relaxed) as f64),
        ),
    ];
    // Row-cache block, present only when the residency carries one.
    // Decoders must treat these keys as optional
    // (`wire::decode_storage_status` is lenient by contract).
    if let Some(cs) = service.storage.cache_status() {
        storage_kvs.push(("cache_policy", Json::str(cs.policy.name())));
        storage_kvs.push(("cache_capacity_bytes", Json::num(cs.capacity_bytes as f64)));
        storage_kvs.push(("cache_hit_rate", Json::num(cs.hit_rate())));
        storage_kvs.push(("cache_evictions", Json::num(cs.evictions as f64)));
        storage_kvs.push(("cache_ghost_hits", Json::num(cs.ghost_hits as f64)));
    }
    let storage = Json::obj(storage_kvs);
    let snap = service.online.load();
    let c = service.online.counters();
    let online = Json::obj(vec![
        ("epoch", Json::num(snap.epoch as f64)),
        ("n_live", Json::num(snap.n_live() as f64)),
        ("n_tombstoned", Json::num(snap.n_tombstoned() as f64)),
        (
            "inserts_total",
            Json::num(c.inserts_total.load(Ordering::Relaxed) as f64),
        ),
        (
            "deletes_total",
            Json::num(c.deletes_total.load(Ordering::Relaxed) as f64),
        ),
        (
            "flushes_total",
            Json::num(c.flushes_total.load(Ordering::Relaxed) as f64),
        ),
        (
            "repair_splices_total",
            Json::num(c.repair_splices_total.load(Ordering::Relaxed) as f64),
        ),
    ]);
    // Load-shedding signals: the exec pool's queue depth is always
    // present; the admission counters appear once a `NetServer` has
    // registered its controller (the threaded JSON server has none).
    let mut admission_kvs = vec![("exec_pending", Json::num(service.exec_pending() as f64))];
    if let Some(adm) = service.obs.admission() {
        let c = adm.counters();
        admission_kvs.push(("in_flight", Json::num(c.in_flight as f64)));
        admission_kvs.push(("admitted", Json::num(c.admitted as f64)));
        admission_kvs.push(("shed_admit", Json::num(c.shed_admit as f64)));
        admission_kvs.push(("shed_dispatch", Json::num(c.shed_dispatch as f64)));
    }
    Json::obj(vec![
        ("v", Json::num(wire::VERSION as f64)),
        ("spec", wire::encode_spec(&service.spec)),
        ("provenance", provenance),
        ("storage", storage),
        ("online", online),
        ("admission", Json::obj(admission_kvs)),
        ("stats", stats_response(service)),
    ])
}

/// The admin `metrics` op: assemble the Prometheus text exposition
/// (format 0.0.4) from the lifetime [`crate::obs::Metrics`] handle plus
/// live service/storage/online counters, and embed it as the
/// `"exposition"` string of the JSON response line (the line protocol
/// carries no raw multi-line bodies). Every histogram cell and stage is
/// emitted unconditionally — fixed label sets keep dashboards stable —
/// and the whole text is rebuilt per request, so there is no retained
/// registry to drift from the live counters.
fn metrics_response(service: &SearchService) -> Json {
    use crate::obs::{Histogram, OpClass, Plane, Stage};
    let obs = &service.obs;
    let mut r = crate::obs::Registry::new();

    // Wire latency: one series per (op, plane).
    let req_labels: Vec<(String, &Histogram)> = OpClass::ALL
        .iter()
        .flat_map(|&op| {
            Plane::ALL.iter().map(move |&plane| {
                (
                    format!("op=\"{}\",plane=\"{}\"", op.name(), plane.name()),
                    &obs.request_us[op as usize][plane as usize],
                )
            })
        })
        .collect();
    let req_refs: Vec<(&str, &Histogram)> =
        req_labels.iter().map(|(l, h)| (l.as_str(), *h)).collect();
    r.histogram(
        "proxima_request_duration_us",
        "End-to-end wire request latency (us), decode to encode.",
        &req_refs,
    );
    r.histogram(
        "proxima_engine_duration_us",
        "In-service query latency (us), excluding wire time.",
        &[("", &obs.engine_us)],
    );
    // Stage breakdown. Stages are NOT disjoint (cold reads happen
    // inside the walk/rerank), so stage sums can exceed the engine sum.
    let stage_labels: Vec<(String, &Histogram)> = Stage::ALL
        .iter()
        .map(|&st| {
            (
                format!("stage=\"{}\"", st.name()),
                &obs.stage_us[st as usize],
            )
        })
        .collect();
    let stage_refs: Vec<(&str, &Histogram)> =
        stage_labels.iter().map(|(l, h)| (l.as_str(), *h)).collect();
    r.histogram(
        "proxima_stage_duration_us",
        "Per-stage query latency (us); stages may overlap.",
        &stage_refs,
    );
    r.histogram(
        "proxima_batch_size",
        "Coalesced batch sizes dispatched by the dynamic batcher.",
        &[("", &obs.batch_size)],
    );

    r.counter(
        "proxima_errors_total",
        "Requests answered with a top-level error line.",
        &[("", obs.errors() as f64)],
    );
    r.gauge(
        "proxima_connections",
        "Currently open connections (both planes).",
        &[("", obs.connections() as f64)],
    );
    r.gauge(
        "proxima_exec_pending",
        "Tasks queued or executing on the exec pool (shed signal).",
        &[("", service.exec_pending() as f64)],
    );
    r.gauge(
        "proxima_exec_workers",
        "Parallelism width of the serving exec pool.",
        &[("", service.workers as f64)],
    );
    if let Some(adm) = obs.admission() {
        let c = adm.counters();
        r.gauge(
            "proxima_admission_in_flight",
            "Admitted queries currently executing or queued.",
            &[("", c.in_flight as f64)],
        );
        r.counter(
            "proxima_admission_admitted_total",
            "Queries admitted by the front-door controller.",
            &[("", c.admitted as f64)],
        );
        r.counter(
            "proxima_admission_shed_total",
            "Queries shed, by gate.",
            &[
                ("gate=\"admit\"", c.shed_admit as f64),
                ("gate=\"dispatch\"", c.shed_dispatch as f64),
            ],
        );
    }

    // Per-epoch service counters (reset by reload/flush hot-swaps,
    // unlike everything above).
    let s = &service.stats;
    r.counter(
        "proxima_epoch_queries_total",
        "Queries answered by the current epoch.",
        &[("", s.queries.load(Ordering::Relaxed) as f64)],
    );
    r.counter(
        "proxima_epoch_early_terminated_total",
        "Early-terminated queries in the current epoch.",
        &[("", s.early_terminated.load(Ordering::Relaxed) as f64)],
    );
    r.counter(
        "proxima_epoch_cold_reads_total",
        "Cold-tier raw-vector fetches in the current epoch.",
        &[("", s.cold_reads.load(Ordering::Relaxed) as f64)],
    );
    r.counter(
        "proxima_epoch_cache_requests_total",
        "Row-cache lookups in the current epoch, by outcome.",
        &[
            ("outcome=\"hit\"", s.cache_hits.load(Ordering::Relaxed) as f64),
            (
                "outcome=\"miss\"",
                s.cache_misses.load(Ordering::Relaxed) as f64,
            ),
        ],
    );
    if let Some(cs) = service.storage.cache_status() {
        r.gauge(
            "proxima_cache_hit_rate",
            "Lifetime row-cache hit rate of the current epoch's cache.",
            &[("", cs.hit_rate())],
        );
    }
    let snap = service.online.load();
    r.gauge(
        "proxima_online_epoch",
        "Write-plane epoch of the served snapshot.",
        &[("", snap.epoch as f64)],
    );
    r.gauge(
        "proxima_online_live",
        "Live vectors in the served snapshot.",
        &[("", snap.n_live() as f64)],
    );

    Json::obj(vec![
        ("v", Json::num(wire::VERSION as f64)),
        ("ok", Json::Bool(true)),
        ("format", Json::str("prometheus-text-0.0.4")),
        ("exposition", Json::str(r.render())),
    ])
}

/// The admin `slowlog` op: dump the flight recorder — the N slowest
/// recent queries, slowest first, each with its per-stage span
/// breakdown (µs, keyed by [`Stage::name`]) and key `SearchStats`
/// counters. Cleared when a hot-swap installs a new epoch.
///
/// [`Stage::name`]: crate::obs::Stage::name
fn slowlog_response(service: &SearchService) -> Json {
    use crate::obs::Stage;
    let slowlog = service.obs.slowlog();
    let entries: Vec<Json> = slowlog
        .snapshot()
        .into_iter()
        .map(|e| {
            let stages = Stage::ALL
                .iter()
                .map(|&st| (st.name(), Json::num(e.spans.get(st) as f64)))
                .collect();
            Json::obj(vec![
                ("seq", Json::num(e.seq as f64)),
                ("latency_us", Json::num(e.latency_us as f64)),
                ("stages", Json::obj(stages)),
                (
                    "stats",
                    Json::obj(vec![
                        ("hops", Json::num(e.stats.hops as f64)),
                        ("pq_dists", Json::num(e.stats.pq_dists as f64)),
                        ("exact_dists", Json::num(e.stats.exact_dists as f64)),
                        ("adt_builds", Json::num(e.stats.adt_builds as f64)),
                        ("queue_wait_us", Json::num(e.stats.queue_wait_us as f64)),
                        ("cold_reads", Json::num(e.stats.cold_reads as f64)),
                        ("cache_hits", Json::num(e.stats.cache_hits as f64)),
                        ("cache_misses", Json::num(e.stats.cache_misses as f64)),
                        (
                            "early_terminated",
                            Json::Bool(e.stats.early_terminated),
                        ),
                    ]),
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("v", Json::num(wire::VERSION as f64)),
        ("ok", Json::Bool(true)),
        ("capacity", Json::num(slowlog.capacity() as f64)),
        ("entries", Json::Arr(entries)),
    ])
}

/// The write-plane `insert` op: typed boundary validation (wrong dim,
/// non-finite values) then the service's single-writer insert. The
/// returned id names the vector in every subsequent result list.
fn insert_response(service: &SearchService, vector: &[f32]) -> Json {
    match service.insert(vector) {
        Err(e) => wire::encode_error(&e),
        Ok((id, epoch)) => Json::obj(vec![
            ("v", Json::num(wire::VERSION as f64)),
            ("op", Json::str("insert")),
            ("id", Json::num(id as f64)),
            ("epoch", Json::num(epoch as f64)),
        ]),
    }
}

/// The write-plane `delete` op: tombstone one id (original id space).
/// `deleted:false` means the id was already tombstoned — idempotent,
/// not an error; an out-of-range id IS a structured error.
fn delete_response(service: &SearchService, id: u32) -> Json {
    match service.delete(id) {
        Err(e) => wire::encode_error(&e),
        Ok((deleted, epoch)) => Json::obj(vec![
            ("v", Json::num(wire::VERSION as f64)),
            ("op", Json::str("delete")),
            ("deleted", Json::Bool(deleted)),
            ("epoch", Json::num(epoch as f64)),
        ]),
    }
}

/// The write-plane `flush` op: compact the served index (tombstones
/// dropped, delta merged, PQ codes recomputed), re-save the artifact,
/// and swap the successor into the cell — the same epoch semantics as
/// `reload`: in-flight requests finish on the old index. On ANY failure
/// the old index keeps serving, uncompacted but intact.
fn flush_response(cell: &ServiceCell, path: Option<&str>) -> Json {
    let old = cell.load();
    match old.flush(path.map(Path::new)) {
        Err(e) => wire::encode_error(&e),
        Ok(fo) => {
            let info = Json::obj(vec![
                ("v", Json::num(wire::VERSION as f64)),
                ("op", Json::str("flush")),
                ("ok", Json::Bool(true)),
                ("path", Json::str(fo.path.clone())),
                ("n_live", Json::num(fo.n_live as f64)),
                ("epoch", Json::num(fo.epoch as f64)),
            ]);
            drop(cell.swap(fo.service));
            info
        }
    }
}

/// The admin `reload` op: open the artifact at `path` (keeping the old
/// index's search params and XLA preference, and — unless the request
/// names them — its vector residency, row-cache configuration, and LSH
/// warm-start setting) and swap it into the epoch cell. On ANY failure
/// — missing file, truncation, corruption, version mismatch — the old
/// index keeps serving and the client gets a structured error line.
fn reload_response(
    cell: &ServiceCell,
    path: &str,
    residency: Option<Residency>,
    cache_mb: Option<u64>,
    cache_policy: Option<CachePolicy>,
    lsh_start: Option<bool>,
) -> Json {
    let old = cell.load();
    let mut residency = residency.unwrap_or_else(|| old.storage.residency());
    // `cache_mb` sizes the new epoch's adaptive layer (the wire decoder
    // gives `cached` the default capacity when the request named none).
    if let (Residency::Cached { capacity_bytes }, Some(mb)) = (&mut residency, cache_mb) {
        *capacity_bytes = mb << 20;
    }
    let old_cache = old.storage.row_cache();
    let opts = OpenOptions {
        residency,
        cache_policy: cache_policy
            .or_else(|| old_cache.map(|c| c.policy()))
            .unwrap_or_default(),
        tiered_cache_bytes: match residency {
            Residency::Tiered => cache_mb.map(|mb| mb << 20).or_else(|| {
                match old.storage.residency() {
                    Residency::Tiered => old_cache.map(|c| c.capacity_bytes()),
                    _ => None,
                }
            }),
            _ => None,
        },
        lsh_start: lsh_start.unwrap_or_else(|| old.lsh_active()),
    };
    // Retry the XLA *preference*, not the old attach *outcome* — a
    // transient attach failure at boot must not disable XLA for every
    // subsequent reload (artifacts may exist by now).
    match SearchService::open_with(Path::new(path), old.params, old.xla_preferred(), &opts) {
        Err(e) => wire::encode_error(&ApiError::from(e)),
        Ok(svc) => {
            // Carry the serve-time execution width across the swap: a
            // dedicated pool installed by `--workers` must not silently
            // revert to the machine-sized shared pool on reload.
            let mut svc = if old.uses_shared_pool() {
                svc
            } else {
                svc.with_workers(old.workers)
            };
            // Adopt the lifetime observability plane (histograms,
            // counters, gauges survive the swap — scrape pipelines need
            // continuous series); the slow-query flight recorder is
            // cleared because its spans describe the OLD epoch's
            // graph/residency. `ServiceStats` stays per-epoch.
            svc.obs = old.obs.clone();
            svc.obs.slowlog().clear();
            let info = Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("dataset", Json::str(svc.name.clone())),
                ("n_base", Json::num(svc.n_base() as f64)),
                ("path", Json::str(path)),
            ]);
            drop(cell.swap(Arc::new(svc)));
            info
        }
    }
}

/// Minimal blocking client for examples/tests. [`Client::search`] speaks
/// the v1 compat path; [`Client::search_batch`] /
/// [`Client::search_with_options`] speak v2.
///
/// Idempotent admin ops (`stats`/`status`/`reload*`) transparently
/// reconnect with exponential backoff on transient transport errors —
/// a server restart, an idle-timeout disconnect, a half-open socket —
/// so loadgen and ops scripts survive a hot-swap restart. Search and
/// write-plane ops do NOT retry: re-sending a possibly-executed
/// `insert`/`flush` is not idempotent, and a failed search is the
/// caller's retry decision.
pub struct Client {
    addr: std::net::SocketAddr,
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    admin_retries: u32,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client {
            addr,
            stream,
            reader,
            admin_retries: 3,
        })
    }

    /// Override the admin-op reconnect budget (0 disables retries).
    pub fn with_admin_retries(mut self, retries: u32) -> Client {
        self.admin_retries = retries;
        self
    }

    fn roundtrip(&mut self, req: Json) -> Result<Json> {
        self.send_raw(&req.to_string_compact())
    }

    /// Send one raw line and read one response line (the escape hatch for
    /// protocol tests — e.g. deliberately malformed input).
    pub fn send_raw(&mut self, line: &str) -> Result<Json> {
        match self.transport_roundtrip(line) {
            Ok(resp) => resp,
            Err(e) => Err(e.into()),
        }
    }

    /// One wire round-trip, separating TRANSPORT failures (outer `Err`:
    /// connect/read/write/EOF — candidates for reconnect-and-retry)
    /// from response-level failures (inner `Err`: unparseable line).
    fn transport_roundtrip(&mut self, line: &str) -> std::io::Result<Result<Json>> {
        writeln!(self.stream, "{line}")?;
        let mut resp = String::new();
        let n = self.reader.read_line(&mut resp)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            ));
        }
        Ok(json::parse(&resp).map_err(|e| anyhow!("bad response: {e}")))
    }

    /// Round-trip for idempotent admin ops: on a transport error,
    /// reconnect with doubling backoff (10 ms start) up to
    /// `admin_retries` times, then re-send. Safe precisely because the
    /// retried ops are idempotent — issuing `status` or re-`reload`ing
    /// the same artifact twice is indistinguishable from once.
    fn admin_roundtrip(&mut self, req: Json) -> Result<Json> {
        let line = req.to_string_compact();
        let mut backoff = std::time::Duration::from_millis(10);
        let mut attempt = 0u32;
        loop {
            match self.transport_roundtrip(&line) {
                Ok(resp) => return resp,
                Err(e) => {
                    if attempt >= self.admin_retries {
                        return Err(e.into());
                    }
                    attempt += 1;
                    std::thread::sleep(backoff);
                    backoff = backoff.saturating_mul(2);
                    if let Ok(fresh) = Client::connect(self.addr) {
                        self.stream = fresh.stream;
                        self.reader = fresh.reader;
                    }
                    // Reconnect failure: loop and burn another attempt —
                    // the server may still be coming back up.
                }
            }
        }
    }

    /// v1 single-query search RPC (compat path); returns
    /// (ids, dists, server latency µs).
    pub fn search(&mut self, query: &[f32], k: usize) -> Result<(Vec<u32>, Vec<f32>, f64)> {
        let resp = self.roundtrip(wire::encode_request_v1(query, k))?;
        if let Some(err) = wire::decode_error(&resp) {
            return Err(anyhow!("server error: {err}"));
        }
        let ids = resp
            .get("ids")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing ids"))?
            .iter()
            .filter_map(|x| x.as_f64())
            .map(|x| x as u32)
            .collect();
        let dists = resp
            .get("dists")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(|x| x.as_f64())
            .map(|x| x as f32)
            .collect();
        let lat = resp.get("latency_us").and_then(Json::as_f64).unwrap_or(0.0);
        Ok((ids, dists, lat))
    }

    /// v2 multi-query search RPC: N queries in ONE round-trip, one
    /// [`NeighborList`] per query, under shared per-request options.
    pub fn search_batch(
        &mut self,
        queries: &[&[f32]],
        k: usize,
        options: &QueryOptions,
    ) -> Result<QueryResponse> {
        let req = QueryRequest::batch(queries, k).with_options(*options);
        let resp = self.roundtrip(wire::encode_request_v2(&req))?;
        if let Some(err) = wire::decode_error(&resp) {
            return Err(anyhow!("server error: {err}"));
        }
        wire::decode_response_v2(&resp).map_err(|e| anyhow!("bad response: {e}"))
    }

    /// v2 single-query search with per-request options.
    pub fn search_with_options(
        &mut self,
        query: &[f32],
        k: usize,
        options: &QueryOptions,
    ) -> Result<QueryResponse> {
        self.search_batch(&[query], k, options)
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.admin_roundtrip(Json::obj(vec![("op", Json::str("stats"))]))
    }

    /// v2 admin: spec + provenance + counters of the served index.
    /// Transparently reconnects on transient transport errors.
    pub fn status(&mut self) -> Result<Json> {
        let resp = self.admin_roundtrip(Json::obj(vec![
            ("v", Json::num(wire::VERSION as f64)),
            ("op", Json::str("status")),
        ]))?;
        if let Some(err) = wire::decode_error(&resp) {
            return Err(anyhow!("server error: {err}"));
        }
        Ok(resp)
    }

    /// v2 admin: the Prometheus text exposition of the server's
    /// lifetime metrics (extracted from the response's `"exposition"`
    /// field). Transparently reconnects on transient transport errors.
    pub fn metrics(&mut self) -> Result<String> {
        let resp = self.admin_roundtrip(Json::obj(vec![
            ("v", Json::num(wire::VERSION as f64)),
            ("op", Json::str("metrics")),
        ]))?;
        if let Some(err) = wire::decode_error(&resp) {
            return Err(anyhow!("server error: {err}"));
        }
        resp.get("exposition")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| anyhow!("metrics response missing 'exposition'"))
    }

    /// v2 admin: the slow-query flight recorder (slowest recent queries
    /// with stage spans). Returns the full response line; `"entries"`
    /// holds the records, slowest first.
    pub fn slowlog(&mut self) -> Result<Json> {
        let resp = self.admin_roundtrip(Json::obj(vec![
            ("v", Json::num(wire::VERSION as f64)),
            ("op", Json::str("slowlog")),
        ]))?;
        if let Some(err) = wire::decode_error(&resp) {
            return Err(anyhow!("server error: {err}"));
        }
        Ok(resp)
    }

    /// v2 admin: hot-swap the served index to the artifact at `path`.
    /// Returns the server's confirmation line; a typed error (bad path,
    /// corrupt artifact, version mismatch) leaves the old index serving.
    pub fn reload(&mut self, path: &str) -> Result<Json> {
        self.reload_opts(path, None)
    }

    /// [`Self::reload`] that also switches the new epoch's vector
    /// residency (`"resident"` / `"cold"` / `"tiered"` / `"cached"`);
    /// `None` keeps the currently-served epoch's residency.
    pub fn reload_opts(&mut self, path: &str, residency: Option<Residency>) -> Result<Json> {
        self.reload_with(path, residency, None, None, None)
    }

    /// Full-knob reload: residency plus row-cache capacity (MiB),
    /// eviction policy, and LSH warm-start toggle. Every `None` keeps
    /// the currently-served epoch's setting.
    pub fn reload_with(
        &mut self,
        path: &str,
        residency: Option<Residency>,
        cache_mb: Option<u64>,
        cache_policy: Option<CachePolicy>,
        lsh_start: Option<bool>,
    ) -> Result<Json> {
        let mut kvs = vec![
            ("v", Json::num(wire::VERSION as f64)),
            ("op", Json::str("reload")),
            ("path", Json::str(path)),
        ];
        if let Some(r) = residency {
            kvs.push(("residency", Json::str(r.name())));
        }
        if let Some(mb) = cache_mb {
            kvs.push(("cache_mb", Json::num(mb as f64)));
        }
        if let Some(p) = cache_policy {
            kvs.push(("cache_policy", Json::str(p.name())));
        }
        if let Some(on) = lsh_start {
            kvs.push(("lsh_start", Json::Bool(on)));
        }
        let resp = self.admin_roundtrip(Json::obj(kvs))?;
        if let Some(err) = wire::decode_error(&resp) {
            return Err(anyhow!("server error: {err}"));
        }
        Ok(resp)
    }

    /// v2 write plane: insert one vector into the served index; returns
    /// `(id, epoch)`. The vector is findable by any request sent after
    /// this returns.
    pub fn insert(&mut self, vector: &[f32]) -> Result<(u32, u64)> {
        let resp = self.roundtrip(wire::encode_insert(vector))?;
        if let Some(err) = wire::decode_error(&resp) {
            return Err(anyhow!("server error: {err}"));
        }
        let id = resp
            .get("id")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("insert response missing 'id'"))? as u32;
        let epoch = resp.get("epoch").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        Ok((id, epoch))
    }

    /// v2 write plane: tombstone `id`; returns `(deleted, epoch)` —
    /// `deleted` is false when the id was already tombstoned.
    pub fn delete(&mut self, id: u32) -> Result<(bool, u64)> {
        let resp = self.roundtrip(wire::encode_delete(id))?;
        if let Some(err) = wire::decode_error(&resp) {
            return Err(anyhow!("server error: {err}"));
        }
        let deleted = resp
            .get("deleted")
            .and_then(Json::as_bool)
            .ok_or_else(|| anyhow!("delete response missing 'deleted'"))?;
        let epoch = resp.get("epoch").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        Ok((deleted, epoch))
    }

    /// v2 write plane: compact + re-save the served index and hot-swap
    /// the successor in. `None` flushes back to the artifact the index
    /// was opened from. Returns the server's confirmation line
    /// (`path`, `n_live`, `epoch`).
    pub fn flush(&mut self, path: Option<&str>) -> Result<Json> {
        let resp = self.roundtrip(wire::encode_flush(path))?;
        if let Some(err) = wire::decode_error(&resp) {
            return Err(anyhow!("server error: {err}"));
        }
        Ok(resp)
    }

    pub fn shutdown(&mut self) -> Result<()> {
        let _ = self.roundtrip(Json::obj(vec![("op", Json::str("shutdown"))]))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GraphParams, PqParams, SearchParams};
    use crate::coordinator::batcher::{spawn, BatchPolicy};
    use crate::dataset::synth::tiny_uniform;
    use crate::distance::Metric;

    #[test]
    fn server_roundtrip() {
        let ds = tiny_uniform(200, 8, Metric::L2, 99);
        let svc = Arc::new(SearchService::build(
            &ds,
            &GraphParams {
                r: 8,
                build_l: 16,
                alpha: 1.2,
                seed: 99,
            },
            &PqParams {
                m: 4,
                c: 16,
                train_sample: 200,
                kmeans_iters: 4,
            },
            SearchParams {
                l: 30,
                k: 5,
                ..Default::default()
            },
            false,
        ));
        let cell = Arc::new(ServiceCell::new(svc));
        let (handle, _join) = spawn(cell.clone(), BatchPolicy::default());
        let server = Server::start(cell, handle, 0).unwrap();
        let addr = server.addr;

        let mut client = Client::connect(addr).unwrap();
        // v1 compat path.
        let (ids, dists, lat) = client.search(ds.queries.row(0), 5).unwrap();
        assert_eq!(ids.len(), 5);
        assert_eq!(dists.len(), 5);
        assert!(lat >= 0.0);

        // v2 batch path: one round-trip, three answers.
        let queries: Vec<&[f32]> = (0..3).map(|i| ds.queries.row(i)).collect();
        let resp = client
            .search_batch(
                &queries,
                5,
                &QueryOptions {
                    want_stats: true,
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(resp.results.len(), 3);
        assert_eq!(resp.results[0].ids, ids, "same query, same answer");
        assert!(resp.stats.unwrap().pq_dists > 0);

        let stats = client.stats().unwrap();
        assert_eq!(stats.get("queries").and_then(Json::as_usize), Some(4));

        // Admin plane: status reports the spec and build provenance.
        let status = client.status().unwrap();
        assert_eq!(
            status
                .get("provenance")
                .and_then(|p| p.get("source"))
                .and_then(Json::as_str),
            Some("built")
        );
        assert_eq!(
            status
                .get("spec")
                .and_then(|s| s.get("dim"))
                .and_then(Json::as_usize),
            Some(8)
        );
        assert_eq!(
            status
                .get("stats")
                .and_then(|s| s.get("queries"))
                .and_then(Json::as_usize),
            Some(4)
        );
        // Built services serve fully resident: every vector byte in
        // DRAM (SIMD-padded rows: dim 8 pads to stride 16), zero
        // cold-tier traffic.
        let storage = status.get("storage").expect("status carries storage");
        assert_eq!(
            storage.get("residency").and_then(Json::as_str),
            Some("resident")
        );
        assert_eq!(
            storage.get("resident_bytes").and_then(Json::as_usize),
            Some(200 * 16 * 4)
        );
        assert_eq!(storage.get("cold_reads").and_then(Json::as_usize), Some(0));

        // Reload with a bad path is a structured error; the connection
        // and the old index keep serving.
        assert!(client.reload("/definitely/not/an/artifact.pxa").is_err());
        let (ids2, _, _) = client.search(ds.queries.row(0), 5).unwrap();
        assert_eq!(ids2, ids, "old index must keep serving after a failed reload");

        client.shutdown().unwrap();
        server.stop();
    }

    #[test]
    fn server_write_plane_roundtrip() {
        let ds = tiny_uniform(200, 8, Metric::L2, 104);
        let svc = Arc::new(SearchService::build(
            &ds,
            &GraphParams {
                r: 8,
                build_l: 16,
                alpha: 1.2,
                seed: 104,
            },
            &PqParams {
                m: 4,
                c: 16,
                train_sample: 200,
                kmeans_iters: 4,
            },
            SearchParams {
                l: 30,
                k: 5,
                ..Default::default()
            },
            false,
        ));
        let cell = Arc::new(ServiceCell::new(svc));
        let (handle, _join) = spawn(cell.clone(), BatchPolicy::default());
        let server = Server::start(cell, handle, 0).unwrap();
        let mut client = Client::connect(server.addr).unwrap();

        // Insert the first query vector: it becomes its own top-1.
        let q = ds.queries.row(0);
        let (id, e1) = client.insert(q).unwrap();
        assert_eq!(id as usize, 200);
        let (ids, _, _) = client.search(q, 1).unwrap();
        assert_eq!(ids, vec![id]);

        // A wrong-dim insert is a typed error; the connection survives.
        assert!(client.insert(&[1.0, 2.0]).is_err());

        // Delete excludes it immediately and is idempotent.
        let (deleted, e2) = client.delete(id).unwrap();
        assert!(deleted && e2 > e1);
        assert!(!client.delete(id).unwrap().0);
        let (ids, _, _) = client.search(q, 5).unwrap();
        assert!(!ids.contains(&id));
        assert!(client.delete(1_000_000).is_err(), "out-of-range id");

        // status reports the write plane's census and counters.
        let status = client.status().unwrap();
        let online = status.get("online").expect("status carries online");
        assert_eq!(online.get("n_live").and_then(Json::as_usize), Some(200));
        assert_eq!(online.get("n_tombstoned").and_then(Json::as_usize), Some(1));
        assert_eq!(online.get("inserts_total").and_then(Json::as_usize), Some(1));
        assert_eq!(online.get("deletes_total").and_then(Json::as_usize), Some(1));

        // A built index refuses a pathless flush...
        assert!(client.flush(None).is_err());
        // ...and flushes to an explicit path, hot-swapping the compacted
        // successor (the tombstoned insert is gone from its census).
        let path = std::env::temp_dir().join(format!(
            "proxima-server-flush-{}.pxa",
            std::process::id()
        ));
        let resp = client.flush(path.to_str()).unwrap();
        assert_eq!(resp.get("n_live").and_then(Json::as_usize), Some(200));
        let status = client.status().unwrap();
        let online = status.get("online").expect("status carries online");
        assert_eq!(online.get("n_tombstoned").and_then(Json::as_usize), Some(0));
        assert_eq!(online.get("flushes_total").and_then(Json::as_usize), Some(1));
        assert_eq!(
            status
                .get("provenance")
                .and_then(|p| p.get("source"))
                .and_then(Json::as_str),
            Some("artifact")
        );
        // The successor keeps serving.
        let (ids, _, _) = client.search(q, 5).unwrap();
        assert_eq!(ids.len(), 5);

        client.shutdown().unwrap();
        server.stop();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn idle_timeout_drops_connection_and_admin_ops_reconnect() {
        let ds = tiny_uniform(200, 8, Metric::L2, 7);
        let svc = Arc::new(SearchService::build(
            &ds,
            &GraphParams {
                r: 8,
                build_l: 16,
                alpha: 1.2,
                seed: 7,
            },
            &PqParams {
                m: 4,
                c: 16,
                train_sample: 200,
                kmeans_iters: 4,
            },
            SearchParams {
                l: 30,
                k: 5,
                ..Default::default()
            },
            false,
        ));
        let cell = Arc::new(ServiceCell::new(svc));
        let (handle, _join) = spawn(cell.clone(), BatchPolicy::default());
        let server =
            Server::start_with(cell, handle, 0, std::time::Duration::from_millis(200)).unwrap();
        let mut client = Client::connect(server.addr).unwrap();
        let (ids, _, _) = client.search(ds.queries.row(0), 5).unwrap();
        assert_eq!(ids.len(), 5);

        // Sit idle past the timeout: the server drops the connection.
        std::thread::sleep(std::time::Duration::from_millis(500));

        // Search does NOT retry — the dead socket surfaces as an error...
        assert!(client.search(ds.queries.row(0), 5).is_err());
        // ...but admin ops transparently reconnect and succeed.
        let status = client.status().unwrap();
        assert!(status.get("spec").is_some());
        // The reconnected socket serves searches again too.
        let (ids2, _, _) = client.search(ds.queries.row(0), 5).unwrap();
        assert_eq!(ids2, ids);

        client.shutdown().unwrap();
        // stop() returns promptly even though a (reconnected) client
        // socket is still open — idle handlers drain instead of pinning
        // their threads.
        server.stop();
    }
}
