//! TCP front end: newline-delimited JSON requests/responses over a local
//! socket, one handler thread per connection feeding the shared batcher.
//!
//! Protocol (one JSON object per line):
//! ```text
//! -> {"op":"search","query":[f32...],"k":10}
//! <- {"ids":[...],"dists":[...],"latency_us":123}
//! -> {"op":"stats"}
//! <- {"queries":N,"early_terminated":E,"mean_latency_us":...}
//! -> {"op":"shutdown"}
//! ```

use super::batcher::BatcherHandle;
use super::SearchService;
use crate::anyhow;
use crate::util::error::Result;
use crate::util::json::{self, Json};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Running server handle.
pub struct Server {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind to `127.0.0.1:port` (0 = ephemeral) and serve.
    pub fn start(
        service: Arc<SearchService>,
        batcher: BatcherHandle,
        port: u16,
    ) -> Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = shutdown.clone();
        let accept_thread = std::thread::spawn(move || {
            let mut handlers = Vec::new();
            while !flag.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nonblocking(false).ok();
                        // Small JSON lines + closed-loop clients: Nagle +
                        // delayed-ACK would add ~40 ms per hop.
                        stream.set_nodelay(true).ok();
                        let svc = service.clone();
                        let bh = batcher.clone();
                        let f = flag.clone();
                        handlers.push(std::thread::spawn(move || {
                            let _ = handle_conn(stream, svc, bh, f);
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            for h in handlers {
                let _ = h.join();
            }
        });
        Ok(Server {
            addr,
            shutdown,
            accept_thread: Some(accept_thread),
        })
    }

    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    service: Arc<SearchService>,
    batcher: BatcherHandle,
    shutdown: Arc<AtomicBool>,
) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let req = json::parse(&line).map_err(|e| anyhow!("bad request: {e}"))?;
        let op = req.get("op").and_then(Json::as_str).unwrap_or("search");
        let resp = match op {
            "search" => {
                let t0 = std::time::Instant::now();
                let query: Vec<f32> = req
                    .get("query")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("missing query"))?
                    .iter()
                    .filter_map(|x| x.as_f64())
                    .map(|x| x as f32)
                    .collect();
                let k = req.get("k").and_then(Json::as_usize).unwrap_or(10);
                match batcher.query(query, k) {
                    Some(out) => Json::obj(vec![
                        ("ids", Json::arr_num(out.ids.iter().map(|&i| i as f64))),
                        ("dists", Json::arr_num(out.dists.iter().map(|&d| d as f64))),
                        (
                            "latency_us",
                            Json::num(t0.elapsed().as_micros() as f64),
                        ),
                    ]),
                    None => Json::obj(vec![("error", Json::str("batcher closed"))]),
                }
            }
            "stats" => Json::obj(vec![
                (
                    "queries",
                    Json::num(service.stats.queries.load(Ordering::Relaxed) as f64),
                ),
                (
                    "early_terminated",
                    Json::num(service.stats.early_terminated.load(Ordering::Relaxed) as f64),
                ),
                ("mean_latency_us", Json::num(service.mean_latency_us())),
                ("dataset", Json::str(service.name.clone())),
            ]),
            "shutdown" => {
                shutdown.store(true, Ordering::Relaxed);
                writeln!(writer, "{}", Json::obj(vec![("ok", Json::Bool(true))]).to_string_compact())?;
                break;
            }
            other => Json::obj(vec![("error", Json::str(format!("unknown op {other}")))]),
        };
        writeln!(writer, "{}", resp.to_string_compact())?;
    }
    Ok(())
}

/// Minimal blocking client for examples/tests.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    fn roundtrip(&mut self, req: Json) -> Result<Json> {
        writeln!(self.stream, "{}", req.to_string_compact())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        json::parse(&line).map_err(|e| anyhow!("bad response: {e}"))
    }

    /// Search RPC; returns (ids, dists, server latency µs).
    pub fn search(&mut self, query: &[f32], k: usize) -> Result<(Vec<u32>, Vec<f32>, f64)> {
        let req = Json::obj(vec![
            ("op", Json::str("search")),
            ("query", Json::arr_num(query.iter().map(|&x| x as f64))),
            ("k", Json::num(k as f64)),
        ]);
        let resp = self.roundtrip(req)?;
        if let Some(err) = resp.get("error").and_then(Json::as_str) {
            return Err(anyhow!("server error: {err}"));
        }
        let ids = resp
            .get("ids")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing ids"))?
            .iter()
            .filter_map(|x| x.as_f64())
            .map(|x| x as u32)
            .collect();
        let dists = resp
            .get("dists")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(|x| x.as_f64())
            .map(|x| x as f32)
            .collect();
        let lat = resp.get("latency_us").and_then(Json::as_f64).unwrap_or(0.0);
        Ok((ids, dists, lat))
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.roundtrip(Json::obj(vec![("op", Json::str("stats"))]))
    }

    pub fn shutdown(&mut self) -> Result<()> {
        let _ = self.roundtrip(Json::obj(vec![("op", Json::str("shutdown"))]))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GraphParams, PqParams, SearchParams};
    use crate::coordinator::batcher::{spawn, BatchPolicy};
    use crate::dataset::synth::tiny_uniform;
    use crate::distance::Metric;

    #[test]
    fn server_roundtrip() {
        let ds = tiny_uniform(200, 8, Metric::L2, 99);
        let svc = Arc::new(SearchService::build(
            &ds,
            &GraphParams {
                r: 8,
                build_l: 16,
                alpha: 1.2,
                seed: 99,
            },
            &PqParams {
                m: 4,
                c: 16,
                train_sample: 200,
                kmeans_iters: 4,
            },
            SearchParams {
                l: 30,
                k: 5,
                ..Default::default()
            },
            false,
        ));
        let (handle, _join) = spawn(svc.clone(), BatchPolicy::default(), 1);
        let server = Server::start(svc.clone(), handle, 0).unwrap();
        let addr = server.addr;

        let mut client = Client::connect(addr).unwrap();
        let (ids, dists, lat) = client.search(ds.queries.row(0), 5).unwrap();
        assert_eq!(ids.len(), 5);
        assert_eq!(dists.len(), 5);
        assert!(lat >= 0.0);

        let stats = client.stats().unwrap();
        assert_eq!(stats.get("queries").and_then(Json::as_usize), Some(1));

        client.shutdown().unwrap();
        server.stop();
    }
}
