//! Gap encoding for adjacency lists (paper §III-E, Fig 5a).
//!
//! Each vertex's neighbor list is sorted ascending; the first id is stored
//! verbatim and the rest as differences to the previous id. Every value in
//! the row is then packed at the bit width of the row's *maximum* value
//! (the paper's formulation: "the bit width is determined by the bits for
//! the maximum difference value"), prefixed by a 5-bit width field.
//!
//! On 1M–100M-scale graphs the paper reports 20–26 b effective widths and
//! 19–37% index compression; `compression_ratio` in the tests reproduces
//! that band on synthetic graphs.

/// A gap-encoded graph: one packed row per vertex.
#[derive(Clone, Debug)]
pub struct GapGraph {
    /// Bit offsets into `bits` for each row (len = n + 1).
    row_offsets: Vec<u64>,
    /// Packed bitstream.
    bits: Vec<u64>,
    n: usize,
}

const WIDTH_FIELD: u32 = 6; // enough for widths up to 63 bits

/// Append `width` low bits of `val` at bit position `pos`.
fn put_bits(bits: &mut Vec<u64>, pos: u64, val: u64, width: u32) {
    debug_assert!(width <= 64);
    if width == 0 {
        return;
    }
    let word = (pos / 64) as usize;
    let off = (pos % 64) as u32;
    while bits.len() <= word + 1 {
        bits.push(0);
    }
    bits[word] |= val << off;
    if off + width > 64 {
        bits[word + 1] |= val >> (64 - off);
    }
}

/// Read `width` bits at position `pos`. Out-of-range words read as zero —
/// this path is only reachable with corrupted row metadata (the bit-error
/// model) and must not panic.
#[inline]
fn get_bits(bits: &[u64], pos: u64, width: u32) -> u64 {
    if width == 0 {
        return 0;
    }
    let word = (pos / 64) as usize;
    let off = (pos % 64) as u32;
    let w0 = bits.get(word).copied().unwrap_or(0);
    let mut v = w0 >> off;
    if off + width > 64 {
        v |= bits.get(word + 1).copied().unwrap_or(0) << (64 - off);
    }
    if width == 64 {
        v
    } else {
        v & ((1u64 << width) - 1)
    }
}

fn width_for(x: u64) -> u32 {
    64 - x.max(1).leading_zeros()
}

impl GapGraph {
    /// Encode from per-vertex neighbor lists. Lists are sorted internally
    /// (the encoding sorts ascending per the paper; search semantics are
    /// order-independent).
    pub fn encode(rows: &[Vec<u32>]) -> GapGraph {
        let mut bits: Vec<u64> = Vec::new();
        let mut row_offsets = Vec::with_capacity(rows.len() + 1);
        let mut pos = 0u64;
        row_offsets.push(0);
        for row in rows {
            let mut sorted = row.clone();
            sorted.sort_unstable();
            sorted.dedup();
            // Compute gaps and the row's max value.
            let mut vals = Vec::with_capacity(sorted.len());
            let mut prev = 0u32;
            for (i, &id) in sorted.iter().enumerate() {
                let v = if i == 0 { id } else { id - prev };
                vals.push(v as u64);
                prev = id;
            }
            let width = vals.iter().copied().map(width_for).max().unwrap_or(1);
            // Row header: 6-bit width, 16-bit count.
            put_bits(&mut bits, pos, width as u64, WIDTH_FIELD);
            pos += WIDTH_FIELD as u64;
            put_bits(&mut bits, pos, vals.len() as u64, 16);
            pos += 16;
            for v in vals {
                put_bits(&mut bits, pos, v, width);
                pos += width as u64;
            }
            row_offsets.push(pos);
        }
        GapGraph {
            row_offsets,
            bits,
            n: rows.len(),
        }
    }

    pub fn len(&self) -> usize {
        self.n
    }
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Decode one row into `out` (cleared first). Returns neighbor count.
    ///
    /// Robust to corrupted payloads (the §V-E error model flips stored
    /// bits): a corrupted count/width field cannot read past the row's
    /// bit extent recorded in the (controller-resident, hence clean)
    /// offsets table.
    pub fn decode_row(&self, v: usize, out: &mut Vec<u32>) -> usize {
        out.clear();
        let end = self.row_offsets[v + 1];
        let mut pos = self.row_offsets[v];
        let width = (get_bits(&self.bits, pos, WIDTH_FIELD) as u32).max(1);
        pos += WIDTH_FIELD as u64;
        let count = get_bits(&self.bits, pos, 16) as usize;
        pos += 16;
        let mut acc = 0u32;
        for i in 0..count {
            if pos + width as u64 > end {
                break; // corrupted count field claims more than stored
            }
            let raw = get_bits(&self.bits, pos, width) as u32;
            pos += width as u64;
            acc = if i == 0 { raw } else { acc.wrapping_add(raw) };
            out.push(acc);
        }
        out.len()
    }

    /// Total size in bits (the paper's compression metric).
    pub fn size_bits(&self) -> u64 {
        *self.row_offsets.last().unwrap()
    }

    /// Size of the row for vertex `v` in bits — this is what the NAND
    /// traffic model charges per index fetch.
    pub fn row_bits(&self, v: usize) -> u64 {
        self.row_offsets[v + 1] - self.row_offsets[v]
    }

    /// Compression ratio vs uncompressed 32-bit adjacency (paper Fig 5a:
    /// 384 b -> 168 b in the worked example).
    pub fn compression_ratio(&self, total_edges: usize) -> f64 {
        let uncompressed = (total_edges as u64) * 32;
        self.size_bits() as f64 / uncompressed as f64
    }

    /// Effective mean bit width per edge.
    pub fn mean_bits_per_edge(&self, total_edges: usize) -> f64 {
        self.size_bits() as f64 / total_edges as f64
    }

    /// Raw access to packed words (used by the bit-error injection model,
    /// which flips bits *in the stored representation* — §V-E).
    pub fn bits_mut(&mut self) -> &mut [u64] {
        &mut self.bits
    }

    /// The serializable parts: `(row_offsets, bits, n)`. Persisted by the
    /// index-artifact format (`crate::artifact`) so an opened index reuses
    /// the stored packed stream instead of re-encoding the graph.
    pub fn to_parts(&self) -> (&[u64], &[u64], usize) {
        (&self.row_offsets, &self.bits, self.n)
    }

    /// Rebuild from serialized parts, validating the structural
    /// invariants a decoder relies on (offset monotonicity and extent)
    /// so corrupted input yields an error, not a panic or a wild read.
    pub fn from_parts(row_offsets: Vec<u64>, bits: Vec<u64>, n: usize) -> Result<GapGraph, String> {
        // `n` comes straight from the file: checked arithmetic, or an
        // absurd count (e.g. u64::MAX) panics debug builds on `n + 1`.
        if n.checked_add(1) != Some(row_offsets.len()) {
            return Err(format!(
                "gap graph: {} row offsets for {n} rows (want n + 1)",
                row_offsets.len()
            ));
        }
        if row_offsets.first() != Some(&0) {
            return Err("gap graph: first row offset must be 0".into());
        }
        if row_offsets.windows(2).any(|w| w[0] > w[1]) {
            return Err("gap graph: row offsets must be non-decreasing".into());
        }
        let extent = *row_offsets.last().unwrap();
        if extent > bits.len() as u64 * 64 {
            return Err(format!(
                "gap graph: rows claim {extent} bits but only {} are stored",
                bits.len() as u64 * 64
            ));
        }
        Ok(GapGraph {
            row_offsets,
            bits,
            n,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn paper_worked_example_sizes() {
        // Fig 5a: 4 vertices x 3 NNs, 32-b uncompressed = 384 b. Gap
        // encoding should land well below that for small ids.
        let rows = vec![
            vec![12, 35, 7],
            vec![2, 40, 21],
            vec![8, 9, 10],
            vec![100, 3, 50],
        ];
        let g = GapGraph::encode(&rows);
        assert!(g.size_bits() < 384, "encoded {} bits", g.size_bits());
        let mut out = Vec::new();
        g.decode_row(2, &mut out);
        assert_eq!(out, vec![8, 9, 10]);
    }

    #[test]
    fn roundtrip_exact() {
        let rows = vec![
            vec![5, 1, 9, 100000],
            vec![],
            vec![0],
            vec![u32::MAX - 1, 7],
        ];
        let g = GapGraph::encode(&rows);
        let mut out = Vec::new();
        for (i, row) in rows.iter().enumerate() {
            g.decode_row(i, &mut out);
            let mut expect = row.clone();
            expect.sort_unstable();
            expect.dedup();
            assert_eq!(out, expect, "row {i}");
        }
    }

    #[test]
    fn prop_roundtrip_random_graphs() {
        prop::check_default(
            "gap-roundtrip",
            201,
            |r| {
                let n = prop::gen::len(r, 30);
                let bound = 1 + r.gen_range(1_000_000);
                (0..n)
                    .map(|_| {
                        let deg = r.gen_range(20);
                        prop::gen::vec_u32(r, deg, bound as u32)
                    })
                    .collect::<Vec<Vec<u32>>>()
            },
            |rows| {
                let g = GapGraph::encode(rows);
                let mut out = Vec::new();
                for (i, row) in rows.iter().enumerate() {
                    g.decode_row(i, &mut out);
                    let mut expect = row.clone();
                    expect.sort_unstable();
                    expect.dedup();
                    if out != expect {
                        return Err(format!("row {i}: {out:?} != {expect:?}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn compression_band_on_realistic_graph() {
        // R=32 regular graph over 100k ids: paper reports >=19-37% savings
        // (ratio 0.63..0.81) for 1M-100M; smaller id spaces compress more.
        let mut rng = crate::util::rng::Xoshiro256pp::seed_from_u64(77);
        let n = 2000;
        let rows: Vec<Vec<u32>> = (0..n)
            .map(|_| prop::gen::vec_u32(&mut rng, 32, 100_000))
            .collect();
        let g = GapGraph::encode(&rows);
        let edges: usize = rows.iter().map(|r| {
            let mut s = r.clone();
            s.sort_unstable();
            s.dedup();
            s.len()
        }).sum();
        let ratio = g.compression_ratio(edges);
        assert!(ratio < 0.81, "ratio {ratio}");
        assert!(ratio > 0.2, "ratio {ratio} suspiciously small");
    }

    #[test]
    fn row_bits_sum_to_total() {
        let rows = vec![vec![1, 2], vec![100], vec![3, 4, 5]];
        let g = GapGraph::encode(&rows);
        let sum: u64 = (0..rows.len()).map(|i| g.row_bits(i)).sum();
        assert_eq!(sum, g.size_bits());
    }

    #[test]
    fn bit_helpers() {
        let mut bits = Vec::new();
        put_bits(&mut bits, 0, 0b1011, 4);
        put_bits(&mut bits, 4, 0xFFFF, 16);
        put_bits(&mut bits, 62, 0b111, 3); // crosses word boundary
        assert_eq!(get_bits(&bits, 0, 4), 0b1011);
        assert_eq!(get_bits(&bits, 4, 16), 0xFFFF);
        assert_eq!(get_bits(&bits, 62, 3), 0b111);
    }
}
