//! 3D NAND bit-error injection (paper §V-E, Fig 17).
//!
//! Proxima stores three data types in SLC NAND without ECC; raw bit error
//! rates are ~1e-5 for SLC, >1e-4 for MLC, and higher for TLC. This module
//! flips stored bits at a given BER in each of the three representations —
//! PQ codes, (gap-encoded) graph indices, and raw f32 vectors — and the
//! Fig 17 bench measures the recall impact. Corrupted neighbor ids that
//! decode out of range are dropped at fetch time (the realistic hardware
//! behaviour: the arbiter's address check rejects them).

use crate::dataset::VectorSet;
use crate::gap::GapGraph;
use crate::graph::Graph;
use crate::pq::PqCodes;
use crate::util::rng::Xoshiro256pp;

/// Error-rate presets from the paper's citations.
pub mod ber {
    /// SLC 3D NAND raw BER (paper: < 1e-5).
    pub const SLC: f64 = 1e-5;
    /// MLC 3D NAND raw BER (paper: > 1e-4).
    pub const MLC: f64 = 1e-4;
    /// TLC 3D NAND raw BER.
    pub const TLC: f64 = 5e-4;
}

/// Flip each bit of `bytes` independently with probability `ber`.
/// Returns the number of flipped bits. For small `ber` we draw geometric
/// gaps between flips instead of per-bit Bernoulli trials.
pub fn flip_bits_u8(bytes: &mut [u8], ber: f64, rng: &mut Xoshiro256pp) -> u64 {
    flip_generic(bytes.len() as u64 * 8, ber, rng, |bit| {
        bytes[(bit / 8) as usize] ^= 1 << (bit % 8);
    })
}

/// Flip bits in a u64 word array.
pub fn flip_bits_u64(words: &mut [u64], ber: f64, rng: &mut Xoshiro256pp) -> u64 {
    flip_generic(words.len() as u64 * 64, ber, rng, |bit| {
        words[(bit / 64) as usize] ^= 1 << (bit % 64);
    })
}

/// Flip bits in f32 data (IEEE-754 bit patterns, as stored in NAND pages).
pub fn flip_bits_f32(vals: &mut [f32], ber: f64, rng: &mut Xoshiro256pp) -> u64 {
    flip_generic(vals.len() as u64 * 32, ber, rng, |bit| {
        let idx = (bit / 32) as usize;
        let b = vals[idx].to_bits() ^ (1 << (bit % 32));
        vals[idx] = f32::from_bits(b);
    })
}

fn flip_generic(total_bits: u64, ber: f64, rng: &mut Xoshiro256pp, mut flip: impl FnMut(u64)) -> u64 {
    if ber <= 0.0 || total_bits == 0 {
        return 0;
    }
    // Geometric skip sampling: P(gap = g) = (1-p)^g * p.
    let ln1p = (1.0 - ber).ln();
    let mut pos = 0u64;
    let mut flips = 0u64;
    loop {
        let u = rng.next_f64().max(1e-300);
        let gap = (u.ln() / ln1p).floor() as u64;
        pos = pos.saturating_add(gap);
        if pos >= total_bits {
            return flips;
        }
        flip(pos);
        flips += 1;
        pos += 1;
    }
}

/// A corrupted copy of the stored index state.
pub struct CorruptedIndex {
    pub codes: PqCodes,
    pub base: VectorSet,
    pub gap: GapGraph,
    pub flipped_bits: u64,
}

/// Corrupt all three stored representations at `ber`.
///
/// `c` is the PQ centroid count: the stored code occupies only
/// `log2(C)` bits, so corrupted code bytes are masked back into
/// `[0, C)` (the hardware cannot read bits that are not stored; with the
/// paper's C=256 the mask is a no-op).
pub fn corrupt(
    base: &VectorSet,
    graph: &Graph,
    codes: &PqCodes,
    c: usize,
    ber: f64,
    seed: u64,
) -> CorruptedIndex {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut codes2 = codes.clone();
    let mut base2 = base.clone();
    let mut gap2 = GapGraph::encode(&graph.to_lists());
    let mut flipped = 0;
    flipped += flip_bits_u8(&mut codes2.codes, ber, &mut rng);
    if c < 256 {
        let mask = (c.next_power_of_two() - 1) as u8;
        for b in codes2.codes.iter_mut() {
            *b &= mask;
            if *b as usize >= c {
                *b %= c as u8;
            }
        }
    }
    flipped += flip_bits_f32(&mut base2.data, ber, &mut rng);
    flipped += flip_bits_u64(gap2.bits_mut(), ber, &mut rng);
    CorruptedIndex {
        codes: codes2,
        base: base2,
        gap: gap2,
        flipped_bits: flipped,
    }
}

/// Rebuild a [`Graph`] from a corrupted gap encoding, dropping out-of-range
/// neighbor ids (the arbiter's address-range check) and self loops.
pub fn graph_from_corrupted_gap(gap: &GapGraph, n: usize, max_degree: usize, entry: u32) -> Graph {
    let mut lists: Vec<Vec<u32>> = Vec::with_capacity(n);
    let mut buf = Vec::new();
    for v in 0..n {
        gap.decode_row(v, &mut buf);
        let mut row: Vec<u32> = buf
            .iter()
            .copied()
            .filter(|&t| (t as usize) < n && t != v as u32)
            .collect();
        row.truncate(max_degree);
        lists.push(row);
    }
    Graph::from_lists(&lists, entry, max_degree)
}

/// NaN/Inf scrubbing for corrupted raw vectors: the FP16/FP32 datapath in
/// the search engine saturates non-finite inputs; mirror that so distances
/// stay ordered (a NaN would poison the sort).
pub fn scrub_nonfinite(base: &mut VectorSet) -> usize {
    let mut scrubbed = 0;
    for x in base.data.iter_mut() {
        if !x.is_finite() {
            *x = if x.is_sign_negative() { -3.4e38 } else { 3.4e38 };
            scrubbed += 1;
        }
    }
    scrubbed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GraphParams;
    use crate::dataset::synth::tiny_uniform;
    use crate::distance::Metric;
    use crate::graph::vamana;
    use crate::pq::PqCodebook;

    #[test]
    fn flip_count_matches_ber() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let mut data = vec![0u8; 1_000_000];
        let flips = flip_bits_u8(&mut data, 1e-3, &mut rng);
        let expect = 8_000_000.0 * 1e-3;
        assert!(
            (flips as f64 - expect).abs() < expect * 0.2,
            "flips {flips} expect {expect}"
        );
        // Every flip visible in the data.
        let ones: u32 = data.iter().map(|b| b.count_ones()).sum();
        assert_eq!(ones as u64, flips);
    }

    #[test]
    fn zero_ber_is_identity() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let mut data = vec![0xAAu8; 1000];
        assert_eq!(flip_bits_u8(&mut data, 0.0, &mut rng), 0);
        assert!(data.iter().all(|&b| b == 0xAA));
    }

    #[test]
    fn f32_flips_change_values() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let mut vals = vec![1.0f32; 10_000];
        let flips = flip_bits_f32(&mut vals, 1e-3, &mut rng);
        assert!(flips > 0);
        let changed = vals.iter().filter(|&&v| v != 1.0).count();
        assert!(changed > 0);
    }

    #[test]
    fn corrupted_graph_stays_in_range() {
        let ds = tiny_uniform(300, 8, Metric::L2, 71);
        let g = vamana::build(
            &ds.base,
            ds.metric,
            &GraphParams {
                r: 8,
                build_l: 24,
                alpha: 1.2,
                seed: 71,
            },
        );
        let cb = PqCodebook::train(&ds.base, ds.metric, 4, 16, 300, 6, 71);
        let codes = cb.encode(&ds.base);
        let cor = corrupt(&ds.base, &g, &codes, 16, 1e-2, 5); // heavy corruption
        let g2 = graph_from_corrupted_gap(&cor.gap, g.n(), g.max_degree, g.entry_point);
        g2.validate().unwrap();
        assert!(cor.flipped_bits > 0);
    }

    #[test]
    fn recall_degrades_monotonically_in_expectation() {
        use crate::config::SearchParams;
        use crate::dataset::ground_truth::brute_force;
        use crate::search::beam::SearchContext;
        use crate::search::proxima::{proxima_search, ProximaFeatures};

        let ds = tiny_uniform(500, 12, Metric::L2, 72);
        let g = vamana::build(
            &ds.base,
            ds.metric,
            &GraphParams {
                r: 12,
                build_l: 32,
                alpha: 1.2,
                seed: 72,
            },
        );
        let cb = PqCodebook::train(&ds.base, ds.metric, 6, 32, 500, 8, 72);
        let codes = cb.encode(&ds.base);
        let gt = brute_force(&ds, 5);
        let params = SearchParams {
            l: 60,
            k: 5,
            ..Default::default()
        };

        let recall_at_ber = |ber: f64| {
            let cor = corrupt(&ds.base, &g, &codes, 32, ber, 9);
            let mut base = cor.base.clone();
            scrub_nonfinite(&mut base);
            let g2 = graph_from_corrupted_gap(&cor.gap, g.n(), g.max_degree, g.entry_point);
            let ctx = SearchContext {
                base: &base,
                metric: ds.metric,
                graph: &g2,
                codes: Some(&cor.codes),
                gap: None,
                storage: None,
                online: None,
                lsh: None,
            };
            let mut r = 0.0;
            for q in 0..ds.n_queries() {
                let adt = cb.build_adt(ds.queries.row(q));
                let out = proxima_search(
                    &ctx,
                    &adt,
                    ds.queries.row(q),
                    &params,
                    ProximaFeatures::default(),
                    false,
                );
                r += crate::dataset::recall_at_k(&out.ids, gt.row(q), 5);
            }
            r / ds.n_queries() as f64
        };

        let clean = recall_at_ber(0.0);
        let slc = recall_at_ber(ber::SLC);
        let catastrophic = recall_at_ber(3e-2);
        // Paper Fig 17 shape: SLC-level BER costs <3% recall; extreme BER
        // collapses recall.
        assert!(clean - slc < 0.05, "clean {clean} slc {slc}");
        assert!(catastrophic < clean - 0.1, "catastrophic {catastrophic} vs {clean}");
    }
}
