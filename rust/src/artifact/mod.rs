//! The versioned, checksummed on-disk index artifact — the first-class
//! deployment unit of the serving stack ("build once, open anywhere").
//!
//! A built index (graph + PQ + raw vectors + layout metadata) is saved
//! as ONE self-describing binary file; opening it reconstructs a
//! serveable index without touching the raw dataset or re-running any
//! build step. The same file feeds the NAND engine/simulator: the
//! `MAPPING` section carries the §IV-E [`DataMapping`] verbatim, and the
//! optional `REORDER` section the hot-node permutation, so software
//! serving and hardware simulation open one artifact.
//!
//! # File layout (format version 1, all integers little-endian)
//!
//! ```text
//! magic           8 B   b"PXARTIF1"
//! format_version  u32   1 (checked before anything else — a future
//!                        version fails with a clean VersionMismatch
//!                        even if the rest of the layout changed)
//! spec                  IndexSpec (see below)
//! n_sections      u32
//! TOC entries           per section: tag u32, len u64, crc32 u32
//! header_crc      u32   CRC-32 (IEEE) over [spec .. end of TOC]
//! payloads              section payloads, concatenated in TOC order
//! ```
//!
//! `IndexSpec` serializes as: dataset (str), metric (str), dim u32,
//! n_base u64, graph_r u32, graph_build_l u32, graph_alpha f32, pq_m
//! u32, pq_c u32, hot_frac f64, build_seed u64 — where `str` is u32
//! length + UTF-8 bytes. Section payload layouts are documented in
//! [`sections`].
//!
//! # Integrity contract
//!
//! Decoding NEVER panics on bad bytes. Every failure is a typed
//! [`ArtifactError`] (convertible to [`ApiError`] for the wire):
//! truncation → [`Truncated`](ArtifactErrorKind::Truncated), a flipped
//! byte → [`Corrupt`](ArtifactErrorKind::Corrupt) (every payload byte is
//! covered by a section CRC and the spec/TOC by the header CRC), a
//! future format → [`VersionMismatch`](ArtifactErrorKind::VersionMismatch),
//! wrong-index-for-this-dataset → [`SpecMismatch`](ArtifactErrorKind::SpecMismatch).
//! Beyond checksums (which only catch accidental corruption), structural
//! invariants are re-validated on open — CSR offset monotonicity, PQ
//! codes within the codebook's centroid range, graph targets in range —
//! so even a crafted file with valid CRCs cannot drive the search
//! kernels' unchecked indexing out of bounds.

pub mod sections;

use crate::api::ApiError;
use crate::dataset::io as bio;
use crate::dataset::{Dataset, VectorSet};
use crate::distance::Metric;
use crate::engine::mapping::DataMapping;
use crate::gap::GapGraph;
use crate::graph::Graph;
use crate::pq::{PqCodebook, PqCodes};
use crate::search::lsh_start::LshIndex;
use std::fmt;
use std::ops::Range;
use std::path::Path;

/// The artifact file magic.
pub const MAGIC: &[u8; 8] = b"PXARTIF1";

/// Highest artifact format version this build reads and the version it
/// writes. Bump ONLY with a migration story: the golden-fixture test
/// (`tests/artifact_golden.rs`) pins the readability of v1 files.
pub const FORMAT_VERSION: u32 = 1;

/// Section tags (TOC `tag` field).
pub const SEC_BASE: u32 = 1;
pub const SEC_GRAPH: u32 = 2;
pub const SEC_GAP: u32 = 3;
pub const SEC_CODEBOOK: u32 = 4;
pub const SEC_CODES: u32 = 5;
pub const SEC_REORDER: u32 = 6;
pub const SEC_MAPPING: u32 = 7;
pub const SEC_LSH: u32 = 8;

/// Upper bound on TOC entries: a corrupt count field must not drive a
/// huge allocation before the header CRC gets a chance to reject it.
const MAX_SECTIONS: usize = 256;

// ---------------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------------

/// Machine-readable artifact failure class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactErrorKind {
    /// Filesystem failure (open/read/write).
    Io,
    /// The file ends before the structure it promises.
    Truncated,
    /// Not an artifact file at all.
    BadMagic,
    /// A format version this build does not speak.
    VersionMismatch,
    /// Checksum mismatch or a structural invariant violated.
    Corrupt,
    /// The artifact is valid but does not fit the dataset/deployment it
    /// was asked to serve (e.g. dimension mismatch).
    SpecMismatch,
}

impl ArtifactErrorKind {
    pub fn name(self) -> &'static str {
        match self {
            ArtifactErrorKind::Io => "io",
            ArtifactErrorKind::Truncated => "truncated",
            ArtifactErrorKind::BadMagic => "bad_magic",
            ArtifactErrorKind::VersionMismatch => "version_mismatch",
            ArtifactErrorKind::Corrupt => "corrupt",
            ArtifactErrorKind::SpecMismatch => "spec_mismatch",
        }
    }
}

/// Typed artifact failure: a stable kind plus a human-readable message.
#[derive(Clone, Debug)]
pub struct ArtifactError {
    pub kind: ArtifactErrorKind,
    pub message: String,
}

impl ArtifactError {
    pub fn new(kind: ArtifactErrorKind, message: impl Into<String>) -> ArtifactError {
        ArtifactError {
            kind,
            message: message.into(),
        }
    }
    pub fn io(message: impl Into<String>) -> ArtifactError {
        Self::new(ArtifactErrorKind::Io, message)
    }
    pub fn truncated(message: impl Into<String>) -> ArtifactError {
        Self::new(ArtifactErrorKind::Truncated, message)
    }
    pub fn corrupt(message: impl Into<String>) -> ArtifactError {
        Self::new(ArtifactErrorKind::Corrupt, message)
    }
    pub fn spec_mismatch(message: impl Into<String>) -> ArtifactError {
        Self::new(ArtifactErrorKind::SpecMismatch, message)
    }
}

impl fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "artifact {}: {}", self.kind.name(), self.message)
    }
}

impl std::error::Error for ArtifactError {}

/// Surface artifact failures on the wire/API boundary: an operator
/// handing the server a bad path or bad bytes is a request problem
/// (`bad_request`); a filesystem failure is the server's (`internal`).
impl From<ArtifactError> for ApiError {
    fn from(e: ArtifactError) -> ApiError {
        match e.kind {
            ArtifactErrorKind::Io => ApiError::internal(e.to_string()),
            _ => ApiError::bad_request(e.to_string()),
        }
    }
}

/// Map the shared byte-reader's string errors into typed artifact
/// errors. Out-of-bounds reads carry the reader's single-sourced
/// [`bio::TRUNCATED_MSG`] sentinel; anything else it produces (bad
/// UTF-8, length overflow) means the bytes are garbage, not short.
pub(crate) fn rd<T>(r: Result<T, crate::util::error::Error>) -> Result<T, ArtifactError> {
    r.map_err(|e| {
        let msg = e.to_string();
        if msg.contains(bio::TRUNCATED_MSG) {
            ArtifactError::truncated(msg)
        } else {
            ArtifactError::corrupt(msg)
        }
    })
}

// ---------------------------------------------------------------------------
// CRC-32 (IEEE 802.3, zlib-compatible)
// ---------------------------------------------------------------------------

/// Byte-at-a-time CRC table, computed at compile time. Artifacts are
/// checksummed in full on BOTH save and open — at deployment scale the
/// base-vector section alone is hundreds of MB, so the open ("fast
/// restart") path cannot afford the bitwise 8-iterations-per-byte
/// formulation.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC-32 over `bytes` (poly 0xEDB88320, init/xorout 0xFFFFFFFF) —
/// matches `zlib.crc32`, so fixtures can be produced by the Python
/// tooling (`python/tools/make_golden_artifact.py`).
pub fn crc32(bytes: &[u8]) -> u32 {
    !crc32_raw(0xFFFF_FFFF, bytes)
}

/// Incremental form for streaming verification (the cold open CRCs the
/// BASE payload chunk by chunk without materializing it): start from
/// `0xFFFF_FFFF`, fold chunks in file order, finish with `!state`.
pub(crate) fn crc32_raw(state: u32, bytes: &[u8]) -> u32 {
    let mut crc = state;
    for &b in bytes {
        crc = CRC_TABLE[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc
}

// ---------------------------------------------------------------------------
// IndexSpec
// ---------------------------------------------------------------------------

/// What was built and how: the identity card of a serialized index.
/// Stored in the artifact header and reported by the wire `status` op.
#[derive(Clone, Debug, PartialEq)]
pub struct IndexSpec {
    /// Dataset id the index was built from.
    pub dataset: String,
    pub metric: Metric,
    pub dim: u32,
    pub n_base: u64,
    /// Vamana max degree R.
    pub graph_r: u32,
    /// Build-time candidate list L_build.
    pub graph_build_l: u32,
    /// Vamana pruning α.
    pub graph_alpha: f32,
    /// PQ subspace count M.
    pub pq_m: u32,
    /// PQ centroids per subspace K (≤ 256).
    pub pq_c: u32,
    /// Hot-node fraction of the §IV-E layout (0 when no reordering was
    /// applied).
    pub hot_frac: f64,
    /// Graph-build seed (PQ training derives its seed from it, exactly
    /// as `SearchService::build` does).
    pub build_seed: u64,
}

impl IndexSpec {
    /// Can this index answer queries drawn from `ds`? Checked when the
    /// CLI pairs `--index` with a query dataset: a dimension or metric
    /// mismatch would otherwise produce garbage distances (or a panic
    /// deep in a kernel) instead of an actionable error.
    pub fn check_compatible(&self, ds: &Dataset) -> Result<(), ArtifactError> {
        if ds.dim() != self.dim as usize {
            return Err(ArtifactError::spec_mismatch(format!(
                "spec/dataset dim mismatch: artifact dim {}, dataset '{}' dim {}",
                self.dim,
                ds.name,
                ds.dim()
            )));
        }
        if ds.metric != self.metric {
            return Err(ArtifactError::spec_mismatch(format!(
                "spec/dataset metric mismatch: artifact {}, dataset '{}' {}",
                self.metric.name(),
                ds.name,
                ds.metric.name()
            )));
        }
        // Same base-set size, or ground truth computed from `ds` refers
        // to different vectors than the artifact's ids and every recall
        // number is garbage (the classic wrong-`--scale` mistake).
        if ds.n_base() as u64 != self.n_base {
            return Err(ArtifactError::spec_mismatch(format!(
                "spec/dataset base-set mismatch: artifact indexes {} vectors, dataset '{}' \
                 holds {} (was the dataset regenerated at a different --scale?)",
                self.n_base,
                ds.name,
                ds.n_base()
            )));
        }
        // Last line of defense: the dataset id itself. Two datasets can
        // coincide on shape yet hold different vectors (the shape checks
        // above give the more actionable message when they differ).
        if ds.name != self.dataset {
            return Err(ArtifactError::spec_mismatch(format!(
                "spec/dataset id mismatch: artifact was built from '{}', queries come \
                 from '{}'",
                self.dataset, ds.name
            )));
        }
        Ok(())
    }

    fn encode(&self, buf: &mut Vec<u8>) {
        bio::put_str(buf, &self.dataset);
        bio::put_str(buf, self.metric.name());
        bio::put_u32(buf, self.dim);
        bio::put_u64(buf, self.n_base);
        bio::put_u32(buf, self.graph_r);
        bio::put_u32(buf, self.graph_build_l);
        bio::put_f32(buf, self.graph_alpha);
        bio::put_u32(buf, self.pq_m);
        bio::put_u32(buf, self.pq_c);
        bio::put_f64(buf, self.hot_frac);
        bio::put_u64(buf, self.build_seed);
    }

    fn decode(r: &mut bio::Reader<'_>) -> Result<IndexSpec, ArtifactError> {
        let dataset = rd(r.str())?;
        let metric_name = rd(r.str())?;
        let metric = Metric::parse(&metric_name).ok_or_else(|| {
            ArtifactError::corrupt(format!("spec: unknown metric '{metric_name}'"))
        })?;
        let spec = IndexSpec {
            dataset,
            metric,
            dim: rd(r.u32())?,
            n_base: rd(r.u64())?,
            graph_r: rd(r.u32())?,
            graph_build_l: rd(r.u32())?,
            graph_alpha: rd(r.f32())?,
            pq_m: rd(r.u32())?,
            pq_c: rd(r.u32())?,
            hot_frac: rd(r.f64())?,
            build_seed: rd(r.u64())?,
        };
        // hot_frac is a fraction by contract: the tiered open sizes its
        // DRAM hot set as `n_base * hot_frac`, so a NaN/negative/huge
        // value (checksum-valid but crafted) must die here, not surface
        // as a nonsense allocation or an empty-by-NaN hot tier.
        if !spec.hot_frac.is_finite() || !(0.0..=1.0).contains(&spec.hot_frac) {
            return Err(ArtifactError::corrupt(format!(
                "spec: hot_frac {} outside [0, 1]",
                spec.hot_frac
            )));
        }
        Ok(spec)
    }
}

/// Where a served index came from — reported by the wire `status` op so
/// an operator can tell a warm-restarted replica from a fresh build.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum IndexProvenance {
    /// Built in-process from a dataset this run.
    Built,
    /// Opened from a serialized artifact.
    Artifact { path: String },
}

// ---------------------------------------------------------------------------
// Section-level writer / reader
// ---------------------------------------------------------------------------

/// Assembles an artifact: a spec plus tagged, individually-checksummed
/// sections. The typed layer ([`ArtifactParts::write`]) is built on it;
/// it stays public so tools can carry extra sections (unknown tags are
/// preserved and ignored by this build's readers).
pub struct ArtifactWriter {
    spec: IndexSpec,
    sections: Vec<(u32, Vec<u8>)>,
}

impl ArtifactWriter {
    pub fn new(spec: IndexSpec) -> ArtifactWriter {
        ArtifactWriter {
            spec,
            sections: Vec::new(),
        }
    }

    /// Append one section (tags need not be unique for forward-compat
    /// tooling, but this build's readers use the first match). Panics
    /// beyond the reader-side section cap — the writer must never emit
    /// a file its own reader rejects.
    pub fn section(&mut self, tag: u32, payload: Vec<u8>) -> &mut ArtifactWriter {
        assert!(
            self.sections.len() < MAX_SECTIONS,
            "artifact section count is capped at {MAX_SECTIONS} (the reader rejects more)"
        );
        self.sections.push((tag, payload));
        self
    }

    /// The file prefix up to (and including) the header CRC — everything
    /// before the concatenated section payloads.
    fn header_bytes(&self) -> Vec<u8> {
        let mut header = Vec::new();
        self.spec.encode(&mut header);
        bio::put_u32(&mut header, self.sections.len() as u32);
        for (tag, payload) in &self.sections {
            bio::put_u32(&mut header, *tag);
            bio::put_u64(&mut header, payload.len() as u64);
            bio::put_u32(&mut header, crc32(payload));
        }
        let mut buf = Vec::with_capacity(16 + header.len());
        buf.extend_from_slice(MAGIC);
        bio::put_u32(&mut buf, FORMAT_VERSION);
        let header_crc = crc32(&header);
        buf.extend_from_slice(&header);
        bio::put_u32(&mut buf, header_crc);
        buf
    }

    /// Serialize to the on-disk byte layout (see the module docs) —
    /// concatenates a full in-memory image; [`Self::write`] streams to
    /// disk instead and is the right call for large artifacts.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = self.header_bytes();
        buf.reserve(self.sections.iter().map(|(_, p)| p.len()).sum::<usize>());
        for (_, payload) in &self.sections {
            buf.extend_from_slice(payload);
        }
        buf
    }

    /// Write atomically (temp file + rename): a crashed save never
    /// leaves a torn artifact at the target path. Payloads stream to the
    /// file directly, so peak memory stays at ONE copy of the encoded
    /// sections (not header-image + concatenated image).
    pub fn write(&self, path: &Path) -> Result<(), ArtifactError> {
        let header = self.header_bytes();
        bio::write_atomic_with(path, |f| {
            use std::io::Write;
            f.write_all(&header)?;
            for (_, payload) in &self.sections {
                f.write_all(payload)?;
            }
            Ok(())
        })
        .map_err(|e| ArtifactError::io(format!("writing {}: {e}", path.display())))
    }
}

/// Parsed artifact header: the spec plus each section's
/// (tag, absolute payload offset, payload len, stored crc). Payload
/// BYTES are not verified here — the two readers do that their own way
/// (whole-buffer CRC vs on-demand/streaming CRC).
struct ParsedHeader {
    spec: IndexSpec,
    toc: Vec<(u32, u64, u64, u32)>,
}

/// The ONE copy of the on-disk header grammar, shared by the in-memory
/// reader ([`ArtifactReader::from_bytes`]) and the file-backed view
/// ([`ArtifactFile::open`]) so the two can never drift: magic, format
/// version, spec, section-count cap, TOC entries, header CRC, and
/// exact-length payload accounting against `total_len` (every byte of
/// the file is owned by exactly one section; an uncovered tail — torn
/// overwrite of a longer file, concatenation — is corruption, not
/// something to silently ignore).
///
/// `head` starts at file offset 0. When it holds less than the whole
/// file (`head_is_whole == false`: the bounded head read of the file
/// view), a parse running off its end means a header larger than any
/// legitimate artifact writes — reported as corruption, not as file
/// truncation.
fn parse_header(
    head: &[u8],
    total_len: u64,
    head_is_whole: bool,
) -> Result<ParsedHeader, ArtifactError> {
    let mut r = bio::Reader::new(head);
    let parse = (|| -> Result<(IndexSpec, Vec<(u32, u64, u32)>, usize, u32), ArtifactError> {
        let magic = rd(r.take(8))?;
        if magic != MAGIC {
            return Err(ArtifactError::new(
                ArtifactErrorKind::BadMagic,
                "not a Proxima index artifact (bad magic)",
            ));
        }
        let version = rd(r.u32())?;
        if version != FORMAT_VERSION {
            return Err(ArtifactError::new(
                ArtifactErrorKind::VersionMismatch,
                format!(
                    "unsupported artifact format version {version} \
                     (this build reads version {FORMAT_VERSION})"
                ),
            ));
        }
        let spec = IndexSpec::decode(&mut r)?;
        let n_sections = rd(r.u32())? as usize;
        if n_sections > MAX_SECTIONS {
            return Err(ArtifactError::corrupt(format!(
                "implausible section count {n_sections}"
            )));
        }
        let mut entries = Vec::with_capacity(n_sections);
        for _ in 0..n_sections {
            let tag = rd(r.u32())?;
            let len = rd(r.u64())?;
            let crc = rd(r.u32())?;
            entries.push((tag, len, crc));
        }
        // The cursor now sits at the end of the TOC = end of the
        // checksummed header region.
        let toc_end = r.pos();
        let stored_header_crc = rd(r.u32())?;
        Ok((spec, entries, toc_end, stored_header_crc))
    })();
    let (spec, entries, toc_end, stored_header_crc) = match parse {
        Ok(v) => v,
        Err(e)
            if e.kind == ArtifactErrorKind::Truncated
                && !head_is_whole
                && (head.len() as u64) < total_len =>
        {
            return Err(ArtifactError::corrupt(format!(
                "header exceeds {HEADER_MAX_BYTES} bytes ({e})"
            )))
        }
        Err(e) => return Err(e),
    };
    // Header region = [spec .. end of TOC]; its CRC follows the TOC.
    let header_start = 12;
    if crc32(&head[header_start..toc_end]) != stored_header_crc {
        return Err(ArtifactError::corrupt(
            "header checksum mismatch (spec or section table corrupted)",
        ));
    }
    let mut toc = Vec::with_capacity(entries.len());
    let mut pos = toc_end as u64 + 4; // payloads start after the header CRC
    for (tag, len, crc) in entries {
        let end = pos.checked_add(len).filter(|&e| e <= total_len).ok_or_else(|| {
            ArtifactError::truncated(format!(
                "section {tag}: payload of {len} bytes runs past end of file"
            ))
        })?;
        toc.push((tag, pos, len, crc));
        pos = end;
    }
    if pos != total_len {
        return Err(ArtifactError::corrupt(format!(
            "{} trailing bytes after the last section",
            total_len - pos
        )));
    }
    Ok(ParsedHeader { spec, toc })
}

/// Validated view of an artifact's bytes: spec parsed, header and every
/// section checksum verified. Section payloads are borrowed from the
/// owned buffer via [`ArtifactReader::section`].
pub struct ArtifactReader {
    spec: IndexSpec,
    buf: Vec<u8>,
    toc: Vec<(u32, Range<usize>)>,
}

impl ArtifactReader {
    /// Read and validate the file at `path`.
    pub fn open(path: &Path) -> Result<ArtifactReader, ArtifactError> {
        let buf = std::fs::read(path)
            .map_err(|e| ArtifactError::io(format!("reading {}: {e}", path.display())))?;
        Self::from_bytes(buf)
    }

    /// Validate an in-memory artifact image: the shared header parse
    /// ([`parse_header`]) plus a CRC check of every section payload.
    pub fn from_bytes(buf: Vec<u8>) -> Result<ArtifactReader, ArtifactError> {
        let parsed = parse_header(&buf, buf.len() as u64, true)?;
        let mut toc = Vec::with_capacity(parsed.toc.len());
        for (tag, off, len, crc) in parsed.toc {
            let range = off as usize..(off + len) as usize;
            if crc32(&buf[range.clone()]) != crc {
                return Err(ArtifactError::corrupt(format!(
                    "section {tag}: checksum mismatch"
                )));
            }
            toc.push((tag, range));
        }
        Ok(ArtifactReader {
            spec: parsed.spec,
            buf,
            toc,
        })
    }

    pub fn spec(&self) -> &IndexSpec {
        &self.spec
    }

    /// The checksum-verified payload of the first section tagged `tag`.
    pub fn section(&self, tag: u32) -> Option<&[u8]> {
        self.toc
            .iter()
            .find(|(t, _)| *t == tag)
            .map(|(_, range)| &self.buf[range.clone()])
    }

    /// Tags present, in file order (unknown tags included).
    pub fn tags(&self) -> impl Iterator<Item = u32> + '_ {
        self.toc.iter().map(|(t, _)| *t)
    }
}

// ---------------------------------------------------------------------------
// Typed artifact: the full index bundle
// ---------------------------------------------------------------------------

/// Borrowed view of everything an index artifact stores — what
/// `SearchService::save` assembles.
pub struct ArtifactParts<'a> {
    pub spec: &'a IndexSpec,
    pub base: &'a VectorSet,
    pub graph: &'a Graph,
    pub gap: Option<&'a GapGraph>,
    pub codebook: &'a PqCodebook,
    pub codes: &'a PqCodes,
    /// §IV-E frequency-reorder permutation (`perm[old] = new`), when the
    /// index was reordered.
    pub reorder: Option<&'a [u32]>,
    /// §IV-E data-allocation layout, so the NAND engine/sim can open the
    /// same artifact.
    pub mapping: Option<&'a DataMapping>,
    /// LSH entry-point index (`--lsh_start` warm starts), when built.
    pub lsh: Option<&'a LshIndex>,
}

impl ArtifactParts<'_> {
    fn writer(&self) -> ArtifactWriter {
        let mut w = ArtifactWriter::new(self.spec.clone());
        w.section(SEC_BASE, sections::encode_base(self.base));
        w.section(SEC_GRAPH, sections::encode_graph(self.graph));
        if let Some(gap) = self.gap {
            w.section(SEC_GAP, sections::encode_gap(gap));
        }
        w.section(SEC_CODEBOOK, sections::encode_codebook(self.codebook));
        w.section(SEC_CODES, sections::encode_codes(self.codes));
        if let Some(perm) = self.reorder {
            w.section(SEC_REORDER, sections::encode_reorder(perm));
        }
        if let Some(m) = self.mapping {
            w.section(SEC_MAPPING, sections::encode_mapping(m));
        }
        if let Some(l) = self.lsh {
            w.section(SEC_LSH, sections::encode_lsh(l));
        }
        w
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        self.writer().to_bytes()
    }

    /// Write the artifact atomically (delegates to
    /// [`ArtifactWriter::write`] — one copy of the save semantics).
    pub fn write(&self, path: &Path) -> Result<(), ArtifactError> {
        self.writer().write(path)
    }
}

/// A fully decoded, cross-validated index artifact.
pub struct IndexArtifact {
    pub spec: IndexSpec,
    pub base: VectorSet,
    pub graph: Graph,
    /// Stored gap encoding when present (absent in minimal artifacts;
    /// `SearchService::open` re-encodes from the graph in that case).
    pub gap: Option<GapGraph>,
    pub codebook: PqCodebook,
    pub codes: PqCodes,
    pub reorder: Option<Vec<u32>>,
    pub mapping: Option<DataMapping>,
    pub lsh: Option<LshIndex>,
}

impl IndexArtifact {
    /// Open, decode and cross-validate the artifact at `path`.
    pub fn open(path: &Path) -> Result<IndexArtifact, ArtifactError> {
        Self::from_reader(&ArtifactReader::open(path)?)
    }

    /// Decode and cross-validate an already checksum-verified reader.
    pub fn from_reader(r: &ArtifactReader) -> Result<IndexArtifact, ArtifactError> {
        let spec = r.spec().clone();
        let need = |tag: u32, name: &str| {
            r.section(tag)
                .ok_or_else(|| ArtifactError::corrupt(format!("missing required section {name}")))
        };
        let base = sections::decode_base(need(SEC_BASE, "BASE")?)?;
        let graph = sections::decode_graph(need(SEC_GRAPH, "GRAPH")?)?;
        let codebook = sections::decode_codebook(need(SEC_CODEBOOK, "CODEBOOK")?)?;
        let codes = sections::decode_codes(need(SEC_CODES, "CODES")?)?;
        let gap = r.section(SEC_GAP).map(sections::decode_gap).transpose()?;
        let reorder = r
            .section(SEC_REORDER)
            .map(sections::decode_reorder)
            .transpose()?;
        let mapping = r
            .section(SEC_MAPPING)
            .map(sections::decode_mapping)
            .transpose()?;
        let lsh = r.section(SEC_LSH).map(sections::decode_lsh).transpose()?;

        // Cross-section consistency (shared with the cold open, which
        // validates the same invariants without materializing BASE).
        cross_validate(
            &spec,
            base.len(),
            base.dim,
            &graph,
            &codebook,
            &codes,
            gap.as_ref(),
            reorder.as_deref(),
            mapping.as_ref(),
            lsh.as_ref(),
        )?;
        // Angular math (`1 - dot`) is cosine distance only on unit-norm
        // vectors — the dataset loaders normalize on load, but an
        // artifact is a new entry point that bypasses them. Reject
        // unnormalized angular bases here (mirroring `io::load_dataset`)
        // instead of letting every query return silently-wrong
        // rankings (or trip the kernels' debug asserts). The cold open
        // performs the same scan during its streaming CRC pass.
        if spec.metric == Metric::Angular {
            for i in 0..base.len() {
                check_angular_row(base.row(i), i)?;
            }
        }
        Ok(IndexArtifact {
            spec,
            base,
            graph,
            gap,
            codebook,
            codes,
            reorder,
            mapping,
            lsh,
        })
    }
}

/// Cross-section consistency: everything the search kernels (and their
/// unchecked indexing) assume must hold, re-proven on EVERY open —
/// resident or cold — so a crafted file with valid checksums still
/// cannot misbehave. `base_n`/`base_dim` come from the BASE section
/// header (the payload itself may still be on disk).
#[allow(clippy::too_many_arguments)]
fn cross_validate(
    spec: &IndexSpec,
    base_n: usize,
    base_dim: usize,
    graph: &Graph,
    codebook: &PqCodebook,
    codes: &PqCodes,
    gap: Option<&GapGraph>,
    reorder: Option<&[u32]>,
    mapping: Option<&DataMapping>,
    lsh: Option<&LshIndex>,
) -> Result<(), ArtifactError> {
    let n = base_n;
    if n as u64 != spec.n_base {
        return Err(ArtifactError::corrupt(format!(
            "spec says {} base vectors, BASE section holds {n}",
            spec.n_base
        )));
    }
    if base_dim != spec.dim as usize {
        return Err(ArtifactError::corrupt(format!(
            "spec says dim {}, BASE section holds dim {}",
            spec.dim, base_dim
        )));
    }
    if n > u32::MAX as usize {
        return Err(ArtifactError::corrupt(format!(
            "{n} base vectors exceed the u32 vertex-id space"
        )));
    }
    if graph.n() != n {
        return Err(ArtifactError::corrupt(format!(
            "graph has {} vertices for {n} base vectors",
            graph.n()
        )));
    }
    graph
        .validate()
        .map_err(|e| ArtifactError::corrupt(format!("graph: {e}")))?;
    if codebook.metric != spec.metric {
        return Err(ArtifactError::corrupt(format!(
            "spec metric {} but codebook metric {}",
            spec.metric.name(),
            codebook.metric.name()
        )));
    }
    if codebook.dim != spec.dim as usize
        || codebook.m != spec.pq_m as usize
        || codebook.c != spec.pq_c as usize
    {
        return Err(ArtifactError::corrupt(format!(
            "codebook shape (dim {}, m {}, c {}) disagrees with spec \
             (dim {}, m {}, c {})",
            codebook.dim, codebook.m, codebook.c, spec.dim, spec.pq_m, spec.pq_c
        )));
    }
    if codes.m != codebook.m {
        return Err(ArtifactError::corrupt(format!(
            "codes have m {} but codebook has m {}",
            codes.m, codebook.m
        )));
    }
    if codes.len() != n {
        return Err(ArtifactError::corrupt(format!(
            "{} code rows for {n} base vectors",
            codes.len()
        )));
    }
    // `Adt::pq_distance` indexes `table[j*c + code]` unchecked: every
    // stored code MUST be < c.
    if let Some(bad) = codes.codes.iter().position(|&cd| cd as usize >= codebook.c) {
        return Err(ArtifactError::corrupt(format!(
            "PQ code {} at position {bad} out of range (c = {})",
            codes.codes[bad], codebook.c
        )));
    }
    if let Some(g) = gap {
        if g.len() != n {
            return Err(ArtifactError::corrupt(format!(
                "gap encoding covers {} rows for {n} vertices",
                g.len()
            )));
        }
    }
    if let Some(perm) = reorder {
        if perm.len() != n {
            return Err(ArtifactError::corrupt(format!(
                "reorder permutation of length {} for {n} vertices",
                perm.len()
            )));
        }
    }
    if let Some(m) = mapping {
        if m.n_nodes as usize != n {
            return Err(ArtifactError::corrupt(format!(
                "mapping laid out for {} nodes, index holds {n}",
                m.n_nodes
            )));
        }
    }
    // LSH warm starts seed traversal with raw ids from the bucket CSR —
    // the kernels index them unchecked, so coverage and dim must match.
    if let Some(l) = lsh {
        if l.len() != n {
            return Err(ArtifactError::corrupt(format!(
                "LSH signatures cover {} rows for {n} base vectors",
                l.len()
            )));
        }
        if l.dim() != base_dim {
            return Err(ArtifactError::corrupt(format!(
                "LSH planes have dim {} but base holds dim {base_dim}",
                l.dim()
            )));
        }
    }
    Ok(())
}

/// The angular unit-norm invariant for one base row (see the resident
/// open for why this is an open-time rejection).
fn check_angular_row(row: &[f32], i: usize) -> Result<(), ArtifactError> {
    let n2 = crate::distance::dot(row, row);
    if (n2 - 1.0).abs() > 1e-3 {
        return Err(ArtifactError::corrupt(format!(
            "angular artifact holds unnormalized base vector {i} (|v|^2 = {n2}); \
             rebuild the artifact from normalized data"
        )));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Header-only file view + cold open
// ---------------------------------------------------------------------------

/// Header-only view of an artifact on disk: spec and TOC parsed and
/// CRC-verified from a bounded head read, section payloads left in the
/// file. This is the substrate of the cold open (`storage::Residency`):
/// it knows every section's absolute file offset, so payloads can be
/// fetched — or served in place — without ever materializing the whole
/// artifact image in memory the way [`ArtifactReader::open`] does.
pub struct ArtifactFile {
    file: std::fs::File,
    path: std::path::PathBuf,
    spec: IndexSpec,
    /// (tag, absolute payload offset, payload len, stored crc).
    toc: Vec<(u32, u64, u64, u32)>,
}

/// Largest legitimate header (spec + TOC): MAX_SECTIONS entries plus a
/// spec whose strings are human-scale names. Far below this in practice;
/// a "header" running past it is corruption, not a big index.
const HEADER_MAX_BYTES: u64 = 1 << 20;

impl ArtifactFile {
    /// Open the file and validate its header via the shared
    /// [`parse_header`] (magic, version, spec, TOC, header CRC,
    /// exact-length payload accounting). Section payloads are NOT read
    /// or checksummed here — fetch them with [`Self::read_section`] /
    /// [`Self::stream_section`], or verify without materializing via
    /// [`Self::verify_section_at`].
    pub fn open(path: &Path) -> Result<ArtifactFile, ArtifactError> {
        let file = std::fs::File::open(path)
            .map_err(|e| ArtifactError::io(format!("opening {}: {e}", path.display())))?;
        let file_len = file
            .metadata()
            .map_err(|e| ArtifactError::io(format!("stat {}: {e}", path.display())))?
            .len();
        let head_len = file_len.min(HEADER_MAX_BYTES) as usize;
        let mut head = vec![0u8; head_len];
        crate::storage::read_exact_at(&file, &mut head, 0)
            .map_err(|e| ArtifactError::io(format!("reading {}: {e}", path.display())))?;
        let parsed = parse_header(&head, file_len, head_len as u64 == file_len)?;
        Ok(ArtifactFile {
            file,
            path: path.to_path_buf(),
            spec: parsed.spec,
            toc: parsed.toc,
        })
    }

    pub fn spec(&self) -> &IndexSpec {
        &self.spec
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// (absolute payload offset, len, stored crc) of the first section
    /// tagged `tag`.
    pub fn section_meta(&self, tag: u32) -> Option<(u64, u64, u32)> {
        self.toc
            .iter()
            .find(|(t, ..)| *t == tag)
            .map(|&(_, off, len, crc)| (off, len, crc))
    }

    /// Number of TOC entries (sections) in file order.
    pub fn n_sections(&self) -> usize {
        self.toc.len()
    }

    /// TOC position of the FIRST section tagged `tag` (the occurrence
    /// this build's readers use).
    pub fn first_index_of(&self, tag: u32) -> Option<usize> {
        self.toc.iter().position(|(t, ..)| *t == tag)
    }

    /// CRC-verify the section at TOC position `idx` by streaming it in
    /// bounded chunks — no materialization. The cold open uses this to
    /// cover sections it does not decode (unknown tags, duplicate
    /// occurrences of known tags), so residency can never change the
    /// open-time validation outcome: every payload byte the resident
    /// reader checks is checked here too.
    pub fn verify_section_at(&self, idx: usize) -> Result<(), ArtifactError> {
        let (tag, off, len, crc) = self.toc[idx];
        let chunk = (1usize << 20).min(len as usize).max(1);
        let mut buf = vec![0u8; chunk];
        let mut state = 0xFFFF_FFFFu32;
        let mut done = 0u64;
        while done < len {
            let take = ((len - done) as usize).min(chunk);
            crate::storage::read_exact_at(&self.file, &mut buf[..take], off + done)
                .map_err(|e| ArtifactError::io(format!("reading {}: {e}", self.path.display())))?;
            state = crc32_raw(state, &buf[..take]);
            done += take as u64;
        }
        if !state != crc {
            return Err(ArtifactError::corrupt(format!(
                "section {tag}: checksum mismatch"
            )));
        }
        Ok(())
    }

    /// Read and CRC-verify one section payload into memory.
    pub fn read_section(&self, tag: u32) -> Result<Option<Vec<u8>>, ArtifactError> {
        let Some((off, len, crc)) = self.section_meta(tag) else {
            return Ok(None);
        };
        let mut buf = vec![0u8; len as usize];
        crate::storage::read_exact_at(&self.file, &mut buf, off)
            .map_err(|e| ArtifactError::io(format!("reading {}: {e}", self.path.display())))?;
        if crc32(&buf) != crc {
            return Err(ArtifactError::corrupt(format!(
                "section {tag}: checksum mismatch"
            )));
        }
        Ok(Some(buf))
    }

    /// Stream one section through `visit` in `chunk_bytes` pieces (the
    /// final piece may be shorter), CRC-verifying the whole payload.
    /// `visit` receives each chunk plus its offset within the payload.
    /// Returns `false` when the section is absent.
    pub fn stream_section(
        &self,
        tag: u32,
        chunk_bytes: usize,
        mut visit: impl FnMut(&[u8], u64) -> Result<(), ArtifactError>,
    ) -> Result<bool, ArtifactError> {
        let Some((off, len, crc)) = self.section_meta(tag) else {
            return Ok(false);
        };
        let chunk_bytes = chunk_bytes.max(1);
        let mut buf = vec![0u8; chunk_bytes.min(len as usize).max(1)];
        let mut state = 0xFFFF_FFFFu32;
        let mut done = 0u64;
        while done < len {
            let take = ((len - done) as usize).min(chunk_bytes);
            crate::storage::read_exact_at(&self.file, &mut buf[..take], off + done)
                .map_err(|e| ArtifactError::io(format!("reading {}: {e}", self.path.display())))?;
            state = crc32_raw(state, &buf[..take]);
            visit(&buf[..take], done)?;
            done += take as u64;
        }
        if !state != crc {
            return Err(ArtifactError::corrupt(format!(
                "section {tag}: checksum mismatch"
            )));
        }
        Ok(true)
    }

    /// Hand the file off (to a cold vector store).
    pub fn into_file(self) -> std::fs::File {
        self.file
    }
}

/// A decoded artifact whose BASE payload stays on disk — what the
/// `Cold`/`Tiered` residencies open. Every non-BASE section is read,
/// checksum-verified and decoded exactly as the resident open does; the
/// BASE section is validated by ONE streaming pass (CRC over the whole
/// payload, the angular unit-norm scan, and — for `Tiered` — capture of
/// the first `n_hot = round(n * hot_frac)` rows into DRAM), leaving the
/// raw vectors to be served in place via `storage::ColdVectors`.
pub struct ColdArtifact {
    pub spec: IndexSpec,
    pub graph: Graph,
    pub gap: Option<GapGraph>,
    pub codebook: PqCodebook,
    pub codes: PqCodes,
    pub reorder: Option<Vec<u32>>,
    pub mapping: Option<DataMapping>,
    pub lsh: Option<LshIndex>,
    /// BASE shape, from the section header (cross-validated vs spec).
    pub n_base: usize,
    pub dim: usize,
    /// Absolute file offset of BASE row 0's first f32.
    pub base_data_offset: u64,
    /// First `n_hot` rows, captured during the validation pass when
    /// `capture_hot` was set (empty otherwise).
    pub hot: VectorSet,
    /// The validated artifact file, ready to serve cold reads.
    pub file: std::fs::File,
}

impl ColdArtifact {
    /// Open `path` without materializing the BASE payload. With
    /// `capture_hot`, the hot prefix (`spec.hot_frac` of rows — the
    /// §IV-E reorder puts the hottest vertices first) is pinned into
    /// [`Self::hot`] during the same validation pass.
    pub fn open(path: &Path, capture_hot: bool) -> Result<ColdArtifact, ArtifactError> {
        let af = ArtifactFile::open(path)?;
        let spec = af.spec().clone();
        // Residency must not change what open-time validation covers:
        // the resident reader CRCs EVERY section, so before decoding,
        // stream-verify the ones this path will NOT otherwise touch —
        // unknown/forward-compat tags and duplicate occurrences of
        // known tags. (The first occurrence of each known tag is
        // verified below: `read_section` for the decoded sections, the
        // streaming validation pass for BASE.)
        let mut covered = vec![false; af.n_sections()];
        for tag in [
            SEC_BASE,
            SEC_GRAPH,
            SEC_GAP,
            SEC_CODEBOOK,
            SEC_CODES,
            SEC_REORDER,
            SEC_MAPPING,
            SEC_LSH,
        ] {
            if let Some(i) = af.first_index_of(tag) {
                covered[i] = true;
            }
        }
        for (i, seen) in covered.iter().enumerate() {
            if !seen {
                af.verify_section_at(i)?;
            }
        }
        let need = |tag: u32, name: &str| -> Result<Vec<u8>, ArtifactError> {
            af.read_section(tag)?
                .ok_or_else(|| ArtifactError::corrupt(format!("missing required section {name}")))
        };
        let graph = sections::decode_graph(&need(SEC_GRAPH, "GRAPH")?)?;
        let codebook = sections::decode_codebook(&need(SEC_CODEBOOK, "CODEBOOK")?)?;
        let codes = sections::decode_codes(&need(SEC_CODES, "CODES")?)?;
        let gap = af
            .read_section(SEC_GAP)?
            .map(|p| sections::decode_gap(&p))
            .transpose()?;
        let reorder = af
            .read_section(SEC_REORDER)?
            .map(|p| sections::decode_reorder(&p))
            .transpose()?;
        let mapping = af
            .read_section(SEC_MAPPING)?
            .map(|p| sections::decode_mapping(&p))
            .transpose()?;
        let lsh = af
            .read_section(SEC_LSH)?
            .map(|p| sections::decode_lsh(&p))
            .transpose()?;

        // BASE header: dim u32, n u64 (see `sections::encode_base`).
        let (base_off, base_len, base_crc) = af
            .section_meta(SEC_BASE)
            .ok_or_else(|| ArtifactError::corrupt("missing required section BASE"))?;
        if base_len < 12 {
            return Err(ArtifactError::truncated(
                "BASE section shorter than its 12-byte header",
            ));
        }
        let mut hdr = [0u8; 12];
        crate::storage::read_exact_at(&af.file, &mut hdr, base_off)
            .map_err(|e| ArtifactError::io(format!("reading {}: {e}", path.display())))?;
        let dim = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
        let n = u64::from_le_bytes(hdr[4..12].try_into().unwrap()) as usize;
        if dim == 0 {
            return Err(ArtifactError::corrupt("BASE: dim must be >= 1"));
        }
        let expect = n
            .checked_mul(dim)
            .and_then(|c| c.checked_mul(4))
            .and_then(|c| c.checked_add(12))
            .ok_or_else(|| ArtifactError::corrupt("BASE: n * dim overflows"))?;
        if expect as u64 != base_len {
            return Err(ArtifactError::corrupt(format!(
                "BASE: payload holds {base_len} bytes for {n} x {dim} vectors \
                 (expected {expect})"
            )));
        }

        cross_validate(
            &spec,
            n,
            dim,
            &graph,
            &codebook,
            &codes,
            gap.as_ref(),
            reorder.as_deref(),
            mapping.as_ref(),
            lsh.as_ref(),
        )?;

        // ONE streaming pass over the BASE payload: CRC every byte
        // (section header + rows), prove the angular norm invariant,
        // and capture the hot prefix — in bounded, row-aligned chunks,
        // never materializing the payload.
        let n_hot = if capture_hot {
            ((n as f64 * spec.hot_frac).round() as usize).min(n)
        } else {
            0
        };
        let row_bytes = dim * 4;
        let rows_per_chunk = ((1usize << 20) / row_bytes).max(1);
        let mut hot_data: Vec<f32> = Vec::with_capacity(n_hot * dim);
        let angular = spec.metric == Metric::Angular;
        let mut row_vals: Vec<f32> = vec![0.0; dim];
        let mut buf = vec![0u8; rows_per_chunk.min(n.max(1)) * row_bytes];
        let data_off = base_off + 12;
        let mut state = crc32_raw(0xFFFF_FFFF, &hdr);
        let mut done = 0usize;
        while done < n {
            let take_rows = (n - done).min(rows_per_chunk);
            let take = take_rows * row_bytes;
            crate::storage::read_exact_at(
                &af.file,
                &mut buf[..take],
                data_off + (done * row_bytes) as u64,
            )
            .map_err(|e| ArtifactError::io(format!("reading {}: {e}", path.display())))?;
            state = crc32_raw(state, &buf[..take]);
            if angular || done < n_hot {
                for (r, raw) in buf[..take].chunks_exact(row_bytes).enumerate() {
                    let row = done + r;
                    let capture = row < n_hot;
                    if !(angular || capture) {
                        break;
                    }
                    for (v, ch) in row_vals.iter_mut().zip(raw.chunks_exact(4)) {
                        *v = f32::from_le_bytes(ch.try_into().unwrap());
                    }
                    if angular {
                        check_angular_row(&row_vals, row)?;
                    }
                    if capture {
                        hot_data.extend_from_slice(&row_vals);
                    }
                }
            }
            done += take_rows;
        }
        if !state != base_crc {
            return Err(ArtifactError::corrupt(format!(
                "section {SEC_BASE}: checksum mismatch"
            )));
        }

        Ok(ColdArtifact {
            spec,
            graph,
            gap,
            codebook,
            codes,
            reorder,
            mapping,
            lsh,
            n_base: n,
            dim,
            base_data_offset: base_off + 12,
            hot: VectorSet { dim, data: hot_data },
            file: af.into_file(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> IndexSpec {
        IndexSpec {
            dataset: "unit".into(),
            metric: Metric::L2,
            dim: 4,
            n_base: 3,
            graph_r: 2,
            graph_build_l: 8,
            graph_alpha: 1.2,
            pq_m: 2,
            pq_c: 4,
            hot_frac: 0.0,
            build_seed: 7,
        }
    }

    #[test]
    fn crc32_matches_the_ieee_check_value() {
        // The standard CRC-32 check value — also what zlib.crc32
        // produces, which the Python fixture generator relies on.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn writer_reader_roundtrip_at_the_byte_level() {
        let mut w = ArtifactWriter::new(spec());
        w.section(SEC_CODES, vec![1, 2, 3]);
        w.section(99, vec![0xAB; 17]); // unknown tag: preserved
        let r = ArtifactReader::from_bytes(w.to_bytes()).unwrap();
        assert_eq!(r.spec(), &spec());
        assert_eq!(r.section(SEC_CODES), Some(&[1u8, 2, 3][..]));
        assert_eq!(r.section(99).map(|p| p.len()), Some(17));
        assert_eq!(r.section(SEC_GRAPH), None);
        assert_eq!(r.tags().collect::<Vec<_>>(), vec![SEC_CODES, 99]);
    }

    #[test]
    fn bad_magic_version_and_flips_are_typed() {
        let mut w = ArtifactWriter::new(spec());
        w.section(SEC_CODES, vec![7; 32]);
        let good = w.to_bytes();

        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert_eq!(
            ArtifactReader::from_bytes(bad).unwrap_err().kind,
            ArtifactErrorKind::BadMagic
        );

        // Future format version fails cleanly BEFORE any layout parsing.
        let mut future = good.clone();
        future[8..12].copy_from_slice(&(FORMAT_VERSION + 1).to_le_bytes());
        let e = ArtifactReader::from_bytes(future).unwrap_err();
        assert_eq!(e.kind, ArtifactErrorKind::VersionMismatch);
        assert!(e.message.contains("version"), "{e}");

        // A flipped spec byte is caught by the header CRC.
        let mut spec_flip = good.clone();
        spec_flip[20] ^= 0x01;
        assert_eq!(
            ArtifactReader::from_bytes(spec_flip).unwrap_err().kind,
            ArtifactErrorKind::Corrupt
        );

        // A flipped payload byte is caught by its section CRC.
        let mut payload_flip = good.clone();
        let last = payload_flip.len() - 1;
        payload_flip[last] ^= 0x80;
        assert_eq!(
            ArtifactReader::from_bytes(payload_flip).unwrap_err().kind,
            ArtifactErrorKind::Corrupt
        );

        // Trailing garbage after the last payload (torn overwrite,
        // concatenation) is rejected, not silently ignored.
        let mut padded = good.clone();
        padded.extend_from_slice(b"JUNK");
        let e = ArtifactReader::from_bytes(padded).unwrap_err();
        assert_eq!(e.kind, ArtifactErrorKind::Corrupt);
        assert!(e.message.contains("trailing"), "{e}");

        // Truncation anywhere is a typed error, never a panic.
        for cut in [5, 11, good.len() / 2, good.len() - 1] {
            let e = ArtifactReader::from_bytes(good[..cut].to_vec()).unwrap_err();
            assert!(
                matches!(
                    e.kind,
                    ArtifactErrorKind::Truncated
                        | ArtifactErrorKind::Corrupt
                        | ArtifactErrorKind::BadMagic
                ),
                "cut {cut}: {e}"
            );
        }
    }

    #[test]
    fn spec_compat_reports_dim_metric_and_scale_mismatches() {
        use crate::dataset::synth::tiny_uniform;
        let mut s = spec();
        s.n_base = 10;
        let ds4 = tiny_uniform(10, 4, Metric::L2, 1);
        s.dataset = ds4.name.clone();
        s.check_compatible(&ds4).unwrap();
        let ds6 = tiny_uniform(10, 6, Metric::L2, 1);
        let e = s.check_compatible(&ds6).unwrap_err();
        assert_eq!(e.kind, ArtifactErrorKind::SpecMismatch);
        assert!(e.message.contains("dim"), "{e}");
        let ip = tiny_uniform(10, 4, Metric::Ip, 1);
        let e = s.check_compatible(&ip).unwrap_err();
        assert_eq!(e.kind, ArtifactErrorKind::SpecMismatch);
        assert!(e.message.contains("metric"), "{e}");
        // Same dim/metric but a different base-set size (the classic
        // wrong-`--scale` regeneration): recall against it would be
        // garbage, so it must be a typed mismatch.
        let bigger = tiny_uniform(20, 4, Metric::L2, 1);
        let e = s.check_compatible(&bigger).unwrap_err();
        assert_eq!(e.kind, ArtifactErrorKind::SpecMismatch);
        assert!(e.message.contains("scale"), "{e}");
        // Identical shape but a different dataset id: still a mismatch
        // (the vectors are not the ones the artifact indexed).
        s.dataset = "something-else".into();
        let e = s.check_compatible(&ds4).unwrap_err();
        assert_eq!(e.kind, ArtifactErrorKind::SpecMismatch);
        assert!(e.message.contains("id mismatch"), "{e}");
    }

    #[test]
    fn unnormalized_angular_artifacts_are_rejected_at_open() {
        use crate::config::{GraphParams, PqParams, SearchParams};
        use crate::coordinator::SearchService;
        use crate::dataset::synth::tiny_uniform;
        let ds = tiny_uniform(60, 6, Metric::Angular, 3);
        let svc = SearchService::build(
            &ds,
            &GraphParams {
                r: 6,
                build_l: 12,
                alpha: 1.2,
                seed: 3,
            },
            &PqParams {
                m: 3,
                c: 8,
                train_sample: 60,
                kmeans_iters: 4,
            },
            SearchParams::default(),
            false,
        );
        // Re-encode the artifact with SCALED base vectors: checksums
        // are valid (the writer computes them over the tampered bytes),
        // but the angular unit-norm precondition is broken.
        let mut bad_base = svc.resident_base().unwrap();
        for x in bad_base.data.iter_mut() {
            *x *= 2.0;
        }
        let parts = ArtifactParts {
            spec: &svc.spec,
            base: &bad_base,
            graph: &svc.graph,
            gap: None,
            codebook: &svc.codebook,
            codes: &svc.codes,
            reorder: None,
            mapping: None,
            lsh: None,
        };
        let r = ArtifactReader::from_bytes(parts.to_bytes()).unwrap();
        let e = IndexArtifact::from_reader(&r).unwrap_err();
        assert_eq!(e.kind, ArtifactErrorKind::Corrupt);
        assert!(e.message.contains("unnormalized"), "{e}");
        // The untampered service round-trips fine.
        let base = svc.resident_base().unwrap();
        let good = ArtifactParts {
            spec: &svc.spec,
            base: &base,
            graph: &svc.graph,
            gap: None,
            codebook: &svc.codebook,
            codes: &svc.codes,
            reorder: None,
            mapping: None,
            lsh: None,
        };
        let r = ArtifactReader::from_bytes(good.to_bytes()).unwrap();
        IndexArtifact::from_reader(&r).unwrap();
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("proxima-artifact-unit-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    #[test]
    fn artifact_file_header_view_agrees_with_the_full_reader() {
        let mut w = ArtifactWriter::new(spec());
        w.section(SEC_CODES, vec![1, 2, 3]);
        w.section(99, vec![0xAB; 17]);
        let bytes = w.to_bytes();
        let path = tmp("header-view.pxa");
        std::fs::write(&path, &bytes).unwrap();

        let af = ArtifactFile::open(&path).unwrap();
        assert_eq!(af.spec(), &spec());
        // Sections read through the file view match the in-memory view.
        let full = ArtifactReader::from_bytes(bytes.clone()).unwrap();
        assert_eq!(
            af.read_section(SEC_CODES).unwrap().as_deref(),
            full.section(SEC_CODES)
        );
        assert_eq!(af.read_section(SEC_GRAPH).unwrap(), None);
        // Streamed == whole, chunk size notwithstanding.
        let mut streamed = Vec::new();
        let found = af
            .stream_section(99, 5, |chunk, off| {
                assert_eq!(off as usize, streamed.len());
                streamed.extend_from_slice(chunk);
                Ok(())
            })
            .unwrap();
        assert!(found);
        assert_eq!(Some(streamed.as_slice()), full.section(99));

        // The same corruption posture as the full reader: flipped
        // payload bytes are caught when the section is READ (or
        // streamed), truncation at the file level at open.
        let mut flipped = bytes.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x01;
        std::fs::write(&path, &flipped).unwrap();
        let af = ArtifactFile::open(&path).unwrap(); // header still valid
        assert_eq!(
            af.read_section(99).unwrap_err().kind,
            ArtifactErrorKind::Corrupt
        );
        assert_eq!(
            af.stream_section(99, 4, |_, _| Ok(())).unwrap_err().kind,
            ArtifactErrorKind::Corrupt
        );
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        let e = ArtifactFile::open(&path).unwrap_err();
        assert_eq!(e.kind, ArtifactErrorKind::Truncated);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cold_open_decodes_identically_and_captures_the_hot_prefix() {
        use crate::config::{GraphParams, PqParams, SearchParams};
        use crate::coordinator::SearchService;
        use crate::dataset::synth::tiny_uniform;
        let ds = tiny_uniform(60, 8, Metric::L2, 5);
        let svc = SearchService::build(
            &ds,
            &GraphParams {
                r: 6,
                build_l: 12,
                alpha: 1.2,
                seed: 5,
            },
            &PqParams {
                m: 4,
                c: 8,
                train_sample: 60,
                kmeans_iters: 4,
            },
            SearchParams::default(),
            false,
        );
        let mut spec2 = svc.spec.clone();
        spec2.hot_frac = 0.1; // 6 of 60 rows hot
        let base = svc.resident_base().unwrap();
        let parts = ArtifactParts {
            spec: &spec2,
            base: &base,
            graph: &svc.graph,
            gap: svc.gap.as_ref(),
            codebook: &svc.codebook,
            codes: &svc.codes,
            reorder: None,
            mapping: None,
            lsh: None,
        };
        let path = tmp("cold-open.pxa");
        parts.write(&path).unwrap();

        let full = IndexArtifact::open(&path).unwrap();
        let cold = ColdArtifact::open(&path, true).unwrap();
        assert_eq!(cold.spec, full.spec);
        assert_eq!(cold.n_base, full.base.len());
        assert_eq!(cold.dim, full.base.dim);
        assert_eq!(cold.graph.offsets, full.graph.offsets);
        assert_eq!(cold.graph.targets, full.graph.targets);
        assert_eq!(cold.codes.codes, full.codes.codes);
        assert_eq!(cold.hot.len(), 6, "hot prefix = round(60 * 0.1)");
        for i in 0..6 {
            assert_eq!(cold.hot.row(i), full.base.row(i), "hot row {i}");
        }
        // Without capture, nothing is pinned.
        let cold = ColdArtifact::open(&path, false).unwrap();
        assert_eq!(cold.hot.len(), 0);
        // The recorded data offset points at row 0's bytes.
        let raw = std::fs::read(&path).unwrap();
        let off = cold.base_data_offset as usize;
        let first = f32::from_le_bytes(raw[off..off + 4].try_into().unwrap());
        assert_eq!(first.to_bits(), full.base.row(0)[0].to_bits());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn lsh_section_roundtrips_at_both_residencies() {
        use crate::config::{GraphParams, PqParams, SearchParams};
        use crate::coordinator::SearchService;
        use crate::dataset::synth::tiny_uniform;
        let ds = tiny_uniform(50, 8, Metric::L2, 9);
        let svc = SearchService::build(
            &ds,
            &GraphParams {
                r: 6,
                build_l: 12,
                alpha: 1.2,
                seed: 9,
            },
            &PqParams {
                m: 4,
                c: 8,
                train_sample: 50,
                kmeans_iters: 4,
            },
            SearchParams::default(),
            false,
        );
        let base = svc.resident_base().unwrap();
        let lsh = LshIndex::build(&base, 5, 0xA11CE);
        let parts = ArtifactParts {
            spec: &svc.spec,
            base: &base,
            graph: &svc.graph,
            gap: None,
            codebook: &svc.codebook,
            codes: &svc.codes,
            reorder: None,
            mapping: None,
            lsh: Some(&lsh),
        };
        let path = tmp("lsh-roundtrip.pxa");
        parts.write(&path).unwrap();

        let full = IndexArtifact::open(&path).unwrap();
        let cold = ColdArtifact::open(&path, false).unwrap();
        for got in [full.lsh.as_ref().unwrap(), cold.lsh.as_ref().unwrap()] {
            assert_eq!(got.n_bits(), lsh.n_bits());
            assert_eq!(got.seed(), lsh.seed());
            assert_eq!(got.signatures(), lsh.signatures());
            assert_eq!(got.planes(), lsh.planes());
        }
        // Coverage mismatch (signatures for a different n) is corruption.
        let short = LshIndex::build(
            &VectorSet {
                dim: base.dim,
                data: base.data[..base.dim * 10].to_vec(),
            },
            5,
            0xA11CE,
        );
        let bad = ArtifactParts {
            lsh: Some(&short),
            ..parts
        };
        let r = ArtifactReader::from_bytes(bad.to_bytes()).unwrap();
        let e = IndexArtifact::from_reader(&r).unwrap_err();
        assert_eq!(e.kind, ArtifactErrorKind::Corrupt);
        assert!(e.message.contains("LSH"), "{e}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cold_open_verifies_sections_it_does_not_decode() {
        use crate::config::{GraphParams, PqParams, SearchParams};
        use crate::coordinator::SearchService;
        use crate::dataset::synth::tiny_uniform;
        // An artifact carrying an unknown forward-compat section:
        // corrupting THAT payload must be rejected by the cold open
        // exactly like the resident open — residency can never change
        // the open-time validation outcome.
        let ds = tiny_uniform(40, 8, Metric::L2, 6);
        let svc = SearchService::build(
            &ds,
            &GraphParams {
                r: 6,
                build_l: 12,
                alpha: 1.2,
                seed: 6,
            },
            &PqParams {
                m: 4,
                c: 8,
                train_sample: 40,
                kmeans_iters: 4,
            },
            SearchParams::default(),
            false,
        );
        let mut w = ArtifactWriter::new(svc.spec.clone());
        w.section(SEC_BASE, sections::encode_base(&svc.resident_base().unwrap()));
        w.section(SEC_GRAPH, sections::encode_graph(&svc.graph));
        w.section(SEC_CODEBOOK, sections::encode_codebook(&svc.codebook));
        w.section(SEC_CODES, sections::encode_codes(&svc.codes));
        w.section(240, vec![0xEE; 64]); // unknown tag: preserved, still CRC'd
        let mut bytes = w.to_bytes();
        let path = tmp("unknown-section.pxa");
        std::fs::write(&path, &bytes).unwrap();
        ColdArtifact::open(&path, false).expect("intact unknown sections are fine");

        // Flip a byte INSIDE the unknown payload (it is the last
        // section, so the tail bytes belong to it).
        let n = bytes.len();
        bytes[n - 10] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        assert_eq!(
            ArtifactReader::from_bytes(bytes.clone()).unwrap_err().kind,
            ArtifactErrorKind::Corrupt,
            "resident reader rejects the corrupt unknown section"
        );
        let e = ColdArtifact::open(&path, false).unwrap_err();
        assert_eq!(
            e.kind,
            ArtifactErrorKind::Corrupt,
            "cold open must reject exactly what the resident open rejects: {e}"
        );
        std::fs::remove_file(&path).ok();
    }
}
