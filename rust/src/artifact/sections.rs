//! Section payload codecs for the index artifact (all integers
//! little-endian; see the tag constants in the parent module).
//!
//! Payload layouts:
//!
//! ```text
//! BASE     (1): dim u32, n u64, data f32 × n·dim
//! GRAPH    (2): entry_point u32, max_degree u32, n_offsets u64,
//!               n_targets u64, offsets u32 × n_offsets,
//!               targets u32 × n_targets
//! GAP      (3): n u64, n_offsets u64, n_words u64,
//!               row_offsets u64 × n_offsets, bits u64 × n_words
//! CODEBOOK (4): metric str, dim u32, m u32, c u32,
//!               centroids f32 × m·c·(dim/m)
//! CODES    (5): m u32, n u64, codes u8 × n·m
//! REORDER  (6): n u64, perm u32 × n   (perm[old] = new)
//! MAPPING  (7): the 11 `DataMapping` fields as u32, in declaration
//!               order: n_nodes, idx_cores, raw_cores, raw_base,
//!               idx_frames_per_page, raw_frames_per_page,
//!               hot_frames_per_page, n_hot, idx_frame_bits,
//!               hot_frame_bits, raw_frame_bits
//! LSH      (8): n_bits u32, seed u64, dim u32, n u64,
//!               planes f32 × n_bits·dim, signatures u32 × n
//! ```
//!
//! Decoders validate per-section structural invariants (dimensions,
//! lengths, zero-divisor guards); cross-section consistency lives in
//! [`IndexArtifact::from_reader`](super::IndexArtifact::from_reader).

use super::{rd, ArtifactError};
use crate::dataset::io as bio;
use crate::dataset::VectorSet;
use crate::distance::Metric;
use crate::engine::mapping::DataMapping;
use crate::gap::GapGraph;
use crate::graph::Graph;
use crate::pq::{PqCodebook, PqCodes};
use crate::search::lsh_start::{LshIndex, MAX_BITS};

/// Every decoder consumes its payload EXACTLY: trailing bytes inside a
/// section are rejected just like trailing bytes after the last section
/// (same corruption posture; sections are exact-length by format v1
/// definition — format growth bumps the version).
fn finish(r: &bio::Reader<'_>, what: &str, payload: &[u8]) -> Result<(), ArtifactError> {
    if r.pos() != payload.len() {
        return Err(ArtifactError::corrupt(format!(
            "{what}: {} trailing bytes in section payload",
            payload.len() - r.pos()
        )));
    }
    Ok(())
}

pub fn encode_base(base: &VectorSet) -> Vec<u8> {
    let mut buf = Vec::with_capacity(12 + base.data.len() * 4);
    bio::put_u32(&mut buf, base.dim as u32);
    bio::put_u64(&mut buf, base.len() as u64);
    bio::put_f32_slice(&mut buf, &base.data);
    buf
}

pub fn decode_base(payload: &[u8]) -> Result<VectorSet, ArtifactError> {
    let mut r = bio::Reader::new(payload);
    let dim = rd(r.u32())? as usize;
    let n = rd(r.u64())? as usize;
    if dim == 0 {
        return Err(ArtifactError::corrupt("BASE: dim must be >= 1"));
    }
    let count = n
        .checked_mul(dim)
        .ok_or_else(|| ArtifactError::corrupt("BASE: n * dim overflows"))?;
    let data = rd(r.f32_vec(count))?;
    finish(&r, "BASE", payload)?;
    Ok(VectorSet { dim, data })
}

pub fn encode_graph(g: &Graph) -> Vec<u8> {
    let mut buf = Vec::with_capacity(24 + (g.offsets.len() + g.targets.len()) * 4);
    bio::put_u32(&mut buf, g.entry_point);
    bio::put_u32(&mut buf, g.max_degree as u32);
    bio::put_u64(&mut buf, g.offsets.len() as u64);
    bio::put_u64(&mut buf, g.targets.len() as u64);
    bio::put_u32_slice(&mut buf, &g.offsets);
    bio::put_u32_slice(&mut buf, &g.targets);
    buf
}

pub fn decode_graph(payload: &[u8]) -> Result<Graph, ArtifactError> {
    let mut r = bio::Reader::new(payload);
    let entry_point = rd(r.u32())?;
    let max_degree = rd(r.u32())? as usize;
    let n_offsets = rd(r.u64())? as usize;
    let n_targets = rd(r.u64())? as usize;
    if n_offsets == 0 {
        return Err(ArtifactError::corrupt("GRAPH: empty offsets table"));
    }
    let offsets = rd(r.u32_vec(n_offsets))?;
    let targets = rd(r.u32_vec(n_targets))?;
    // CSR invariants `Graph::neighbors` slices on — must hold before any
    // caller touches adjacency, or a corrupt row panics the process.
    if offsets[0] != 0 {
        return Err(ArtifactError::corrupt("GRAPH: offsets must start at 0"));
    }
    if offsets.windows(2).any(|w| w[0] > w[1]) {
        return Err(ArtifactError::corrupt(
            "GRAPH: offsets must be non-decreasing",
        ));
    }
    if *offsets.last().unwrap() as usize != targets.len() {
        return Err(ArtifactError::corrupt(format!(
            "GRAPH: offsets end at {} but {} targets stored",
            offsets.last().unwrap(),
            targets.len()
        )));
    }
    finish(&r, "GRAPH", payload)?;
    Ok(Graph {
        offsets,
        targets,
        entry_point,
        max_degree,
    })
}

pub fn encode_gap(gap: &GapGraph) -> Vec<u8> {
    let (row_offsets, bits, n) = gap.to_parts();
    let mut buf = Vec::with_capacity(24 + (row_offsets.len() + bits.len()) * 8);
    bio::put_u64(&mut buf, n as u64);
    bio::put_u64(&mut buf, row_offsets.len() as u64);
    bio::put_u64(&mut buf, bits.len() as u64);
    bio::put_u64_slice(&mut buf, row_offsets);
    bio::put_u64_slice(&mut buf, bits);
    buf
}

pub fn decode_gap(payload: &[u8]) -> Result<GapGraph, ArtifactError> {
    let mut r = bio::Reader::new(payload);
    let n = rd(r.u64())? as usize;
    let n_offsets = rd(r.u64())? as usize;
    let n_words = rd(r.u64())? as usize;
    let row_offsets = rd(r.u64_vec(n_offsets))?;
    let bits = rd(r.u64_vec(n_words))?;
    finish(&r, "GAP", payload)?;
    GapGraph::from_parts(row_offsets, bits, n)
        .map_err(|e| ArtifactError::corrupt(format!("GAP: {e}")))
}

pub fn encode_codebook(cb: &PqCodebook) -> Vec<u8> {
    let mut buf = Vec::with_capacity(32 + cb.centroids.len() * 4);
    bio::put_str(&mut buf, cb.metric.name());
    bio::put_u32(&mut buf, cb.dim as u32);
    bio::put_u32(&mut buf, cb.m as u32);
    bio::put_u32(&mut buf, cb.c as u32);
    bio::put_f32_slice(&mut buf, &cb.centroids);
    buf
}

pub fn decode_codebook(payload: &[u8]) -> Result<PqCodebook, ArtifactError> {
    let mut r = bio::Reader::new(payload);
    let metric_name = rd(r.str())?;
    let metric = Metric::parse(&metric_name).ok_or_else(|| {
        ArtifactError::corrupt(format!("CODEBOOK: unknown metric '{metric_name}'"))
    })?;
    let dim = rd(r.u32())? as usize;
    let m = rd(r.u32())? as usize;
    let c = rd(r.u32())? as usize;
    if m == 0 || dim == 0 || dim % m != 0 {
        return Err(ArtifactError::corrupt(format!(
            "CODEBOOK: dim {dim} not divisible into {m} subspaces"
        )));
    }
    if c == 0 || c > 256 {
        return Err(ArtifactError::corrupt(format!(
            "CODEBOOK: c = {c} outside 1..=256 (codes are one byte)"
        )));
    }
    let centroids = rd(r.f32_vec(m * c * (dim / m)))?;
    finish(&r, "CODEBOOK", payload)?;
    Ok(PqCodebook {
        metric,
        dim,
        m,
        c,
        centroids,
    })
}

pub fn encode_codes(codes: &PqCodes) -> Vec<u8> {
    let mut buf = Vec::with_capacity(12 + codes.codes.len());
    bio::put_u32(&mut buf, codes.m as u32);
    bio::put_u64(&mut buf, codes.len() as u64);
    buf.extend_from_slice(&codes.codes);
    buf
}

pub fn decode_codes(payload: &[u8]) -> Result<PqCodes, ArtifactError> {
    let mut r = bio::Reader::new(payload);
    let m = rd(r.u32())? as usize;
    let n = rd(r.u64())? as usize;
    if m == 0 {
        return Err(ArtifactError::corrupt("CODES: m must be >= 1"));
    }
    let count = n
        .checked_mul(m)
        .ok_or_else(|| ArtifactError::corrupt("CODES: n * m overflows"))?;
    let codes = rd(r.take(count))?.to_vec();
    finish(&r, "CODES", payload)?;
    Ok(PqCodes { m, codes })
}

pub fn encode_reorder(perm: &[u32]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(8 + perm.len() * 4);
    bio::put_u64(&mut buf, perm.len() as u64);
    bio::put_u32_slice(&mut buf, perm);
    buf
}

pub fn decode_reorder(payload: &[u8]) -> Result<Vec<u32>, ArtifactError> {
    let mut r = bio::Reader::new(payload);
    let n = rd(r.u64())? as usize;
    let perm = rd(r.u32_vec(n))?;
    finish(&r, "REORDER", payload)?;
    // Must be a bijection on 0..n, or id remapping silently corrupts
    // results.
    let mut seen = vec![false; n];
    for &p in &perm {
        let idx = p as usize;
        if idx >= n || seen[idx] {
            return Err(ArtifactError::corrupt(format!(
                "REORDER: not a permutation of 0..{n} (value {p})"
            )));
        }
        seen[idx] = true;
    }
    Ok(perm)
}

pub fn encode_mapping(m: &DataMapping) -> Vec<u8> {
    let mut buf = Vec::with_capacity(44);
    for x in [
        m.n_nodes,
        m.idx_cores,
        m.raw_cores,
        m.raw_base,
        m.idx_frames_per_page,
        m.raw_frames_per_page,
        m.hot_frames_per_page,
        m.n_hot,
        m.idx_frame_bits,
        m.hot_frame_bits,
        m.raw_frame_bits,
    ] {
        bio::put_u32(&mut buf, x);
    }
    buf
}

pub fn decode_mapping(payload: &[u8]) -> Result<DataMapping, ArtifactError> {
    let mut r = bio::Reader::new(payload);
    let m = DataMapping {
        n_nodes: rd(r.u32())?,
        idx_cores: rd(r.u32())?,
        raw_cores: rd(r.u32())?,
        raw_base: rd(r.u32())?,
        idx_frames_per_page: rd(r.u32())?,
        raw_frames_per_page: rd(r.u32())?,
        hot_frames_per_page: rd(r.u32())?,
        n_hot: rd(r.u32())?,
        idx_frame_bits: rd(r.u32())?,
        hot_frame_bits: rd(r.u32())?,
        raw_frame_bits: rd(r.u32())?,
    };
    finish(&r, "MAPPING", payload)?;
    // Address translation divides/mods by these — zero would panic.
    if m.idx_cores == 0
        || m.raw_cores == 0
        || m.idx_frames_per_page == 0
        || m.raw_frames_per_page == 0
        || m.hot_frames_per_page == 0
    {
        return Err(ArtifactError::corrupt(
            "MAPPING: cores and frames-per-page must be >= 1",
        ));
    }
    Ok(m)
}

pub fn encode_lsh(lsh: &LshIndex) -> Vec<u8> {
    let mut buf =
        Vec::with_capacity(24 + lsh.planes().len() * 4 + lsh.signatures().len() * 4);
    bio::put_u32(&mut buf, lsh.n_bits());
    bio::put_u64(&mut buf, lsh.seed());
    bio::put_u32(&mut buf, lsh.dim() as u32);
    bio::put_u64(&mut buf, lsh.len() as u64);
    bio::put_f32_slice(&mut buf, lsh.planes());
    bio::put_u32_slice(&mut buf, lsh.signatures());
    buf
}

pub fn decode_lsh(payload: &[u8]) -> Result<LshIndex, ArtifactError> {
    let mut r = bio::Reader::new(payload);
    let n_bits = rd(r.u32())?;
    let seed = rd(r.u64())?;
    let dim = rd(r.u32())? as usize;
    let n = rd(r.u64())? as usize;
    if !(1..=MAX_BITS).contains(&n_bits) {
        return Err(ArtifactError::corrupt(format!(
            "LSH: n_bits {n_bits} outside 1..={MAX_BITS}"
        )));
    }
    if dim == 0 {
        return Err(ArtifactError::corrupt("LSH: dim must be >= 1"));
    }
    let n_plane = (n_bits as usize)
        .checked_mul(dim)
        .ok_or_else(|| ArtifactError::corrupt("LSH: n_bits * dim overflows"))?;
    let planes = rd(r.f32_vec(n_plane))?;
    let signatures = rd(r.u32_vec(n))?;
    let mask = if n_bits == 32 { u32::MAX } else { (1u32 << n_bits) - 1 };
    if let Some(&bad) = signatures.iter().find(|&&s| s & !mask != 0) {
        return Err(ArtifactError::corrupt(format!(
            "LSH: signature {bad:#x} wider than {n_bits} bits"
        )));
    }
    finish(&r, "LSH", payload)?;
    Ok(LshIndex::from_parts(n_bits, seed, dim, planes, signatures))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn graph_codec_rejects_broken_csr() {
        let good = Graph::from_lists(&[vec![1], vec![0]], 0, 4);
        let buf = encode_graph(&good);
        let back = decode_graph(&buf).unwrap();
        assert_eq!(back.offsets, good.offsets);
        assert_eq!(back.targets, good.targets);
        assert_eq!(back.entry_point, 0);

        // Non-monotonic offsets must be rejected, not slice-panic later.
        let mut bad = good.clone();
        bad.offsets = vec![0, 2, 1];
        let e = decode_graph(&encode_graph(&bad)).unwrap_err();
        assert_eq!(e.kind, super::super::ArtifactErrorKind::Corrupt);
    }

    #[test]
    fn intra_section_trailing_bytes_are_rejected() {
        let good = Graph::from_lists(&[vec![1], vec![0]], 0, 4);
        let mut p = encode_graph(&good);
        p.push(0xAB);
        let e = decode_graph(&p).unwrap_err();
        assert_eq!(e.kind, super::super::ArtifactErrorKind::Corrupt);
        assert!(e.message.contains("trailing"), "{e}");
    }

    #[test]
    fn gap_codec_rejects_absurd_row_counts_without_panicking() {
        // n = u64::MAX with empty tables: the row-count check must use
        // checked arithmetic (a plain `n + 1` overflows in debug).
        let mut p = Vec::new();
        p.extend_from_slice(&u64::MAX.to_le_bytes());
        p.extend_from_slice(&0u64.to_le_bytes());
        p.extend_from_slice(&0u64.to_le_bytes());
        let e = decode_gap(&p).unwrap_err();
        assert_eq!(e.kind, super::super::ArtifactErrorKind::Corrupt);
    }

    #[test]
    fn reorder_codec_rejects_non_permutations() {
        assert_eq!(decode_reorder(&encode_reorder(&[2, 0, 1])).unwrap(), vec![2, 0, 1]);
        assert!(decode_reorder(&encode_reorder(&[0, 0, 1])).is_err());
        assert!(decode_reorder(&encode_reorder(&[0, 1, 3])).is_err());
    }

    #[test]
    fn lsh_codec_roundtrips_and_rejects_bad_shapes() {
        use crate::dataset::synth::tiny_uniform;
        use crate::distance::Metric;
        let base = tiny_uniform(64, 8, Metric::L2, 0xA11CE).base;
        let lsh = LshIndex::build(&base, 5, 99);
        let back = decode_lsh(&encode_lsh(&lsh)).unwrap();
        assert_eq!(back.n_bits(), 5);
        assert_eq!(back.seed(), 99);
        assert_eq!(back.dim(), 8);
        assert_eq!(back.planes(), lsh.planes());
        assert_eq!(back.signatures(), lsh.signatures());
        // Probes (bucket CSR rebuilt on decode) must agree exactly.
        let mut a = [0u32; 4];
        let mut b = [0u32; 4];
        for i in 0..8 {
            assert_eq!(lsh.probe_into(base.row(i), &mut a), back.probe_into(base.row(i), &mut b));
            assert_eq!(a, b);
        }

        // n_bits outside 1..=24 rejected.
        let mut p = encode_lsh(&lsh);
        p[0] = 25;
        assert!(decode_lsh(&p).is_err());
        // A signature wider than n_bits rejected (flip a high bit of the
        // first signature, which sits after header + planes).
        let mut p = encode_lsh(&lsh);
        let sig_off = 24 + lsh.planes().len() * 4 + 3;
        p[sig_off] ^= 0x80;
        assert!(decode_lsh(&p).is_err());
        // Trailing bytes rejected.
        let mut p = encode_lsh(&lsh);
        p.push(0);
        assert!(decode_lsh(&p).is_err());
    }

    #[test]
    fn mapping_codec_roundtrips_and_guards_zero_divisors() {
        let m = DataMapping {
            n_nodes: 64,
            idx_cores: 2,
            raw_cores: 2,
            raw_base: 2,
            idx_frames_per_page: 33,
            raw_frames_per_page: 9,
            hot_frames_per_page: 3,
            n_hot: 2,
            idx_frame_bits: 1088,
            hot_frame_bits: 2000,
            raw_frame_bits: 256,
        };
        assert_eq!(decode_mapping(&encode_mapping(&m)).unwrap(), m);
        let mut z = m.clone();
        z.idx_frames_per_page = 0;
        assert!(decode_mapping(&encode_mapping(&z)).is_err());
    }
}
