//! Binary container format for vectors, graphs, PQ codebooks and ground
//! truth (no `serde`/`bincode` offline). Layout: magic, u32 version, then
//! section-specific little-endian payloads. Deliberately simple and
//! versioned so examples can cache expensive artifacts (graph builds).

use super::{Dataset, GroundTruth, VectorSet};
use crate::bail;
use crate::distance::Metric;
use crate::util::error::{Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"PROXIMA1";

pub(crate) fn put_u32(buf: &mut Vec<u8>, x: u32) {
    buf.extend_from_slice(&x.to_le_bytes());
}
pub(crate) fn put_u64(buf: &mut Vec<u8>, x: u64) {
    buf.extend_from_slice(&x.to_le_bytes());
}
pub(crate) fn put_f32(buf: &mut Vec<u8>, x: f32) {
    buf.extend_from_slice(&x.to_le_bytes());
}
pub(crate) fn put_f64(buf: &mut Vec<u8>, x: f64) {
    buf.extend_from_slice(&x.to_le_bytes());
}

/// Bulk little-endian append of numeric slices: one memcpy on LE
/// targets (where native order IS the wire order), a per-element loop
/// elsewhere. The artifact's BASE section alone is hundreds of MB at
/// deployment scale, so per-element appends are a measurable save cost.
macro_rules! put_slice_le {
    ($name:ident, $t:ty) => {
        pub(crate) fn $name(buf: &mut Vec<u8>, xs: &[$t]) {
            #[cfg(target_endian = "little")]
            {
                // SAFETY: plain-old-data reinterpretation; u8 alignment
                // is 1 and every element's bytes are initialized.
                let bytes = unsafe {
                    std::slice::from_raw_parts(
                        xs.as_ptr().cast::<u8>(),
                        std::mem::size_of_val(xs),
                    )
                };
                buf.extend_from_slice(bytes);
            }
            #[cfg(not(target_endian = "little"))]
            {
                buf.reserve(std::mem::size_of_val(xs));
                for &x in xs {
                    buf.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    };
}
put_slice_le!(put_f32_slice, f32);
put_slice_le!(put_u32_slice, u32);
put_slice_le!(put_u64_slice, u64);

/// Sentinel every out-of-bounds read's message starts with. The
/// artifact codec's error classifier (`artifact::rd`) dispatches on
/// this exact string to tell truncation apart from garbage bytes — it
/// lives here, next to the one `bail!` that emits it, so the two sites
/// cannot drift apart.
pub(crate) const TRUNCATED_MSG: &str = "truncated";

/// Little-endian cursor over an in-memory buffer, shared by the dataset
/// container below and the index-artifact codec (`crate::artifact`).
/// Every read is bounds-checked: running off the end is a typed
/// "truncated" error, never a panic.
pub(crate) struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }
    /// Current byte offset (used by the artifact codec to delimit its
    /// checksummed header region).
    pub(crate) fn pos(&self) -> usize {
        self.pos
    }
    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.buf.len() - self.pos {
            bail!("{TRUNCATED_MSG} file at offset {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    pub(crate) fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub(crate) fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub(crate) fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub(crate) fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub(crate) fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let bytes = self.take(n.checked_mul(4).context("length overflow")?)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    pub(crate) fn u32_vec(&mut self, n: usize) -> Result<Vec<u32>> {
        let bytes = self.take(n.checked_mul(4).context("length overflow")?)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    pub(crate) fn u64_vec(&mut self, n: usize) -> Result<Vec<u64>> {
        let bytes = self.take(n.checked_mul(8).context("length overflow")?)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    pub(crate) fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8(self.take(n)?.to_vec())?)
    }
}

pub(crate) fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

/// Save a dataset (base + queries + metric).
pub fn save_dataset(ds: &Dataset, path: &Path) -> Result<()> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    put_u32(&mut buf, 1); // version
    put_str(&mut buf, &ds.name);
    put_str(&mut buf, ds.metric.name());
    put_u64(&mut buf, ds.base.len() as u64);
    put_u64(&mut buf, ds.queries.len() as u64);
    put_u32(&mut buf, ds.base.dim as u32);
    for x in &ds.base.data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    for x in &ds.queries.data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    write_atomic(path, &buf)
}

/// Load a dataset saved by [`save_dataset`].
pub fn load_dataset(path: &Path) -> Result<Dataset> {
    let buf = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let mut r = Reader::new(&buf);
    if r.take(8)? != MAGIC {
        bail!("bad magic in {}", path.display());
    }
    let ver = r.u32()?;
    if ver != 1 {
        bail!("unsupported version {ver}");
    }
    let name = r.str()?;
    let metric = Metric::parse(&r.str()?).context("bad metric")?;
    let n_base = r.u64()? as usize;
    let n_q = r.u64()? as usize;
    let dim = r.u32()? as usize;
    let base = VectorSet::new(dim, r.f32_vec(n_base * dim)?);
    let queries = VectorSet::new(dim, r.f32_vec(n_q * dim)?);
    // Guard the Angular unit-norm invariant loudly. Silently normalizing
    // here would desynchronize the vectors from any ground-truth file
    // computed on the raw data (wrong recall, no error) — so a foreign
    // container with unnormalized Angular vectors is rejected instead;
    // normalize at generation time (`fvecs::prepare_for_metric`) and
    // recompute its ground truth. The scan's per-row |v|^2 goes through
    // the dispatched SIMD dot kernel (`distance::dot`).
    if metric == Metric::Angular {
        for (set, what) in [(&base, "base"), (&queries, "query")] {
            for i in 0..set.len() {
                let n2 = crate::distance::dot(set.row(i), set.row(i));
                if (n2 - 1.0).abs() > 1e-3 {
                    bail!(
                        "{}: angular container holds unnormalized {what} vector {i} \
                         (|v|^2 = {n2}); regenerate it (and any ground truth) from \
                         normalized data",
                        path.display()
                    );
                }
            }
        }
    }
    Ok(Dataset {
        name,
        metric,
        base,
        queries,
    })
}

/// Save ground truth.
pub fn save_ground_truth(gt: &GroundTruth, path: &Path) -> Result<()> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    put_u32(&mut buf, 1);
    put_u32(&mut buf, gt.k as u32);
    put_u64(&mut buf, gt.ids.len() as u64);
    for id in &gt.ids {
        buf.extend_from_slice(&id.to_le_bytes());
    }
    write_atomic(path, &buf)
}

/// Load ground truth.
pub fn load_ground_truth(path: &Path) -> Result<GroundTruth> {
    let buf = std::fs::read(path)?;
    let mut r = Reader::new(&buf);
    if r.take(8)? != MAGIC {
        bail!("bad magic");
    }
    let _ver = r.u32()?;
    let k = r.u32()? as usize;
    let n = r.u64()? as usize;
    Ok(GroundTruth {
        k,
        ids: r.u32_vec(n)?,
    })
}

/// Save a flat u32 adjacency structure (graph CSR): offsets then targets.
pub fn save_csr(offsets: &[u32], targets: &[u32], path: &Path) -> Result<()> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    put_u32(&mut buf, 1);
    put_u64(&mut buf, offsets.len() as u64);
    put_u64(&mut buf, targets.len() as u64);
    for x in offsets {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    for x in targets {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    write_atomic(path, &buf)
}

/// Load CSR saved by [`save_csr`].
pub fn load_csr(path: &Path) -> Result<(Vec<u32>, Vec<u32>)> {
    let buf = std::fs::read(path)?;
    let mut r = Reader::new(&buf);
    if r.take(8)? != MAGIC {
        bail!("bad magic");
    }
    let _ver = r.u32()?;
    let n_off = r.u64()? as usize;
    let n_tgt = r.u64()? as usize;
    Ok((r.u32_vec(n_off)?, r.u32_vec(n_tgt)?))
}

/// Write via a temp file + rename so partially-written caches are never
/// observed by a concurrent reader.
pub(crate) fn write_atomic(path: &Path, buf: &[u8]) -> Result<()> {
    write_atomic_with(path, |f| f.write_all(buf))
}

/// [`write_atomic`] with a caller-supplied streaming writer — the
/// index-artifact save path (`crate::artifact`) streams its section
/// payloads straight to the temp file instead of concatenating a second
/// full in-memory copy of a potentially huge artifact. Same contract: a
/// crashed save never leaves a torn file at the target path.
pub(crate) fn write_atomic_with<F>(path: &Path, write: F) -> Result<()>
where
    F: FnOnce(&mut std::fs::File) -> std::io::Result<()>,
{
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    // Temp name derived from the FULL file name plus the pid:
    // `with_extension("tmp")` would collide across file families
    // sharing a stem (`x.bin` and `x.pxa` both → `x.tmp`), letting two
    // concurrent writers publish each other's half-written bytes.
    let file_name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_default();
    let tmp = path.with_file_name(format!("{file_name}.{}.tmp", std::process::id()));
    let result: Result<()> = (|| {
        let mut f = std::fs::File::create(&tmp)?;
        write(&mut f)?;
        f.sync_all().ok();
        std::fs::rename(&tmp, path)?;
        Ok(())
    })();
    if result.is_err() {
        // Unlike the old shared `x.tmp` name (overwritten by the next
        // save), a pid-unique temp file nobody cleans up would leak a
        // full-artifact-sized orphan per failed save.
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

/// Read a whole file as a string with context.
pub fn read_string(path: &Path) -> Result<String> {
    let mut s = String::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?
        .read_to_string(&mut s)?;
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth::tiny_uniform;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("proxima-io-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn dataset_roundtrip() {
        let ds = tiny_uniform(50, 7, Metric::Angular, 9);
        let p = tmpdir().join("ds.bin");
        save_dataset(&ds, &p).unwrap();
        let back = load_dataset(&p).unwrap();
        assert_eq!(back.name, ds.name);
        assert_eq!(back.metric, ds.metric);
        assert_eq!(back.base.data, ds.base.data);
        assert_eq!(back.queries.data, ds.queries.data);
    }

    #[test]
    fn rejects_unnormalized_angular_container() {
        // A foreign container with raw Angular vectors must fail loudly —
        // silently normalizing would desync it from stored ground truth.
        let mut ds = tiny_uniform(10, 4, Metric::Angular, 3);
        for x in ds.base.data.iter_mut() {
            *x *= 3.0;
        }
        let p = tmpdir().join("bad-angular.bin");
        save_dataset(&ds, &p).unwrap();
        let err = load_dataset(&p).unwrap_err();
        assert!(
            err.to_string().contains("unnormalized"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn ground_truth_roundtrip() {
        let gt = GroundTruth {
            k: 3,
            ids: vec![1, 2, 3, 4, 5, 6],
        };
        let p = tmpdir().join("gt.bin");
        save_ground_truth(&gt, &p).unwrap();
        let back = load_ground_truth(&p).unwrap();
        assert_eq!(back.k, 3);
        assert_eq!(back.ids, gt.ids);
    }

    #[test]
    fn csr_roundtrip() {
        let p = tmpdir().join("csr.bin");
        save_csr(&[0, 2, 5], &[1, 2, 0, 1, 2], &p).unwrap();
        let (off, tgt) = load_csr(&p).unwrap();
        assert_eq!(off, vec![0, 2, 5]);
        assert_eq!(tgt, vec![1, 2, 0, 1, 2]);
    }

    #[test]
    fn rejects_corrupt_magic() {
        let p = tmpdir().join("bad.bin");
        std::fs::write(&p, b"NOTMAGIC????????").unwrap();
        assert!(load_dataset(&p).is_err());
        assert!(load_ground_truth(&p).is_err());
    }
}
