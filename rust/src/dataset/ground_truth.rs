//! Exact k-NN ground truth by brute force.
//!
//! Two paths: a blocked native implementation (used by unit tests and when
//! artifacts are absent) and an XLA-artifact path in `runtime::` that runs
//! the AOT-lowered batch-distance kernel (preferred for large sets — XLA's
//! CPU backend uses an optimized GEMM).

use super::{Dataset, GroundTruth};
use crate::distance::Metric;

/// Max-heap entry so the BinaryHeap keeps the *worst* of the current top-k
/// at the root.
#[derive(PartialEq)]
struct Entry(f32, u32);
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0
            .partial_cmp(&other.0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(self.1.cmp(&other.1))
    }
}

/// Brute-force exact top-k for every query (native path).
pub fn brute_force(ds: &Dataset, k: usize) -> GroundTruth {
    brute_force_with(ds.metric, &ds.base.data, ds.base.dim, &ds.queries.data, k)
}

/// Brute-force over raw slices (used by tests and the error model, where the
/// base data may have been perturbed).
pub fn brute_force_with(
    metric: Metric,
    base: &[f32],
    dim: usize,
    queries: &[f32],
    k: usize,
) -> GroundTruth {
    let n = base.len() / dim;
    let nq = queries.len() / dim;
    assert!(k <= n, "k={k} > n={n}");
    let mut ids = Vec::with_capacity(nq * k);
    for q in 0..nq {
        let qv = &queries[q * dim..(q + 1) * dim];
        let mut heap = std::collections::BinaryHeap::with_capacity(k + 1);
        for i in 0..n {
            let d = metric.distance(qv, &base[i * dim..(i + 1) * dim]);
            if heap.len() < k {
                heap.push(Entry(d, i as u32));
            } else if d < heap.peek().unwrap().0 {
                heap.pop();
                heap.push(Entry(d, i as u32));
            }
        }
        let mut top: Vec<Entry> = heap.into_vec();
        top.sort_by(|a, b| a.cmp(b));
        ids.extend(top.iter().map(|e| e.1));
    }
    GroundTruth { k, ids }
}

/// Exact top-k for a single query; returns (distance, id) ascending.
pub fn top_k_single(metric: Metric, base: &[f32], dim: usize, q: &[f32], k: usize) -> Vec<(f32, u32)> {
    let gt = brute_force_with(metric, base, dim, q, k);
    gt.ids
        .iter()
        .map(|&id| {
            (
                metric.distance(q, &base[id as usize * dim..(id as usize + 1) * dim]),
                id,
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth::tiny_uniform;

    #[test]
    fn nearest_is_self_for_base_queries() {
        let ds = tiny_uniform(200, 8, Metric::L2, 3);
        // Query with base vectors: nearest neighbor must be the vector itself.
        let gt = brute_force_with(Metric::L2, &ds.base.data, 8, &ds.base.data[..8 * 10], 1);
        for q in 0..10 {
            assert_eq!(gt.row(q)[0] as usize, q);
        }
    }

    #[test]
    fn results_sorted_by_distance() {
        let ds = tiny_uniform(300, 12, Metric::L2, 4);
        let gt = brute_force(&ds, 10);
        for q in 0..ds.n_queries() {
            let qv = ds.queries.row(q);
            let dists: Vec<f32> = gt
                .row(q)
                .iter()
                .map(|&id| Metric::L2.distance(qv, ds.base.row(id as usize)))
                .collect();
            for w in dists.windows(2) {
                assert!(w[0] <= w[1] + 1e-6);
            }
        }
    }

    #[test]
    fn works_for_all_metrics() {
        for m in [Metric::L2, Metric::Ip, Metric::Angular] {
            let ds = tiny_uniform(100, 6, m, 5);
            let gt = brute_force(&ds, 5);
            assert_eq!(gt.n_queries(), ds.n_queries());
            // ids are distinct per row
            for q in 0..gt.n_queries() {
                let mut r = gt.row(q).to_vec();
                r.sort_unstable();
                r.dedup();
                assert_eq!(r.len(), 5);
            }
        }
    }

    #[test]
    fn k_equals_n() {
        let ds = tiny_uniform(10, 4, Metric::L2, 6);
        let gt = brute_force(&ds, 10);
        for q in 0..gt.n_queries() {
            let mut r = gt.row(q).to_vec();
            r.sort_unstable();
            assert_eq!(r, (0..10).collect::<Vec<u32>>());
        }
    }
}
