//! fvecs/ivecs interchange (the TEXMEX format SIFT/GIST/BIGANN ship in):
//! each record is a little-endian `u32` dimension followed by `dim`
//! f32 (fvecs) or i32 (ivecs) payload values. Lets the pipeline run on the
//! real corpora when they are available instead of the synthetic registry.

use super::VectorSet;
use crate::bail;
use crate::util::error::{Context, Result};
use std::path::Path;

/// Read an .fvecs file (optionally capping at `max_rows`).
pub fn read_fvecs(path: &Path, max_rows: Option<usize>) -> Result<VectorSet> {
    let buf = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    parse_fvecs(&buf, max_rows)
}

/// Read an .fvecs file and prepare it for `metric` — the loading path real
/// corpora must use (bare [`read_fvecs`] returns raw vectors and is only
/// appropriate when the metric needs no preparation). Angular datasets
/// (e.g. raw GLOVE embeddings) are normalized to unit L2 norm here,
/// because `Metric::Angular`'s `1 - dot` is only the cosine distance on
/// unit vectors (debug builds assert it).
pub fn read_fvecs_for_metric(
    path: &Path,
    metric: crate::distance::Metric,
    max_rows: Option<usize>,
) -> Result<VectorSet> {
    let mut vs = read_fvecs(path, max_rows)?;
    prepare_for_metric(&mut vs, metric)?;
    Ok(vs)
}

/// Assemble a full [`Dataset`] from fvecs base + query files, prepared
/// for `metric` — the supported end-to-end path for running the pipeline
/// on real corpora (it cannot skip Angular normalization).
pub fn load_fvecs_dataset(
    name: &str,
    metric: crate::distance::Metric,
    base_path: &Path,
    query_path: &Path,
    max_base_rows: Option<usize>,
) -> Result<super::Dataset> {
    let base = read_fvecs_for_metric(base_path, metric, max_base_rows)?;
    let queries = read_fvecs_for_metric(query_path, metric, None)?;
    if queries.dim != base.dim {
        bail!(
            "query dim {} != base dim {} ({} vs {})",
            queries.dim,
            base.dim,
            query_path.display(),
            base_path.display()
        );
    }
    Ok(super::Dataset {
        name: name.to_string(),
        metric,
        base,
        queries,
    })
}

/// Normalize a loaded vector set for `metric` (no-op except Angular).
/// Rows already at unit norm are left bit-exact so save/load roundtrips
/// of properly normalized sets are lossless. Zero-norm rows under Angular
/// are an error here — they cannot be normalized, and letting them
/// through would only defer the failure to a misleading assertion (or a
/// silently constant distance) deep inside graph build.
///
/// The whole-set norm scan rides the runtime-dispatched SIMD dot kernel
/// (`distance::dot` → `simd::kernels()`), as does `normalize` itself.
pub fn prepare_for_metric(vs: &mut VectorSet, metric: crate::distance::Metric) -> Result<()> {
    if metric == crate::distance::Metric::Angular {
        for i in 0..vs.len() {
            let row = vs.row_mut(i);
            let n2 = crate::distance::dot(row, row);
            if n2 == 0.0 {
                bail!("angular dataset row {i} has zero norm and cannot be normalized");
            }
            if (n2 - 1.0).abs() > 1e-6 {
                crate::distance::normalize(row);
            }
        }
    }
    Ok(())
}

/// Parse fvecs bytes.
pub fn parse_fvecs(buf: &[u8], max_rows: Option<usize>) -> Result<VectorSet> {
    let mut pos = 0usize;
    let mut dim = 0usize;
    let mut data: Vec<f32> = Vec::new();
    let mut rows = 0usize;
    while pos + 4 <= buf.len() {
        if let Some(cap) = max_rows {
            if rows >= cap {
                break;
            }
        }
        let d = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
        pos += 4;
        if d == 0 || d > 1_000_000 {
            bail!("implausible dimension {d} at offset {pos}");
        }
        if dim == 0 {
            dim = d;
        } else if d != dim {
            bail!("ragged fvecs: {d} != {dim} at row {rows}");
        }
        if pos + 4 * d > buf.len() {
            bail!("truncated record at row {rows}");
        }
        for i in 0..d {
            data.push(f32::from_le_bytes(
                buf[pos + 4 * i..pos + 4 * i + 4].try_into().unwrap(),
            ));
        }
        pos += 4 * d;
        rows += 1;
    }
    if rows == 0 {
        bail!("empty fvecs file");
    }
    Ok(VectorSet::new(dim, data))
}

/// Write an .fvecs file.
pub fn write_fvecs(vs: &VectorSet, path: &Path) -> Result<()> {
    let mut buf = Vec::with_capacity(vs.len() * (4 + 4 * vs.dim));
    for row in vs.iter_rows() {
        buf.extend_from_slice(&(vs.dim as u32).to_le_bytes());
        for x in row {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, buf)?;
    Ok(())
}

/// Read an .ivecs ground-truth file: rows of i32 neighbor ids.
pub fn read_ivecs(path: &Path, max_rows: Option<usize>) -> Result<(usize, Vec<u32>)> {
    let buf = std::fs::read(path)?;
    let mut pos = 0usize;
    let mut k = 0usize;
    let mut ids: Vec<u32> = Vec::new();
    let mut rows = 0usize;
    while pos + 4 <= buf.len() {
        if let Some(cap) = max_rows {
            if rows >= cap {
                break;
            }
        }
        let d = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
        pos += 4;
        if k == 0 {
            k = d;
        } else if d != k {
            bail!("ragged ivecs");
        }
        if pos + 4 * d > buf.len() {
            bail!("truncated ivecs at row {rows}");
        }
        for i in 0..d {
            ids.push(u32::from_le_bytes(
                buf[pos + 4 * i..pos + 4 * i + 4].try_into().unwrap(),
            ));
        }
        pos += 4 * d;
        rows += 1;
    }
    if rows == 0 {
        bail!("empty ivecs file");
    }
    Ok((k, ids))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth::tiny_uniform;
    use crate::distance::Metric;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("proxima-fvecs-{}-{name}", std::process::id()))
    }

    #[test]
    fn fvecs_roundtrip() {
        let ds = tiny_uniform(40, 7, Metric::L2, 51);
        let p = tmp("a.fvecs");
        write_fvecs(&ds.base, &p).unwrap();
        let back = read_fvecs(&p, None).unwrap();
        assert_eq!(back.dim, 7);
        assert_eq!(back.data, ds.base.data);
    }

    #[test]
    fn fvecs_row_cap() {
        let ds = tiny_uniform(40, 5, Metric::L2, 52);
        let p = tmp("b.fvecs");
        write_fvecs(&ds.base, &p).unwrap();
        let back = read_fvecs(&p, Some(10)).unwrap();
        assert_eq!(back.len(), 10);
        assert_eq!(&back.data[..], &ds.base.data[..50]);
    }

    #[test]
    fn angular_loads_are_normalized() {
        use crate::distance::{norm, Metric};
        // Deliberately unnormalized rows on disk.
        let vs = VectorSet::new(3, vec![3.0, 4.0, 0.0, 0.0, 5.0, 12.0]);
        let p = tmp("ang.fvecs");
        write_fvecs(&vs, &p).unwrap();
        let l2 = read_fvecs_for_metric(&p, Metric::L2, None).unwrap();
        assert_eq!(l2.data, vs.data, "L2 loads must stay untouched");
        let ang = read_fvecs_for_metric(&p, Metric::Angular, None).unwrap();
        for i in 0..ang.len() {
            assert!((norm(ang.row(i)) - 1.0).abs() < 1e-5, "row {i} not unit");
        }
        // And the distance is now the true cosine distance of the originals.
        // cos((3,4,0), (0,5,12)) = 20 / (5 * 13).
        let d = Metric::Angular.distance(ang.row(0), ang.row(1));
        assert!((d - (1.0 - 20.0 / 65.0)).abs() < 1e-5, "cosine distance wrong: {d}");
    }

    #[test]
    fn angular_zero_row_is_rejected() {
        use crate::distance::Metric;
        let vs = VectorSet::new(2, vec![1.0, 2.0, 0.0, 0.0]);
        let p = tmp("zero.fvecs");
        write_fvecs(&vs, &p).unwrap();
        assert!(read_fvecs_for_metric(&p, Metric::Angular, None).is_err());
        assert!(read_fvecs_for_metric(&p, Metric::L2, None).is_ok());
    }

    #[test]
    fn fvecs_dataset_assembly_normalizes() {
        use crate::distance::{norm, Metric};
        let base = VectorSet::new(2, vec![3.0, 4.0, 5.0, 12.0]);
        let queries = VectorSet::new(2, vec![8.0, 6.0]);
        let bp = tmp("dsb.fvecs");
        let qp = tmp("dsq.fvecs");
        write_fvecs(&base, &bp).unwrap();
        write_fvecs(&queries, &qp).unwrap();
        let ds = load_fvecs_dataset("glove-raw", Metric::Angular, &bp, &qp, None).unwrap();
        assert_eq!(ds.metric, Metric::Angular);
        assert!((norm(ds.base.row(0)) - 1.0).abs() < 1e-5);
        assert!((norm(ds.queries.row(0)) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn rejects_ragged_and_truncated() {
        // Ragged: dim 2 then dim 3.
        let mut buf = Vec::new();
        buf.extend_from_slice(&2u32.to_le_bytes());
        buf.extend_from_slice(&1.0f32.to_le_bytes());
        buf.extend_from_slice(&2.0f32.to_le_bytes());
        buf.extend_from_slice(&3u32.to_le_bytes());
        buf.extend_from_slice(&1.0f32.to_le_bytes());
        assert!(parse_fvecs(&buf, None).is_err());
        assert!(parse_fvecs(&[], None).is_err());
    }

    #[test]
    fn ivecs_roundtrip_by_hand() {
        let p = tmp("c.ivecs");
        let mut buf = Vec::new();
        for row in [[1u32, 2, 3], [4, 5, 6]] {
            buf.extend_from_slice(&3u32.to_le_bytes());
            for id in row {
                buf.extend_from_slice(&id.to_le_bytes());
            }
        }
        std::fs::write(&p, buf).unwrap();
        let (k, ids) = read_ivecs(&p, None).unwrap();
        assert_eq!(k, 3);
        assert_eq!(ids, vec![1, 2, 3, 4, 5, 6]);
    }
}
