//! Synthetic dataset generators mirroring the paper's Table I workloads.
//!
//! The authors evaluate on SIFT (1M, D=128, Euclidean), GLOVE (1M, D=100,
//! Angular), DEEP (10M/100M, D=96, Inner Product) and BIGANN (10M/100M,
//! D=128, Euclidean). Those corpora are not available offline, so we
//! synthesize clustered data with the same dimension, metric and
//! distributional character (see DESIGN.md §1): a Gaussian-mixture base set
//! whose cluster count/spread is tuned so graph search difficulty (hops to
//! converge, distance-computation counts) lands in the same regime. Queries
//! are drawn from the same mixture (in-distribution, as in all four
//! benchmarks).

use super::{Dataset, VectorSet};
use crate::distance::{normalize, Metric};
use crate::util::rng::Xoshiro256pp;

/// Parameters for the Gaussian-mixture generator.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub name: String,
    pub metric: Metric,
    pub dim: usize,
    pub n_base: usize,
    pub n_queries: usize,
    /// Number of mixture components.
    pub clusters: usize,
    /// Cluster center scale relative to intra-cluster stddev (1.0).
    pub center_scale: f32,
    /// SIFT-like datasets are non-negative byte-ish magnitudes.
    pub nonneg: bool,
    pub seed: u64,
}

impl SynthSpec {
    /// The registry of Table I lookalikes. `scale` multiplies the default
    /// base-set size (defaults are laptop-scale stand-ins; see DESIGN.md).
    pub fn registry(scale: f64) -> Vec<SynthSpec> {
        let s = |n: usize| ((n as f64 * scale) as usize).max(1000);
        vec![
            SynthSpec {
                name: "sift-s".into(),
                metric: Metric::L2,
                dim: 128,
                n_base: s(100_000),
                n_queries: 500,
                clusters: 64,
                center_scale: 4.0,
                nonneg: true,
                seed: 0x5EED_0001,
            },
            SynthSpec {
                name: "glove-s".into(),
                metric: Metric::Angular,
                dim: 100,
                n_base: s(100_000),
                n_queries: 500,
                // GLOVE is notoriously "hard" (low recall at big T): weak
                // cluster structure -> more distance computations (paper
                // §V-C observes 6-8x more work on GLOVE).
                clusters: 16,
                center_scale: 1.2,
                nonneg: false,
                seed: 0x5EED_0002,
            },
            SynthSpec {
                name: "deep-10m-s".into(),
                metric: Metric::Ip,
                dim: 96,
                n_base: s(200_000),
                n_queries: 500,
                clusters: 128,
                center_scale: 3.0,
                nonneg: false,
                seed: 0x5EED_0003,
            },
            SynthSpec {
                name: "bigann-10m-s".into(),
                metric: Metric::L2,
                dim: 128,
                n_base: s(200_000),
                n_queries: 500,
                clusters: 128,
                center_scale: 4.0,
                nonneg: true,
                seed: 0x5EED_0004,
            },
            SynthSpec {
                name: "deep-100m-s".into(),
                metric: Metric::Ip,
                dim: 96,
                n_base: s(400_000),
                n_queries: 500,
                clusters: 256,
                center_scale: 3.0,
                nonneg: false,
                seed: 0x5EED_0005,
            },
            SynthSpec {
                name: "bigann-100m-s".into(),
                metric: Metric::L2,
                dim: 128,
                n_base: s(400_000),
                n_queries: 500,
                clusters: 256,
                center_scale: 4.0,
                nonneg: true,
                seed: 0x5EED_0006,
            },
        ]
    }

    pub fn by_name(name: &str, scale: f64) -> Option<SynthSpec> {
        Self::registry(scale).into_iter().find(|s| s.name == name)
    }

    /// Generate the dataset.
    pub fn generate(&self) -> Dataset {
        let mut rng = Xoshiro256pp::seed_from_u64(self.seed);
        // Cluster centers.
        let mut centers = vec![0.0f32; self.clusters * self.dim];
        for c in centers.iter_mut() {
            *c = rng.next_gaussian() as f32 * self.center_scale;
        }
        // Per-cluster weights (Zipf-ish so some clusters are hot, matching
        // real corpora where density is uneven).
        let weights: Vec<f64> = (0..self.clusters)
            .map(|i| 1.0 / ((i + 1) as f64).sqrt())
            .collect();
        let wsum: f64 = weights.iter().sum();
        let cdf: Vec<f64> = weights
            .iter()
            .scan(0.0, |acc, w| {
                *acc += w / wsum;
                Some(*acc)
            })
            .collect();

        let gen_set = |n: usize, rng: &mut Xoshiro256pp| -> VectorSet {
            let mut data = vec![0.0f32; n * self.dim];
            for i in 0..n {
                let u = rng.next_f64();
                let c = cdf.partition_point(|&x| x < u).min(self.clusters - 1);
                let center = &centers[c * self.dim..(c + 1) * self.dim];
                let row = &mut data[i * self.dim..(i + 1) * self.dim];
                for (j, r) in row.iter_mut().enumerate() {
                    *r = center[j] + rng.next_gaussian() as f32;
                }
                if self.nonneg {
                    // SIFT-like: shift+clip to non-negative "gradient
                    // histogram" style magnitudes.
                    for r in row.iter_mut() {
                        *r = (*r + self.center_scale).max(0.0);
                    }
                }
                if self.metric == Metric::Angular {
                    normalize(row);
                }
            }
            VectorSet::new(self.dim, data)
        };

        let base = gen_set(self.n_base, &mut rng);
        let queries = gen_set(self.n_queries, &mut rng);
        Dataset {
            name: self.name.clone(),
            metric: self.metric,
            base,
            queries,
        }
    }
}

/// Small uniform dataset for unit tests (no cluster structure).
pub fn tiny_uniform(n: usize, dim: usize, metric: Metric, seed: u64) -> Dataset {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut mk = |n: usize| {
        let mut data = vec![0.0f32; n * dim];
        for x in data.iter_mut() {
            *x = rng.next_f32() * 2.0 - 1.0;
        }
        if metric == Metric::Angular {
            for i in 0..n {
                normalize(&mut data[i * dim..(i + 1) * dim]);
            }
        }
        VectorSet::new(dim, data)
    };
    let base = mk(n);
    let queries = mk((n / 10).clamp(4, 64));
    Dataset {
        name: format!("tiny-{n}x{dim}"),
        metric,
        base,
        queries,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::norm;

    #[test]
    fn registry_mirrors_table1() {
        let reg = SynthSpec::registry(0.01);
        assert_eq!(reg.len(), 6);
        let sift = &reg[0];
        assert_eq!(sift.dim, 128);
        assert_eq!(sift.metric, Metric::L2);
        let glove = &reg[1];
        assert_eq!(glove.dim, 100);
        assert_eq!(glove.metric, Metric::Angular);
        let deep = &reg[2];
        assert_eq!(deep.dim, 96);
        assert_eq!(deep.metric, Metric::Ip);
    }

    #[test]
    fn generation_deterministic() {
        let spec = SynthSpec::by_name("sift-s", 0.002).unwrap();
        let a = spec.generate();
        let b = spec.generate();
        assert_eq!(a.base.data, b.base.data);
        assert_eq!(a.n_base(), spec.n_base);
        assert_eq!(a.dim(), 128);
    }

    #[test]
    fn angular_sets_are_normalized() {
        let spec = SynthSpec::by_name("glove-s", 0.002).unwrap();
        let d = spec.generate();
        for i in 0..d.n_base().min(100) {
            assert!((norm(d.base.row(i)) - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn nonneg_datasets_are_nonneg() {
        let spec = SynthSpec::by_name("bigann-10m-s", 0.002).unwrap();
        let d = spec.generate();
        assert!(d.base.data.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn clusters_create_structure() {
        // Mean pairwise distance within the dataset should be markedly
        // larger than nearest-neighbor distance when clusters exist.
        let spec = SynthSpec {
            name: "t".into(),
            metric: Metric::L2,
            dim: 16,
            n_base: 400,
            n_queries: 4,
            clusters: 8,
            center_scale: 6.0,
            nonneg: false,
            seed: 7,
        };
        let d = spec.generate();
        let a = d.base.row(0);
        let mut dists: Vec<f32> = (1..d.n_base())
            .map(|i| crate::distance::l2_sq(a, d.base.row(i)))
            .collect();
        dists.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let nn = dists[0];
        let med = dists[dists.len() / 2];
        assert!(med > 4.0 * nn, "no cluster structure: nn={nn} med={med}");
    }
}
