//! Datasets: a flat row-major f32 matrix plus metric metadata, a registry
//! of synthetic workloads mirroring the paper's Table I, exact ground
//! truth, and a simple binary container format for persistence.

pub mod fvecs;
pub mod ground_truth;
pub mod io;
pub mod synth;

use crate::distance::Metric;

/// A dense row-major `n x dim` f32 matrix of base or query vectors.
#[derive(Clone, Debug)]
pub struct VectorSet {
    pub dim: usize,
    pub data: Vec<f32>,
}

impl VectorSet {
    pub fn new(dim: usize, data: Vec<f32>) -> Self {
        assert!(dim > 0 && data.len() % dim == 0, "data not a multiple of dim");
        Self { dim, data }
    }

    pub fn zeros(n: usize, dim: usize) -> Self {
        Self {
            dim,
            data: vec![0.0; n * dim],
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.dim..(i + 1) * self.dim]
    }

    pub fn iter_rows(&self) -> impl Iterator<Item = &[f32]> {
        self.data.chunks_exact(self.dim)
    }
}

/// A complete benchmark dataset: base set, query set, metric, and name.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub metric: Metric,
    pub base: VectorSet,
    pub queries: VectorSet,
}

impl Dataset {
    pub fn dim(&self) -> usize {
        self.base.dim
    }
    pub fn n_base(&self) -> usize {
        self.base.len()
    }
    pub fn n_queries(&self) -> usize {
        self.queries.len()
    }
}

/// Ground truth: for each query, the ids of its exact k nearest neighbors
/// (ascending by distance).
#[derive(Clone, Debug)]
pub struct GroundTruth {
    pub k: usize,
    pub ids: Vec<u32>, // n_queries * k
}

impl GroundTruth {
    #[inline]
    pub fn row(&self, q: usize) -> &[u32] {
        &self.ids[q * self.k..(q + 1) * self.k]
    }
    pub fn n_queries(&self) -> usize {
        self.ids.len() / self.k
    }
}

/// Recall@k between returned ids and ground truth (paper Eq. 2).
pub fn recall_at_k(returned: &[u32], truth: &[u32], k: usize) -> f64 {
    let truth_k = &truth[..k.min(truth.len())];
    let hit = returned
        .iter()
        .take(k)
        .filter(|id| truth_k.contains(id))
        .count();
    hit as f64 / k as f64
}

/// Mean recall over all queries; `results` is row-major n_queries x k.
pub fn mean_recall(results: &[Vec<u32>], gt: &GroundTruth, k: usize) -> f64 {
    assert_eq!(results.len(), gt.n_queries());
    let s: f64 = results
        .iter()
        .enumerate()
        .map(|(q, r)| recall_at_k(r, gt.row(q), k))
        .sum();
    s / results.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vectorset_indexing() {
        let vs = VectorSet::new(2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(vs.len(), 3);
        assert_eq!(vs.row(1), &[3.0, 4.0]);
        assert_eq!(vs.iter_rows().count(), 3);
    }

    #[test]
    #[should_panic(expected = "multiple of dim")]
    fn vectorset_rejects_ragged() {
        VectorSet::new(3, vec![1.0; 4]);
    }

    #[test]
    fn recall_computation() {
        let truth = [1, 2, 3, 4, 5];
        assert_eq!(recall_at_k(&[1, 2, 3, 4, 5], &truth, 5), 1.0);
        assert_eq!(recall_at_k(&[1, 2, 9, 8, 7], &truth, 5), 0.4);
        assert_eq!(recall_at_k(&[9, 8, 7, 6, 0], &truth, 5), 0.0);
        // k smaller than returned list
        assert_eq!(recall_at_k(&[1, 9], &truth, 2), 0.5);
    }

    #[test]
    fn ground_truth_rows() {
        let gt = GroundTruth {
            k: 2,
            ids: vec![0, 1, 2, 3],
        };
        assert_eq!(gt.n_queries(), 2);
        assert_eq!(gt.row(1), &[2, 3]);
    }
}
