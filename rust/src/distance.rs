//! Distance metrics for ANNS (paper Table I: Euclidean, Angular, Inner
//! Product). All are expressed as *distances* (smaller = closer) so the
//! search code is metric-agnostic:
//!
//! * `L2`      — squared Euclidean (monotone in Euclidean; sqrt avoided on
//!               the hot path exactly as DiskANN/HNSW do).
//! * `Ip`      — negative inner product.
//! * `Angular` — cosine distance `1 - cos(a,b)`; vectors are expected to be
//!               pre-normalized by the dataset loader, reducing it to
//!               `1 + Ip` on unit vectors.
//!
//! Since the SIMD refactor the arithmetic itself lives in [`crate::simd`]:
//! every function here calls through the runtime-dispatched kernel table
//! (`simd::kernels()`), so `l2_sq`/`dot`/`norm`/`normalize` — and with
//! them the Angular unit-norm scans in the dataset loaders and artifact
//! open — pick up AVX2/AVX-512/NEON automatically. `PROXIMA_FORCE_SCALAR`
//! pins the original scalar loops for bitwise-reproducible runs; see the
//! `simd` module docs for the FMA tolerance policy.

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Metric {
    L2,
    Ip,
    Angular,
}

impl Metric {
    pub fn parse(s: &str) -> Option<Metric> {
        match s.to_ascii_lowercase().as_str() {
            "l2" | "euclidean" => Some(Metric::L2),
            "ip" | "inner" | "inner_product" => Some(Metric::Ip),
            "angular" | "cosine" => Some(Metric::Angular),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Metric::L2 => "l2",
            Metric::Ip => "ip",
            Metric::Angular => "angular",
        }
    }

    /// Full-precision distance between two D-dim vectors.
    ///
    /// For `Angular`, `1 - dot` equals the cosine distance only on
    /// unit-norm inputs — the dataset loaders normalize on load (see
    /// `dataset::fvecs::prepare_for_metric` / the synthetic generators).
    /// Debug builds assert that the first operand is unit-norm: every
    /// caller passes the query / a stored base row / the normalized medoid
    /// there, so raw unnormalized data trips this immediately (during
    /// graph build, not as silently-wrong recall). The second operand is
    /// deliberately unchecked because PQ-decoded reconstructions flow
    /// through it, and quantization does not preserve the norm.
    #[inline]
    pub fn distance(&self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        match self {
            Metric::L2 => l2_sq(a, b),
            Metric::Ip => -dot(a, b),
            Metric::Angular => {
                debug_assert!(
                    (dot(a, a) - 1.0).abs() < 1e-2,
                    "Angular metric on non-unit-norm input (|a|^2 = {}): \
                     normalize vectors in the dataset loader",
                    dot(a, a)
                );
                1.0 - dot(a, b)
            }
        }
    }

    /// Per-subvector partial distance used to build PQ asymmetric distance
    /// tables; summing partials over subspaces must reconstruct
    /// `distance()` exactly for the decomposable metrics we support.
    #[inline]
    pub fn partial(&self, a: &[f32], b: &[f32]) -> f32 {
        match self {
            Metric::L2 => l2_sq(a, b),
            // For IP and Angular the decomposable part is the dot product;
            // the angular "1 +" constant is folded in at ADT build time
            // (added to subspace 0) so sums still reconstruct distance().
            Metric::Ip | Metric::Angular => -dot(a, b),
        }
    }

    /// Batched [`Metric::partial`]: the query against `out.len()`
    /// contiguous rows, where row `i` is `rows[i * stride..][..q.len()]`.
    /// Bitwise-identical to calling `partial` per row at the same
    /// dispatch level (the `simd` batching invariant), so centroid
    /// sweeps (ADT builds, k-means) can batch freely.
    #[inline]
    pub fn partial_batch(&self, q: &[f32], rows: &[f32], stride: usize, out: &mut [f32]) {
        let k = crate::simd::kernels();
        match self {
            Metric::L2 => (k.l2_sq_batch)(q, rows, stride, out),
            Metric::Ip | Metric::Angular => {
                (k.dot_batch)(q, rows, stride, out);
                for d in out.iter_mut() {
                    *d = -*d;
                }
            }
        }
    }

    /// Batched [`Metric::distance`] over rows picked by id from a flat
    /// row-major matrix (`flat[id * stride..][..q.len()]`) — the rerank
    /// gather. Bitwise-identical to calling `distance` per picked row at
    /// the same dispatch level.
    #[inline]
    pub fn distance_gather(
        &self,
        q: &[f32],
        flat: &[f32],
        stride: usize,
        ids: &[u32],
        out: &mut [f32],
    ) {
        let k = crate::simd::kernels();
        match self {
            Metric::L2 => (k.l2_sq_gather)(q, flat, stride, ids, out),
            Metric::Ip => {
                (k.dot_gather)(q, flat, stride, ids, out);
                for d in out.iter_mut() {
                    *d = -*d;
                }
            }
            Metric::Angular => {
                debug_assert!(
                    (dot(q, q) - 1.0).abs() < 1e-2,
                    "Angular metric on non-unit-norm input (|a|^2 = {}): \
                     normalize vectors in the dataset loader",
                    dot(q, q)
                );
                (k.dot_gather)(q, flat, stride, ids, out);
                for d in out.iter_mut() {
                    *d = 1.0 - *d;
                }
            }
        }
    }

    /// Constant folded into the ADT so that partial sums equal distances.
    #[inline]
    pub fn adt_bias(&self) -> f32 {
        match self {
            Metric::L2 | Metric::Ip => 0.0,
            Metric::Angular => 1.0,
        }
    }
}

/// Squared L2 distance through the runtime-dispatched kernel table.
/// The scalar fallback is the original 4-way-unrolled loop, moved
/// verbatim to `simd::scalar` (forced-scalar runs reproduce it bitwise).
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    (crate::simd::kernels().l2_sq)(a, b)
}

/// Dot product through the runtime-dispatched kernel table.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    (crate::simd::kernels().dot)(a, b)
}

/// L2 norm (dispatched dot, so large unit-norm validation scans — the
/// Angular loaders, cold artifact opens — get the SIMD path too).
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Normalize in place to unit L2 norm (no-op on zero vectors).
pub fn normalize(a: &mut [f32]) {
    let n = norm(a);
    if n > 0.0 {
        for x in a.iter_mut() {
            *x /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn l2_reference() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [0.0, 0.0, 0.0, 0.0, 0.0];
        assert_eq!(l2_sq(&a, &b), 55.0);
        assert_eq!(Metric::L2.distance(&a, &a), 0.0);
    }

    #[test]
    fn dot_reference() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        assert_eq!(Metric::Ip.distance(&a, &b), -32.0);
    }

    #[test]
    fn angular_on_unit_vectors() {
        let mut a = vec![3.0, 4.0];
        let mut b = vec![4.0, 3.0];
        normalize(&mut a);
        normalize(&mut b);
        let d = Metric::Angular.distance(&a, &b);
        assert!((d - (1.0 - 24.0 / 25.0)).abs() < 1e-6);
        assert!(Metric::Angular.distance(&a, &a).abs() < 1e-6);
    }

    #[test]
    fn parse_names() {
        assert_eq!(Metric::parse("L2"), Some(Metric::L2));
        assert_eq!(Metric::parse("cosine"), Some(Metric::Angular));
        assert_eq!(Metric::parse("inner_product"), Some(Metric::Ip));
        assert_eq!(Metric::parse("hamming"), None);
    }

    #[test]
    fn prop_l2_matches_naive_all_lengths() {
        prop::check_default(
            "l2-unrolled-vs-naive",
            101,
            |r| {
                let n = prop::gen::len(r, 200);
                (
                    prop::gen::vec_f32(r, n, -5.0, 5.0),
                    prop::gen::vec_f32(r, n, -5.0, 5.0),
                )
            },
            |(a, b)| {
                let naive: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
                let fast = l2_sq(a, b);
                if (naive - fast).abs() <= 1e-3 * naive.abs().max(1.0) {
                    Ok(())
                } else {
                    Err(format!("naive={naive} fast={fast}"))
                }
            },
        );
    }

    #[test]
    fn metric_batches_match_per_pair_bitwise() {
        // The simd batching invariant, observed at the Metric level:
        // batched/gathered forms equal the per-pair calls bit for bit.
        let dim = 13;
        let stride = 16;
        let n = 6;
        let mut rows = vec![0.0f32; n * stride];
        for (i, r) in rows.chunks_exact_mut(stride).enumerate() {
            for (j, x) in r[..dim].iter_mut().enumerate() {
                *x = ((i * 31 + j) as f32 * 0.13).sin();
            }
        }
        let mut q: Vec<f32> = (0..dim).map(|j| (j as f32 * 0.7).cos()).collect();
        normalize(&mut q); // Angular requires a unit-norm first operand
        let ids = vec![5u32, 0, 2, 2];
        let mut out = vec![0.0f32; n];
        let mut gout = vec![0.0f32; ids.len()];
        for metric in [Metric::L2, Metric::Ip, Metric::Angular] {
            metric.partial_batch(&q, &rows, stride, &mut out);
            for (i, &o) in out.iter().enumerate() {
                let want = metric.partial(&q, &rows[i * stride..i * stride + dim]);
                assert_eq!(o.to_bits(), want.to_bits(), "{metric:?} partial row {i}");
            }
            metric.distance_gather(&q, &rows, stride, &ids, &mut gout);
            for (&id, &o) in ids.iter().zip(&gout) {
                let base = id as usize * stride;
                let want = metric.distance(&q, &rows[base..base + dim]);
                assert_eq!(o.to_bits(), want.to_bits(), "{metric:?} gather id {id}");
            }
        }
    }

    #[test]
    fn prop_triangle_inequality_l2() {
        prop::check_default(
            "l2-triangle",
            103,
            |r| {
                let n = 16;
                (
                    prop::gen::vec_f32(r, n, -1.0, 1.0),
                    prop::gen::vec_f32(r, n, -1.0, 1.0),
                    prop::gen::vec_f32(r, n, -1.0, 1.0),
                )
            },
            |(a, b, c)| {
                let ab = l2_sq(a, b).sqrt();
                let bc = l2_sq(b, c).sqrt();
                let ac = l2_sq(a, c).sqrt();
                if ac <= ab + bc + 1e-5 {
                    Ok(())
                } else {
                    Err(format!("ac={ac} > ab+bc={}", ab + bc))
                }
            },
        );
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "normalize vectors in the dataset loader")]
    fn angular_rejects_unnormalized_inputs_in_debug() {
        // Raw (unnormalized) embeddings must not silently produce wrong
        // distances: norms > 1 trip the debug assertion.
        let a = [3.0f32, 4.0];
        let b = [4.0f32, 3.0];
        Metric::Angular.distance(&a, &b);
    }

    #[test]
    fn normalize_gives_unit_norm() {
        let mut v = vec![1.0, -2.0, 3.5, 0.25];
        normalize(&mut v);
        assert!((norm(&v) - 1.0).abs() < 1e-6);
        let mut z = vec![0.0; 4];
        normalize(&mut z);
        assert_eq!(z, vec![0.0; 4]);
    }
}
