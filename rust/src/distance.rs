//! Distance metrics for ANNS (paper Table I: Euclidean, Angular, Inner
//! Product). All are expressed as *distances* (smaller = closer) so the
//! search code is metric-agnostic:
//!
//! * `L2`      — squared Euclidean (monotone in Euclidean; sqrt avoided on
//!               the hot path exactly as DiskANN/HNSW do).
//! * `Ip`      — negative inner product.
//! * `Angular` — cosine distance `1 - cos(a,b)`; vectors are expected to be
//!               pre-normalized by the dataset loader, reducing it to
//!               `1 + Ip` on unit vectors.

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Metric {
    L2,
    Ip,
    Angular,
}

impl Metric {
    pub fn parse(s: &str) -> Option<Metric> {
        match s.to_ascii_lowercase().as_str() {
            "l2" | "euclidean" => Some(Metric::L2),
            "ip" | "inner" | "inner_product" => Some(Metric::Ip),
            "angular" | "cosine" => Some(Metric::Angular),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Metric::L2 => "l2",
            Metric::Ip => "ip",
            Metric::Angular => "angular",
        }
    }

    /// Full-precision distance between two D-dim vectors.
    ///
    /// For `Angular`, `1 - dot` equals the cosine distance only on
    /// unit-norm inputs — the dataset loaders normalize on load (see
    /// `dataset::fvecs::prepare_for_metric` / the synthetic generators).
    /// Debug builds assert that the first operand is unit-norm: every
    /// caller passes the query / a stored base row / the normalized medoid
    /// there, so raw unnormalized data trips this immediately (during
    /// graph build, not as silently-wrong recall). The second operand is
    /// deliberately unchecked because PQ-decoded reconstructions flow
    /// through it, and quantization does not preserve the norm.
    #[inline]
    pub fn distance(&self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        match self {
            Metric::L2 => l2_sq(a, b),
            Metric::Ip => -dot(a, b),
            Metric::Angular => {
                debug_assert!(
                    (dot(a, a) - 1.0).abs() < 1e-2,
                    "Angular metric on non-unit-norm input (|a|^2 = {}): \
                     normalize vectors in the dataset loader",
                    dot(a, a)
                );
                1.0 - dot(a, b)
            }
        }
    }

    /// Per-subvector partial distance used to build PQ asymmetric distance
    /// tables; summing partials over subspaces must reconstruct
    /// `distance()` exactly for the decomposable metrics we support.
    #[inline]
    pub fn partial(&self, a: &[f32], b: &[f32]) -> f32 {
        match self {
            Metric::L2 => l2_sq(a, b),
            // For IP and Angular the decomposable part is the dot product;
            // the angular "1 +" constant is folded in at ADT build time
            // (added to subspace 0) so sums still reconstruct distance().
            Metric::Ip | Metric::Angular => -dot(a, b),
        }
    }

    /// Constant folded into the ADT so that partial sums equal distances.
    #[inline]
    pub fn adt_bias(&self) -> f32 {
        match self {
            Metric::L2 | Metric::Ip => 0.0,
            Metric::Angular => 1.0,
        }
    }
}

/// Squared L2 distance, 4-way unrolled accumulators: the compiler
/// auto-vectorizes this shape well, and separate accumulators break the
/// add-latency chain on the 1-wide test box.
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let mut s0 = 0.0f32;
    let mut s1 = 0.0f32;
    let mut s2 = 0.0f32;
    let mut s3 = 0.0f32;
    let chunks = n / 4;
    for i in 0..chunks {
        let j = i * 4;
        let d0 = a[j] - b[j];
        let d1 = a[j + 1] - b[j + 1];
        let d2 = a[j + 2] - b[j + 2];
        let d3 = a[j + 3] - b[j + 3];
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        let d = a[j] - b[j];
        s += d * d;
    }
    s
}

/// Dot product with the same unrolling scheme.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let mut s0 = 0.0f32;
    let mut s1 = 0.0f32;
    let mut s2 = 0.0f32;
    let mut s3 = 0.0f32;
    let chunks = n / 4;
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// L2 norm.
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Normalize in place to unit L2 norm (no-op on zero vectors).
pub fn normalize(a: &mut [f32]) {
    let n = norm(a);
    if n > 0.0 {
        for x in a.iter_mut() {
            *x /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn l2_reference() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [0.0, 0.0, 0.0, 0.0, 0.0];
        assert_eq!(l2_sq(&a, &b), 55.0);
        assert_eq!(Metric::L2.distance(&a, &a), 0.0);
    }

    #[test]
    fn dot_reference() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        assert_eq!(Metric::Ip.distance(&a, &b), -32.0);
    }

    #[test]
    fn angular_on_unit_vectors() {
        let mut a = vec![3.0, 4.0];
        let mut b = vec![4.0, 3.0];
        normalize(&mut a);
        normalize(&mut b);
        let d = Metric::Angular.distance(&a, &b);
        assert!((d - (1.0 - 24.0 / 25.0)).abs() < 1e-6);
        assert!(Metric::Angular.distance(&a, &a).abs() < 1e-6);
    }

    #[test]
    fn parse_names() {
        assert_eq!(Metric::parse("L2"), Some(Metric::L2));
        assert_eq!(Metric::parse("cosine"), Some(Metric::Angular));
        assert_eq!(Metric::parse("inner_product"), Some(Metric::Ip));
        assert_eq!(Metric::parse("hamming"), None);
    }

    #[test]
    fn prop_l2_matches_naive_all_lengths() {
        prop::check_default(
            "l2-unrolled-vs-naive",
            101,
            |r| {
                let n = prop::gen::len(r, 200);
                (
                    prop::gen::vec_f32(r, n, -5.0, 5.0),
                    prop::gen::vec_f32(r, n, -5.0, 5.0),
                )
            },
            |(a, b)| {
                let naive: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
                let fast = l2_sq(a, b);
                if (naive - fast).abs() <= 1e-3 * naive.abs().max(1.0) {
                    Ok(())
                } else {
                    Err(format!("naive={naive} fast={fast}"))
                }
            },
        );
    }

    #[test]
    fn prop_triangle_inequality_l2() {
        prop::check_default(
            "l2-triangle",
            103,
            |r| {
                let n = 16;
                (
                    prop::gen::vec_f32(r, n, -1.0, 1.0),
                    prop::gen::vec_f32(r, n, -1.0, 1.0),
                    prop::gen::vec_f32(r, n, -1.0, 1.0),
                )
            },
            |(a, b, c)| {
                let ab = l2_sq(a, b).sqrt();
                let bc = l2_sq(b, c).sqrt();
                let ac = l2_sq(a, c).sqrt();
                if ac <= ab + bc + 1e-5 {
                    Ok(())
                } else {
                    Err(format!("ac={ac} > ab+bc={}", ab + bc))
                }
            },
        );
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "normalize vectors in the dataset loader")]
    fn angular_rejects_unnormalized_inputs_in_debug() {
        // Raw (unnormalized) embeddings must not silently produce wrong
        // distances: norms > 1 trip the debug assertion.
        let a = [3.0f32, 4.0];
        let b = [4.0f32, 3.0];
        Metric::Angular.distance(&a, &b);
    }

    #[test]
    fn normalize_gives_unit_norm() {
        let mut v = vec![1.0, -2.0, 3.5, 0.25];
        normalize(&mut v);
        assert!((norm(&v) - 1.0).abs() < 1e-6);
        let mut z = vec![0.0; 4];
        normalize(&mut z);
        assert_eq!(z, vec![0.0; 4]);
    }
}
