//! Atomic log-linear (HDR-style) latency histogram.
//!
//! Values are microseconds. The bucket layout is **log-linear**: exact
//! buckets below [`SUB`] (one per value, so sub-16µs latencies lose no
//! resolution), then [`SUB`] equal-width sub-buckets per power-of-two
//! octave up to the cap `2^MAX_EXP - 1` µs (~67s) — the classic HDR
//! trade: bounded relative error (≤ 1/SUB ≈ 6.25%) at every scale for a
//! fixed 368-slot table. Everything is `AtomicU64` with relaxed
//! ordering, so recording from the query hot path is one index
//! computation plus three `fetch_add`s — no locks, no allocation —
//! and histograms held in `Arc` can be recorded from any thread and
//! merged (`merge_from`) or snapshotted while live.
//!
//! The histogram itself never reads a clock: callers time with
//! [`crate::net::Clock`] (wall or fake) and record the measured µs, so
//! every distribution in the metrics plane is fake-clock testable.

use std::sync::atomic::{AtomicU64, Ordering};

/// log2 of the sub-bucket count per octave.
pub const SUB_BITS: u32 = 4;
/// Sub-buckets per octave (and the top of the exact linear region).
pub const SUB: usize = 1 << SUB_BITS;
/// Values are clamped to `2^MAX_EXP - 1` µs (~67s) before bucketing.
pub const MAX_EXP: u32 = 26;
/// Total bucket count: one linear group + one group per octave.
pub const BUCKETS: usize = (MAX_EXP - SUB_BITS + 1) as usize * SUB;

const CAP: u64 = (1u64 << MAX_EXP) - 1;

/// Bucket index for a (pre-clamped) value.
fn index(v: u64) -> usize {
    if v < SUB as u64 {
        v as usize
    } else {
        let msb = 63 - v.leading_zeros(); // >= SUB_BITS for v >= SUB
        let group = (msb - (SUB_BITS - 1)) as usize;
        let within = ((v >> (msb - SUB_BITS)) & (SUB as u64 - 1)) as usize;
        group * SUB + within
    }
}

/// `[lo, hi]` value range covered by bucket `idx` (inclusive).
pub fn bucket_bounds(idx: usize) -> (u64, u64) {
    debug_assert!(idx < BUCKETS);
    if idx < SUB {
        (idx as u64, idx as u64)
    } else {
        let group = (idx / SUB) as u32;
        let within = (idx % SUB) as u64;
        let msb = group + (SUB_BITS - 1);
        let width = 1u64 << (msb - SUB_BITS);
        let lo = (1u64 << msb) + within * width;
        (lo, lo + width - 1)
    }
}

/// Mergeable atomic log-linear histogram of µs values.
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one value (µs). Lock-free, allocation-free.
    pub fn record(&self, v: u64) {
        let c = v.min(CAP);
        self.buckets[index(c)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of recorded values (µs), un-clamped.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    /// Add every bucket of `other` into `self` (both may be live).
    pub fn merge_from(&self, other: &Histogram) {
        for (dst, src) in self.buckets.iter().zip(other.buckets.iter()) {
            let v = src.load(Ordering::Relaxed);
            if v > 0 {
                dst.fetch_add(v, Ordering::Relaxed);
            }
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum
            .fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Zero all buckets (not linearizable against concurrent `record`;
    /// used only on explicit operator reset paths).
    pub fn reset(&self) {
        for b in self.buckets.iter() {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
    }

    /// Point-in-time copy of the raw bucket counters.
    pub fn bucket_snapshot(&self) -> [u64; BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// Upper bound (µs) of the bucket holding the `p`-th percentile
    /// (`p` in 0..=100). 0 when empty. Relative error ≤ 1/SUB.
    pub fn percentile(&self, p: f64) -> u64 {
        let counts = self.bucket_snapshot();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_bounds(i).1;
            }
        }
        bucket_bounds(BUCKETS - 1).1
    }

    /// Cumulative counts at octave upper bounds for Prometheus
    /// exposition: `(le, cumulative)` for `le = 2^j - 1`, j = 1..=26.
    /// These are EXACT boundaries — no bucket range straddles a power
    /// of two — so the cumulative counts are not approximations.
    pub fn octave_cumulative(&self) -> Vec<(u64, u64)> {
        let counts = self.bucket_snapshot();
        let mut out = Vec::with_capacity(MAX_EXP as usize);
        for j in 1..=MAX_EXP {
            let le = (1u64 << j) - 1;
            let upto = if j <= SUB_BITS {
                1usize << j
            } else {
                SUB * (j - SUB_BITS + 1) as usize
            };
            let cum: u64 = counts[..upto].iter().sum();
            out.push((le, cum));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_region_is_exact() {
        let h = Histogram::new();
        for v in 0..SUB as u64 {
            h.record(v);
        }
        let counts = h.bucket_snapshot();
        for (i, c) in counts.iter().enumerate().take(SUB) {
            assert_eq!(*c, 1, "bucket {i}");
            assert_eq!(bucket_bounds(i), (i as u64, i as u64));
        }
        assert_eq!(h.count(), SUB as u64);
        assert_eq!(h.sum(), (0..SUB as u64).sum::<u64>());
    }

    #[test]
    fn index_matches_bucket_bounds() {
        // Every probe value must land in a bucket whose range holds it,
        // and indices must be monotone in the value.
        let mut last = 0usize;
        for exp in 0..MAX_EXP {
            for off in [0u64, 1, 3] {
                let v = (1u64 << exp) + off;
                if v > CAP {
                    continue;
                }
                let i = index(v);
                let (lo, hi) = bucket_bounds(i);
                assert!(lo <= v && v <= hi, "v={v} i={i} range=[{lo},{hi}]");
                assert!(i >= last, "index not monotone at v={v}");
                last = i;
            }
        }
        assert_eq!(index(CAP), BUCKETS - 1);
        // Over-cap values clamp into the last bucket.
        let h = Histogram::new();
        h.record(u64::MAX);
        assert_eq!(h.bucket_snapshot()[BUCKETS - 1], 1);
    }

    #[test]
    fn percentile_bounded_relative_error() {
        let h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        for (p, exact) in [(50.0, 5_000u64), (95.0, 9_500), (99.0, 9_900)] {
            let est = h.percentile(p);
            let err = (est as f64 - exact as f64).abs() / exact as f64;
            assert!(err <= 1.0 / SUB as f64, "p{p}: est {est} vs {exact}");
            assert!(est >= exact, "upper-bound estimate must not undershoot");
        }
        assert_eq!(h.percentile(0.0), h.percentile(0.01));
        assert_eq!(Histogram::new().percentile(99.0), 0);
    }

    #[test]
    fn merge_equals_union() {
        let a = Histogram::new();
        let b = Histogram::new();
        let u = Histogram::new();
        for v in [3u64, 17, 900, 40_000] {
            a.record(v);
            u.record(v);
        }
        for v in [5u64, 17, 1_000_000] {
            b.record(v);
            u.record(v);
        }
        a.merge_from(&b);
        assert_eq!(a.bucket_snapshot(), u.bucket_snapshot());
        assert_eq!(a.count(), u.count());
        assert_eq!(a.sum(), u.sum());
    }

    #[test]
    fn octave_cumulative_is_exact_and_monotone() {
        let h = Histogram::new();
        for v in [0u64, 1, 2, 15, 16, 31, 32, 1023, 1024, CAP] {
            h.record(v);
        }
        let oct = h.octave_cumulative();
        assert_eq!(oct.len(), MAX_EXP as usize);
        // Exactness at hand-checkable boundaries.
        let at = |le: u64| oct.iter().find(|(l, _)| *l == le).unwrap().1;
        assert_eq!(at(1), 2); // 0, 1
        assert_eq!(at(15), 4); // + 2, 15
        assert_eq!(at(31), 6); // + 16, 31
        assert_eq!(at(63), 7); // + 32
        assert_eq!(at(1023), 8); // + 1023
        assert_eq!(at((1 << MAX_EXP) - 1), 10); // everything
        let mut prev = 0;
        for (_, c) in oct {
            assert!(c >= prev);
            prev = c;
        }
    }
}
