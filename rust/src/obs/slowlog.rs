//! Slow-query flight recorder: a fixed-size ring of the N slowest
//! recent queries, each with its full stage spans and `SearchStats`.
//!
//! Recording is allocation-free after construction: the ring `Vec` is
//! preallocated to capacity, entries are plain value moves (no heap
//! fields), and an atomic floor lets the common case — a query no
//! slower than everything already held — return without touching the
//! lock. Dumped via the `{"op":"slowlog"}` admin op; cleared when an
//! index hot-swap (`reload`/`flush`) installs a new epoch, because
//! spans from the previous epoch's graph/residency are not comparable.

use super::StageSpans;
use crate::search::SearchStats;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Default ring capacity (slowest N recent queries retained).
pub const DEFAULT_CAP: usize = 32;

/// One retained slow query.
#[derive(Clone, Debug)]
pub struct SlowEntry {
    /// Monotonic sequence number of the recording (admission order of
    /// retained entries, not of all queries).
    pub seq: u64,
    /// End-to-end service latency (µs).
    pub latency_us: u64,
    pub spans: StageSpans,
    pub stats: SearchStats,
}

/// Fixed-capacity "keep the slowest" recorder.
pub struct SlowLog {
    cap: usize,
    /// Once the ring is full: the smallest retained latency. Queries at
    /// or below it skip the lock entirely. (Stays 0 while filling, so
    /// only 0µs queries — by definition not slow — are ever skipped
    /// early.)
    floor_us: AtomicU64,
    seq: AtomicU64,
    ring: Mutex<Vec<SlowEntry>>,
}

impl SlowLog {
    pub fn new(cap: usize) -> SlowLog {
        SlowLog {
            cap: cap.max(1),
            floor_us: AtomicU64::new(0),
            seq: AtomicU64::new(0),
            ring: Mutex::new(Vec::with_capacity(cap.max(1))),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Offer one finished query. Keeps it only if it beats the current
    /// floor; allocation-free in all cases.
    pub fn record(&self, latency_us: u64, spans: StageSpans, stats: SearchStats) {
        if latency_us <= self.floor_us.load(Ordering::Relaxed) {
            return;
        }
        let mut ring = self.ring.lock().unwrap();
        if ring.len() < self.cap {
            let seq = self.seq.fetch_add(1, Ordering::Relaxed);
            ring.push(SlowEntry {
                seq,
                latency_us,
                spans,
                stats,
            });
            if ring.len() == self.cap {
                self.update_floor(&ring);
            }
            return;
        }
        // Full: replace the current minimum if we beat it (re-check
        // under the lock — the floor may have risen since the fast
        // path).
        let (min_idx, min_lat) = ring
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.latency_us)
            .map(|(i, e)| (i, e.latency_us))
            .expect("ring is non-empty when full");
        if latency_us <= min_lat {
            return;
        }
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        ring[min_idx] = SlowEntry {
            seq,
            latency_us,
            spans,
            stats,
        };
        self.update_floor(&ring);
    }

    fn update_floor(&self, ring: &[SlowEntry]) {
        let floor = ring.iter().map(|e| e.latency_us).min().unwrap_or(0);
        self.floor_us.store(floor, Ordering::Relaxed);
    }

    /// Drop everything (epoch hot-swap: old spans are not comparable).
    pub fn clear(&self) {
        let mut ring = self.ring.lock().unwrap();
        ring.clear();
        self.floor_us.store(0, Ordering::Relaxed);
    }

    /// Snapshot sorted slowest-first (admin path; allocation is fine
    /// here).
    pub fn snapshot(&self) -> Vec<SlowEntry> {
        let mut out = self.ring.lock().unwrap().clone();
        out.sort_by(|a, b| b.latency_us.cmp(&a.latency_us).then(a.seq.cmp(&b.seq)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry_stats(hops: usize) -> SearchStats {
        SearchStats {
            hops,
            ..Default::default()
        }
    }

    #[test]
    fn keeps_the_slowest_n() {
        let log = SlowLog::new(3);
        for lat in [10u64, 50, 20, 5, 60, 30, 1] {
            log.record(lat, StageSpans::default(), entry_stats(lat as usize));
        }
        let snap = log.snapshot();
        let lats: Vec<u64> = snap.iter().map(|e| e.latency_us).collect();
        assert_eq!(lats, vec![60, 50, 30]);
        // Payload rides along with its entry.
        assert_eq!(snap[0].stats.hops, 60);
        // Floor now blocks anything at or below the retained minimum.
        log.record(30, StageSpans::default(), entry_stats(999));
        assert_eq!(
            log.snapshot().iter().map(|e| e.latency_us).collect::<Vec<_>>(),
            vec![60, 50, 30]
        );
    }

    #[test]
    fn clear_resets_floor_and_contents() {
        let log = SlowLog::new(2);
        log.record(100, StageSpans::default(), SearchStats::default());
        log.record(200, StageSpans::default(), SearchStats::default());
        assert_eq!(log.len(), 2);
        log.clear();
        assert!(log.is_empty());
        // After clear, small latencies are accepted again.
        log.record(1, StageSpans::default(), SearchStats::default());
        assert_eq!(log.len(), 1);
        assert_eq!(log.snapshot()[0].latency_us, 1);
    }

    #[test]
    fn spans_survive_in_entries() {
        let log = SlowLog::new(4);
        let mut spans = StageSpans::default();
        spans.add(super::super::Stage::GraphWalk, 70);
        spans.total_us = 90;
        log.record(90, spans, SearchStats::default());
        let snap = log.snapshot();
        assert_eq!(snap[0].spans.get(super::super::Stage::GraphWalk), 70);
        assert_eq!(snap[0].spans.total_us, 90);
    }
}
