//! Prometheus text exposition (format 0.0.4) for the metrics plane.
//!
//! [`Registry`] is a streaming text builder, not a retained registry:
//! the server assembles one per `{"op":"metrics"}` request from live
//! counters/gauges/histograms and renders it, so there is no second
//! copy of metric state to keep in sync. Histograms are exposed at
//! octave granularity (`le = 2^j - 1`, exact bucket boundaries — see
//! [`super::hist::Histogram::octave_cumulative`]) plus `+Inf`, `_sum`
//! and `_count`, which is what `histogram_quantile()` consumes.
//!
//! Rendering is deterministic for a fixed set of inputs — the golden
//! test below pins the exact text output — so clients and dashboards
//! can rely on stable names and label sets.

use super::hist::Histogram;
use std::fmt::Write as _;

/// Streaming Prometheus text-format builder.
#[derive(Default)]
pub struct Registry {
    buf: String,
}

/// Format a sample value: integers render without a fraction so the
/// output is stable and diff-friendly.
fn fmt_val(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9.007_199_254_740_992e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn series(name: &str, labels: &str) -> String {
    if labels.is_empty() {
        name.to_string()
    } else {
        format!("{name}{{{labels}}}")
    }
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    fn header(&mut self, name: &str, help: &str, kind: &str) {
        let _ = writeln!(self.buf, "# HELP {name} {help}");
        let _ = writeln!(self.buf, "# TYPE {name} {kind}");
    }

    /// A counter with one or more label sets (`labels` like
    /// `op="search",plane="json"`, or `""` for none).
    pub fn counter(&mut self, name: &str, help: &str, samples: &[(&str, f64)]) {
        self.header(name, help, "counter");
        for (labels, v) in samples {
            let _ = writeln!(self.buf, "{} {}", series(name, labels), fmt_val(*v));
        }
    }

    /// A gauge with one or more label sets.
    pub fn gauge(&mut self, name: &str, help: &str, samples: &[(&str, f64)]) {
        self.header(name, help, "gauge");
        for (labels, v) in samples {
            let _ = writeln!(self.buf, "{} {}", series(name, labels), fmt_val(*v));
        }
    }

    /// A histogram metric with one series per label set.
    pub fn histogram(&mut self, name: &str, help: &str, samples: &[(&str, &Histogram)]) {
        self.header(name, help, "histogram");
        for (labels, h) in samples {
            let sep = if labels.is_empty() { "" } else { "," };
            for (le, cum) in h.octave_cumulative() {
                let _ = writeln!(
                    self.buf,
                    "{name}_bucket{{{labels}{sep}le=\"{le}\"}} {cum}"
                );
            }
            let _ = writeln!(
                self.buf,
                "{name}_bucket{{{labels}{sep}le=\"+Inf\"}} {}",
                h.count()
            );
            let _ = writeln!(self.buf, "{} {}", series(&format!("{name}_sum"), labels), h.sum());
            let _ = writeln!(
                self.buf,
                "{} {}",
                series(&format!("{name}_count"), labels),
                h.count()
            );
        }
    }

    /// Finish and return the exposition text.
    pub fn render(self) -> String {
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden test: exact exposition text for a fixed registry. Pins
    /// the format (HELP/TYPE lines, label ordering, le bounds, value
    /// formatting) so dashboards never silently break. CI runs this in
    /// the `rust-obs` arm.
    #[test]
    fn golden_exposition_text() {
        let h = Histogram::new();
        h.record(3);
        h.record(20);
        h.record(20);
        let mut r = Registry::new();
        r.counter("proxima_errors_total", "Errored requests.", &[("", 7.0)]);
        r.gauge(
            "proxima_connections",
            "Open connections.",
            &[("plane=\"json\"", 2.0), ("plane=\"bin\"", 1.0)],
        );
        r.histogram(
            "proxima_engine_duration_us",
            "In-service query latency (us).",
            &[("", &h)],
        );
        let text = r.render();

        let mut want = String::new();
        want.push_str("# HELP proxima_errors_total Errored requests.\n");
        want.push_str("# TYPE proxima_errors_total counter\n");
        want.push_str("proxima_errors_total 7\n");
        want.push_str("# HELP proxima_connections Open connections.\n");
        want.push_str("# TYPE proxima_connections gauge\n");
        want.push_str("proxima_connections{plane=\"json\"} 2\n");
        want.push_str("proxima_connections{plane=\"bin\"} 1\n");
        want.push_str("# HELP proxima_engine_duration_us In-service query latency (us).\n");
        want.push_str("# TYPE proxima_engine_duration_us histogram\n");
        // le = 2^j - 1 for j = 1..=26: value 3 crosses at le=3, the two
        // 20s at le=31.
        for j in 1..=26u32 {
            let le = (1u64 << j) - 1;
            let cum = if le < 3 {
                0
            } else if le < 31 {
                1
            } else {
                3
            };
            want.push_str(&format!(
                "proxima_engine_duration_us_bucket{{le=\"{le}\"}} {cum}\n"
            ));
        }
        want.push_str("proxima_engine_duration_us_bucket{le=\"+Inf\"} 3\n");
        want.push_str("proxima_engine_duration_us_sum 43\n");
        want.push_str("proxima_engine_duration_us_count 3\n");
        assert_eq!(text, want);
    }

    #[test]
    fn labeled_histogram_series() {
        let h = Histogram::new();
        h.record(100);
        let mut r = Registry::new();
        r.histogram("m", "h.", &[("op=\"search\"", &h)]);
        let text = r.render();
        assert!(text.contains("m_bucket{op=\"search\",le=\"127\"} 1"));
        assert!(text.contains("m_bucket{op=\"search\",le=\"+Inf\"} 1"));
        assert!(text.contains("m_sum{op=\"search\"} 100"));
        assert!(text.contains("m_count{op=\"search\"} 1"));
    }

    #[test]
    fn float_values_keep_fraction() {
        let mut r = Registry::new();
        r.gauge("g", "g.", &[("", 0.25)]);
        assert!(r.render().contains("g 0.25\n"));
    }
}
