//! Per-query stage spans: a fixed, `Copy` breakdown of where one
//! query's latency went.
//!
//! The span buffer lives in [`crate::search::QueryScratch`] (pooled)
//! and is copied into [`crate::search::SearchOutput`], so collecting a
//! breakdown allocates nothing on the steady-state path. Stages are
//! **not disjoint**: [`Stage::ColdRead`] time is spent *inside* the
//! graph walk and rerank stages (it is the storage-wait component of
//! both), and the queue/admission waits precede engine time entirely —
//! so spans must not be summed and compared against `total_us`.

/// One timed stage of a query's life. The discriminant is the index
/// into [`StageSpans::us`] and the per-stage histogram array.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Stage {
    /// Wait between admission (decode) and dispatch, binary plane.
    AdmissionWait = 0,
    /// Wait in the exec-pool queue before a worker lane picked it up.
    QueueWait = 1,
    /// Building the per-query ADT (PQ lookup tables).
    AdtBuild = 2,
    /// Beam traversal of the graph (seed + expand).
    GraphWalk = 3,
    /// Exact-distance rerank of surviving candidates.
    Rerank = 4,
    /// Raw-row fetches that missed DRAM: cold reads + cache fills
    /// (overlaps GraphWalk/Rerank — it is their storage-wait share).
    ColdRead = 5,
    /// Encoding the response frame/line (binary plane).
    FrameEncode = 6,
    /// Decoding the request frame/line (binary plane).
    FrameDecode = 7,
}

/// Number of stages (length of [`StageSpans::us`]).
pub const STAGE_COUNT: usize = 8;

impl Stage {
    /// Every stage, in discriminant order.
    pub const ALL: [Stage; STAGE_COUNT] = [
        Stage::AdmissionWait,
        Stage::QueueWait,
        Stage::AdtBuild,
        Stage::GraphWalk,
        Stage::Rerank,
        Stage::ColdRead,
        Stage::FrameEncode,
        Stage::FrameDecode,
    ];

    /// Stable label used in metric names, slowlog dumps, and docs.
    pub fn name(self) -> &'static str {
        match self {
            Stage::AdmissionWait => "admission_wait",
            Stage::QueueWait => "queue_wait",
            Stage::AdtBuild => "adt_build",
            Stage::GraphWalk => "graph_walk",
            Stage::Rerank => "rerank",
            Stage::ColdRead => "cold_read",
            Stage::FrameEncode => "frame_encode",
            Stage::FrameDecode => "frame_decode",
        }
    }
}

/// Fixed-size per-query stage breakdown (µs). `Copy`, zero-alloc.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageSpans {
    /// End-to-end latency of the query inside the service (µs).
    pub total_us: u64,
    /// Per-stage µs, indexed by [`Stage`] discriminant.
    pub us: [u64; STAGE_COUNT],
}

impl StageSpans {
    /// Zero every stage (reused across pooled queries).
    pub fn reset(&mut self) {
        *self = StageSpans::default();
    }

    /// Accumulate `us` microseconds into `stage`.
    pub fn add(&mut self, stage: Stage, us: u64) {
        self.us[stage as usize] += us;
    }

    pub fn get(&self, stage: Stage) -> u64 {
        self.us[stage as usize]
    }

    /// True when no stage recorded any time (e.g. spans never wired).
    pub fn is_empty(&self) -> bool {
        self.total_us == 0 && self.us.iter().all(|&v| v == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_get_reset_roundtrip() {
        let mut s = StageSpans::default();
        assert!(s.is_empty());
        s.add(Stage::GraphWalk, 120);
        s.add(Stage::GraphWalk, 30);
        s.add(Stage::Rerank, 55);
        s.total_us = 210;
        assert_eq!(s.get(Stage::GraphWalk), 150);
        assert_eq!(s.get(Stage::Rerank), 55);
        assert_eq!(s.get(Stage::AdtBuild), 0);
        assert!(!s.is_empty());
        s.reset();
        assert!(s.is_empty());
    }

    #[test]
    fn stage_names_are_unique_and_ordered() {
        let mut seen = std::collections::HashSet::new();
        for (i, st) in Stage::ALL.iter().enumerate() {
            assert_eq!(*st as usize, i, "discriminant order");
            assert!(seen.insert(st.name()), "duplicate name {}", st.name());
        }
        assert_eq!(seen.len(), STAGE_COUNT);
    }
}
