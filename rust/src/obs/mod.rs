//! Observability plane: stage-timed query spans, log-linear latency
//! histograms, a Prometheus-text metrics registry, and a slow-query
//! flight recorder.
//!
//! Everything here is zero-dependency and designed so the steady-state
//! query path stays **zero-alloc and lock-free**: histograms are
//! fixed-size atomic tables ([`hist::Histogram`]), stage spans are a
//! `Copy` array pooled in `QueryScratch` ([`spans::StageSpans`]), and
//! the slow-query ring ([`slowlog::SlowLog`]) is preallocated with an
//! atomic-floor fast path. The one shared handle is [`Metrics`], held
//! as an `Arc` by the serving stack:
//!
//! - `coordinator::SearchService` records per-query engine latency,
//!   per-stage histograms, and slowlog entries;
//! - `coordinator::server` / `net::server` record per-op, per-plane
//!   request latency, connection gauges, and frame/admission stages;
//! - `coordinator::server::metrics_response` assembles the Prometheus
//!   text for `{"op":"metrics"}` from this handle plus live service
//!   counters.
//!
//! **Lifetime vs epoch**: the `Arc<Metrics>` is *adopted* across index
//! hot-swaps (`reload`/`flush`) — histograms and counters are lifetime
//! series, which is what a scrape pipeline needs — while the slowlog
//! is *cleared* on swap (spans from another epoch's graph/residency
//! are not comparable) and `ServiceStats` stays per-epoch. That
//! three-way split is pinned by `tests/obs_metrics.rs`.
//!
//! Timing uses [`crate::net::Clock`] (wall or fake) at the service and
//! wire layers so latency distributions are fake-clock testable; the
//! deep kernel stages (walk/rerank/cold-read) use `Instant` directly —
//! they time real work inside one query, where simulated time has
//! nothing to inject.

pub mod hist;
pub mod registry;
pub mod slowlog;
pub mod spans;

pub use hist::Histogram;
pub use registry::Registry;
pub use slowlog::{SlowEntry, SlowLog};
pub use spans::{Stage, StageSpans, STAGE_COUNT};

use crate::net::admission::{Admission, Clock};
use crate::search::SearchStats;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Request class for per-op latency series (label `op`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum OpClass {
    /// Query-plane search (any version).
    Search = 0,
    /// Write plane: insert / delete / flush.
    Write = 1,
    /// Admin plane: stats / status / reload / metrics / slowlog.
    Admin = 2,
}

/// Number of [`OpClass`] values.
pub const OP_CLASSES: usize = 3;

/// Which wire plane served the request (label `plane`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Plane {
    /// Newline-delimited JSON (v1/v2).
    Json = 0,
    /// Length-prefixed binary frames (PXW3).
    Bin = 1,
}

/// Number of [`Plane`] values.
pub const PLANES: usize = 2;

impl OpClass {
    pub const ALL: [OpClass; OP_CLASSES] = [OpClass::Search, OpClass::Write, OpClass::Admin];

    pub fn name(self) -> &'static str {
        match self {
            OpClass::Search => "search",
            OpClass::Write => "write",
            OpClass::Admin => "admin",
        }
    }
}

impl Plane {
    pub const ALL: [Plane; PLANES] = [Plane::Json, Plane::Bin];

    pub fn name(self) -> &'static str {
        match self {
            Plane::Json => "json",
            Plane::Bin => "bin",
        }
    }
}

/// The shared metrics handle: every latency histogram, counter, and
/// gauge the serving stack records into, plus the slow-query ring.
/// One `Arc<Metrics>` lives on `SearchService` and is adopted across
/// hot-swaps (see module docs).
pub struct Metrics {
    clock: Clock,
    /// End-to-end wire latency per `[OpClass][Plane]` (decode to
    /// response-encode, µs).
    pub request_us: [[Histogram; PLANES]; OP_CLASSES],
    /// In-service query latency (µs): scratch checkout to result
    /// mapping, excluding wire time.
    pub engine_us: Histogram,
    /// Per-stage latency, indexed by [`Stage`] discriminant.
    pub stage_us: [Histogram; STAGE_COUNT],
    /// Coalesced batch sizes dispatched by the batcher.
    pub batch_size: Histogram,
    /// Requests answered with an error (any op, any plane).
    pub errors_total: AtomicU64,
    /// Currently open connections (both planes).
    pub connections: AtomicI64,
    admission: Mutex<Option<Arc<Admission>>>,
    slowlog: SlowLog,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::with_clock(Clock::wall())
    }

    /// Inject the time source (fake in tests: deterministic latencies).
    pub fn with_clock(clock: Clock) -> Metrics {
        Metrics {
            clock,
            request_us: std::array::from_fn(|_| std::array::from_fn(|_| Histogram::new())),
            engine_us: Histogram::new(),
            stage_us: std::array::from_fn(|_| Histogram::new()),
            batch_size: Histogram::new(),
            errors_total: AtomicU64::new(0),
            connections: AtomicI64::new(0),
            admission: Mutex::new(None),
            slowlog: SlowLog::new(slowlog::DEFAULT_CAP),
        }
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    /// Current µs on the injected clock.
    pub fn now_us(&self) -> u64 {
        self.clock.now_us()
    }

    /// Record one wire request's end-to-end latency.
    pub fn record_request(&self, op: OpClass, plane: Plane, us: u64) {
        self.request_us[op as usize][plane as usize].record(us);
    }

    /// Record one finished query: engine latency, every non-zero stage,
    /// and a slowlog offer. Allocation-free.
    pub fn record_query(&self, spans: &StageSpans, stats: &SearchStats) {
        self.engine_us.record(spans.total_us);
        for st in Stage::ALL {
            let us = spans.get(st);
            if us > 0 {
                self.stage_us[st as usize].record(us);
            }
        }
        self.slowlog.record(spans.total_us, *spans, stats.clone());
    }

    /// Record time into one stage histogram directly (used for stages
    /// measured outside a query's own span buffer: batch-staged ADT
    /// builds, admission wait, frame encode/decode).
    pub fn record_stage(&self, stage: Stage, us: u64) {
        self.stage_us[stage as usize].record(us);
    }

    /// Record one coalesced batch's size.
    pub fn record_batch(&self, n: usize) {
        self.batch_size.record(n as u64);
    }

    pub fn inc_errors(&self) {
        self.errors_total.fetch_add(1, Ordering::Relaxed);
    }

    pub fn errors(&self) -> u64 {
        self.errors_total.load(Ordering::Relaxed)
    }

    pub fn conn_opened(&self) {
        self.connections.fetch_add(1, Ordering::Relaxed);
    }

    pub fn conn_closed(&self) {
        self.connections.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn connections(&self) -> i64 {
        self.connections.load(Ordering::Relaxed)
    }

    /// Register the listener's admission controller so the metrics and
    /// status planes can expose its counters (in-flight, admitted,
    /// shed) next to the exec-pool shed signal.
    pub fn register_admission(&self, adm: Arc<Admission>) {
        *self.admission.lock().unwrap() = Some(adm);
    }

    pub fn admission(&self) -> Option<Arc<Admission>> {
        self.admission.lock().unwrap().clone()
    }

    pub fn slowlog(&self) -> &SlowLog {
        &self.slowlog
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering as AtomOrd;

    #[test]
    fn record_query_fills_engine_stage_and_slowlog() {
        let m = Metrics::new();
        let mut spans = StageSpans::default();
        spans.add(Stage::GraphWalk, 400);
        spans.add(Stage::Rerank, 100);
        spans.total_us = 520;
        let stats = SearchStats {
            hops: 9,
            ..Default::default()
        };
        m.record_query(&spans, &stats);
        assert_eq!(m.engine_us.count(), 1);
        assert_eq!(m.engine_us.sum(), 520);
        assert_eq!(m.stage_us[Stage::GraphWalk as usize].count(), 1);
        assert_eq!(m.stage_us[Stage::Rerank as usize].count(), 1);
        // Zero stages are not recorded (keeps their histograms sparse).
        assert_eq!(m.stage_us[Stage::AdtBuild as usize].count(), 0);
        let slow = m.slowlog().snapshot();
        assert_eq!(slow.len(), 1);
        assert_eq!(slow[0].latency_us, 520);
        assert_eq!(slow[0].stats.hops, 9);
    }

    #[test]
    fn fake_clock_drives_deterministic_request_latency() {
        let (clock, t) = Clock::fake();
        let m = Metrics::with_clock(clock);
        let t0 = m.now_us();
        t.store(1500, AtomOrd::Release);
        m.record_request(OpClass::Search, Plane::Bin, m.now_us() - t0);
        let h = &m.request_us[OpClass::Search as usize][Plane::Bin as usize];
        assert_eq!(h.count(), 1);
        assert_eq!(h.sum(), 1500);
        // Other cells untouched.
        assert_eq!(
            m.request_us[OpClass::Admin as usize][Plane::Json as usize].count(),
            0
        );
    }

    #[test]
    fn gauges_and_admission_registration() {
        let m = Metrics::new();
        m.conn_opened();
        m.conn_opened();
        m.conn_closed();
        assert_eq!(m.connections(), 1);
        m.inc_errors();
        assert_eq!(m.errors(), 1);
        assert!(m.admission().is_none());
        let adm = Arc::new(Admission::new(Default::default(), Clock::wall()));
        m.register_admission(adm.clone());
        let got = m.admission().unwrap();
        assert_eq!(got.counters(), adm.counters());
    }
}
